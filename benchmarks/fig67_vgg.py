"""Paper Figs. 6-7: VGG on CIFAR-like data.

Fig. 6: random vs selective masking across masking rates, static sampling.
Fig. 7: effect of the dynamic-sampling decay coefficient under masking."""

from repro.core import MaskingConfig

from benchmarks.common import make_schedule, run_federated


def run():
    rows = []
    sched = make_schedule("static", rate=1.0)
    for gamma in (0.1, 0.4, 0.7):                       # fig 6
        for mode in ("random", "selective"):
            r = run_federated("vgg", sched,
                              MaskingConfig(mode=mode, gamma=gamma),
                              rounds=12, lr=0.25)
            rows.append({"figure": "fig6", "mode": mode, "gamma": gamma, **r})

    for beta in (0.01, 0.1, 0.5):                       # fig 7
        for mode in ("random", "selective"):
            r = run_federated("vgg", make_schedule("dynamic", beta),
                              MaskingConfig(mode=mode, gamma=0.5),
                              rounds=12, lr=0.25)
            rows.append({"figure": "fig7", "mode": mode, "beta": beta,
                         "gamma": 0.5, **r})
    return rows
