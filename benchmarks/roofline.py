"""Roofline reader: aggregates results/dryrun/*.json into the §Roofline
table (EXPERIMENTS.md).  Pure report — run the dry-run first."""

import glob
import json
import os


def load(out_dir: str = "results/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def table(recs, multi_pod=False, fed=None):
    rows = []
    for r in recs:
        if r.get("multi_pod", False) != multi_pod:
            continue
        if fed is not None and r.get("fed", False) != fed:
            continue
        t = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "fed": r.get("fed", False),
            "compute_s": round(t["compute_s"], 4),
            "memory_s": round(t["memory_s"], 4),
            "collective_s": round(t["collective_s"], 4),
            "dominant": t["dominant"].replace("_s", ""),
            "useful_flops": round(t["useful_flop_fraction"], 3),
            "hbm_GB_dev": round((r["memory"]["argument_bytes"] +
                                 r["memory"]["temp_bytes"]) / 1e9, 1),
            "fits": r["memory"]["fits_hbm"],
        })
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def run():
    recs = load()
    rows = []
    for mp in (False, True):
        for r in table(recs, multi_pod=mp):
            rows.append({"figure": "roofline",
                         "mesh": "2x16x16" if mp else "16x16", **r})
    return rows


def main():
    from benchmarks.common import fmt_rows
    print(fmt_rows(run()))


if __name__ == "__main__":
    main()
