"""Roofline reader: aggregates results/dryrun/*.json into the §Roofline
table (EXPERIMENTS.md), plus the wire-path HBM-bound floor (DESIGN.md §10)
from ``BENCH_wirepath.json``.  Pure report — run the dry-run and
``benchmarks.kernels_bench`` first."""

import glob
import json
import os

WIRE_JSON = os.path.join(os.path.dirname(__file__), os.pardir,
                         "BENCH_wirepath.json")


def load(out_dir: str = "results/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def table(recs, multi_pod=False, fed=None):
    rows = []
    for r in recs:
        if r.get("multi_pod", False) != multi_pod:
            continue
        if fed is not None and r.get("fed", False) != fed:
            continue
        t = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "fed": r.get("fed", False),
            "compute_s": round(t["compute_s"], 4),
            "memory_s": round(t["memory_s"], 4),
            "collective_s": round(t["collective_s"], 4),
            "dominant": t["dominant"].replace("_s", ""),
            "useful_flops": round(t["useful_flop_fraction"], 3),
            "hbm_GB_dev": round((r["memory"]["argument_bytes"] +
                                 r["memory"]["temp_bytes"]) / 1e9, 1),
            "fits": r["memory"]["fits_hbm"],
        })
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def wirepath_table(path: str = WIRE_JSON):
    """HBM-bound time floor for one upload's wire encode on a v5e chip.

    Reads the bytes-moved model rows that ``benchmarks.kernels_bench``
    writes to ``BENCH_wirepath.json`` and divides by the chip HBM bandwidth
    — the fused path's floor is the bytes ratio (not the sweep ratio),
    since its narrow int8/bitmap sweeps are cheaper than fp32 ones."""
    from repro.launch.mesh import HBM_BW
    if not os.path.exists(path):
        return []
    from benchmarks.common import read_bench
    recs = read_bench(path)["rows"]
    rows = []
    for r in recs:
        if r.get("figure") != "wirepath":
            continue
        rows.append({
            "figure": "roofline_wirepath", "model": r["model"],
            "n_params": r["n_params"],
            "fused_hbm_us": round(r["fused_hbm_bytes"] / HBM_BW * 1e6, 1),
            "jnp_hbm_us": round(r["jnp_hbm_bytes"] / HBM_BW * 1e6, 1),
            "floor_speedup": round(r["jnp_hbm_bytes"]
                                   / r["fused_hbm_bytes"], 2),
            "sweep_ratio": r["sweep_ratio"],
        })
    return rows


def run():
    recs = load()
    rows = []
    for mp in (False, True):
        for r in table(recs, multi_pod=mp):
            rows.append({"figure": "roofline",
                         "mesh": "2x16x16" if mp else "16x16", **r})
    return rows + wirepath_table()


def main():
    from benchmarks.common import fmt_rows
    print(fmt_rows(run()))


if __name__ == "__main__":
    main()
