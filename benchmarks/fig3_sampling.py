"""Paper Fig. 3: static vs dynamic sampling (beta 0.01 / 0.1) on LeNet —
accuracy and transport cost after 10 / 30 rounds of federated training."""

from repro.core import MaskingConfig

from benchmarks.common import make_schedule, run_federated


def run():
    rows = []
    none = MaskingConfig(mode="none")
    for rounds in (10, 30):
        for name, sched in [
                ("static", make_schedule("static")),
                ("dynamic_b0.01", make_schedule("dynamic", 0.01)),
                ("dynamic_b0.1", make_schedule("dynamic", 0.1))]:
            r = run_federated("lenet", sched, none, rounds)
            rows.append({"figure": "fig3", "sampling": name,
                         "rounds": rounds, **r})
    return rows
