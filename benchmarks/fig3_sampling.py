"""Paper Fig. 3: static vs dynamic sampling (beta 0.01 / 0.1) on LeNet —
accuracy and transport cost after 10 / 30 rounds of federated training.

Also hosts the cohort-engine execution benchmark (DESIGN.md §3.5): the
full-population vmap runs every registered client each round, so its
per-round wall-clock is flat in c(t); the cohort engine materializes only
the sampled bucket, so wall-clock decays with c(t).  Rows are written to
``BENCH_cohort.json`` at the repo root:

  PYTHONPATH=src python -m benchmarks.fig3_sampling --cohort [--smoke]

``--smoke`` (CI) shrinks the population and round count so regressions
fail fast without tying up a runner.
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DynamicSampling, FederatedServer, StaticSampling,
                        strategy)

from benchmarks.common import make_schedule, run_strategy

BENCH_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_cohort.json")
# smoke runs (CI) write here so they never clobber the tracked full-run JSON
SMOKE_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_cohort.smoke.json")


def run():
    rows = []
    # "fig3" is the beta=0.1 preset; the other schedules are field
    # overrides of the same strategy record.
    settings = [
        ("static", strategy.get("dense-baseline")),
        ("dynamic_b0.01", strategy.get(
            "fig3", sampling=make_schedule("dynamic", 0.01))),
        ("dynamic_b0.1", strategy.get("fig3")),
    ]
    for rounds in (10, 30):
        for name, strat in settings:
            r = run_strategy("lenet", strat, rounds)
            rows.append({"figure": "fig3", "sampling": name,
                         "rounds": rounds, **r})
    return rows


# ---------------------------------------------------------------------------
# cohort engine vs full-population vmap
# ---------------------------------------------------------------------------
def _logistic_problem(num_clients, num_batches=2, batch=32, dim=256,
                      classes=10, seed=0):
    """Synthetic softmax regression sized so client_update compute (not the
    model) dominates: the bench isolates execution scaling in the number of
    clients actually run per round."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (num_clients, num_batches, batch, dim),
                          jnp.float32)
    y = jax.random.randint(jax.random.fold_in(key, 1),
                           (num_clients, num_batches, batch), 0, classes)

    def loss_fn(params, data):
        xb, yb = data
        logits = xb @ params["w"] + params["b"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

    params = {
        "w": 0.1 * jax.random.normal(jax.random.fold_in(key, 2),
                                     (dim, classes)),
        "b": jnp.zeros((classes,)),
    }
    n = np.ones((num_clients,), np.float32)
    return loss_fn, params, (x, y), n


def _steady_rows(server, engine, M):
    recs = server.history
    steady = [r.wall_s for r in recs]
    return {
        "figure": "cohort_engine", "engine": engine, "num_clients": M,
        "rounds": len(recs),
        "cohort_size": recs[-1].cohort_size,
        "num_sampled": recs[-1].num_sampled,
        "steady_wall_ms_per_round": round(1e3 * float(np.mean(steady)), 3),
        "compile_s": round(sum(r.compile_s for r in recs), 2),
        "flop_proxy_per_round": recs[-1].flop_proxy,
    }


def run_cohort(Ms=(64, 256, 1024), rounds=8, smoke=False):
    """Two cases: (a) steady-state at c(t)=0.125 — full baseline vs cohort
    engine per M; (b) a dynamic-decay trace showing per-round wall-clock
    falling with c(t) under the cohort engine."""
    if smoke:
        Ms, rounds = (16,), 2
    rows = []

    # (a) steady state at c = 0.125
    for M in Ms:
        loss_fn, params, batches, n = _logistic_problem(M)
        sched = StaticSampling(initial_rate=0.125, min_clients=2)
        walls = {}
        for engine in ("full", "cohort"):
            strat = strategy.get("dense-baseline", sampling=sched)
            server = FederatedServer.from_strategy(strat, loss_fn, params, M,
                                                   engine=engine)
            server.run(batches, n, rounds)
            row = _steady_rows(server, engine, M)
            walls[engine] = row["steady_wall_ms_per_round"]
            rows.append(row)
        rows[-1]["speedup_vs_full"] = round(
            walls["full"] / max(walls["cohort"], 1e-9), 2)

    # (b) wall-clock decays with c(t) under dynamic sampling
    M = Ms[-1]
    loss_fn, params, batches, n = _logistic_problem(M)
    sched = DynamicSampling(initial_rate=1.0, beta=0.3, min_clients=2)
    strat = strategy.get("fig3", sampling=sched)
    server = FederatedServer.from_strategy(strat, loss_fn, params, M,
                                           engine="cohort")
    server.run(batches, n, rounds if smoke else 2 * rounds)
    for r in server.history:
        rows.append({
            "figure": "cohort_decay", "engine": "cohort", "num_clients": M,
            "round": r.round, "num_sampled": r.num_sampled,
            "cohort_size": r.cohort_size,
            "wall_ms": round(1e3 * r.wall_s, 3),
            "compile_s": round(r.compile_s, 2),
            "flop_proxy": r.flop_proxy,
        })

    from benchmarks.common import write_bench
    write_bench(SMOKE_PATH if smoke else BENCH_PATH, "cohort", rows)
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    ap = argparse.ArgumentParser()
    ap.add_argument("--cohort", action="store_true",
                    help="run the cohort-engine bench (writes BENCH_cohort.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny M / 2 rounds for CI")
    args = ap.parse_args()
    if args.cohort or args.smoke:
        print(fmt_rows(run_cohort(smoke=args.smoke)))
    else:
        print(fmt_rows(run()))
