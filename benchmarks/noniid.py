"""Non-IID benchmark grid: bytes-to-target-loss under Dirichlet label skew.

The paper evaluates IID partitioning only (§5.1.2); this grid asks the
beyond-paper question the LocalObjective axis (DESIGN.md §12) exists for:
under Dirichlet(alpha) label skew, how many wire bytes does each local
objective need to reach a target training loss, and does norm-adaptive
client selection change that answer?

  PYTHONPATH=src python -m benchmarks.noniid            # full grid
  PYTHONPATH=src python -m benchmarks.noniid --smoke    # CI gate row

Grid axes (full run):

* partition   — Dirichlet alpha in {0.1, 0.5} (harsh / moderate skew),
                ``repro.data.dirichlet_partition_images``;
* objective   — fedavg (plain), prox (FedProx mu=0.1), dyn (FedDyn
                alpha=0.1 with the drift tree riding the client-state
                store) — the ``fig5`` / ``fig5-prox`` / ``fig5-dyn``
                presets;
* sampler     — importance | threshold (both norm-adaptive, DESIGN.md §5).

Every cell runs fig5's wire operating point (dynamic c(t) beta=0.1,
selective masking gamma=0.5, sparse COO codec) and reports
``bytes_to_target``: cumulative EXACT wire bytes at the first round whose
mean training loss <= TARGET_LOSS (-1 when the budgeted rounds never get
there, with ``reached=false``).  Writes ``BENCH_noniid.json`` (or
``BENCH_noniid.smoke.json``) in the shared envelope; CI diffs the smoke
artifact against ``benchmarks/baselines/BENCH_noniid.smoke.json``.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.core import FederatedServer, strategy
from repro.core.sampling import ImportanceSampler, ThresholdSampler
from repro.data import class_gaussian_images, dirichlet_partition_images
from repro.models import (classifier_accuracy, classifier_loss, init_lenet,
                          lenet_forward)

NUM_CLIENTS, IMG = 8, 12
TARGET_LOSS = 1.0
ROUNDS_FULL, ROUNDS_SMOKE = 16, 4

OUT_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_noniid.json")
SMOKE_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_noniid.smoke.json")

_OBJECTIVES = {"fedavg": "fig5", "prox": "fig5-prox", "dyn": "fig5-dyn"}
_SAMPLERS = {"importance": ImportanceSampler, "threshold": ThresholdSampler}


def _data(alpha: float, seed: int = 0):
    d = class_gaussian_images(num_train=NUM_CLIENTS * 160, num_test=512,
                              image_size=IMG, noise=0.6, seed=seed)
    xs, ys, n = dirichlet_partition_images(d.train_x, d.train_y,
                                           NUM_CLIENTS, 16, alpha=alpha,
                                           seed=seed)
    return ((jnp.asarray(xs), jnp.asarray(ys)), n,
            (jnp.asarray(d.test_x), jnp.asarray(d.test_y)))


def run_cell(alpha: float, objective: str, sampler: str, rounds: int,
             seed: int = 0):
    """One grid cell: fig5's wire operating point + the named local
    objective + the named adaptive sampler, on Dirichlet(alpha) shards."""
    batches, n, eval_data = _data(alpha, seed)
    strat = strategy.get(_OBJECTIVES[objective],
                         sampler=_SAMPLERS[sampler]())
    params = init_lenet(jax.random.PRNGKey(seed), IMG)
    server = FederatedServer.from_strategy(
        strat, classifier_loss(lenet_forward), params, NUM_CLIENTS,
        eval_fn=jax.jit(classifier_accuracy(lenet_forward)), seed=seed)
    t0 = time.time()
    server.run(batches, n, rounds, eval_every=rounds, eval_data=eval_data)
    wall = time.time() - t0
    s = server.summary()

    cum_bytes, bytes_to_target = 0, -1
    for rec in server.history:
        cum_bytes += rec.transport_bytes
        if bytes_to_target < 0 and rec.mean_loss <= TARGET_LOSS:
            bytes_to_target = cum_bytes
    return {
        "figure": "noniid_grid",
        "alpha": alpha,
        "objective": objective,
        "sampler": sampler,
        "rounds": rounds,
        "target_loss": TARGET_LOSS,
        "reached": bytes_to_target >= 0,
        "bytes_to_target": bytes_to_target,
        "final_loss": round(s["final_loss"], 4),
        "final_eval": round(s["final_eval"], 4),
        "transport_bytes": s["transport_bytes"],
        "steady_wall_s": round(s["steady_wall_s"], 4),
        "compile_s": round(s["compile_s"], 2),
        "wall_s": round(wall, 2),
    }


def run(smoke: bool = False):
    if smoke:
        # One representative cell per objective at moderate skew — enough
        # to gate byte accounting and the dyn drift path without a long run.
        cells = [(0.5, obj, "importance", ROUNDS_SMOKE)
                 for obj in ("fedavg", "prox", "dyn")]
    else:
        cells = [(alpha, obj, smp, ROUNDS_FULL)
                 for alpha in (0.1, 0.5)
                 for obj in ("fedavg", "prox", "dyn")
                 for smp in ("importance", "threshold")]
    return [run_cell(*cell) for cell in cells]


def main():
    from benchmarks.common import fmt_rows, write_bench
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="3-cell CI gate (writes BENCH_noniid.smoke.json)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    write_bench(SMOKE_PATH if args.smoke else OUT_PATH, "noniid", rows)
    print(fmt_rows(rows))


if __name__ == "__main__":
    main()
