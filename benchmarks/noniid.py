"""Beyond-paper ablation: the paper evaluates IID partitioning only (§5.1.2).
Here: selective vs random masking under McMahan-style pathological non-IID
label sharding (2 labels/client), plus error feedback — does top-k masking
survive client drift?"""

import jax
import jax.numpy as jnp

from repro.core import FederatedServer, MaskingConfig, StaticSampling
from repro.core.strategy import FedStrategy
from repro.data import class_gaussian_images, noniid_partition_images
from repro.models import (classifier_accuracy, classifier_loss, init_lenet,
                          lenet_forward)

NUM_CLIENTS, IMG = 8, 12


def _run(masking, error_feedback=False, rounds=14, seed=0):
    data = class_gaussian_images(num_train=NUM_CLIENTS * 160, num_test=512,
                                 image_size=IMG, noise=0.6, seed=seed)
    xs, ys, n = noniid_partition_images(data.train_x, data.train_y,
                                        NUM_CLIENTS, 16,
                                        shards_per_client=2, seed=seed)
    strat = FedStrategy.from_components(
        "noniid", StaticSampling(initial_rate=1.0), masking,
        learning_rate=0.05, error_feedback=error_feedback)
    params = init_lenet(jax.random.PRNGKey(seed), IMG)
    server = FederatedServer.from_strategy(
        strat, classifier_loss(lenet_forward), params, NUM_CLIENTS,
        eval_fn=jax.jit(classifier_accuracy(lenet_forward)))
    server.run((jnp.asarray(xs), jnp.asarray(ys)), n, rounds,
               eval_every=rounds,
               eval_data=(jnp.asarray(data.test_x), jnp.asarray(data.test_y)))
    return server.summary()


def run():
    rows = []
    for name, masking, ef in [
            ("dense", MaskingConfig(mode="none"), False),
            ("random_g0.2", MaskingConfig(mode="random", gamma=0.2), False),
            ("selective_g0.2", MaskingConfig(mode="selective", gamma=0.2), False),
            ("selective_g0.2_ef", MaskingConfig(mode="selective", gamma=0.2), True)]:
        s = _run(masking, ef)
        rows.append({"figure": "noniid", "setting": name,
                     "final_eval": s["final_eval"],
                     "final_loss": s["final_loss"],
                     "transport_units": s["transport_units"]})
    return rows
