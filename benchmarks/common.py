"""Shared benchmark plumbing: tiny federated runs matching the paper's
experimental axes (sampling schedule x masking mode x rate), scaled to CPU.

Every figure module exposes ``run() -> list[dict]`` rows; ``run.py`` prints
them as CSV and writes results/benchmarks.json.
"""

from __future__ import annotations

import functools
import json
import os
import subprocess
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import (DynamicSampling, FederatedServer, MaskingConfig,
                        StaticSampling)
from repro.core.strategy import FedStrategy
from repro.data import (class_gaussian_images, iid_partition_images,
                        markov_text, partition_text)
from repro.models import (classifier_accuracy, classifier_loss, init_gru_lm,
                          init_lenet, init_vgg, gru_lm_loss, lenet_forward,
                          perplexity, vgg_forward)

NUM_CLIENTS = 8
IMG_SIZE = 12
LM_VOCAB = 256


@functools.lru_cache()
def mnist_like(seed: int = 0):
    d = class_gaussian_images(num_train=NUM_CLIENTS * 128, num_test=512,
                              image_size=IMG_SIZE, channels=1, noise=0.6,
                              seed=seed)
    xs, ys, n = iid_partition_images(d.train_x, d.train_y, NUM_CLIENTS, 16,
                                     seed=seed)
    return ((jnp.asarray(xs), jnp.asarray(ys)), n,
            (jnp.asarray(d.test_x), jnp.asarray(d.test_y)))


@functools.lru_cache()
def cifar_like(seed: int = 0):
    d = class_gaussian_images(num_train=NUM_CLIENTS * 96, num_test=384,
                              image_size=16, channels=3, noise=0.6, seed=seed)
    xs, ys, n = iid_partition_images(d.train_x, d.train_y, NUM_CLIENTS, 16,
                                     seed=seed)
    return ((jnp.asarray(xs), jnp.asarray(ys)), n,
            (jnp.asarray(d.test_x), jnp.asarray(d.test_y)))


@functools.lru_cache()
def wikitext_like(seed: int = 0):
    d = markov_text(num_train=NUM_CLIENTS * 3200, num_test=4096,
                    vocab_size=LM_VOCAB, seed=seed)
    x, y, n = partition_text(d.train_tokens, NUM_CLIENTS, 8, 24, seed=seed)
    tx = d.test_tokens[: (len(d.test_tokens) - 1) // 24 * 24 + 1]
    ex = tx[:-1].reshape(-1, 24)[:64]
    ey = tx[1:].reshape(-1, 24)[:64]
    return ((jnp.asarray(x), jnp.asarray(y)), n,
            (jnp.asarray(ex), jnp.asarray(ey)))


def make_schedule(kind: str, beta: float = 0.0, rate: float = 1.0):
    if kind == "dynamic":
        return DynamicSampling(initial_rate=rate, beta=beta)
    return StaticSampling(initial_rate=rate)


def run_federated(model: str, schedule, masking: MaskingConfig, rounds: int,
                  lr: float = 0.05, seed: int = 0,
                  error_feedback: bool = False) -> Dict:
    """Legacy-shaped helper: build the equivalent FedStrategy and run it."""
    strat = FedStrategy.from_components(
        "bench", schedule, masking,
        learning_rate=lr, error_feedback=error_feedback)
    return run_strategy(model, strat, rounds, seed=seed)


def run_strategy(model: str, strat: FedStrategy, rounds: int,
                 seed: int = 0) -> Dict:
    """One federated training run driven by a FedStrategy; returns summary
    metrics (transport bytes are the codec's exact wire accounting)."""
    if model == "lenet":
        batches, n, eval_data = mnist_like(seed)
        params = init_lenet(jax.random.PRNGKey(seed), IMG_SIZE, 1)
        loss_fn = classifier_loss(lenet_forward)
        eval_fn = jax.jit(classifier_accuracy(lenet_forward))
        metric = "accuracy"
    elif model == "vgg":
        batches, n, eval_data = cifar_like(seed)
        params = init_vgg(jax.random.PRNGKey(seed), 16, 3,
                          widths=(16, 32, 64))
        loss_fn = classifier_loss(vgg_forward)
        eval_fn = jax.jit(classifier_accuracy(vgg_forward))
        metric = "accuracy"
    elif model == "gru":
        batches, n, eval_data = wikitext_like(seed)
        params = init_gru_lm(jax.random.PRNGKey(seed), LM_VOCAB, 64, 64)
        loss_fn = gru_lm_loss
        eval_fn = jax.jit(perplexity)
        metric = "perplexity"
    else:
        raise ValueError(model)

    server = FederatedServer.from_strategy(
        strat, loss_fn, params, NUM_CLIENTS, eval_fn=eval_fn, seed=seed)
    t0 = time.time()
    server.run(batches, n, rounds, eval_every=rounds, eval_data=eval_data)
    s = server.summary()
    return {
        "metric": metric,
        "final_eval": s["final_eval"],
        "final_loss": s["final_loss"],
        "transport_units": s["transport_units"],
        "transport_GB": s["transport_GB"],
        "codec": s["codec"],
        "client_upload_bytes": s["client_upload_bytes"],
        "rounds": rounds,
        "wall_s": round(time.time() - t0, 2),
        # steady-state vs compile split (PR 3 metering) for bench JSON
        "steady_wall_s": round(s["steady_wall_s"], 4),
        "compile_s": round(s["compile_s"], 2),
    }


# ---- the shared BENCH_*.json envelope --------------------------------------
# Every benchmark writes the SAME top-level shape so benchmarks/compare.py
# can diff any smoke artifact against its tracked baseline without
# per-figure knowledge:
#
#   {"schema": 1, "name": ..., "commit": ..., "rows": [...],
#    "totals": {"steady_wall_s": ..., "transport_bytes": ...}}
#
# ``rows`` keeps each figure's own columns; only the envelope is unified.
BENCH_SCHEMA = 1


def _git_commit() -> Optional[str]:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or None
    except OSError:
        return None


def _row_steady_s(row: Dict) -> float:
    """Best-effort steady wall seconds of one row (0.0 when the row carries
    no timing) — compile time is metered separately everywhere, so these
    are comparable across commits."""
    for key, scale in (("steady_wall_s", 1.0), ("wall_s", 1.0),
                       ("wall_ms", 1e-3), ("steady_wall_ms_per_round", None),
                       ("segmented_us", 1e-6)):
        v = row.get(key)
        if isinstance(v, (int, float)):
            if scale is None:  # per-round milliseconds
                return float(v) * 1e-3 * float(row.get("rounds", 1))
            return float(v) * scale
    return 0.0


def _row_bytes(row: Dict) -> int:
    for key, scale in (("transport_bytes", 1), ("transport_GB", 1e9),
                       ("wire_bytes", 1)):
        v = row.get(key)
        if isinstance(v, (int, float)):
            return int(float(v) * scale)
    curve = row.get("cum_bytes_curve")
    if isinstance(curve, list) and curve:
        return int(curve[-1])
    return 0


def bench_totals(rows: List[Dict]) -> Dict:
    return {
        "steady_wall_s": round(sum(_row_steady_s(r) for r in rows), 4),
        "transport_bytes": sum(_row_bytes(r) for r in rows),
    }


def write_bench(path: str, name: str, rows: List[Dict],
                totals: Optional[Dict] = None) -> Dict:
    """Write one BENCH_*.json in the shared envelope; returns the envelope."""
    env = {
        "schema": BENCH_SCHEMA,
        "name": name,
        "commit": _git_commit(),
        "rows": rows,
        "totals": totals if totals is not None else bench_totals(rows),
    }
    with open(path, "w") as f:
        json.dump(env, f, indent=1)
    return env


def read_bench(path: str) -> Dict:
    """Read a BENCH_*.json; pre-envelope files (a bare row list) are wrapped
    into a schema-0 envelope so every reader sees one shape."""
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, list):
        name = os.path.basename(path).split(".")[0]
        if name.startswith("BENCH_"):
            name = name[len("BENCH_"):]
        return {"schema": 0, "name": name, "commit": None, "rows": data,
                "totals": bench_totals(data)}
    return data


def fmt_rows(rows: List[Dict]) -> str:
    if not rows:
        return ""
    keys: List[str] = []
    for r in rows:                      # union, first-seen order
        for k in r:
            if k not in keys:
                keys.append(k)
    out = [",".join(keys)]
    for r in rows:
        vals = []
        for k in keys:
            v = r.get(k, "")
            vals.append(f"{v:.4f}" if isinstance(v, float) else str(v))
        out.append(",".join(vals))
    return "\n".join(out)
