"""Adaptive client selection on a heterogeneous fleet (DESIGN.md §5).

Compares the three client samplers — ``uniform`` (the paper's rule),
``importance`` (norm-proportional with-replacement draws, unbiased HT
weights), ``threshold`` (water-filled independent transmission) — on the
same dynamic c(t) schedule, running every round on the simulated
``mobile`` fleet so the records carry both the codec's exact wire bytes
AND the simulated straggler wall-clock:

  PYTHONPATH=src python -m benchmarks.hetero_sampling            # full
  PYTHONPATH=src python -m benchmarks.hetero_sampling --smoke    # CI

Writes ``BENCH_hetero.json`` (or ``BENCH_hetero.smoke.json``): one row per
sampler with the per-round loss / cumulative-bytes / cumulative-sim-clock
curves and the bytes + simulated seconds needed to first reach the uniform
run's final loss (bytes-to-target-loss).
"""

import argparse
import os

import jax
import numpy as np

from repro.core import FederatedServer, strategy
from repro.core.hetero import HeteroModel
from repro.core.sampling import get_sampler
from repro.models import (classifier_accuracy, classifier_loss, init_lenet,
                          lenet_forward)

from benchmarks.common import IMG_SIZE, NUM_CLIENTS, mnist_like

OUT_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_hetero.json")
SMOKE_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_hetero.smoke.json")

SAMPLERS = ("uniform", "importance", "threshold")


def run_sampler(name: str, rounds: int, seed: int = 0):
    """One federated run with the named sampler on the mobile fleet;
    returns the per-round curves a cost-to-quality comparison needs."""
    batches, n, eval_data = mnist_like(seed)
    params = init_lenet(jax.random.PRNGKey(seed), IMG_SIZE, 1)
    loss_fn = classifier_loss(lenet_forward)
    eval_fn = jax.jit(classifier_accuracy(lenet_forward))

    strat = strategy.get(
        "fig3", sampler=get_sampler(name),
        hetero=HeteroModel(profile="mobile", seed=seed),
        learning_rate=0.1)
    server = FederatedServer.from_strategy(
        strat, loss_fn, params, NUM_CLIENTS, eval_fn=eval_fn, seed=seed)
    server.run(batches, n, rounds, eval_every=rounds, eval_data=eval_data)

    loss = [r.mean_loss for r in server.history]
    cum_bytes = np.cumsum([r.transport_bytes for r in server.history])
    cum_sim_s = np.cumsum([r.sim_round_s for r in server.history])
    s = server.summary()
    return {
        "sampler": name,
        "rounds": rounds,
        "loss_curve": [round(v, 4) for v in loss],
        "cum_bytes_curve": [int(v) for v in cum_bytes],
        "cum_sim_s_curve": [round(float(v), 2) for v in cum_sim_s],
        "final_loss": round(s["final_loss"], 4),
        "final_eval": round(s["final_eval"], 4),
        "transport_bytes": s["transport_bytes"],
        "sim_total_s": round(s["sim_total_s"], 2),
        "dropped_uploads": s["dropped_uploads"],
        "steady_wall_s": round(s["steady_wall_s"], 4),
    }


def _cost_to_target(row, target_loss):
    """First-round cumulative (bytes, sim seconds) at which the run's loss
    reaches ``target_loss`` (None when it never does).  Empty rounds (the
    threshold sampler's count can be 0) report NaN loss and are skipped."""
    for loss, b, t in zip(row["loss_curve"], row["cum_bytes_curve"],
                          row["cum_sim_s_curve"]):
        if np.isfinite(loss) and loss <= target_loss:
            return int(b), float(t)
    return None, None


def run(rounds: int = 24, seed: int = 0):
    """All three samplers + bytes/sim-clock to the uniform run's final
    loss, the bench's cost-to-quality headline."""
    rows = [run_sampler(name, rounds, seed=seed) for name in SAMPLERS]
    target = rows[0]["final_loss"]          # uniform's final loss
    for row in rows:
        b, t = _cost_to_target(row, target)
        row["target_loss"] = target
        row["bytes_to_target"] = b
        row["sim_s_to_target"] = t
    return rows


def main():
    """CLI entry: full bench, or tiny --smoke rows for the CI artifact."""
    from benchmarks.common import fmt_rows
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="3-round CI smoke (writes BENCH_hetero.smoke.json)")
    args = ap.parse_args()
    rounds = 3 if args.smoke else 24
    rows = run(rounds=rounds)
    path = SMOKE_PATH if args.smoke else OUT_PATH
    from benchmarks.common import write_bench
    write_bench(path, "hetero", rows)
    brief = [{k: v for k, v in r.items()
              if not k.endswith("_curve")} for r in rows]
    print(fmt_rows(brief))


if __name__ == "__main__":
    main()
