"""Masking micro-benchmarks: Pallas kernel pipeline (interpret mode on this
CPU container; compiled on TPU) vs the pure-jnp bisection vs the exact sort,
plus the analytic HBM-sweep accounting that matters on TPU:

* per-array kernel pipeline: 1 histogram + ``iters`` count sweeps + 1 apply
  = ``iters + 2`` passes (the bracket counts are threaded from the histogram,
  so there is no post-refine counting sweep), vs ``2*iters + 1`` for pure
  bisection and a full sort for the oracle;
* whole-pytree masking: the segmented single-pass subsystem
  (``ops.topk_mask_pytree``) costs ``refine_sweeps + 2`` sweeps TOTAL —
  leaf-count independent — vs ``L * (iters + 2)`` for the per-leaf loop.

The whole-pytree rows are also written to ``BENCH_masking.json`` at the repo
root so the perf trajectory tracks this hot path, and the wire-path section
(DESIGN.md §10) — fused mask+pack+quantise vs the jnp mask-then-codec chain,
plus the COO-vs-bitmap density table — to ``BENCH_wirepath.json``.
"""

import os
import time

import jax

from repro.core.codecs import ChainCodec, Int8Codec, SparseCodec
from repro.core.masking import (MaskingConfig, mask_pytree,
                                selective_mask_exact,
                                selective_mask_threshold)
from repro.kernels import ops

ITERS = 8
BENCH_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_masking.json")
# smoke runs (CI) write here so they never clobber the tracked full-run JSON
SMOKE_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_masking.smoke.json")
WIRE_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                         "BENCH_wirepath.json")
WIRE_SMOKE_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                               "BENCH_wirepath.smoke.json")


def _time(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))            # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _paper_models_pytree(seed=0):
    """The paper's actual workload shape: VGG + GRU-LM deltas — dozens of
    small/odd leaves, the regime where the per-leaf pipeline pads every leaf
    to a full kernel tile and retraces per distinct shape."""
    from repro.models import init_gru_lm, init_vgg
    key = jax.random.PRNGKey(seed)
    return {"vgg": init_vgg(key, 16, 3, widths=(16, 32, 64)),
            "gru": init_gru_lm(jax.random.fold_in(key, 1), 256, 64, 64)}


def _transformer_pytree(seed=0, layers=12, d=256):
    """A big-leaf transformer-stack delta (62 leaves, ~10M params)."""
    key = jax.random.PRNGKey(seed)
    tree = {}
    for i in range(layers):
        for j, s in enumerate([(d, 3 * d), (d, d), (d, 4 * d),
                               (4 * d, d), (d,)]):
            tree[f"l{i}_{j}"] = jax.random.normal(
                jax.random.fold_in(key, i * 10 + j), s)
    tree["embed"] = jax.random.normal(jax.random.fold_in(key, 999), (1000, d))
    tree["odd"] = jax.random.normal(jax.random.fold_in(key, 998), (300, 77))
    return tree


def _per_leaf_mask(tree, gamma, min_leaf_size=256):
    return jax.tree.map(
        lambda leaf: (leaf if leaf.size < min_leaf_size
                      else ops.topk_mask(leaf, gamma, iters=ITERS,
                                         interpret=True)),
        tree)


def _wirepath_rows(smoke: bool):
    """Wire-path rows (DESIGN.md §10): one upload's delta -> wire payload.

    Compares the fused kernel pipeline (``ops.topk_encode_pytree``: stats +
    refine counts + ONE encode sweep emitting int8 values and the keep
    bitmap) against the jnp chain the engines previously ran (mask_pytree
    then ``SparseCodec``+``Int8Codec``, which re-reads the dense fp32 tree
    three more times).  Wall-clock is interpret-mode (CPU container — the
    analytic sweep/byte columns are the TPU-relevant numbers), HBM cost is
    ``ops.wirepath_sweep_count`` / ``ops.wirepath_bytes_moved``.
    """
    gamma = 0.1
    reps = 2 if smoke else 5
    trees = [("paper_vgg_gru", _paper_models_pytree())]
    if not smoke:
        trees.append(("transformer_12L", _transformer_pytree()))
    rows = []
    cfg = MaskingConfig(gamma=gamma, mode="selective")
    chain = ChainCodec((SparseCodec(gamma=gamma), Int8Codec()))
    key = jax.random.PRNGKey(0)
    for model, tree in trees:
        n = int(sum(leaf.size for leaf in jax.tree_util.tree_leaves(tree)))
        t_jnp = _time(jax.jit(
            lambda t: chain.encode(mask_pytree(key, t, cfg))), tree,
            reps=reps)
        t_fused = _time(
            lambda t: ops.topk_encode_pytree(t, gamma, quantize=True,
                                             interpret=True), tree,
            reps=reps)
        s_fused = ops.wirepath_sweep_count(fused=True)
        s_jnp = ops.wirepath_sweep_count(fused=False)
        b_fused = ops.wirepath_bytes_moved(n, gamma, fused=True)
        b_jnp = ops.wirepath_bytes_moved(n, gamma, fused=False)
        rows.append({
            "figure": "wirepath", "model": model, "n_params": n,
            "gamma": gamma,
            "jnp_chain_us": round(t_jnp, 1),
            "fused_interpret_us": round(t_fused, 1),
            "fused_hbm_sweeps": s_fused,
            "jnp_hbm_sweeps": s_jnp,
            "sweep_ratio": round(s_jnp / s_fused, 2),
            "fused_hbm_bytes": b_fused["total"],
            "jnp_hbm_bytes": b_jnp["total"],
            "byte_ratio": round(b_jnp["total"] / b_fused["total"], 2),
            "payload_bytes": b_fused["payload_bytes"],
        })

    # ---- COO vs bitmap wire density table (crossover at k/n = 1/32)
    n = 1 << 16 if smoke else 1 << 20
    for g in (0.005, 0.01, 0.02, 0.03125, 0.05, 0.1, 0.2, 0.5):
        coo = ops.wirepath_bytes_moved(n, g, fused=True,
                                       wire="coo")["payload_bytes"]
        bmp = ops.wirepath_bytes_moved(n, g, fused=True,
                                       wire="bitmap")["payload_bytes"]
        rows.append({
            "figure": "wirepath_density", "n_params": n, "gamma": g,
            "coo_payload_bytes": coo, "bitmap_payload_bytes": bmp,
            "winner": "bitmap" if bmp < coo else "coo",
            "bitmap_saving": round(1.0 - bmp / coo, 3),
        })
    return rows


def run(smoke: bool = False):
    """``smoke=True`` (CI): one small size, fewer reps, VGG+GRU tree only —
    enough to catch pipeline regressions without tying up a runner."""
    rows = []
    gamma = 0.1
    reps = 2 if smoke else 5
    for n in ((1 << 14,) if smoke else (1 << 16, 1 << 20)):
        x = jax.random.normal(jax.random.PRNGKey(0), (n,))
        t_sort = _time(jax.jit(
            lambda x: selective_mask_exact(x, gamma)), x, reps=reps)
        t_bisect = _time(jax.jit(
            lambda x: selective_mask_threshold(x, gamma, 24)), x, reps=reps)
        t_kernel = _time(
            lambda x: ops.topk_mask(x, gamma, iters=ITERS, interpret=True), x,
            reps=reps)
        rows.append({
            "figure": "kernels", "n": n, "gamma": gamma,
            "sort_us": round(t_sort, 1),
            "bisect_us": round(t_bisect, 1),
            "kernel_interpret_us": round(t_kernel, 1),
            "kernel_hbm_sweeps": ITERS + 2,
            "bisect_hbm_sweeps": 2 * 24 + 1,
        })

    # ---- whole-pytree masking: per-leaf pipeline vs segmented single-pass
    mask_rows = []
    models = [("paper_vgg_gru", _paper_models_pytree())]
    if not smoke:
        models.append(("transformer_12L", _transformer_pytree()))
    for model, tree in models:
        leaves = jax.tree_util.tree_leaves(tree)
        maskable = sum(1 for leaf in leaves if leaf.size >= 256)
        t_per_leaf = _time(lambda t: _per_leaf_mask(t, gamma), tree, reps=reps)
        t_seg = _time(
            lambda t: ops.topk_mask_pytree(t, gamma, interpret=True), tree,
            reps=reps)
        mask_rows.append({
            "figure": "masking_pytree", "model": model, "gamma": gamma,
            "num_leaves": len(leaves), "maskable_leaves": maskable,
            "num_params": int(sum(leaf.size for leaf in leaves)),
            "per_leaf_us": round(t_per_leaf, 1),
            "segmented_us": round(t_seg, 1),
            "speedup": round(t_per_leaf / max(t_seg, 1e-9), 2),
            "per_leaf_hbm_sweeps": ops.pytree_sweep_count(
                maskable, segmented=False, iters=ITERS),
            "segmented_hbm_sweeps": ops.pytree_sweep_count(
                maskable, segmented=True),
            "per_leaf_kernel_launches": maskable * (ITERS + 2),
            "segmented_kernel_launches": ops.DEFAULT_REFINE_SWEEPS + 2,
        })
    from benchmarks.common import write_bench
    write_bench(SMOKE_PATH if smoke else BENCH_PATH, "masking", mask_rows)

    wire_rows = _wirepath_rows(smoke)
    write_bench(WIRE_SMOKE_PATH if smoke else WIRE_PATH, "wirepath",
                wire_rows)
    return rows + mask_rows + wire_rows


if __name__ == "__main__":
    import argparse

    from benchmarks.common import fmt_rows
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes / few reps for CI regression gating")
    print(fmt_rows(run(smoke=ap.parse_args().smoke)))
