"""Masking micro-benchmarks: Pallas kernel pipeline (interpret mode on this
CPU container; compiled on TPU) vs the pure-jnp bisection vs the exact sort,
plus the analytic sweep-count accounting that matters on TPU (the kernel
does 1 histogram + ``iters`` count sweeps + 1 apply = ``iters+2`` HBM passes
vs ``2*iters+1`` for pure bisection and a full sort for the oracle)."""

import time

import jax
import jax.numpy as jnp

from repro.core.masking import selective_mask_exact, selective_mask_threshold
from repro.kernels import ops


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()               # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    for n in (1 << 16, 1 << 20):
        x = jax.random.normal(jax.random.PRNGKey(0), (n,))
        gamma = 0.1
        t_sort = _time(jax.jit(
            lambda x: selective_mask_exact(x, gamma)), x)
        t_bisect = _time(jax.jit(
            lambda x: selective_mask_threshold(x, gamma, 24)), x)
        t_kernel = _time(
            lambda x: ops.topk_mask(x, gamma, interpret=True), x)
        rows.append({
            "figure": "kernels", "n": n, "gamma": gamma,
            "sort_us": round(t_sort, 1),
            "bisect_us": round(t_bisect, 1),
            "kernel_interpret_us": round(t_kernel, 1),
            "kernel_hbm_sweeps": 8 + 2,
            "bisect_hbm_sweeps": 2 * 24 + 1,
        })
    return rows
