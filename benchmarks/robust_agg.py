"""Byzantine-robust aggregation under sparse uploads (DESIGN.md §9).

Sweeps the adversarial fraction x aggregation rule x upload density grid:
every cell trains the SAME LeNet problem under amplified sign-flip
adversaries (``AttackModel(kind="sign_flip", strength=4.0)``) so the
curves isolate the aggregation rule — at f = 0.3 the FedAvg mean is an
ascent direction (0.7·u − 1.2·u = −0.5·u) while the robust rules stay
below their breakdown points:

  PYTHONPATH=src python -m benchmarks.robust_agg            # full grid
  PYTHONPATH=src python -m benchmarks.robust_agg --smoke    # CI chaos

Writes ``BENCH_robust.json`` (or ``BENCH_robust.smoke.json``): one row
per (masking, aggregator, fraction) with the per-round loss curve and the
server's Byzantine ledger (adversarial uploads seen, quarantined rows).

The smoke variant runs the fig5 sparse operating point at f ∈ {0, 0.3}
for {fedavg, coordinate_median, multi_krum} and ASSERTS the §9 chaos
criterion: both robust rules must land within 10% of their honest-fleet
final loss while plain FedAvg visibly diverges — CI fails the moment a
regression lets sign-flipped mass move a robust model.
"""

import argparse
import os

import jax

from repro.core import (FederatedServer, coordinate_median, multi_krum,
                        strategy, trimmed_mean)
from repro.core.attacks import AttackModel
from repro.core.sampling import DynamicSampling
from repro.models import classifier_accuracy, classifier_loss, init_lenet, \
    lenet_forward

from benchmarks.common import IMG_SIZE, NUM_CLIENTS, mnist_like

OUT_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_robust.json")
SMOKE_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_robust.smoke.json")

FRACTIONS = (0.0, 0.1, 0.3)
MASKINGS = ("dense", "sparse")
# mirrors the preset quorum floor: min_clients = 5 keeps late cohorts an
# honest majority at f = 0.3 and gives Krum its n >= f + 3 candidates
ROBUST_SAMPLING = DynamicSampling(initial_rate=1.0, beta=0.1, min_clients=5)


def aggregators():
    """The grid's rules, breakdown-ordered: none, norm-bounded, beta-trim,
    median, geometric."""
    return {
        "fedavg": strategy.FEDAVG,
        "clipped": strategy.clipped_fedavg(1.0),
        "trimmed_mean": trimmed_mean(0.3),
        "coordinate_median": coordinate_median(),
        "multi_krum": multi_krum(f=2, m=2),
    }


def make_strategy(masking: str, agg_name: str, fraction: float):
    """One grid cell: fig5's sparse wire or fig3's dense wire, the robust
    quorum floor, an amplified sign-flip fleet at ``fraction``."""
    base = strategy.get("fig5" if masking == "sparse" else "fig3",
                        learning_rate=0.1)
    return base.replace(
        name=f"robust-{masking}-{agg_name}-f{fraction}",
        sampling=ROBUST_SAMPLING,
        aggregator=aggregators()[agg_name],
        attack=AttackModel(kind="sign_flip", fraction=fraction,
                           strength=4.0),
    )


def run_cell(masking: str, agg_name: str, fraction: float, rounds: int,
             seed: int = 0):
    """Train one grid cell; returns the loss curve + Byzantine ledger."""
    batches, n, eval_data = mnist_like(seed)
    params = init_lenet(jax.random.PRNGKey(seed), IMG_SIZE, 1)
    loss_fn = classifier_loss(lenet_forward)
    eval_fn = jax.jit(classifier_accuracy(lenet_forward))

    strat = make_strategy(masking, agg_name, fraction)
    server = FederatedServer.from_strategy(
        strat, loss_fn, params, NUM_CLIENTS, eval_fn=eval_fn, seed=seed)
    server.run(batches, n, rounds, eval_every=rounds, eval_data=eval_data)

    s = server.summary()
    loss = [r.mean_loss for r in server.history]
    return {
        "masking": masking,
        "aggregator": agg_name,
        "fraction": fraction,
        "rounds": rounds,
        "loss_curve": [round(v, 4) for v in loss],
        "final_loss": round(s["final_loss"], 4),
        "final_eval": round(s["final_eval"], 4),
        "transport_bytes": s["transport_bytes"],
        "quarantined": s["quarantined"],
        "adversarial_uploads": s.get("adversarial_uploads", 0),
        "attack": s.get("attack", "none"),
    }


def run(rounds: int = 16, seed: int = 0):
    """The full grid, plus a per-(masking, aggregator) robustness ratio:
    final loss at f = 0.3 over final loss on the honest fleet."""
    rows = []
    for masking in MASKINGS:
        for agg_name in aggregators():
            by_f = {}
            for fraction in FRACTIONS:
                row = run_cell(masking, agg_name, fraction, rounds,
                               seed=seed)
                by_f[fraction] = row
                rows.append(row)
            honest = by_f[0.0]["final_loss"]
            for fraction in FRACTIONS:
                by_f[fraction]["loss_vs_honest"] = round(
                    by_f[fraction]["final_loss"] / honest, 4)
    return rows


SMOKE_AGGS = ("fedavg", "coordinate_median", "multi_krum")
ROBUST_TOL = 1.10       # robust rules: within 10% of the honest final loss
DIVERGE_FACTOR = 1.5    # fedavg under attack: visibly off the honest curve


def run_smoke(rounds: int = 8, seed: int = 0):
    """The CI chaos gate (§9 acceptance): fig5 sparse wire, f = 0.3
    amplified sign-flip, {fedavg, median, multi-Krum} each against its own
    honest-fleet control.  Asserts the robust rules hold and FedAvg does
    not — a silent robustness regression fails the build."""
    rows = []
    finals = {}
    for agg_name in SMOKE_AGGS:
        for fraction in (0.0, 0.3):
            row = run_cell("sparse", agg_name, fraction, rounds, seed=seed)
            honest = finals.get((agg_name, 0.0), row["final_loss"])
            row["loss_vs_honest"] = round(row["final_loss"] / honest, 4)
            finals[(agg_name, fraction)] = row["final_loss"]
            rows.append(row)
        assert finals[(agg_name, 0.3)] > 0 and finals[(agg_name, 0.0)] > 0

    for agg_name in ("coordinate_median", "multi_krum"):
        ratio = finals[(agg_name, 0.3)] / finals[(agg_name, 0.0)]
        assert ratio <= ROBUST_TOL, (
            f"{agg_name}: f=0.3 sign-flip moved the model "
            f"{ratio:.3f}x off the honest-fleet final loss "
            f"(tolerance {ROBUST_TOL}x) — robustness regression")
    fed_ratio = finals[("fedavg", 0.3)] / finals[("fedavg", 0.0)]
    assert fed_ratio >= DIVERGE_FACTOR, (
        f"plain fedavg under f=0.3 sign-flip should diverge "
        f"(>= {DIVERGE_FACTOR}x honest final loss) but scored "
        f"{fed_ratio:.3f}x — the attack injection is not biting")
    return rows


def main():
    """CLI entry: full grid, or --smoke chaos rows for the CI artifact."""
    from benchmarks.common import fmt_rows
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="8-round CI chaos smoke asserting the §9 "
                         "criterion (writes BENCH_robust.smoke.json)")
    args = ap.parse_args()
    rows = run_smoke() if args.smoke else run()
    path = SMOKE_PATH if args.smoke else OUT_PATH
    from benchmarks.common import write_bench
    write_bench(path, "robust", rows)
    brief = [{k: v for k, v in r.items()
              if not k.endswith("_curve")} for r in rows]
    print(fmt_rows(brief))


if __name__ == "__main__":
    main()
