"""Regression gate for BENCH_*.json artifacts (the shared envelope in
``benchmarks.common.write_bench``): diff a freshly produced smoke JSON
against the tracked baseline and exit non-zero when steady wall-clock
regresses past the threshold.

  PYTHONPATH=src python -m benchmarks.compare \
      BENCH_store.smoke.json benchmarks/baselines/BENCH_store.smoke.json

Rows are matched by their identity columns (every string/bool field the two
files share — figure, preset, backend, M, ...); each matched pair compares
its per-row steady wall seconds, and the envelope totals are compared as
the headline.  Byte fields are checked for EXACT equality — wire accounting
is deterministic, so any byte drift is a correctness change, not noise.
Only regressions fail; speedups and added/removed rows are reported but
pass (new rows are new coverage, not a regression).
"""

from __future__ import annotations

import argparse
import sys
from typing import Dict, List, Tuple

from benchmarks.common import _row_bytes, _row_steady_s, read_bench

DEFAULT_THRESHOLD = 0.15


def _identity(row: Dict) -> Tuple:
    """Hashable identity of one row: its non-measurement fields.  Ints are
    included (sizes, round counts, client counts are configuration, not
    measurement) unless they look like byte/time measurements."""
    key = []
    for k in sorted(row):
        v = row[k]
        if isinstance(v, bool) or isinstance(v, str):
            key.append((k, v))
        elif isinstance(v, int) and not any(
                s in k for s in ("bytes", "_us", "_ms", "_s", "wall",
                                 "flop", "timeout", "retr", "quarantin",
                                 "dropped", "flush", "evict")):
            key.append((k, v))
    return tuple(key)


def compare(cur_path: str, base_path: str,
            threshold: float = DEFAULT_THRESHOLD) -> List[str]:
    """Returns the list of failure messages (empty = pass), printing the
    per-row report as a side effect."""
    cur = read_bench(cur_path)
    base = read_bench(base_path)
    failures: List[str] = []

    cur_rows = {_identity(r): r for r in cur["rows"]}
    base_rows = {_identity(r): r for r in base["rows"]}
    matched = sorted(set(cur_rows) & set(base_rows))
    print(f"{cur['name']}: {len(matched)} matched rows "
          f"({len(cur_rows) - len(matched)} new, "
          f"{len(base_rows) - len(matched)} gone) "
          f"vs baseline commit {base.get('commit')}")

    for key in matched:
        c, b = cur_rows[key], base_rows[key]
        label = " ".join(f"{k}={v}" for k, v in key) or "<row>"
        tc, tb = _row_steady_s(c), _row_steady_s(b)
        if tb > 0:
            ratio = tc / tb
            flag = ""
            if ratio > 1.0 + threshold:
                flag = "  <-- REGRESSION"
                failures.append(
                    f"{label}: steady wall {tb:.4f}s -> {tc:.4f}s "
                    f"({ratio:.2f}x, threshold {1 + threshold:.2f}x)")
            print(f"  {label}: {tb:.4f}s -> {tc:.4f}s ({ratio:.2f}x){flag}")
        bc, bb = _row_bytes(c), _row_bytes(b)
        if bc != bb:
            failures.append(
                f"{label}: wire bytes changed {bb} -> {bc} (byte "
                "accounting is deterministic — this is a semantic change)")

    tc = float(cur.get("totals", {}).get("steady_wall_s") or 0.0)
    tb = float(base.get("totals", {}).get("steady_wall_s") or 0.0)
    if tb > 0:
        ratio = tc / tb
        print(f"totals: steady wall {tb:.4f}s -> {tc:.4f}s ({ratio:.2f}x)")
        if ratio > 1.0 + threshold:
            failures.append(
                f"totals: steady wall {tb:.4f}s -> {tc:.4f}s "
                f"({ratio:.2f}x, threshold {1 + threshold:.2f}x)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(
        description="diff a BENCH smoke JSON against its tracked baseline")
    ap.add_argument("current", help="freshly produced BENCH_*.json")
    ap.add_argument("baseline", help="tracked baseline BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="fractional steady-wall regression tolerance "
                         "(default 0.15 = +15%%)")
    args = ap.parse_args()
    failures = compare(args.current, args.baseline, args.threshold)
    if failures:
        print("\nFAIL:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("OK: within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
