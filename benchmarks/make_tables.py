"""Render EXPERIMENTS.md §Roofline tables from results/dryrun*/ JSONs,
plus the DESIGN.md §9 Byzantine-robustness grid from BENCH_robust.json.

  PYTHONPATH=src python -m benchmarks.make_tables [--dir results/dryrun_baseline]
  PYTHONPATH=src python -m benchmarks.make_tables --robust BENCH_robust.json
"""

import argparse
import glob
import json
import os


def rows_from(dir_, multi_pod=None, fed=None):
    out = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        r = json.load(open(f))
        if multi_pod is not None and r.get("multi_pod", False) != multi_pod:
            continue
        if fed is not None and r.get("fed", False) != fed:
            continue
        out.append(r)
    out.sort(key=lambda r: (r["arch"], r["shape"]))
    return out


def md_table(recs, title):
    lines = [f"### {title}", "",
             "| arch | shape | compute s | memory s | collective s "
             "(raw / bf16-comm) | dominant | useful | HBM GB/dev (fits) |",
             "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        t = r["roofline"]
        m = r["memory"]
        adj = t.get("collective_s_bf16comm")
        coll = (f"{t['collective_s']:.2f} / {adj:.2f}" if adj is not None
                else f"{t['collective_s']:.2f}")
        hbm = (m["argument_bytes"] + m["temp_bytes"]) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']}{' (fed)' if r.get('fed') else ''} "
            f"| {t['compute_s']:.3f} | {t['memory_s']:.2f} | {coll} "
            f"| {t['dominant'].replace('_s', '')} "
            f"| {t['useful_flop_fraction']:.2f} "
            f"| {hbm:.1f} ({'Y' if m['fits_hbm'] else 'n'}) |")
    lines.append("")
    return "\n".join(lines)


def robust_table(path):
    """BENCH_robust[.smoke].json -> markdown grid: one row per
    (masking, aggregator), one final-loss column per adversarial
    fraction, plus the f = 0.3 robustness ratio against the honest
    fleet (the §9 chaos criterion holds while ratio <= 1.10 for the
    robust rules and >> 1 for plain fedavg)."""
    from benchmarks.common import read_bench
    recs = read_bench(path)["rows"]
    fracs = sorted({r["fraction"] for r in recs})
    cells = {}
    for r in recs:
        cells[(r["masking"], r["aggregator"], r["fraction"])] = r
    keys = sorted({(r["masking"], r["aggregator"]) for r in recs})
    head = " | ".join(f"loss f={f}" for f in fracs)
    lines = [f"### Byzantine robustness ({os.path.basename(path)})", "",
             f"| masking | aggregator | {head} | worst/honest |",
             "|---|---|" + "---|" * (len(fracs) + 1)]
    for masking, agg in keys:
        vals, ratio = [], ""
        for f in fracs:
            r = cells.get((masking, agg, f))
            vals.append(f"{r['final_loss']:.3f}" if r else "-")
            if r and f == max(fracs):
                ratio = f"{r.get('loss_vs_honest', float('nan')):.3f}"
        lines.append(f"| {masking} | {agg} | " + " | ".join(vals) +
                     f" | {ratio} |")
    lines.append("")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun_baseline")
    ap.add_argument("--mp-dir", default="results/dryrun")
    ap.add_argument("--robust", default=None, metavar="JSON",
                    help="render the Byzantine grid from this "
                         "BENCH_robust[.smoke].json and exit")
    args = ap.parse_args()

    if args.robust:
        print(robust_table(args.robust))
        return

    print(md_table(rows_from(args.dir, fed=False),
                   "Single-pod 16x16 baselines (paper-faithful system)"))
    mp = rows_from(args.mp_dir, multi_pod=True, fed=False)
    if mp:
        print(md_table(mp, "Multi-pod 2x16x16 (proves the pod axis shards; "
                           "includes perf iterations 1-2)"))
    fed = rows_from(args.mp_dir, fed=True)
    if fed:
        print(md_table(fed, "Federated round (the paper's technique at pod "
                            "scale)"))


if __name__ == "__main__":
    main()
