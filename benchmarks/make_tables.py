"""Render EXPERIMENTS.md §Roofline tables from results/dryrun*/ JSONs.

  PYTHONPATH=src python -m benchmarks.make_tables [--dir results/dryrun_baseline]
"""

import argparse
import glob
import json
import os


def rows_from(dir_, multi_pod=None, fed=None):
    out = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        r = json.load(open(f))
        if multi_pod is not None and r.get("multi_pod", False) != multi_pod:
            continue
        if fed is not None and r.get("fed", False) != fed:
            continue
        out.append(r)
    out.sort(key=lambda r: (r["arch"], r["shape"]))
    return out


def md_table(recs, title):
    lines = [f"### {title}", "",
             "| arch | shape | compute s | memory s | collective s "
             "(raw / bf16-comm) | dominant | useful | HBM GB/dev (fits) |",
             "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        t = r["roofline"]
        m = r["memory"]
        adj = t.get("collective_s_bf16comm")
        coll = (f"{t['collective_s']:.2f} / {adj:.2f}" if adj is not None
                else f"{t['collective_s']:.2f}")
        hbm = (m["argument_bytes"] + m["temp_bytes"]) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']}{' (fed)' if r.get('fed') else ''} "
            f"| {t['compute_s']:.3f} | {t['memory_s']:.2f} | {coll} "
            f"| {t['dominant'].replace('_s', '')} "
            f"| {t['useful_flop_fraction']:.2f} "
            f"| {hbm:.1f} ({'Y' if m['fits_hbm'] else 'n'}) |")
    lines.append("")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun_baseline")
    ap.add_argument("--mp-dir", default="results/dryrun")
    args = ap.parse_args()

    print(md_table(rows_from(args.dir, fed=False),
                   "Single-pod 16x16 baselines (paper-faithful system)"))
    mp = rows_from(args.mp_dir, multi_pod=True, fed=False)
    if mp:
        print(md_table(mp, "Multi-pod 2x16x16 (proves the pod axis shards; "
                           "includes perf iterations 1-2)"))
    fed = rows_from(args.mp_dir, fed=True)
    if fed:
        print(md_table(fed, "Federated round (the paper's technique at pod "
                            "scale)"))


if __name__ == "__main__":
    main()
