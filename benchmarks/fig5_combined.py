"""Paper Fig. 5: dynamic sampling + masking combined — initial rates
{0.5, 1.0} x decay {0.01, 0.1} x {random, selective} @ gamma=0.5, 20 rounds,
LeNet (the paper's 50-round MNIST chart, scaled)."""

from repro.core import MaskingConfig

from benchmarks.common import make_schedule, run_federated


def run():
    rows = []
    for rate in (0.5, 1.0):
        for beta in (0.01, 0.1):
            for mode in ("random", "selective"):
                sched = make_schedule("dynamic", beta, rate)
                r = run_federated(
                    "lenet", sched, MaskingConfig(mode=mode, gamma=0.5),
                    rounds=20)
                rows.append({"figure": "fig5", "init_rate": rate,
                             "beta": beta, "mode": mode, **r})
    return rows
