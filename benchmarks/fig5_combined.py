"""Paper Fig. 5: dynamic sampling + masking combined — initial rates
{0.5, 1.0} x decay {0.01, 0.1} x {random, selective} @ gamma=0.5, 20 rounds,
LeNet (the paper's 50-round MNIST chart, scaled).  Every run is a field
override of the "fig5" strategy preset.

Also hosts the strategy-preset smoke bench for CI:

  PYTHONPATH=src python -m benchmarks.fig5_combined --smoke

runs every registry preset ("dense-baseline", "fig3", "fig4", "fig5",
"fig5-int8") on a small federated problem and writes
``BENCH_strategy.smoke.json`` rows comparing round wall-clock and the
codec's EXACT per-round wire bytes — the bench-smoke CI job exercises the
whole strategy surface (registry -> from_strategy -> codec round-trip ->
byte metering) on every push.
"""

import argparse
import os

from repro.core import strategy
from repro.core.strategy import MaskPolicy

from benchmarks.common import make_schedule, run_strategy

SMOKE_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_strategy.smoke.json")


def run():
    rows = []
    for rate in (0.5, 1.0):
        for beta in (0.01, 0.1):
            for mode in ("random", "selective"):
                policy = (MaskPolicy.random(0.5) if mode == "random"
                          else MaskPolicy.selective(0.5))
                strat = strategy.get(
                    "fig5", masking=policy,
                    sampling=make_schedule("dynamic", beta, rate))
                r = run_strategy("lenet", strat, rounds=20)
                rows.append({"figure": "fig5", "init_rate": rate,
                             "beta": beta, "mode": mode, **r})
    return rows


def run_strategy_smoke(rounds=4):
    """Tiny-scale comparison of every registered preset: steady wall-clock
    per round + exact codec wire bytes per round.  Writes
    BENCH_strategy.smoke.json (CI artifact)."""
    rows = []
    for name in strategy.names():
        strat = strategy.get(name)
        r = run_strategy("lenet", strat, rounds=rounds)
        per_round = r["transport_GB"] * 1e9 / rounds
        rows.append({
            "figure": "strategy_smoke",
            "preset": name,
            "sampling": type(strat.sampling).__name__,
            "masking": strat.masking.mode,
            "codec": r["codec"],
            "rounds": rounds,
            "client_upload_bytes": r["client_upload_bytes"],
            "wire_bytes_per_round": round(per_round),
            "final_loss": r["final_loss"],
            # steady-state execution only — compile is metered separately
            # (RoundRecord.compile_s split, PR 3), so the per-preset
            # comparison is not skewed by first-round AOT compiles.
            "steady_wall_ms_per_round": round(
                1e3 * r["steady_wall_s"] / rounds, 3),
            "compile_s": r["compile_s"],
        })
    from benchmarks.common import write_bench
    write_bench(SMOKE_PATH, "strategy", rows)
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="preset-comparison smoke bench for CI "
                         "(writes BENCH_strategy.smoke.json)")
    args = ap.parse_args()
    if args.smoke:
        print(fmt_rows(run_strategy_smoke()))
    else:
        print(fmt_rows(run()))
