"""Client-state store scaling benchmark (DESIGN.md §11): dense (M, …)
server state vs the retention-window sharded store, at fleet sizes where
the dense footprint stops fitting.

  PYTHONPATH=src python -m benchmarks.client_store            # full sweep
  PYTHONPATH=src python -m benchmarks.client_store --smoke    # CI gate

The full sweep runs M in {1k, 10k, 100k, 1M}.  The dense backend actually
RUNS only while its residual footprint fits ``DENSE_BUDGET`` (past that it
reports the analytic footprint with ``oom_estimated=True`` — allocating
5 GB of residuals to prove a point would kill the runner, which IS the
point).  The sharded backend runs every M through a batch *provider*
callable, so neither the residual stack nor the batch stack ever
materializes at (M, …); its footprint column stays flat in M up to the
O(M) norm/version vectors.

The M = 100k sharded row is the PR's acceptance run: 20 fig5-style rounds
(EF residuals + adaptive importance sampling, dynamic c(t) rescaled so
cohorts are ~256 clients) asserting

  residual_bytes <= (retention / M) * dense_equiv_bytes  + slack
  total store    <= that + O(M) vectors

Writes ``BENCH_store.json`` (or ``BENCH_store.smoke.json``) in the shared
envelope; CI diffs the smoke artifact against
``benchmarks/baselines/BENCH_store.smoke.json`` via ``benchmarks.compare``.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DynamicSampling, FederatedServer, strategy
from repro.core.client_store import DenseStore, ShardedStore
from repro.core.sampling import ImportanceSampler

OUT_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_store.json")
SMOKE_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_store.smoke.json")

# Above this dense per-client footprint (residual row + batch rows — the
# dense engines close over BOTH full (M, …) stacks) we stop pretending:
# report the analytic bytes instead of allocating them.  256 MB leaves
# headroom on a CI runner for params and XLA working copies.
DENSE_BUDGET = 256 * 1024 * 1024

DIM = 64          # model: DIM-dim linear regression -> DIM+1 params
NUM_BATCHES = 2
BATCH = 8
POOL = 512        # distinct client datasets; client i serves pool[i % POOL]


def _problem(seed=0):
    key = jax.random.PRNGKey(seed)
    xs = jax.random.normal(key, (POOL, NUM_BATCHES, BATCH, DIM))
    w_true = jnp.linspace(-1.0, 1.0, DIM)
    ys = jnp.einsum("mnbd,d->mnb", xs, w_true)
    params = {"w": jnp.zeros((DIM,)), "b": jnp.zeros(())}

    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"] + p["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    def provider(ids):
        idx = jnp.asarray(np.asarray(ids) % POOL)
        return {"x": jnp.take(xs, idx, axis=0), "y": jnp.take(ys, idx, axis=0)}

    dense_batches = provider(np.arange(POOL))  # for small dense runs
    return loss_fn, params, provider, dense_batches


def _strategy_for(M: int, cohort_target: int = 256):
    """fig5's operating point (selective masking gamma=0.5, sparse codec,
    EF, importance sampling) with the dynamic schedule rescaled so round-1
    cohorts are ~cohort_target clients regardless of M."""
    rate = min(1.0, cohort_target / M)
    return strategy.get(
        "fig5",
        sampling=DynamicSampling(initial_rate=rate, beta=0.05,
                                 min_clients=min(32, M)),
        sampler=ImportanceSampler(),
        error_feedback=True)


def run_backend(M: int, backend: str, rounds: int, retention: int,
                seed: int = 0):
    """One federated run at fleet size M on the given store backend;
    returns the row dict (footprint + steady wall + transport)."""
    loss_fn, params, provider, dense_batches = _problem(seed)
    strat = _strategy_for(M)

    per_client = sum(leaf.nbytes for leaf in
                     jax.tree_util.tree_leaves(params))
    batch_per_client = sum(
        leaf.nbytes for leaf in jax.tree_util.tree_leaves(
            jax.tree.map(lambda x: x[0], dense_batches)))
    dense_bytes = per_client * M
    if backend == "dense" and (per_client + batch_per_client) * M \
            > DENSE_BUDGET:
        return {
            "figure": "store_scaling", "backend": "dense",
            "num_clients": M, "rounds": 0,
            "oom_estimated": True,
            "residual_bytes": dense_bytes,
            "store_bytes": dense_bytes,
            "dense_equiv_bytes": dense_bytes,
        }

    if backend == "dense":
        store = DenseStore(M, params, track_norms=True)
        batches = jax.tree.map(
            lambda x: jnp.take(x, jnp.arange(M) % POOL, axis=0),
            dense_batches)
    else:
        store = ShardedStore(M, params, retention=retention,
                             track_norms=True)
        batches = provider
    server = FederatedServer.from_strategy(
        strat, loss_fn, params, M, seed=seed, engine="cohort", store=store)
    n_samples = np.full((M,), NUM_BATCHES * BATCH, np.float64)
    t0 = time.time()
    server.run(batches, n_samples, rounds)
    wall = time.time() - t0
    s = server.summary()
    mem = store.memory_bytes()
    row = {
        "figure": "store_scaling", "backend": backend,
        "num_clients": M, "rounds": rounds,
        "oom_estimated": False,
        "final_loss": round(s["final_loss"], 4),
        "transport_bytes": s["transport_bytes"],
        "steady_wall_s": round(s["steady_wall_s"], 4),
        "compile_s": round(s["compile_s"], 2),
        "wall_s": round(wall, 2),
        "residual_bytes": mem["residual_bytes"],
        "store_bytes": mem["residual_bytes"] + mem["vector_bytes"],
        "vector_bytes": mem["vector_bytes"],
        "dense_equiv_bytes": mem["dense_equiv_bytes"],
    }
    if backend == "sharded":
        row["retention"] = retention
        row["evictions"] = mem["evictions"]
        # The PR's acceptance bound: residual backing stays inside the
        # retention window's share of the dense footprint (+1 slot for the
        # zero sentinel); everything else the store holds is O(M) vectors.
        bound = (retention + 1) / M * mem["dense_equiv_bytes"]
        assert mem["residual_bytes"] <= bound + per_client, (
            f"sharded residual backing {mem['residual_bytes']} exceeds the "
            f"retention bound {bound:.0f} at M={M}")
    return row


def run(smoke: bool = False):
    retention = 1024
    if smoke:
        cases = [(100_000, "sharded", 6)]
    else:
        cases = []
        for M in (1_000, 10_000, 100_000, 1_000_000):
            rounds = 20 if M == 100_000 else 8
            cases.append((M, "dense", rounds))
            cases.append((M, "sharded", rounds))
    rows = []
    for M, backend, rounds in cases:
        rows.append(run_backend(M, backend, rounds,
                                retention=min(retention, M)))
    return rows


def main():
    from benchmarks.common import fmt_rows, write_bench
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="M=100k sharded CI gate "
                         "(writes BENCH_store.smoke.json)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    write_bench(SMOKE_PATH if args.smoke else OUT_PATH, "store", rows)
    print(fmt_rows(rows))


if __name__ == "__main__":
    main()
