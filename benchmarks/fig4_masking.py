"""Paper Fig. 4: random vs selective masking, masking rate (fraction KEPT)
0.1..0.9, static sampling, 10 rounds, LeNet.

Every run is the "fig4" strategy preset with the mask policy overridden —
``strategy.get`` re-derives the sparse COO codec per gamma, so transport
columns are exact wire bytes."""

from repro.core import strategy
from repro.core.strategy import MaskPolicy

from benchmarks.common import run_strategy


def run():
    rows = []
    for gamma in (0.1, 0.3, 0.5, 0.7, 0.9):
        for mode in ("random", "selective"):
            policy = (MaskPolicy.random(gamma) if mode == "random"
                      else MaskPolicy.selective(gamma))
            r = run_strategy("lenet", strategy.get("fig4", masking=policy),
                             rounds=10)
            rows.append({"figure": "fig4", "mode": mode, "gamma": gamma, **r})
    return rows
