"""Paper Fig. 4: random vs selective masking, masking rate (fraction KEPT)
0.1..0.9, static sampling, 10 rounds, LeNet."""

from repro.core import MaskingConfig

from benchmarks.common import make_schedule, run_federated


def run():
    rows = []
    sched = make_schedule("static", rate=1.0)
    for gamma in (0.1, 0.3, 0.5, 0.7, 0.9):
        for mode in ("random", "selective"):
            r = run_federated("lenet", sched,
                              MaskingConfig(mode=mode, gamma=gamma),
                              rounds=10)
            rows.append({"figure": "fig4", "mode": mode, "gamma": gamma, **r})
    return rows
