"""Run every benchmark (one module per paper figure + kernels + roofline).

  PYTHONPATH=src python -m benchmarks.run [--only fig3,fig4,...]

Prints one CSV block per figure and writes results/benchmarks.json.
"""

import argparse
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from benchmarks import (fig3_sampling, fig4_masking, fig5_combined,
                            fig67_vgg, fig89_lm, hetero_sampling,
                            kernels_bench, noniid, roofline)
    from benchmarks.common import fmt_rows

    modules = {
        "fig3": fig3_sampling, "fig4": fig4_masking, "fig5": fig5_combined,
        "fig67": fig67_vgg, "fig89": fig89_lm, "kernels": kernels_bench,
        "noniid": noniid, "hetero": hetero_sampling, "roofline": roofline,
    }
    only = set(args.only.split(",")) if args.only else None

    all_rows = []
    for name, mod in modules.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"== {name}: FAILED: {e!r}")
            continue
        print(f"== {name} ({time.time() - t0:.0f}s)")
        print(fmt_rows(rows))
        print()
        all_rows.extend(rows)

    os.makedirs("results", exist_ok=True)
    with open("results/benchmarks.json", "w") as f:
        json.dump(all_rows, f, indent=1)
    print(f"wrote results/benchmarks.json ({len(all_rows)} rows)")


if __name__ == "__main__":
    main()
