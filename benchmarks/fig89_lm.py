"""Paper Figs. 8-9: GRU language model (tied embeddings) on the WikiText-2
stand-in.

Fig. 8: static vs dynamic sampling under masking (perplexity, lower=better).
Fig. 9: random vs selective masking across masking rates."""

from repro.core import MaskingConfig

from benchmarks.common import make_schedule, run_federated


def run():
    rows = []
    for beta in (0.0, 0.1, 0.5):                        # fig 8 (0 = static)
        sched = make_schedule("dynamic" if beta else "static", beta)
        for gamma in (0.3, 0.7):
            r = run_federated("gru", sched,
                              MaskingConfig(mode="selective", gamma=gamma),
                              rounds=12, lr=0.25)
            rows.append({"figure": "fig8",
                         "sampling": f"beta{beta}" if beta else "static",
                         "gamma": gamma, **r})

    sched = make_schedule("static", rate=1.0)           # fig 9
    for gamma in (0.1, 0.3, 0.5, 0.9):
        for mode in ("random", "selective"):
            r = run_federated("gru", sched,
                              MaskingConfig(mode=mode, gamma=gamma),
                              rounds=12, lr=0.25)
            rows.append({"figure": "fig9", "mode": mode, "gamma": gamma, **r})
    return rows
