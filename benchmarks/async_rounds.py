"""Sync barrier vs async buffered aggregation on faulty fleets (DESIGN.md §8).

Runs the SAME strategy preset — sampling schedule, masking, codec, fleet —
under both execution engines, so the curves isolate the execution
semantics: the sync cohort engine pays the straggler barrier every round,
the async engine (``repro.core.async_engine``) applies buffered flushes as
uploads arrive, under deadlines + retry/backoff + quarantine:

  PYTHONPATH=src python -m benchmarks.async_rounds            # full
  PYTHONPATH=src python -m benchmarks.async_rounds --smoke    # CI chaos

Writes ``BENCH_async.json`` (or ``BENCH_async.smoke.json``): one row per
(fleet preset, engine) with the per-round loss curve against BOTH cost
axes — cumulative simulated wall-clock and cumulative wire bytes — plus
the async engine's fault ledger (timeouts, retries, quarantined, flushes,
mean staleness).  The smoke variant injects NaN uploads
(``corrupt_rate``) so CI exercises the quarantine gate end to end and
fails if a poisoned upload ever reaches the global model.
"""

import argparse
import dataclasses
import os

import jax
import numpy as np

from repro.core import FederatedServer, strategy
from repro.models import (classifier_accuracy, classifier_loss, init_lenet,
                          lenet_forward)

from benchmarks.common import IMG_SIZE, NUM_CLIENTS, mnist_like

OUT_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_async.json")
SMOKE_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_async.smoke.json")

FLEETS = ("async-mobile", "async-flaky")


def run_engine(preset: str, engine: str, rounds: int, seed: int = 0,
               corrupt_rate: float = 0.0):
    """One federated run of ``preset`` under ``engine``; returns the
    loss-vs-cost curves plus (async only) the fault ledger."""
    batches, n, eval_data = mnist_like(seed)
    params = init_lenet(jax.random.PRNGKey(seed), IMG_SIZE, 1)
    loss_fn = classifier_loss(lenet_forward)
    eval_fn = jax.jit(classifier_accuracy(lenet_forward))

    strat = strategy.get(preset, learning_rate=0.1)
    if corrupt_rate > 0.0:
        strat = strat.replace(async_cfg=dataclasses.replace(
            strat.async_cfg, corrupt_rate=corrupt_rate))
    server = FederatedServer.from_strategy(
        strat, loss_fn, params, NUM_CLIENTS, eval_fn=eval_fn, seed=seed,
        engine=engine)
    server.run(batches, n, rounds, eval_every=rounds, eval_data=eval_data)

    if corrupt_rate > 0.0 and engine == "async":
        # the chaos check CI rides on: poisoned uploads must never reach Θ
        for leaf in jax.tree_util.tree_leaves(server.params):
            assert np.isfinite(np.asarray(leaf)).all(), \
                "quarantine gate leaked a non-finite upload into params"

    loss = [r.mean_loss for r in server.history]
    cum_bytes = np.cumsum([r.transport_bytes for r in server.history])
    cum_sim_s = np.cumsum([r.sim_round_s for r in server.history])
    s = server.summary()
    row = {
        "fleet": preset,
        "engine": engine,
        "rounds": rounds,
        "loss_curve": [round(v, 4) for v in loss],
        "cum_bytes_curve": [int(v) for v in cum_bytes],
        "cum_sim_s_curve": [round(float(v), 2) for v in cum_sim_s],
        "final_loss": round(s["final_loss"], 4),
        "final_eval": round(s["final_eval"], 4),
        "transport_bytes": s["transport_bytes"],
        "sim_total_s": round(s["sim_total_s"], 2),
        "dropped_uploads": s["dropped_uploads"],
        "steady_wall_s": round(s["steady_wall_s"], 4),
    }
    if engine == "async":
        row.update(
            timeouts=s["timeouts"], retries=s["retries"],
            quarantined=s["quarantined"], flushes=s["flushes"],
            mean_staleness=round(s["mean_staleness"], 3),
        )
    return row


def run(rounds: int = 24, seed: int = 0, corrupt_rate: float = 0.0):
    """Both fleets x both engines, plus per-fleet headline deltas: how much
    simulated wall-clock and how many wire bytes the async engine spends
    to reach the sync run's final loss (None if it never does)."""
    rows = []
    for preset in FLEETS:
        pair = {}
        for engine in ("cohort", "async"):
            row = run_engine(preset, engine, rounds, seed=seed,
                             corrupt_rate=corrupt_rate)
            pair[engine] = row
            rows.append(row)
        target = pair["cohort"]["final_loss"]
        b, t = _cost_to_target(pair["async"], target)
        pair["async"]["target_loss"] = target
        pair["async"]["bytes_to_sync_loss"] = b
        pair["async"]["sim_s_to_sync_loss"] = t
    return rows


def _cost_to_target(row, target_loss):
    """First-round cumulative (bytes, sim seconds) at which the run's loss
    reaches ``target_loss``; empty rounds report NaN loss and are
    skipped."""
    for loss, b, t in zip(row["loss_curve"], row["cum_bytes_curve"],
                          row["cum_sim_s_curve"]):
        if np.isfinite(loss) and loss <= target_loss:
            return int(b), float(t)
    return None, None


def main():
    """CLI entry: full bench, or --smoke chaos rows for the CI artifact
    (short run WITH fault injection, so the quarantine path executes)."""
    from benchmarks.common import fmt_rows
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="3-round CI chaos smoke with corrupt_rate=0.3 "
                         "(writes BENCH_async.smoke.json)")
    args = ap.parse_args()
    rounds = 3 if args.smoke else 24
    corrupt = 0.3 if args.smoke else 0.0
    rows = run(rounds=rounds, corrupt_rate=corrupt)
    path = SMOKE_PATH if args.smoke else OUT_PATH
    from benchmarks.common import write_bench
    write_bench(path, "async", rows)
    brief = [{k: v for k, v in r.items()
              if not k.endswith("_curve")} for r in rows]
    print(fmt_rows(brief))


if __name__ == "__main__":
    main()
