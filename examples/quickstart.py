"""Quickstart: the paper's two techniques through the strategy API.

A federated scenario is ONE object — ``strategy.get(name)`` returns a
``FedStrategy`` composing the sampling schedule, mask policy, wire codec
and aggregation rule; ``FederatedServer.from_strategy`` runs it.  This
trains LeNet on a synthetic MNIST stand-in under four presets and prints
the accuracy-vs-transport trade-off the paper is about, with transport as
the codec's EXACT wire bytes.

  PYTHONPATH=src python examples/quickstart.py

The full public surface (every entry point with a runnable snippet) is
documented in docs/api.md — executed by CI, so it cannot rot.
"""

import jax

from repro.core import FederatedServer, strategy
from repro.core.strategy import MaskPolicy
from repro.data import class_gaussian_images, iid_partition_images
from repro.models import (classifier_accuracy, classifier_loss, init_lenet,
                          lenet_forward)

NUM_CLIENTS, ROUNDS, IMG = 8, 12, 12


def main():
    data = class_gaussian_images(num_train=NUM_CLIENTS * 128, num_test=512,
                                 image_size=IMG, noise=0.6, seed=0)
    xs, ys, n = iid_partition_images(data.train_x, data.train_y,
                                     NUM_CLIENTS, 16, seed=0)
    batches = (jax.numpy.asarray(xs), jax.numpy.asarray(ys))
    eval_data = (jax.numpy.asarray(data.test_x),
                 jax.numpy.asarray(data.test_y))
    loss_fn = classifier_loss(lenet_forward)
    eval_fn = jax.jit(classifier_accuracy(lenet_forward))

    # Registry presets + field overrides: the paper's operating point
    # (Fig. 5) is dynamic sampling AND selective top-k masking combined.
    settings = {
        "dense-baseline (static)": strategy.get("dense-baseline"),
        "fig3: dynamic sampling": strategy.get("fig3"),
        "fig4: selective g=0.1": strategy.get("fig4"),
        "fig5 @ g=0.1 (paper)": strategy.get(
            "fig5", masking=MaskPolicy.selective(0.1)),
        "fig5-int8 wire": strategy.get("fig5-int8"),
    }

    print(f"{'strategy':26s} {'accuracy':>9s} {'transport':>10s} "
          f"{'wire MB':>8s}  codec")
    for name, strat in settings.items():
        params = init_lenet(jax.random.PRNGKey(0), IMG)
        server = FederatedServer.from_strategy(
            strat, loss_fn, params, NUM_CLIENTS, eval_fn=eval_fn)
        server.run(batches, n, ROUNDS, eval_every=ROUNDS,
                   eval_data=eval_data)
        s = server.summary()
        print(f"{name:26s} {s['final_eval']:9.3f} "
              f"{s['transport_units']:10.2f} "
              f"{s['transport_bytes'] / 1e6:8.2f}  {s['codec']}")


if __name__ == "__main__":
    main()
