"""Quickstart: the paper's two techniques in ~60 lines.

Trains LeNet on a synthetic MNIST stand-in under four federated settings
(static/dynamic sampling x dense/selective-masked uploads) and prints the
accuracy-vs-transport trade-off the paper is about.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.core import (ClientConfig, DynamicSampling, FederatedConfig,
                        FederatedServer, MaskingConfig, StaticSampling)
from repro.data import class_gaussian_images, iid_partition_images
from repro.models import (classifier_accuracy, classifier_loss, init_lenet,
                          lenet_forward)

NUM_CLIENTS, ROUNDS, IMG = 8, 12, 12


def main():
    data = class_gaussian_images(num_train=NUM_CLIENTS * 128, num_test=512,
                                 image_size=IMG, noise=0.6, seed=0)
    xs, ys, n = iid_partition_images(data.train_x, data.train_y,
                                     NUM_CLIENTS, 16, seed=0)
    batches = (jax.numpy.asarray(xs), jax.numpy.asarray(ys))
    eval_data = (jax.numpy.asarray(data.test_x),
                 jax.numpy.asarray(data.test_y))
    loss_fn = classifier_loss(lenet_forward)
    eval_fn = jax.jit(classifier_accuracy(lenet_forward))

    settings = {
        "static + dense": (StaticSampling(initial_rate=1.0),
                           MaskingConfig(mode="none")),
        "dynamic(b=0.1) + dense": (DynamicSampling(initial_rate=1.0, beta=0.1),
                                   MaskingConfig(mode="none")),
        "static + selective(g=0.1)": (StaticSampling(initial_rate=1.0),
                                      MaskingConfig(mode="selective",
                                                    gamma=0.1)),
        "dynamic + selective (paper)": (
            DynamicSampling(initial_rate=1.0, beta=0.1),
            MaskingConfig(mode="selective", gamma=0.1)),
    }

    print(f"{'setting':32s} {'accuracy':>9s} {'transport':>10s} (full-model units)")
    for name, (schedule, masking) in settings.items():
        params = init_lenet(jax.random.PRNGKey(0), IMG)
        cfg = FederatedConfig(
            num_clients=NUM_CLIENTS,
            client=ClientConfig(local_epochs=1, learning_rate=0.05,
                                masking=masking))
        server = FederatedServer(loss_fn, schedule, cfg, params,
                                 eval_fn=eval_fn)
        server.run(batches, n, ROUNDS, eval_every=ROUNDS,
                   eval_data=eval_data)
        s = server.summary()
        print(f"{name:32s} {s['final_eval']:9.3f} "
              f"{s['transport_units']:10.2f}")


if __name__ == "__main__":
    main()
