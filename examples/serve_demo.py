"""Batched serving demo: prefill + decode with KV caches on a reduced arch —
exercises the same decode_step the decode_32k / long_500k dry-runs lower.

  PYTHONPATH=src python examples/serve_demo.py --arch gemma2-2b --gen 24
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.launch.serve import generate
from repro.models import transformer as tr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    key = jax.random.PRNGKey(0)
    params = tr.init_params(key, cfg, cfg.param_dtype_serve)
    audio = cfg.modality == "audio_stub" and cfg.num_codebooks > 1
    shape = (args.batch, cfg.num_codebooks, args.prompt_len) if audio \
        else (args.batch, args.prompt_len)
    prompts = jax.random.randint(key, shape, 0, cfg.vocab_size)

    t0 = time.time()
    toks = generate(cfg, params, prompts, args.gen,
                    args.prompt_len + args.gen + 1, args.temperature)
    dt = time.time() - t0
    print(f"arch={cfg.name}: generated {tuple(toks.shape)} tokens "
          f"in {dt:.2f}s ({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", np.asarray(toks)[0][..., :10])


if __name__ == "__main__":
    main()
