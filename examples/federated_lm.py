"""End-to-end driver: federated training of a (reduced) qwen2-family LM with
dynamic sampling + selective masking — the paper's technique applied to a
modern transformer through the pod-scale round (launch/fedtrain), a few
hundred steps of client SGD in total.

  PYTHONPATH=src python examples/federated_lm.py [--rounds 20] [--clients 8]

This is the "train ~100M-class model for a few hundred steps" example: the
default reduced qwen2-1.5b (2 layers, d=256) over 8 clients x 25 rounds x
2 local steps = 400 client SGD steps; pass --full-layers to scale depth up.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import strategy
from repro.core.sampling import DynamicSampling, participation_mask
from repro.core.strategy import MaskPolicy
from repro.data import markov_text
from repro.launch.fedtrain import FedPodConfig, make_fed_round
from repro.models import transformer as tr
from repro.models.transformer import cross_entropy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=25)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--gamma", type=float, default=0.2)
    ap.add_argument("--beta", type=float, default=0.05)
    ap.add_argument("--lr", type=float, default=0.3)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    C, S = args.clients, args.local_steps
    # The pod round collapses to one strategy record: the "fig5" preset
    # (dynamic sampling + selective masking + sparse COO wire) specialized
    # to the CLI's beta/gamma/lr.
    strat = strategy.get(
        "fig5",
        sampling=DynamicSampling(initial_rate=1.0, beta=args.beta),
        masking=MaskPolicy.selective(args.gamma),
        learning_rate=args.lr)
    schedule = strat.sampling
    fed_cfg = FedPodConfig.from_strategy(strat, num_clients=C, local_steps=S)
    fed_round = jax.jit(make_fed_round(cfg, fed_cfg))

    data = markov_text(num_train=C * args.rounds * S * args.batch * args.seq
                       + args.seq, vocab_size=min(cfg.vocab_size, 512),
                       seed=0)
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    n_samples = jnp.ones((C,), jnp.float32)
    key = jax.random.PRNGKey(1)

    # eval batch
    ev = data.test_tokens[: 16 * args.seq + 1]
    ev_x = jnp.asarray(ev[:-1].reshape(16, args.seq)) % cfg.vocab_size
    ev_y = jnp.asarray(ev[1:].reshape(16, args.seq)) % cfg.vocab_size

    @jax.jit
    def eval_ppl(p):
        logits, _ = tr.forward(p, cfg, ev_x)
        return jnp.exp(cross_entropy(logits, ev_y))

    toks = data.train_tokens
    per_round = C * S * args.batch * args.seq
    total_transport = 0.0
    for t in range(1, args.rounds + 1):
        key, k_part, k_mask = jax.random.split(key, 3)
        part = participation_mask(k_part, schedule, t, C)
        w = toks[(t - 1) * per_round: t * per_round + 1]
        x = (w[:-1].reshape(C, S, args.batch, args.seq) % cfg.vocab_size)
        y = (w[1:].reshape(C, S, args.batch, args.seq) % cfg.vocab_size)
        batches = {"tokens": jnp.asarray(x), "labels": jnp.asarray(y)}
        t0 = time.time()
        params, m = fed_round(params, batches, n_samples, part, k_mask)
        total_transport += float(m["num_sampled"]) * fed_cfg.gamma
        if t % 5 == 0 or t == 1:
            print(f"round {t:3d}: sampled={int(m['num_sampled'])}/{C} "
                  f"loss={float(m['mean_loss']):.3f} "
                  f"eval_ppl={float(eval_ppl(params)):.1f} "
                  f"transport={total_transport:.1f}u "
                  f"dt={time.time() - t0:.2f}s", flush=True)
    print(f"done: total transport {total_transport:.1f} full-model units "
          f"(dense-static would be {args.rounds * C * 1.0:.0f})")


if __name__ == "__main__":
    main()
