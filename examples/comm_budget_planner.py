"""Communication-budget planner (paper Eq. 6 in reverse): given a transport
budget in full-model-upload units, compare how many federated rounds each
(sampling schedule x masking rate) affords and what that implies at real
model sizes.

  PYTHONPATH=src python examples/comm_budget_planner.py --budget 100
"""

import argparse


from repro.configs import ARCH_IDS, get_arch
from repro.core.compression import pytree_payload_bytes
from repro.core.sampling import (DynamicSampling, StaticSampling,
                                 cumulative_transport, rounds_for_budget)
from repro.launch import steps as steps_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=float, default=100.0,
                    help="transport budget in full-model-upload units")
    ap.add_argument("--clients", type=int, default=16)
    args = ap.parse_args()

    print(f"budget = {args.budget} full-model uploads, "
          f"M = {args.clients} clients\n")
    print(f"{'schedule':22s} {'gamma':>6s} {'rounds':>7s} {'cost/round':>11s}")
    for name, sched in [
            ("static C=1.0", StaticSampling(initial_rate=1.0)),
            ("static C=0.5", StaticSampling(initial_rate=0.5)),
            ("dynamic b=0.01", DynamicSampling(initial_rate=1.0, beta=0.01)),
            ("dynamic b=0.1", DynamicSampling(initial_rate=1.0, beta=0.1))]:
        for gamma in (1.0, 0.1):
            r = rounds_for_budget(sched, gamma, args.clients, args.budget)
            per = cumulative_transport(sched, gamma, max(r, 1),
                                       args.clients) / max(r, 1)
            print(f"{name:22s} {gamma:6.2f} {r:7d} {per:11.2f}")

    print("\nwhat one full-model upload means per assigned arch "
          "(fp32 dense vs gamma=0.1 selective+bitmap):")
    for a in ARCH_IDS:
        cfg = get_arch(a)
        specs = steps_lib.params_specs(cfg)
        stats = pytree_payload_bytes(specs, gamma=0.1)
        print(f"  {a:28s} dense {stats.dense_bytes / 1e9:8.2f} GB   "
              f"masked {stats.sparse_bytes / 1e9:8.2f} GB "
              f"({stats.ratio:.2%})")


if __name__ == "__main__":
    main()
