"""Pallas kernel validation (interpret=True on CPU) vs the pure-jnp oracle,
sweeping shapes and dtypes per the spec."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import topk_mask as tk


SHAPES = [(256,), (1000,), (128, 128), (300, 77), (8, 8, 65)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(shape, dtype, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_exponent_histogram_kernel(shape, dtype):
    x = _rand(shape, dtype, seed=1)
    x2d = ops._pad_to_blocks(x.reshape(-1).astype(jnp.float32))
    got = tk.exponent_histogram(x2d, interpret=True)
    want = ref.exponent_histogram_ref(x.astype(jnp.float32))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("tau", [0.0, 0.5, 1.5])
def test_count_kernel(shape, tau):
    x = _rand(shape, jnp.float32, seed=2)
    x2d = ops._pad_to_blocks(x.reshape(-1))
    got = tk.count_ge(x2d, jnp.asarray(tau + 1e-9), interpret=True)
    want = ref.count_ge_ref(x, tau + 1e-9)
    # padding zeros count when tau == 0; use tau > 0 effectively
    assert int(got) == int(want)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_apply_threshold_kernel(shape, dtype):
    x = _rand(shape, dtype, seed=3)
    flat = x.reshape(-1).astype(jnp.float32)
    x2d = ops._pad_to_blocks(flat)
    tau = jnp.asarray(0.7)
    got = tk.apply_threshold(x2d, tau, interpret=True)
    want = ref.threshold_mask_ref(x2d, 0.7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-7)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("gamma", [0.05, 0.2, 0.5, 0.9])
@pytest.mark.parametrize("dtype", DTYPES)
def test_topk_mask_kernel_vs_oracle(shape, gamma, dtype):
    """End-to-end kernel pipeline vs exact-sort oracle.

    For continuous random input the threshold pipeline must (a) keep <= k
    entries, (b) keep only entries at least as large as everything it
    drops, (c) agree with the oracle on clearly-separated magnitudes."""
    x = _rand(shape, dtype, seed=4)
    out = ops.topk_mask(x, gamma, interpret=True)
    assert out.shape == x.shape and out.dtype == x.dtype

    n = x.size
    k = max(1, round(gamma * n))
    kept_mask = np.asarray(out != 0).reshape(-1)
    mags = np.abs(np.asarray(x, np.float32)).reshape(-1)
    assert kept_mask.sum() <= k
    assert kept_mask.sum() >= max(1, int(0.9 * k) - 2)
    if kept_mask.any() and (~kept_mask).any():
        assert mags[kept_mask].min() >= mags[~kept_mask].max() - 1e-6


def test_topk_mask_kernel_exact_against_sort_oracle():
    """With well-separated magnitudes the kernel output must match the
    oracle exactly."""
    base = jnp.arange(1, 513, dtype=jnp.float32)          # distinct magnitudes
    sign = jnp.where(jnp.arange(512) % 2 == 0, 1.0, -1.0)
    x = (base * sign)[jax.random.permutation(jax.random.PRNGKey(0), 512)]
    got = ops.topk_mask(x, 0.25, interpret=True)
    want = ref.topk_mask_ref(x, 0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_topk_mask_kernel_preserves_values():
    x = _rand((2048,), jnp.float32, seed=5)
    out = ops.topk_mask(x, 0.3, interpret=True)
    nz = np.asarray(out != 0)
    np.testing.assert_allclose(np.asarray(out)[nz], np.asarray(x)[nz])


def test_masked_count_kernel():
    x = _rand((4096,), jnp.float32, seed=6)
    got = ops.masked_count(x, 0.5, interpret=True)
    assert int(got) == int(jnp.sum(jnp.abs(x) >= 0.5))


# ---------------------------------------------------------------------------
# Segmented whole-pytree kernels (kernels/segmented.py + kernels/packing.py)
# ---------------------------------------------------------------------------
from repro.kernels import packing as pk
from repro.kernels import segmented as seg

SEG_SHAPES = [(300, 77), (128, 128), (8, 8, 65), (70000,), (257,)]


def _seg_leaves(dtype=jnp.float32):
    return [_rand(s, dtype, seed=10 + i) for i, s in enumerate(SEG_SHAPES)]


def _packed(leaves, slab_rows=None):
    x2d, spec = pk.pack_leaves(leaves)
    x2d, seg_ids = seg.pad_rows(x2d, jnp.asarray(spec.seg_ids()),
                                interpret=True, slab_rows=slab_rows)
    return x2d, seg_ids, spec


def test_packing_roundtrip():
    leaves = _seg_leaves(jnp.float32) + [_rand((64, 64), jnp.bfloat16, 99)]
    x2d, spec = pk.pack_leaves(leaves)
    assert x2d.shape == (spec.total_rows, pk.SEG_LANE)
    assert spec.seg_ids().shape == (spec.total_rows, 1)
    back = pk.unpack_leaves(x2d, spec)
    for a, b in zip(leaves, back):
        assert b.shape == a.shape and b.dtype == a.dtype
        np.testing.assert_allclose(np.asarray(b, np.float32),
                                   np.asarray(a, np.float32))


# Exercise both the single-slab interpret default and a small slab that
# forces multi-step grids (the compiled TPU shape).
SLABS = [None, 128]


@pytest.mark.parametrize("slab", SLABS)
def test_segmented_histogram_matches_per_leaf_ref(slab):
    leaves = _seg_leaves()
    x2d, seg_ids, spec = _packed(leaves, slab)
    hist = seg.segmented_histogram(x2d, seg_ids, spec.num_segments,
                                   interpret=True, slab_rows=slab)
    assert hist.shape == (len(leaves), seg.SEG_NBINS)
    for s, leaf in enumerate(leaves):
        bins = ref.group_histogram_ref(leaf, seg.OCTAVES_PER_BIN)
        want = jnp.cumsum(bins[::-1])[::-1]          # suffix form
        np.testing.assert_array_equal(np.asarray(hist[s]), np.asarray(want))


@pytest.mark.parametrize("slab", SLABS)
def test_segmented_count_matches_ref_per_candidate(slab):
    leaves = _seg_leaves()
    x2d, seg_ids, spec = _packed(leaves, slab)
    taus = jnp.stack([jnp.asarray([0.25, 0.5, 1.0, 2.0]) * (1 + 0.1 * s)
                      for s in range(len(leaves))])
    got = seg.segmented_count(x2d, seg_ids, taus, interpret=True,
                              slab_rows=slab)
    for s, leaf in enumerate(leaves):
        for c in range(taus.shape[1]):
            assert int(got[s, c]) == int(ref.count_ge_ref(leaf, taus[s, c]))


@pytest.mark.parametrize("slab", SLABS)
def test_segmented_apply_matches_ref_and_counts(slab):
    leaves = _seg_leaves()
    x2d, seg_ids, spec = _packed(leaves, slab)
    taus = jnp.asarray([0.3, 0.7, 1.1, 0.5, 0.9])
    out2d, kept = seg.segmented_apply(x2d, seg_ids, taus, interpret=True,
                                      slab_rows=slab)
    back = pk.unpack_leaves(out2d[:spec.rows], spec)
    for s, leaf in enumerate(leaves):
        want = ref.threshold_mask_ref(leaf, float(taus[s]))
        np.testing.assert_allclose(np.asarray(back[s]), np.asarray(want),
                                   atol=1e-7)
        assert int(kept[s, 0]) == int(ref.count_ge_ref(leaf, float(taus[s])))


def test_select_thresholds_brackets_every_segment():
    leaves = _seg_leaves()
    x2d, seg_ids, spec = _packed(leaves)
    hist = seg.segmented_histogram(x2d, seg_ids,
                                   spec.num_segments, interpret=True)
    k = jnp.asarray([max(1, round(0.1 * leaf.size)) for leaf in leaves],
                    jnp.int32)
    lo, hi, cnt_lo, cnt_hi = seg.select_thresholds(hist, k)
    for s, leaf in enumerate(leaves):
        mag = jnp.sort(jnp.abs(leaf.reshape(-1)))
        kth = float(mag[leaf.size - int(k[s])])
        assert float(lo[s]) <= kth < float(hi[s]) * (1 + 1e-6)
        # the threaded counts ARE the exact counts at the bracket ends
        assert int(cnt_lo[s]) == int(ref.count_ge_ref(leaf, float(lo[s])))
        assert int(cnt_hi[s]) == int(ref.count_ge_ref(leaf, float(hi[s])))


def test_topk_mask_pytree_sweep_budget():
    """The segmented path must cost a leaf-count-independent <= 4 sweeps."""
    assert ops.pytree_sweep_count(1, segmented=True) <= 4
    assert ops.pytree_sweep_count(100, segmented=True) <= 4
    assert ops.pytree_sweep_count(100, segmented=False) == 100 * 10


def test_select_threshold_counts_per_leaf():
    x = _rand((8192,), jnp.float32, seed=7)
    x2d = ops._pad_to_blocks(jnp.abs(x.reshape(-1)))
    hist = tk.exponent_histogram(x2d, interpret=True)
    for k in [1, 64, 1024]:
        lo, hi, cnt_lo, cnt_hi = tk.select_threshold_counts(
            hist, jnp.asarray(k))
        assert int(cnt_lo) == int(jnp.sum(jnp.abs(x) >= lo))
        assert int(cnt_hi) == int(jnp.sum(jnp.abs(x) >= hi))


def test_histogram_threshold_octave_bounds():
    """select_threshold returns an octave [lo, hi) bracketing the k-th
    largest magnitude."""
    x = _rand((8192,), jnp.float32, seed=7)
    x2d = ops._pad_to_blocks(jnp.abs(x.reshape(-1)))
    hist = tk.exponent_histogram(x2d, interpret=True)
    for k in [1, 64, 1024]:
        lo, hi = tk.select_threshold(hist, jnp.asarray(k))
        kth = jnp.sort(jnp.abs(x))[x.size - k]
        assert float(lo) <= float(kth) < float(hi) * (1 + 1e-6)


# ---------------------------------------------------------------------------
# Pallas SSM-scan kernel (kernels/ssm_scan.py) — §Perf hillclimb 2 outcome
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(1, 8, 4, 2), (2, 37, 19, 4),
                                   (2, 300, 33, 16), (1, 256, 256, 8)])
def test_ssm_scan_kernel_vs_oracle(shape):
    B, T, d, N = shape
    key = jax.random.PRNGKey(0)
    a = jax.nn.sigmoid(jax.random.normal(key, (B, T, d, N)))
    bx = jax.random.normal(jax.random.fold_in(key, 1), (B, T, d, N))
    c = jax.random.normal(jax.random.fold_in(key, 2), (B, T, N))
    h0 = jax.random.normal(jax.random.fold_in(key, 3), (B, d, N))
    y, hT = ops.ssm_scan(a, bx, c, h0, interpret=True)
    yr, hTr = ref.ssm_scan_ref(a.transpose(0, 1, 3, 2),
                               bx.transpose(0, 1, 3, 2), c,
                               h0.transpose(0, 2, 1))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hT),
                               np.asarray(hTr.transpose(0, 2, 1)), atol=1e-5)


def test_ssm_scan_kernel_matches_model_ssm():
    """End-to-end: the kernel computes the same recurrence the hymba model
    uses (models/ssm.ssm_forward, pre-gate/skip)."""
    from repro.models import ssm as ssm_lib
    key = jax.random.PRNGKey(5)
    d_model, d_inner, N, B, T = 16, 16, 4, 2, 64
    params = ssm_lib.init_ssm_params(key, d_model, d_inner, N, jnp.float32)
    xz = jax.random.normal(jax.random.fold_in(key, 1), (B, T, 2 * d_inner))
    h0 = jnp.zeros((B, d_inner, N))

    x, z, a, bx, Cm = ssm_lib._selective_terms(params, xz)
    y_kernel, hT_kernel = ops.ssm_scan(a, bx, Cm, h0, interpret=True)

    # reference path: full model forward minus gate/skip
    _, hT_model = ssm_lib.ssm_forward(params, xz, h0)
    np.testing.assert_allclose(np.asarray(hT_kernel), np.asarray(hT_model),
                               atol=1e-4, rtol=1e-4)
    # and the y-term before gating: recompute via step loop
    h = h0
    ys = []
    for t in range(T):
        h = a[:, t] * h + bx[:, t]
        ys.append(jnp.einsum("bdn,bn->bd", h, Cm[:, t]))
    np.testing.assert_allclose(np.asarray(y_kernel),
                               np.asarray(jnp.stack(ys, 1)), atol=1e-4)


# ---------------------------------------------------------------------------
# Pallas wkv6 kernel (kernels/wkv6.py)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("T", [8, 64, 100])
def test_wkv6_kernel_vs_chunked_and_naive(T):
    from repro.models import rwkv as rwkv_lib
    key = jax.random.PRNGKey(11)
    B, H, D = 2, 3, 8
    r = jax.random.normal(key, (B, T, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, D))
    logw = -jnp.exp(jax.random.uniform(
        jax.random.fold_in(key, 3), (B, T, H, D), minval=-4.0, maxval=1.0))
    u = 0.1 * jax.random.normal(jax.random.fold_in(key, 4), (H, D))
    s0 = jax.random.normal(jax.random.fold_in(key, 5), (B, H, D, D))

    y_k, s_k = ops.wkv6(r, k, v, logw, u, s0, interpret=True)
    y_m, s_m = rwkv_lib.wkv6_chunked(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_m),
                               atol=2e-3, rtol=2e-3)
