"""The unified cross-engine equivalence matrix (DESIGN.md §3.5/§12).

Every registry preset, normalized to the deterministic ideal fleet, must
produce BIT-identical run state — params, EF residuals, adaptive-sampler
norm EMAs and FedDyn drift — whichever engine executes it (full-population
oracle / cohort / async-degenerate) and whichever store backend holds the
client state (dense / sharded with retention covering the fleet).

This consolidates the per-engine keystones that grew one PR at a time —
cohort == oracle (tests/test_cohort.py), async-degenerate == sync
(tests/test_async.py), dense == sharded (tests/test_client_store.py) —
into ONE (preset × engine × store) matrix anchored at the (full, dense)
oracle, so a new preset or a new engine axis is covered by adding one
parametrize value, not a new ad-hoc test.

Plus the LocalObjective degeneration/conservation properties:

* ``prox(0)`` / ``dyn(0)`` are bit-identical to plain fedavg on every
  engine (the objectives module's static-inactivity contract);
* FedDyn drift obeys the same dropout conservation law as EF residuals
  (test_hetero.py): a dropped client's drift row is untouched.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st
from repro.core import FederatedServer, LocalObjective, strategy
from repro.core.async_engine import AsyncConfig
from repro.core.client_store import ShardedStore
from repro.core.codecs import ChainCodec, FusedSparseCodec, Int8Codec
from repro.core.hetero import HeteroModel
from repro.core.sampling import StaticSampling
from repro.core.strategy import build_round

# D exceeds the presets' masking/codec min_leaf_size (256) so selective
# masking binds and EF residuals / drift rows carry real mass; with a
# smaller leaf every state comparison would be vacuously 0 == 0.
M, NB, B, D = 16, 2, 4, 320
ROUNDS = 3


def _problem(num_clients=M, seed=0):
    key = jax.random.PRNGKey(seed)
    xs = jax.random.normal(key, (num_clients, NB, B, D))
    w_true = jnp.arange(1.0, D + 1.0)
    ys = jnp.einsum("mnbd,d->mnb", xs, w_true)
    params = {"w": jnp.zeros((D,)), "b": jnp.zeros(())}

    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"] + p["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    return loss_fn, params, {"x": xs, "y": ys}, np.full(
        (num_clients,), NB * B, np.float64)


def _template():
    return {"w": jnp.zeros((D,)), "b": jnp.zeros(())}


def _tree_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _tree_close(a, b, **tol):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **tol)


def _lossy_wire(codec):
    """True when the preset's wire loses bits (int8 quantisation).

    Lossless wires (identity / COO / bitmap under the mask contract) decode
    to the EXACT upload bits, so ``u - w == 0`` in every compiled program
    and the cross-engine contract is bitwise.  Lossy wires dequantise
    ``q * scale``, and XLA:CPU — which deletes ``optimization_barrier``
    during optimization — is free to contract/rearrange that chain
    differently per program shape, so the EF wire-loss term can wobble by
    ~1 ulp between the in-program engines and the store-form body.  Those
    presets get a tight tolerance instead (see DESIGN.md §12)."""
    if isinstance(codec, Int8Codec):
        return True
    if isinstance(codec, ChainCodec):
        return any(_lossy_wire(s) for s in codec.stages)
    if isinstance(codec, FusedSparseCodec):
        return codec.quantized
    return False


def _normalize(name):
    """A preset pinned to the deterministic common ground every engine
    shares: ideal fleet (no hetero clock/drops), sync schedule (the async
    axis is added back per-combo as the DEGENERATE AsyncConfig), error
    feedback on so residual state is live, one fixed lr."""
    return strategy.get(name, hetero=None, async_cfg=None,
                        error_feedback=True, learning_rate=0.05)


# (engine, store) combos measured against the (full, dense) anchor.  The
# full oracle engine closes over dense (M, …) state by construction, so
# (full, sharded) is rejected by the server and is not a matrix cell.
COMBOS = [("full", "dense"), ("cohort", "dense"), ("cohort", "sharded"),
          ("async", "dense"), ("async", "sharded")]


def _run_cell(name, engine, store_kind, seed=0):
    loss_fn, params, batches, n = _problem()
    strat = _normalize(name)
    if engine == "async":
        # K = m_t, no deadline, no faults: dispatch + one flush of
        # everyone at staleness zero — the degenerate async round.
        strat = strat.replace(async_cfg=AsyncConfig())
    store = None
    if store_kind == "sharded":
        extra = ({"drift": _template()}
                 if strat.objective.uses_drift else None)
        store = ShardedStore(M, _template(), retention=M,
                             track_norms=strat.sampler.adaptive,
                             extra_trees=extra)
    server = FederatedServer.from_strategy(
        strat, loss_fn, params, M, seed=seed, engine=engine, store=store)
    server.run(batches, n, ROUNDS)
    if store is not None:
        assert store.evictions == 0
    return server


@functools.lru_cache(maxsize=None)
def _anchor(name):
    return _run_cell(name, "full", "dense")


@pytest.mark.parametrize("engine,store_kind", COMBOS[1:],
                         ids=[f"{e}-{s}" for e, s in COMBOS[1:]])
@pytest.mark.parametrize("preset", strategy.names())
def test_matrix_bit_exact_vs_full_dense_oracle(preset, engine, store_kind):
    ref = _anchor(preset)
    got = _run_cell(preset, engine, store_kind)
    strat = _normalize(preset)
    if _lossy_wire(strat.codec):
        eq = functools.partial(_tree_close, rtol=1e-4, atol=1e-4)
    else:
        eq = _tree_equal
    eq(ref.params, got.params)
    eq(ref._residuals, got._residuals)
    if strat.sampler.adaptive:
        eq(np.asarray(ref._norms), np.asarray(got._norms))
    if strat.objective.uses_drift:
        eq(ref.store.dense_view("drift"),
           got.store.dense_view("drift"))
    ref_loss = [r.mean_loss for r in ref.history]
    got_loss = [r.mean_loss for r in got.history]
    if engine == "async" or _lossy_wire(strat.codec):
        # async meters loss host-side per flush; lossy wires carry the
        # per-program dequantisation wobble: close, not bitwise
        np.testing.assert_allclose(got_loss, ref_loss, rtol=1e-5,
                                   atol=1e-7, equal_nan=True)
    else:
        np.testing.assert_array_equal(got_loss, ref_loss)


# ---------------------------------------------------------------------------
# degeneration: mu = 0 / alpha = 0 ARE plain fedavg, on every engine
# ---------------------------------------------------------------------------
@settings(deadline=None, max_examples=6)
@given(st.sampled_from(["prox", "dyn"]),
       st.sampled_from([e for e, _ in COMBOS]))
def test_zero_strength_objective_is_bitwise_fedavg(kind, engine):
    """``prox(0.0)`` / ``dyn(0.0)`` must run the IDENTICAL program as
    ``none``: localize() returns the caller's loss object and no drift
    state exists, so every engine reproduces plain fedavg to the bit."""
    zero = (LocalObjective.prox(0.0) if kind == "prox"
            else LocalObjective.dyn(0.0))
    assert not zero.active and not zero.uses_drift

    def run(objective):
        loss_fn, params, batches, n = _problem()
        strat = _normalize("fig5").replace(objective=objective)
        if engine == "async":
            strat = strat.replace(async_cfg=AsyncConfig())
        s = FederatedServer.from_strategy(strat, loss_fn, params, M,
                                          seed=0, engine=engine)
        s.run(batches, n, ROUNDS)
        return s

    plain = run(LocalObjective.none())
    zeroed = run(zero)
    _tree_equal(plain.params, zeroed.params)
    _tree_equal(plain._residuals, zeroed._residuals)
    assert "drift" not in zeroed.store.trees


@settings(deadline=None, max_examples=4)
@given(st.sampled_from([0.05, 0.3]), st.booleans())
def test_active_objective_changes_the_math(strength, use_dyn):
    """The complement of the degeneration contract: a NONZERO strength
    must actually alter the trained params (the regularizer is live)."""
    obj = (LocalObjective.dyn(strength) if use_dyn
           else LocalObjective.prox(strength))
    loss_fn, params, batches, n = _problem()

    def run(objective):
        strat = _normalize("fig5").replace(objective=objective)
        s = FederatedServer.from_strategy(strat, loss_fn, params, M,
                                          seed=0, engine="cohort")
        s.run(batches, n, ROUNDS)
        return s

    plain = run(LocalObjective.none())
    reg = run(obj)
    diff = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
               for a, b in zip(jax.tree_util.tree_leaves(plain.params),
                               jax.tree_util.tree_leaves(reg.params)))
    assert diff > 0.0


# ---------------------------------------------------------------------------
# conservation: dropped clients keep their drift rows EXACTLY
# ---------------------------------------------------------------------------
def test_dropout_never_corrupts_drift_state():
    """FedDyn mirror of test_hetero.py's EF-residual invariant: a
    participant whose upload is dropped lost its whole local update, so
    its drift row h_k must stay bit-identical — otherwise the dynamic
    regularizer would remember an update the server never saw."""
    loss_fn, params, batches, n = _problem(M)
    st_ = strategy.get("fig5-dyn",
                       sampling=StaticSampling(initial_rate=1.0),
                       hetero=HeteroModel(profile="mobile", dropout=0.5),
                       error_feedback=True, learning_rate=0.1)
    residuals = jax.tree.map(
        lambda p: 0.01 * jnp.ones((M,) + p.shape, p.dtype), params)
    drift = jax.tree.map(
        lambda p: 0.02 * jnp.ones((M,) + p.shape, p.dtype), params)
    round_fn = jax.jit(build_round(st_, loss_fn, M, form="full"))
    nj = jnp.asarray(n)

    saw_drop = False
    for seed in range(6):
        _, new_res, new_drift, metrics = round_fn(
            params, residuals, drift, batches, nj, jnp.float32(1.0),
            jax.random.PRNGKey(seed))
        part = np.asarray(metrics["part_mask"])
        arrived = np.asarray(metrics["arrived_mask"])
        dropped = (part > 0) & (arrived == 0)
        saw_drop = saw_drop or dropped.any()
        for trees in ((residuals, new_res), (drift, new_drift)):
            for old, new in zip(jax.tree_util.tree_leaves(trees[0]),
                                jax.tree_util.tree_leaves(trees[1])):
                old, new = np.asarray(old), np.asarray(new)
                np.testing.assert_array_equal(new[dropped], old[dropped])
                # arrived clients DID advance the state
                assert np.abs(new[arrived > 0] - old[arrived > 0]).max() > 0
    assert saw_drop, "dropout=0.5 never dropped in 6 rounds?"
