"""Wire-codec layer (repro.core.codecs) properties.

Contract (DESIGN.md §4): identity and sparse COO round-trip BIT-exact on
masked uploads; int8 round-trip error is bounded by half a quantisation
step (scale/2 with scale = max|x|/127); ``wire_bytes()`` equals the actual
serialized nbytes of the encoded wire pytree; and the server's
``summary()["transport_bytes"]`` comes from the codec, not from the old
``pytree_payload_bytes`` estimate.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st

from repro.core.codecs import (ChainCodec, IdentityCodec, Int8Codec,
                               SparseCodec, roundtrip_stacked,
                               tree_wire_nbytes)
from repro.core.compression import (decode_sparse, dequantize_int8,
                                    encode_sparse, quantize_int8)
from repro.core.masking import random_mask, selective_mask_threshold


def _tree(key, shapes, dtype=jnp.float32):
    keys = jax.random.split(key, len(shapes))
    return {f"leaf{i}": jax.random.normal(k, s, dtype)
            for i, (k, s) in enumerate(zip(keys, shapes))}


def _masked_tree(key, shapes, gamma, min_leaf_size, mode="selective"):
    tree = _tree(key, shapes)

    def mask(k, leaf):
        if leaf.size < min_leaf_size:
            return leaf
        if mode == "random":
            return random_mask(k, leaf, gamma)
        return selective_mask_threshold(leaf, gamma)

    keys = jax.random.split(key, len(tree))
    return {name: mask(k, leaf)
            for k, (name, leaf) in zip(keys, tree.items())}


def _assert_bit_exact(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# round-trip properties
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_identity_roundtrip_bit_exact(seed):
    tree = _tree(jax.random.PRNGKey(seed), [(17, 31), (300,), (5,)])
    codec = IdentityCodec()
    _assert_bit_exact(tree, codec.roundtrip(tree))


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=0, max_value=1000),
       st.floats(min_value=0.05, max_value=0.9),
       st.sampled_from(["selective", "random"]))
def test_sparse_roundtrip_bit_exact_on_masked(seed, gamma, mode):
    """Sparse COO is bit-exact whenever the tensor has at most
    k = round(gamma * n) nonzeros — which the masks guarantee."""
    shapes = [(40, 40), (513,), (64,), (3, 5, 41)]
    masked = _masked_tree(jax.random.PRNGKey(seed), shapes, gamma,
                          min_leaf_size=256, mode=mode)
    codec = SparseCodec(gamma=gamma, min_leaf_size=256)
    _assert_bit_exact(masked, codec.roundtrip(masked))


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=1000))
def test_int8_roundtrip_error_bounded(seed):
    """|x - dequant(quant(x))| <= scale/2 per entry (scale = max|x|/127),
    and exact zeros stay exactly zero."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (37, 53))
    x = x * (jax.random.uniform(jax.random.PRNGKey(seed + 1), x.shape) > 0.5)
    payload = quantize_int8(x)
    back = dequantize_int8(payload)
    scale = float(payload["scale"])
    assert float(jnp.max(jnp.abs(back - x))) <= 0.5 * scale + 1e-7
    # exact zeros stay exactly zero (sparsity structure survives)
    assert np.all(np.asarray(back)[np.asarray(x) == 0] == 0)


def test_chain_sparse_int8_roundtrip():
    """Chained wire: COO first, then int8 on the surviving values — support
    is preserved exactly, values within half a quantisation step."""
    gamma = 0.2
    masked = _masked_tree(jax.random.PRNGKey(7), [(64, 64), (40,)], gamma,
                          min_leaf_size=256)
    codec = ChainCodec((SparseCodec(gamma=gamma, min_leaf_size=256),
                        Int8Codec()))
    back = codec.roundtrip(masked)
    for a, b in zip(jax.tree_util.tree_leaves(masked),
                    jax.tree_util.tree_leaves(back)):
        a, b = np.asarray(a), np.asarray(b)
        # decode only scatters encoded slots: dropped entries stay zero
        assert (b[a == 0] == 0).all()
        scale = np.abs(a).max() / 127.0
        assert np.abs(a - b).max() <= 0.5 * scale + 1e-7


def test_roundtrip_stacked_restores_dtype():
    stacked = {"w": jnp.ones((3, 300), jnp.bfloat16) *
               jnp.arange(3, dtype=jnp.bfloat16)[:, None]}
    codec = ChainCodec((SparseCodec(gamma=1.0), Int8Codec()))
    out = roundtrip_stacked(codec, stacked)
    assert out["w"].dtype == jnp.bfloat16
    # identity/None short-circuit: the SAME object comes back
    assert roundtrip_stacked(None, stacked) is stacked
    assert roundtrip_stacked(IdentityCodec(), stacked) is stacked


# ---------------------------------------------------------------------------
# exact wire bytes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("codec", [
    IdentityCodec(),
    SparseCodec(gamma=0.1, min_leaf_size=256),
    SparseCodec(gamma=0.5, min_leaf_size=64),
    Int8Codec(),
    ChainCodec((SparseCodec(gamma=0.25, min_leaf_size=256), Int8Codec())),
])
def test_wire_bytes_matches_serialized_nbytes(codec):
    """wire_bytes() (shape-only eval_shape trace) == the summed nbytes of
    the actually-encoded wire leaves."""
    tree = _tree(jax.random.PRNGKey(0), [(100, 30), (1000,), (10,)])
    wire = codec.encode(tree)
    actual = sum(np.asarray(leaf).nbytes
                 for leaf in jax.tree_util.tree_leaves(wire))
    assert codec.wire_bytes(tree) == actual == tree_wire_nbytes(wire)


def test_sparse_wire_bytes_formula():
    """COO leaf = k int32 indices + k values + int32 shape vector."""
    n, gamma = 1000, 0.1
    k = round(gamma * n)
    tree = {"w": jnp.zeros((n,)), "b": jnp.zeros((10,))}
    codec = SparseCodec(gamma=gamma, min_leaf_size=256)
    expected = (k * 4 + k * 4 + 1 * 4) + 10 * 4   # big leaf COO + small dense
    assert codec.wire_bytes(tree) == expected


# ---------------------------------------------------------------------------
# overflow behavior: magnitude-ranked slots, pod per-slice budgeting
# ---------------------------------------------------------------------------
def test_encode_sparse_overflow_sheds_smallest():
    """More nonzeros than slots: the wire keeps the k LARGEST magnitudes
    (graceful top-k degradation), never dropping dominant coordinates."""
    x = jnp.asarray([5.0, -1.0, 4.0, 0.0, -2.0, 3.0])
    back = decode_sparse(encode_sparse(x, k=3))
    np.testing.assert_array_equal(np.asarray(back),
                                  [5.0, 0.0, 4.0, 0.0, 0.0, 3.0])


def test_sparse_axis0_slices_budget():
    """Per-first-axis-slice masking (the pod path) can keep more than
    round(gamma*n) entries per leaf; axis0_slices sizes the wire to the
    per-slice budget so those uploads round-trip bit-exact."""
    G, d, gamma = 4, 15, 0.1
    # per-slice top-k keeps max(1, round(0.1*15)) = 2 each -> 8 total;
    # the whole-leaf budget would be round(0.1*60) = 6.
    leaf = jnp.zeros((G, d)).at[:, :2].set(
        jnp.arange(1, 2 * G + 1, dtype=jnp.float32).reshape(G, 2))
    whole = SparseCodec(gamma=gamma, min_leaf_size=1)
    sliced = SparseCodec(gamma=gamma, min_leaf_size=1, axis0_slices=True)

    assert np.count_nonzero(np.asarray(whole.roundtrip(leaf))) == 6  # shed 2
    np.testing.assert_array_equal(np.asarray(sliced.roundtrip(leaf)),
                                  np.asarray(leaf))
    # wire bytes reflect the bigger slot budget, exactly
    assert sliced.wire_bytes(leaf) == 8 * 8 + 2 * 4
    assert whole.wire_bytes(leaf) == 6 * 8 + 2 * 4


def test_pod_config_rebudgets_sparse_stages():
    """FedPodConfig.from_strategy switches every SparseCodec stage to the
    pod masks' per-slice budgeting, including inside chains."""
    from repro.core import strategy
    from repro.core.codecs import with_axis0_slices
    from repro.launch.fedtrain import FedPodConfig

    cfg = FedPodConfig.from_strategy(strategy.get("fig5-int8"), 4)
    assert isinstance(cfg.codec, ChainCodec)
    assert cfg.codec.stages[0].axis0_slices
    # idempotent + identity passthrough
    assert with_axis0_slices(cfg.codec) == cfg.codec
    assert with_axis0_slices(IdentityCodec()) == IdentityCodec()


# ---------------------------------------------------------------------------
# malformed-payload error paths (compression.py satellite)
# ---------------------------------------------------------------------------
def test_decode_sparse_rejects_malformed():
    good = encode_sparse(jnp.asarray([0.0, 2.0, 0.0, 3.0]), k=2)
    _assert_bit_exact(decode_sparse(good), jnp.asarray([0.0, 2.0, 0.0, 3.0]))

    bad = dict(good)
    del bad["indices"]
    with pytest.raises(ValueError, match="missing"):
        decode_sparse(bad)

    with pytest.raises(ValueError, match="integers"):
        decode_sparse({**good, "indices": good["indices"].astype(jnp.float32)})

    with pytest.raises(ValueError, match="matching 1-D"):
        decode_sparse({**good, "values": jnp.zeros((3,))})

    with pytest.raises(ValueError, match="out of range"):
        decode_sparse({**good, "indices": jnp.asarray([1, 9], jnp.int32)})

    with pytest.raises(ValueError, match="slots"):
        decode_sparse({"indices": jnp.zeros((9,), jnp.int32),
                       "values": jnp.zeros((9,)),
                       "shape": np.asarray([4], np.int32)})


def test_encode_sparse_rejects_bad_k():
    x = jnp.zeros((8,))
    with pytest.raises(ValueError, match="k >= 1"):
        encode_sparse(x, k=0)
    with pytest.raises(ValueError, match="exceeds"):
        encode_sparse(x, k=9)


def test_decoders_reject_non_array_payloads():
    """Non-array garbage raises the documented ValueError (coerced where
    possible, rejected otherwise) — never a bare AttributeError."""
    with pytest.raises(ValueError, match="not array-like"):
        dequantize_int8({"q": object(), "scale": jnp.float32(0.5)})
    with pytest.raises(ValueError, match="int8"):
        dequantize_int8({"q": [1, 2, 3], "scale": jnp.float32(0.5)})
    # coercible lists decode fine
    out = decode_sparse({"indices": [0, 2], "values": [1.0, 3.0],
                         "shape": np.asarray([4], np.int32)})
    np.testing.assert_array_equal(np.asarray(out), [1.0, 0.0, 3.0, 0.0])


def test_dequantize_int8_rejects_malformed():
    good = quantize_int8(jnp.asarray([1.0, -2.0, 0.5]))
    with pytest.raises(ValueError, match="missing"):
        dequantize_int8({"q": good["q"]})
    with pytest.raises(ValueError, match="int8"):
        dequantize_int8({**good, "q": good["q"].astype(jnp.int32)})
    with pytest.raises(ValueError, match="scalar"):
        dequantize_int8({**good, "scale": jnp.ones((3,))})
    with pytest.raises(ValueError, match="float"):
        quantize_int8(jnp.asarray([1, 2, 3], jnp.int32))


# ---------------------------------------------------------------------------
# decode-boundary non-finite rejection (the sync-path quarantine analogue)
# ---------------------------------------------------------------------------
def test_identity_decode_rejects_non_finite():
    with pytest.raises(ValueError, match="non-finite"):
        IdentityCodec().decode({"a": jnp.array([1.0, jnp.nan, 2.0])})
    with pytest.raises(ValueError, match="non-finite"):
        IdentityCodec().decode({"a": jnp.array([jnp.inf])})


def test_sparse_decode_rejects_non_finite():
    sc = SparseCodec(gamma=0.5, min_leaf_size=256)
    # dense pass-through leaf (below min_leaf_size) hits the gate
    with pytest.raises(ValueError, match="non-finite"):
        sc.decode({"a": jnp.array([jnp.inf, 0.0])})
    # poisoned COO value payload is caught in decode_sparse
    wire = sc.encode({"a": jnp.zeros((512,)).at[3].set(1.0)})
    wire["a"]["values"] = wire["a"]["values"].at[0].set(jnp.nan)
    with pytest.raises(ValueError, match="non-finite"):
        sc.decode(wire)


def test_int8_decode_rejects_non_finite():
    # non-finite scale is caught in dequantize_int8
    q = quantize_int8(jnp.ones((8,)))
    q["scale"] = jnp.asarray(jnp.nan, jnp.float32)
    with pytest.raises(ValueError, match="non-finite"):
        Int8Codec().decode({"a": q})
    # float pass-through leaves (e.g. unquantized metadata) hit the gate
    with pytest.raises(ValueError, match="non-finite"):
        Int8Codec().decode({"a": jnp.array([jnp.nan])})


def test_chain_decode_rejects_non_finite():
    chain = ChainCodec((SparseCodec(gamma=0.5, min_leaf_size=8),
                        Int8Codec()))
    wire = chain.encode({"a": jnp.zeros((64,)).at[5].set(1.0)})
    wire["a"]["scale"] = jnp.asarray(jnp.inf, jnp.float32)
    with pytest.raises(ValueError, match="non-finite"):
        chain.decode(wire)
    # finite wires still decode (the gate is a pass-through, not a tax)
    ok = chain.encode({"a": jnp.zeros((64,)).at[5].set(1.0)})
    out = chain.decode(ok)
    assert np.isfinite(np.asarray(out["a"])).all()
