"""Client-state store (DESIGN.md §11): DenseStore/ShardedStore semantics,
Dense-vs-Sharded bit-exactness across the strategy registry, eviction
divergence, checkpoint round-trips with pre-restore validation, and the
async engine's cross-round staleness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DynamicSampling, FederatedServer, strategy
from repro.core.async_engine import AsyncConfig, AsyncRoundRunner
from repro.core.client_store import DenseStore, ShardedStore, make_store
from repro.core.hetero import HeteroModel

# D exceeds the presets' masking/codec min_leaf_size (256), so selective
# masking binds and EF residuals carry real mass — with a smaller leaf the
# wire is lossless and every residual comparison would be vacuously 0 == 0.
M, NB, B, D = 16, 2, 4, 320


def _problem(num_clients=M, seed=0):
    key = jax.random.PRNGKey(seed)
    xs = jax.random.normal(key, (num_clients, NB, B, D))
    w_true = jnp.arange(1.0, D + 1.0)
    ys = jnp.einsum("mnbd,d->mnb", xs, w_true)
    params = {"w": jnp.zeros((D,)), "b": jnp.zeros(())}

    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"] + p["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    batches = {"x": xs, "y": ys}
    n = np.full((num_clients,), NB * B, np.float64)
    return loss_fn, params, batches, n


def _run(name, *, store=None, num_clients=M, rounds=3, engine=None,
         seed=0, **overrides):
    loss_fn, params, batches, n = _problem(num_clients, seed)
    strat = strategy.get(name, **overrides) if overrides \
        else strategy.get(name)
    if engine is None:
        engine = "async" if strat.async_cfg is not None else "cohort"
    server = FederatedServer.from_strategy(
        strat, loss_fn, params, num_clients, seed=seed, engine=engine,
        store=store)
    server.run(batches, n, rounds)
    return server


def _tree_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _template():
    return {"w": jnp.zeros((D,)), "b": jnp.zeros(())}


# ---- backend semantics ----------------------------------------------------
def test_make_store_kinds_and_validation():
    t = _template()
    assert make_store("dense", M, t).kind == "dense"
    sh = make_store("sharded", M, t, retention=4)
    assert sh.kind == "sharded" and sh.retention == 4
    with pytest.raises(ValueError, match="unknown store kind"):
        make_store("mmap", M, t)


def test_sharded_gather_zero_on_miss():
    sh = ShardedStore(M, _template(), retention=4)
    rows = sh.gather(np.asarray([3, 7, 11]))
    for leaf in jax.tree_util.tree_leaves(rows):
        np.testing.assert_array_equal(np.asarray(leaf), 0.0)


def test_sharded_scatter_commit_mask_and_roundtrip():
    sh = ShardedStore(M, _template(), retention=4)
    ids = np.asarray([2, 5])
    rows = {"w": jnp.ones((2, D)), "b": jnp.full((2,), 3.0)}
    sh.scatter(ids, rows, np.asarray([1.0, 0.0], np.float32), 1)
    got = sh.gather(ids)
    np.testing.assert_array_equal(np.asarray(got["w"][0]), 1.0)
    np.testing.assert_array_equal(np.asarray(got["b"][0]), 3.0)
    # commit=0 row never landed: client 5 still reads zeros
    np.testing.assert_array_equal(np.asarray(got["w"][1]), 0.0)


def test_sharded_lru_eviction_and_counter():
    sh = ShardedStore(M, _template(), retention=2)
    one = {"w": jnp.ones((1, D)), "b": jnp.ones((1,))}
    keep = np.ones((1,), np.float32)
    sh.scatter(np.asarray([0]), one, keep, 1)
    sh.scatter(np.asarray([1]), one, keep, 2)
    assert sh.evictions == 0
    sh.scatter(np.asarray([2]), one, keep, 3)   # evicts client 0 (oldest)
    assert sh.evictions == 1
    np.testing.assert_array_equal(np.asarray(sh.gather([0])["w"]), 0.0)
    np.testing.assert_array_equal(np.asarray(sh.gather([1])["w"]), 1.0)
    np.testing.assert_array_equal(np.asarray(sh.gather([2])["w"]), 1.0)


def test_sharded_over_capacity_raises():
    sh = ShardedStore(M, _template(), retention=2)
    rows = {"w": jnp.ones((3, D)), "b": jnp.ones((3,))}
    with pytest.raises(ValueError, match="retains only"):
        sh.scatter(np.asarray([0, 1, 2]), rows, np.ones((3,), np.float32), 1)


def test_version_vector_and_staleness():
    sh = ShardedStore(M, _template(), retention=4)
    sh.mark_dispatched(np.asarray([1, 4]), 3)
    s = sh.staleness(np.asarray([1, 4]), 7)
    np.testing.assert_array_equal(s, [4, 4])
    sh.mark_dispatched(np.asarray([4]), 7)
    s = sh.staleness(np.asarray([1, 4]), 7)
    np.testing.assert_array_equal(s, [4, 0])


def test_memory_bytes_retention_bound():
    retention = 4
    sh = ShardedStore(M, _template(), retention=retention)
    mem = sh.memory_bytes()
    per_client = mem["client_bytes"]
    assert mem["dense_equiv_bytes"] == per_client * M
    # slot pool = retention + 1 sentinel rows, regardless of M
    assert mem["residual_bytes"] == per_client * (retention + 1)
    assert mem["residual_bytes"] <= \
        (retention + 1) / M * mem["dense_equiv_bytes"] + per_client


def test_shard_over_single_device_mesh_is_noop_safe():
    from jax.sharding import Mesh
    sh = ShardedStore(M, _template(), retention=4, track_norms=True)
    one = {"w": jnp.ones((1, D)), "b": jnp.ones((1,))}
    sh.scatter(np.asarray([3]), one, np.ones((1,), np.float32), 1)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    sh.shard_over(mesh)
    np.testing.assert_array_equal(np.asarray(sh.gather([3])["w"]), 1.0)
    np.testing.assert_array_equal(np.asarray(sh.norms), 1.0)


# ---- Dense vs Sharded bit-exactness across the registry -------------------
@pytest.mark.parametrize("preset", strategy.names())
def test_dense_vs_sharded_bit_exact(preset):
    """With retention covering every client, the sharded store reproduces
    the dense engines bit for bit — params, EF residuals, norm EMAs and
    version vectors — on every registry preset, under whichever engine the
    preset targets (async presets run the async engine).

    The systematic version of this keystone lives in
    tests/test_equivalence.py (preset x engine x store vs the full/dense
    oracle); this test is kept because it runs each preset AS CONFIGURED
    (hetero fleet, async schedule and all) rather than normalized to the
    deterministic common ground, and checks the version vectors too."""
    strat = strategy.get(preset)
    extra = ({"drift": _template()} if strat.objective.uses_drift else None)
    dense = _run(preset)
    sh = ShardedStore(M, _template(), retention=M,
                      track_norms=strat.sampler.adaptive,
                      extra_trees=extra)
    sharded = _run(preset, store=sh)
    assert sh.evictions == 0
    _tree_equal(dense.params, sharded.params)
    _tree_equal(dense.store.residuals_dense(),
                sharded.store.residuals_dense())
    if strat.objective.uses_drift:
        _tree_equal(dense.store.dense_view("drift"),
                    sharded.store.dense_view("drift"))
    if strat.async_cfg is not None:
        # both backends share the async runner, which versions dispatches
        np.testing.assert_array_equal(dense.store.versions,
                                      sharded.store.versions)
    else:
        # the sync dense engines keep the historical scan path (no version
        # bookkeeping); the store program marks dispatches, so the sharded
        # run must have versioned someone
        assert sharded.store.versions.max() > 0
    if strat.sampler.adaptive:
        np.testing.assert_array_equal(np.asarray(dense.store.norms),
                                      np.asarray(sharded.store.norms))


def test_eviction_divergence_is_the_documented_one():
    """With a retention window SMALLER than the active cohort history the
    sharded run diverges from the dense oracle exactly as documented:
    evicted clients re-enter with a ZERO residual (their correction mass
    is dropped), everything still inside the window stays bit-exact."""
    name = "fig5"
    # ~4-client cohorts, so each round's commit set fits retention=4 but
    # the union of cohorts across rounds does not
    overrides = dict(error_feedback=True,
                     sampling=DynamicSampling(initial_rate=0.25, beta=0.0,
                                              min_clients=2))
    dense = _run(name, rounds=8, **overrides)
    sh = ShardedStore(M, _template(), retention=4, track_norms=False)
    sharded = _run(name, store=sh, rounds=8, **overrides)
    assert sh.evictions > 0
    dense_res = dense.store.residuals_dense()
    shard_res = sharded.store.residuals_dense()
    # evicted-and-not-recommitted clients hold exact zeros in the sharded
    # store; the dense oracle still remembers their residuals
    live = set(sh._slot_of)
    gone = [c for c in range(M) if c not in live]
    assert gone, "retention=4 over 5 rounds must have evicted someone"
    for leaf in jax.tree_util.tree_leaves(shard_res):
        np.testing.assert_array_equal(np.asarray(leaf)[gone], 0.0)
    dense_gone = np.concatenate(
        [np.abs(np.asarray(leaf)[gone]).ravel()
         for leaf in jax.tree_util.tree_leaves(dense_res)])
    assert dense_gone.max() > 0.0  # the oracle DID hold mass there


def test_full_engine_rejects_sharded_store():
    loss_fn, params, _, _ = _problem()
    sh = ShardedStore(M, _template(), retention=4)
    with pytest.raises(ValueError, match="engine='full'"):
        FederatedServer.from_strategy(strategy.get("dense-baseline"),
                                      loss_fn, params, M, engine="full",
                                      store=sh)


def test_adaptive_sampler_requires_norm_tracking():
    loss_fn, params, _, _ = _problem()
    sh = ShardedStore(M, _template(), retention=M, track_norms=False)
    with pytest.raises(ValueError, match="track_norms"):
        FederatedServer.from_strategy(strategy.get("fig3-importance"),
                                      loss_fn, params, M, store=sh)


def test_batch_provider_requires_sharded_store():
    loss_fn, params, batches, n = _problem()
    server = FederatedServer.from_strategy(strategy.get("fig5"), loss_fn,
                                           params, M)
    with pytest.raises(ValueError, match="provider"):
        server.run(lambda ids: jax.tree.map(
            lambda x: jnp.take(x, jnp.asarray(np.asarray(ids)), axis=0),
            batches), n, 1)


def test_batch_provider_matches_stacked_batches():
    """A provider callable on the sharded store reproduces the stacked-
    batches run bit for bit — gathering rows on demand changes nothing."""
    loss_fn, params, batches, n = _problem()

    def provider(ids):
        idx = jnp.asarray(np.asarray(ids))
        return jax.tree.map(lambda x: jnp.take(x, idx, axis=0), batches)

    outs = []
    for client_batches in (batches, provider):
        sh = ShardedStore(M, _template(), retention=M)
        server = FederatedServer.from_strategy(
            strategy.get("fig5", error_feedback=True), loss_fn, params, M,
            store=sh)
        server.run(client_batches, n, 3)
        outs.append(server)
    _tree_equal(outs[0].params, outs[1].params)
    _tree_equal(outs[0].store.residuals_dense(),
                outs[1].store.residuals_dense())


# ---- checkpointing --------------------------------------------------------
def test_sharded_checkpoint_roundtrip_bit_exact(tmp_path):
    loss_fn, params, batches, n = _problem()
    name = "fig3-importance"

    def fresh():
        sh = ShardedStore(M, _template(), retention=M, track_norms=True)
        return FederatedServer.from_strategy(strategy.get(name), loss_fn,
                                             params, M, store=sh)

    oracle = fresh()
    oracle.run(batches, n, 4)

    a = fresh()
    a.run(batches, n, 2)
    a.save_state(str(tmp_path))

    b = fresh()
    step = b.restore_state(str(tmp_path))
    assert step == 2
    b.run(batches, n, 2)
    _tree_equal(oracle.params, b.params)
    _tree_equal(oracle.store.residuals_dense(),
                b.store.residuals_dense())
    np.testing.assert_array_equal(np.asarray(oracle.store.norms),
                                  np.asarray(b.store.norms))
    np.testing.assert_array_equal(oracle.store.versions, b.store.versions)


def test_restore_rejects_population_mismatch(tmp_path):
    server = _run("fig5", rounds=1)
    server.save_state(str(tmp_path))
    loss_fn, params, _, _ = _problem(24)
    other = FederatedServer.from_strategy(strategy.get("fig5"), loss_fn,
                                          params, 24)
    with pytest.raises(ValueError, match=r"num_clients=16.*num_clients=24"):
        other.restore_state(str(tmp_path))


def test_restore_rejects_store_kind_mismatch(tmp_path):
    server = _run("fig5", rounds=1)          # dense store checkpoint
    server.save_state(str(tmp_path))
    loss_fn, params, _, _ = _problem()
    sh = ShardedStore(M, _template(), retention=M)
    other = FederatedServer.from_strategy(strategy.get("fig5"), loss_fn,
                                          params, M, store=sh)
    with pytest.raises(ValueError, match="'dense'.*'sharded'"):
        other.restore_state(str(tmp_path))


# ---- cross-round staleness (async engine) ---------------------------------
def _async_rounds(strat, store, rounds, num_clients=M, seed=0):
    loss_fn, params, batches, n = _problem(num_clients, seed)
    runner = AsyncRoundRunner(strat, loss_fn, num_clients, store=store)
    residuals = None
    if store is None or store.kind == "dense":
        residuals = jax.tree.map(
            lambda x: jnp.zeros((num_clients,) + x.shape), params)
    norms = store.norms if store is not None else None
    key = jax.random.PRNGKey(seed)
    stats_log = []
    for t in range(1, rounds + 1):
        key, sub = jax.random.split(key)
        m = strat.sampling.num_clients_host(t, num_clients)
        bucket = strat.sampler.cohort_bucket(strat.sampling, m, num_clients)
        params, residuals, norms, stats = runner.run_round(
            params, residuals, norms, batches,
            jnp.asarray(n, jnp.float32), t, sub, cohort_size=bucket,
            flops=1e6, wire_bytes=1000)
        stats_log.append(stats)
    return params, stats_log


def test_crossround_requires_store():
    strat = strategy.get("async-crossround")
    loss_fn, _, _, _ = _problem()
    with pytest.raises(ValueError, match="ClientStateStore"):
        AsyncRoundRunner(strat, loss_fn, M, store=None)


def test_crossround_keystone_degenerates_on_ideal_fleet():
    """With K = m_t and no deadline on the ideal fleet there is exactly
    one flush and nothing is ever cut, so max_round_stale > 0 must change
    NOTHING — the run is bit-identical to the legacy flush-distance mode.
    (Under buffered flushes the two modes legitimately differ even without
    carries: cross-round mode measures staleness in ROUND distance, so
    same-round rows apply undiscounted where legacy applies the
    flush-distance factor.)"""
    base = strategy.get("async-mobile", hetero=HeteroModel(profile="ideal"),
                        async_cfg=AsyncConfig())
    legacy = base
    cross = base.replace(async_cfg=dataclasses.replace(
        base.async_cfg, max_round_stale=3))
    p_legacy, s_legacy = _async_rounds(
        legacy, DenseStore(M, _template()), 4)
    p_cross, s_cross = _async_rounds(
        cross, DenseStore(M, _template()), 4)
    _tree_equal(p_legacy, p_cross)
    assert all(s["carried"] == 0 and s["pending"] == 0 for s in s_cross)


def test_crossround_carries_deadline_cut_uploads():
    """On the mobile fleet with a harsh deadline, cross-round mode carries
    cut uploads into later rounds: they apply with round-distance
    staleness > 0 instead of timing out, and expired/superseded entries
    drain from the pending set."""
    strat = strategy.get("async-crossround")
    _, stats = _async_rounds(strat, DenseStore(M, _template()), 10)
    assert sum(s["carried"] for s in stats) > 0
    assert any(s["pending"] > 0 for s in stats)
    # carried applies happen at s >= 1, so SOME round shows mean staleness
    assert any(s["mean_staleness"] > 0 for s in stats)
    # legacy mode on the same fleet times those uploads out instead
    legacy = strat.replace(async_cfg=dataclasses.replace(
        strat.async_cfg, max_round_stale=0))
    _, stats0 = _async_rounds(legacy, DenseStore(M, _template()), 10)
    assert all("carried" in s and s["carried"] == 0 for s in stats0)
    assert sum(s["timeouts"] for s in stats0) >= \
        sum(s["timeouts"] for s in stats) - 1


def test_crossround_dense_vs_sharded_bit_exact():
    strat = strategy.get("async-crossround")
    p_dense, s_dense = _async_rounds(strat, DenseStore(M, _template()), 8)
    p_shard, s_shard = _async_rounds(
        strat, ShardedStore(M, _template(), retention=M), 8)
    _tree_equal(p_dense, p_shard)
    assert [s["carried"] for s in s_dense] == \
        [s["carried"] for s in s_shard]


def test_async_config_validates_max_round_stale():
    with pytest.raises(ValueError, match="max_round_stale"):
        AsyncConfig(max_round_stale=-1)


# ---------------------------------------------------------------------------
# shard_over on a REAL 8-device mesh (subprocess; forced host devices)
# ---------------------------------------------------------------------------
STORE_SHARD_CHECK = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
import jax.numpy as jnp
import numpy as np
from repro.core import FederatedServer, strategy
from repro.core.client_store import DenseStore, ShardedStore

M, NB, B, D = 32, 2, 4, 320
key = jax.random.PRNGKey(0)
xs = jax.random.normal(key, (M, NB, B, D))
w_true = jnp.arange(1.0, D + 1.0)
ys = jnp.einsum("mnbd,d->mnb", xs, w_true)
params = {"w": jnp.zeros((D,)), "b": jnp.zeros(())}
def loss_fn(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    return jnp.mean((pred - batch["y"]) ** 2)
batches = {"x": xs, "y": ys}
n = np.full((M,), NB * B, np.float64)
template = {"w": jnp.zeros((D,)), "b": jnp.zeros(())}

def run(store):
    strat = strategy.get("fig5-dyn", hetero=None, async_cfg=None,
                         error_feedback=True, learning_rate=0.05)
    s = FederatedServer.from_strategy(strat, loss_fn, params, M, seed=0,
                                      engine="cohort", store=store)
    s.run(batches, n, 3)
    return s

dense = run(DenseStore(M, template,
                       extra_trees={"drift": template}))
mesh = jax.sharding.Mesh(np.asarray(jax.devices()), ("data",))
store = ShardedStore(M, template, retention=M,
                     extra_trees={"drift": template})
store.shard_over(mesh)
# capture placement NOW: round scatters rebuild the pool arrays from jit
# outputs, so shard_over's placement is a round-entry property
pool_devs = {len(getattr(leaf.sharding, "device_set", set()))
             for pool in store._pools.values()
             for leaf in jax.tree_util.tree_leaves(pool)}
sharded = run(store)

def dmax(a, b):
    return max(float(np.abs(np.asarray(x, np.float64)
                            - np.asarray(y, np.float64)).max())
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))

print(json.dumps({
    "dparams": dmax(dense.params, sharded.params),
    "dres": dmax(dense.store.dense_view("residuals"),
                 sharded.store.dense_view("residuals")),
    "ddrift": dmax(dense.store.dense_view("drift"),
                   sharded.store.dense_view("drift")),
    "evictions": store.evictions,
    "pool_devices": sorted(pool_devs),
}))
"""


def test_sharded_store_shard_over_8dev_subprocess():
    """``ShardedStore.shard_over(mesh)`` on 8 forced host devices: the slot
    pools (residuals AND the FedDyn drift tree) distribute their client
    axis over the mesh, and 3 cohort rounds of fig5-dyn — gather, compute,
    commit crossing a REAL device boundary each round — stay bit-identical
    to the unsharded DenseStore run."""
    import json
    import os
    import subprocess
    import sys

    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", STORE_SHARD_CHECK], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    # EVERY pool leaf (residuals and drift alike) spans all 8 devices
    assert rec["pool_devices"] == [8], rec
    assert rec["evictions"] == 0, rec
    assert rec["dparams"] == 0.0, rec
    assert rec["dres"] == 0.0, rec
    assert rec["ddrift"] == 0.0, rec
