"""Heterogeneous-fleet simulator + adaptive-sampler integration
(DESIGN.md §5): profile draws, the simulated clock, dropout's interaction
with error feedback, and cohort==oracle bit-exactness under non-uniform
selection and dropout."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FederatedServer, strategy
from repro.core.hetero import HeteroModel, profile_names, simulate_round
from repro.core.sampling import StaticSampling, ThresholdSampler
from repro.core.strategy import build_round


@functools.lru_cache()
def _problem(num_clients, dim=8, classes=3, num_batches=2, batch=4, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (num_clients, num_batches, batch, dim))
    y = jax.random.randint(jax.random.fold_in(key, 1),
                           (num_clients, num_batches, batch), 0, classes)

    def loss_fn(params, data):
        xb, yb = data
        logp = jax.nn.log_softmax(xb @ params["w"] + params["b"])
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

    params = {"w": 0.1 * jax.random.normal(jax.random.fold_in(key, 2),
                                           (dim, classes)),
              "b": jnp.zeros((classes,))}
    n = np.ones((num_clients,), np.float32)
    return loss_fn, params, (x, y), n


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# HeteroModel / ClientTraits / simulate_round
# ---------------------------------------------------------------------------
def test_profile_validation():
    assert set(profile_names()) == {"ideal", "mobile", "flaky-mobile"}
    with pytest.raises(ValueError, match="unknown hetero profile"):
        HeteroModel(profile="datacenter")
    with pytest.raises(ValueError, match="dropout"):
        HeteroModel(dropout=1.5)


def test_traits_deterministic_and_shaped():
    a = HeteroModel(profile="mobile", seed=3).client_traits(16)
    b = HeteroModel(profile="mobile", seed=3).client_traits(16)
    np.testing.assert_array_equal(a.flops_per_s, b.flops_per_s)
    np.testing.assert_array_equal(a.latency_s, b.latency_s)
    assert a.flops_per_s.shape == (16,)
    # real spread on the mobile fleet; none on the ideal one
    assert a.flops_per_s.std() > 0
    ideal = HeteroModel(profile="ideal").client_traits(16)
    assert ideal.flops_per_s.std() == 0 and (ideal.drop_rate == 0).all()
    # dropout override wins over the profile default
    assert (HeteroModel(profile="mobile", dropout=0.5)
            .drop_rates(4) == 0.5).all()


def test_simulate_round_straggler_and_drops():
    traits = HeteroModel(profile="mobile", seed=0).client_traits(8)
    part = np.ones(8)
    arrived = part.copy()
    arrived[2] = 0.0
    sim = simulate_round(traits, part, arrived, flops=1e9,
                         upload_bytes=1_000_000)
    assert sim["dropped"] == 1
    times = traits.client_time_s(1e9, 1_000_000)
    assert sim["sim_round_s"] == pytest.approx(times[arrived > 0].max())
    assert 0 <= sim["straggler_s"] <= sim["sim_round_s"]
    # nobody arrived: the clock reads zero rather than NaN
    empty = simulate_round(traits, part, np.zeros(8), 1e9, 1)
    assert empty["sim_round_s"] == 0.0 and empty["dropped"] == 8


# ---------------------------------------------------------------------------
# dropout inside the round: aggregation + error feedback
# ---------------------------------------------------------------------------
def test_dropout_never_corrupts_error_feedback_residuals():
    """A participant whose upload is dropped keeps its residual EXACTLY:
    the whole local update is lost, so its error-feedback state must stay
    consistent with the global model it re-downloads."""
    M = 8
    loss_fn, params, batches, n = _problem(M, dim=128, classes=4)
    st = strategy.get("fig5", sampling=StaticSampling(initial_rate=1.0),
                      hetero=HeteroModel(profile="mobile", dropout=0.5),
                      error_feedback=True, learning_rate=0.1)
    residuals = jax.tree.map(
        lambda p: 0.01 * jnp.ones((M,) + p.shape, p.dtype), params)
    round_fn = jax.jit(build_round(st, loss_fn, M, form="full"))
    nj = jnp.asarray(n)

    saw_drop = False
    for seed in range(6):
        _, new_res, metrics = round_fn(params, residuals, batches, nj,
                                       jnp.float32(1.0),
                                       jax.random.PRNGKey(seed))
        part = np.asarray(metrics["part_mask"])
        arrived = np.asarray(metrics["arrived_mask"])
        dropped = (part > 0) & (arrived == 0)
        saw_drop = saw_drop or dropped.any()
        for old, new in zip(jax.tree_util.tree_leaves(residuals),
                            jax.tree_util.tree_leaves(new_res)):
            old, new = np.asarray(old), np.asarray(new)
            np.testing.assert_array_equal(new[dropped], old[dropped])
            # arrived clients DID advance their residual state
            assert (np.abs(new[arrived > 0] - old[arrived > 0]).max() > 0)
    assert saw_drop, "dropout=0.5 never dropped in 6 rounds?"


def test_hetero_metrics_and_records():
    """Server-level: hetero runs record sim_round_s/straggler_s/dropped and
    summary() rolls them up; transport still counts attempted uploads."""
    M = 8
    loss_fn, params, batches, n = _problem(M)
    st = strategy.get("hetero-dropout", learning_rate=0.1)
    s = FederatedServer.from_strategy(st, loss_fn, params, M, seed=0)
    s.run(batches, n, rounds=4)
    assert all(r.sim_round_s > 0 for r in s.history)
    assert all(r.straggler_s >= 0 for r in s.history)
    assert sum(r.dropped for r in s.history) > 0     # 20% loss on 32 uploads
    assert all(r.transport_bytes ==
               r.num_sampled * s.client_upload_bytes for r in s.history)
    summ = s.summary()
    assert summ["hetero"] == "flaky-mobile"
    assert summ["sim_total_s"] == pytest.approx(
        sum(r.sim_round_s for r in s.history))
    assert summ["dropped_uploads"] == sum(r.dropped for r in s.history)


# ---------------------------------------------------------------------------
# cohort == oracle under non-uniform selection (the §5.2 guarantee)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sampler_name", ["importance", "threshold"])
def test_cohort_matches_oracle_nonuniform(sampler_name):
    """Bit-exact params/residuals/norms across engines for the adaptive
    samplers (the preset test covers fig3-importance; this adds threshold
    and the sampler x dropout cross)."""
    from repro.core.sampling import get_sampler

    M = 16
    loss_fn, params, batches, n = _problem(M, dim=128, classes=4)
    st = strategy.get("fig3", sampler=get_sampler(sampler_name),
                      hetero=HeteroModel(profile="mobile", seed=1),
                      error_feedback=True, learning_rate=0.1)

    servers = {}
    for engine in ("full", "cohort"):
        s = FederatedServer.from_strategy(st, loss_fn, params, M, seed=11,
                                          engine=engine)
        s.run(batches, n, rounds=6)
        servers[engine] = s
    full, cohort = servers["full"], servers["cohort"]
    _assert_trees_equal(full.params, cohort.params)
    _assert_trees_equal(full._residuals, cohort._residuals)
    np.testing.assert_array_equal(np.asarray(full._norms),
                                  np.asarray(cohort._norms))
    assert [r.num_sampled for r in full.history] == \
        [r.num_sampled for r in cohort.history]
    assert [r.dropped for r in full.history] == \
        [r.dropped for r in cohort.history]
    np.testing.assert_allclose(
        [r.mean_loss for r in full.history],
        [r.mean_loss for r in cohort.history], rtol=1e-5, atol=1e-6)
    # the norm tracker actually moved off its all-ones init
    assert float(np.abs(np.asarray(cohort._norms) - 1.0).max()) > 0
    # cohort buffers obey the sampler's bucket plan
    smp = st.sampler
    for t, rec in enumerate(cohort.history, start=1):
        m = st.sampling.num_clients_host(t, M)
        assert rec.cohort_size == smp.cohort_bucket(st.sampling, m, M)
        assert rec.num_sampled <= rec.cohort_size


def test_empty_round_reports_nan_not_zero_loss():
    """A threshold round that selects nobody is a params no-op and reports
    NaN mean_loss (a fabricated 0.0 would read as 'target loss reached'
    in the benches)."""
    M = 8
    loss_fn, params, batches, n = _problem(M)
    st = strategy.get("fig3", sampler=ThresholdSampler(),
                      sampling=StaticSampling(initial_rate=0.5,
                                              min_clients=2),
                      learning_rate=0.1)
    residuals = jax.tree.map(
        lambda p: jnp.zeros((M,) + p.shape, p.dtype), params)
    round_fn = jax.jit(build_round(st, loss_fn, M, form="full"))
    norms = jnp.ones((M,), jnp.float32)
    nj = jnp.asarray(n)

    for seed in range(400):
        p_new, _, _, met = round_fn(params, residuals, norms, batches, nj,
                                    jnp.float32(1.0),
                                    jax.random.PRNGKey(seed))
        if int(met["num_sampled"]) == 0:
            assert np.isnan(float(met["mean_loss"]))
            _assert_trees_equal(params, p_new)        # exact no-op round
            return
    pytest.skip("no empty round in 400 seeds (p ~ 2% each)")


def test_threshold_scan_segments_match_per_round_dispatch():
    """scan_rounds=True folds same-bucket rounds into one lax.scan dispatch;
    with an adaptive sampler the norm tracker threads the carry, so the
    result must match per-round dispatch bit-exactly."""
    M = 8
    loss_fn, params, batches, n = _problem(M)
    st = strategy.get("fig3", sampler=ThresholdSampler(),
                      sampling=StaticSampling(initial_rate=0.5,
                                              min_clients=2),
                      learning_rate=0.1, error_feedback=True)
    runs = {}
    for scan in (True, False):
        s = FederatedServer.from_strategy(st, loss_fn, params, M, seed=4,
                                          scan_rounds=scan)
        s.run(batches, n, rounds=5)
        runs[scan] = s
    _assert_trees_equal(runs[True].params, runs[False].params)
    np.testing.assert_array_equal(np.asarray(runs[True]._norms),
                                  np.asarray(runs[False]._norms))
    assert [r.num_sampled for r in runs[True].history] == \
        [r.num_sampled for r in runs[False].history]


def test_drop_rate_clamp_bounds_ht_correction():
    """Regression for the documented MAX_DROP_RATE contract: a dropout
    override beyond 0.5 clamps, so the Horvitz-Thompson 1/(1-q) dropout
    correction never inflates a single surviving upload by more than 2x."""
    from repro.core.hetero import MAX_DROP_RATE

    rates = HeteroModel(profile="mobile", dropout=0.95).drop_rates(8)
    np.testing.assert_array_equal(rates, np.full((8,), MAX_DROP_RATE))
    assert (1.0 / (1.0 - rates) <= 2.0).all()
    # in-range overrides pass through unclamped
    assert (HeteroModel(profile="mobile", dropout=0.3).drop_rates(8)
            == 0.3).all()
    # the profile defaults themselves respect the bound
    for name in profile_names():
        assert (HeteroModel(profile=name).drop_rates(8)
                <= MAX_DROP_RATE).all()


def test_arrival_stream_ordering_and_membership():
    """The async engine's event queue contract: one event per participant,
    sorted by (time, client id) — id is the tie break, which is what makes
    the ideal fleet (all arrivals simultaneous) deterministic."""
    from repro.core.hetero import arrival_stream

    part = np.array([1, 0, 1, 1, 0, 1, 1, 1], np.float32)
    for profile in ("ideal", "mobile"):
        traits = HeteroModel(profile=profile).client_traits(8)
        events = list(arrival_stream(traits, part, 1e9, 4096))
        assert sorted(cid for _, cid in events) == [0, 2, 3, 5, 6, 7]
        assert events == sorted(events)
        if profile == "ideal":  # simultaneous arrivals: id breaks the tie
            assert [cid for _, cid in events] == [0, 2, 3, 5, 6, 7]
        times = traits.arrival_times_s(1e9, 4096)
        for t_s, cid in events:
            assert t_s == float(times[cid])
