"""Fused Pallas wire path (DESIGN.md §10): delta -> wire payload in one
HBM sweep, validated against the pure-jnp codec oracle.

Layers under test, bottom-up:

* ``seg.segmented_stats`` / ``seg.segmented_encode`` — the new fused
  kernels, vs per-leaf jnp references (histogram, absmax, packbits).
* ``codecs.FusedSparseCodec`` / ``codecs.BitmapCodec`` — byte-exact
  ``wire_bytes`` and bit-exact roundtrips vs the jnp ``SparseCodec`` /
  ``Int8Codec`` oracle on every sparse pairing, incl. the chained int8
  wire, and the EF-conservation property (unquantised roundtrip IS the
  masked delta).
* whole-run equivalence — fig5 vs its fused/bitmap presets produce
  bit-identical params AND error-feedback residuals through the sync
  cohort engine, and the async engine's decode gate quarantines poisoned
  fused wires without touching the global model.
* the COO<->bitmap crossover (bitmap wins iff kept density > 1/32) and
  ``decode_bitmap``'s loud-failure contract on malformed payloads.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st
from repro.core import FederatedServer, strategy
from repro.core.async_engine import AsyncConfig
from repro.core.codecs import (BitmapCodec, ChainCodec, FusedSparseCodec,
                               Int8Codec, SparseCodec, roundtrip_stacked)
from repro.core.compression import decode_bitmap, encode_bitmap
from repro.core.hetero import HeteroModel
from repro.core.masking import MaskingConfig, mask_pytree
from repro.kernels import ops
from repro.kernels import packing as pk
from repro.kernels import segmented as seg


def _rand(shape, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


SEG_SHAPES = [(300, 77), (128, 128), (70000,), (257,)]


def _packed(slab=None):
    leaves = [_rand(s, seed=20 + i) for i, s in enumerate(SEG_SHAPES)]
    x2d, spec = pk.pack_leaves(leaves)
    x2d, seg_ids = seg.pad_rows(x2d, jnp.asarray(spec.seg_ids()),
                                interpret=True, slab_rows=slab)
    return leaves, x2d, seg_ids, spec


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


SLABS = [None, 128]


# ---------------------------------------------------------------------------
# Kernel layer: segmented_stats / segmented_encode vs jnp references
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("slab", SLABS)
def test_segmented_stats_matches_histogram_and_absmax(slab):
    """One stats sweep == the histogram kernel's output + per-leaf max|x|."""
    leaves, x2d, seg_ids, spec = _packed(slab)
    hist, amax = seg.segmented_stats(x2d, seg_ids, spec.num_segments,
                                     interpret=True, slab_rows=slab)
    want_hist = seg.segmented_histogram(x2d, seg_ids, spec.num_segments,
                                        interpret=True, slab_rows=slab)
    np.testing.assert_array_equal(np.asarray(hist), np.asarray(want_hist))
    assert amax.shape == (len(leaves), 1)
    for s, leaf in enumerate(leaves):
        want = float(jnp.max(jnp.abs(leaf)))
        assert float(amax[s, 0]) == want


@pytest.mark.parametrize("slab", SLABS)
def test_segmented_encode_matches_apply_and_packbits(slab):
    """The fused encode sweep == segmented_apply values + an LSB-first
    packbits of the keep mask + the kept counts, in one pass."""
    leaves, x2d, seg_ids, spec = _packed(slab)
    taus = jnp.asarray([0.3, 0.7, 1.1, 0.5])
    out, bm, kept = seg.segmented_encode(x2d, seg_ids, taus,
                                         interpret=True, slab_rows=slab)
    want_out, want_kept = seg.segmented_apply(x2d, seg_ids, taus,
                                              interpret=True, slab_rows=slab)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want_out))
    np.testing.assert_array_equal(np.asarray(kept), np.asarray(want_kept))
    tau_row = np.asarray(taus)[np.asarray(seg_ids)[:, 0]]
    keep = np.abs(np.asarray(x2d)) >= tau_row[:, None]
    want_bm = np.packbits(keep, axis=1, bitorder="little")
    np.testing.assert_array_equal(np.asarray(bm), want_bm)


@pytest.mark.parametrize("slab", SLABS)
def test_segmented_encode_quantized_matches_reference(slab):
    """With per-segment scales the sweep emits exactly
    clip(round(masked / scale), -127, 127) as int8 — the
    compression.quantize_int8 formula, applied in-kernel."""
    leaves, x2d, seg_ids, spec = _packed(slab)
    taus = jnp.asarray([0.3, 0.7, 1.1, 0.5])
    _, amax = seg.segmented_stats(x2d, seg_ids, spec.num_segments,
                                  interpret=True, slab_rows=slab)
    scales = jnp.maximum(amax[:, 0] / 127.0, 1e-12)
    out, bm, kept = seg.segmented_encode(x2d, seg_ids, taus, scales,
                                         interpret=True, slab_rows=slab)
    assert out.dtype == jnp.int8
    tau_row = np.asarray(taus)[np.asarray(seg_ids)[:, 0]]
    scale_row = np.asarray(scales)[np.asarray(seg_ids)[:, 0]]
    x = np.asarray(x2d)
    masked = np.where(np.abs(x) >= tau_row[:, None], x, 0.0)
    want = np.clip(np.round(masked / scale_row[:, None]),
                   -127, 127).astype(np.int8)
    np.testing.assert_array_equal(np.asarray(out), want)


def test_wirepath_sweep_budget_is_at_least_halved():
    """THE acceptance number: the fused path costs >= 2x fewer full-width
    HBM sweeps per upload than the jnp mask-then-codec path, in both the
    full pipeline and the assume_masked codec position."""
    full_fused = ops.wirepath_sweep_count(fused=True)
    full_jnp = ops.wirepath_sweep_count(fused=False)
    assert 2 * full_fused <= full_jnp
    codec_fused = ops.wirepath_sweep_count(fused=True, assume_masked=True)
    codec_jnp = ops.wirepath_sweep_count(fused=False, assume_masked=True)
    assert 2 * codec_fused <= codec_jnp
    # and the analytic bytes model agrees on the direction
    a = ops.wirepath_bytes_moved(10_000_000, 0.5, fused=True)
    b = ops.wirepath_bytes_moved(10_000_000, 0.5, fused=False)
    assert a["total"] < b["total"]
    assert a["payload_bytes"] == b["payload_bytes"]


# ---------------------------------------------------------------------------
# Codec layer: fused == jnp oracle, byte- and bit-exact, on every pairing
# ---------------------------------------------------------------------------
def _tree():
    return {"w": _rand((300, 77), 0), "b": _rand((7,), 1),
            "e": _rand((70000,), 2)}


def _masked(gamma):
    return mask_pytree(jax.random.PRNGKey(3), _tree(),
                       MaskingConfig(gamma=gamma, mode="selective"))


def _pairings(gamma):
    return {
        "coo": (SparseCodec(gamma=gamma),
                FusedSparseCodec(gamma=gamma)),
        "coo+int8": (ChainCodec((SparseCodec(gamma=gamma), Int8Codec())),
                     FusedSparseCodec(gamma=gamma, quantized=True)),
        "bitmap": (BitmapCodec(gamma=gamma),
                   FusedSparseCodec(gamma=gamma, wire="bitmap")),
        "bitmap+int8": (ChainCodec((BitmapCodec(gamma=gamma), Int8Codec())),
                        FusedSparseCodec(gamma=gamma, wire="bitmap",
                                         quantized=True)),
    }


@pytest.mark.parametrize("gamma", [0.1, 0.5])
@pytest.mark.parametrize("pairing", sorted(_pairings(0.1)))
def test_fused_codec_matches_jnp_oracle(gamma, pairing):
    """Every sparse wire pairing — COO / bitmap, plain / chained int8 —
    is byte-exact on wire_bytes and bit-exact on the decoded roundtrip
    vs its jnp oracle codec."""
    masked = _masked(gamma)
    oracle, fused = _pairings(gamma)[pairing]
    assert oracle.wire_bytes(masked) == fused.wire_bytes(masked)
    _assert_trees_equal(oracle.roundtrip(masked), fused.roundtrip(masked))


@pytest.mark.parametrize("wire", ["coo", "bitmap"])
def test_fused_unquantized_roundtrip_is_lossless(wire):
    """EF conservation at the codec layer: the unquantised fused wire
    reproduces the masked delta EXACTLY, so the error-feedback residual
    delta - decode(encode(masked)) equals delta - masked bit-for-bit."""
    masked = _masked(0.5)
    fused = FusedSparseCodec(gamma=0.5, wire=wire)
    _assert_trees_equal(fused.roundtrip(masked), masked)


def test_fused_codec_under_jit_vmap_stacked():
    """The engine position: a stacked (client-axis) masked delta through
    roundtrip_stacked under jit — bit-exact vs the jnp oracle."""
    masked = _masked(0.5)
    stacked = jax.tree_util.tree_map(lambda l: jnp.stack([l, 0.5 * l]),
                                     masked)
    f = jax.jit(lambda s: roundtrip_stacked(
        FusedSparseCodec(gamma=0.5, quantized=True), s))
    ref = roundtrip_stacked(
        ChainCodec((SparseCodec(gamma=0.5), Int8Codec())), stacked)
    _assert_trees_equal(f(stacked), ref)


# ---------------------------------------------------------------------------
# Engine layer: whole runs agree, EF residuals conserved, gate holds
# ---------------------------------------------------------------------------
@functools.lru_cache()
def _problem(num_clients, dim=32, classes=10, num_batches=2, batch=4):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (num_clients, num_batches, batch, dim))
    y = jax.random.randint(jax.random.fold_in(key, 1),
                           (num_clients, num_batches, batch), 0, classes)

    def loss_fn(params, data):
        xb, yb = data
        logp = jax.nn.log_softmax(xb @ params["w"] + params["b"])
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

    params = {"w": 0.1 * jax.random.normal(jax.random.fold_in(key, 2),
                                           (dim, classes)),
              "b": jnp.zeros((classes,))}
    n = np.ones((num_clients,), np.float32)
    return loss_fn, params, (x, y), n


# The weight leaf (32 x 10) clears min_leaf_size=256, so the wire codecs
# actually engage; the pairs share masking exactly and differ ONLY in the
# codec backend / wire format.
RUN_PAIRS = [("fig5", "fig5-fused"),
             ("fig5-int8", "fig5-fused-int8"),
             ("fig5", "fig5-bitmap")]


@pytest.mark.parametrize("jnp_preset,fused_preset", RUN_PAIRS)
def test_fused_run_matches_oracle_run_with_error_feedback(jnp_preset,
                                                          fused_preset):
    """Whole sync-engine runs through the fused/bitmap wire are
    bit-identical to the jnp-codec runs — params AND the EF residual
    state after every round (the conservation acceptance)."""
    M = 8
    loss_fn, params, batches, n = _problem(M)
    runs = {}
    for name in (jnp_preset, fused_preset):
        s = FederatedServer.from_strategy(
            strategy.get(name, error_feedback=True), loss_fn, params, M,
            seed=5, engine="cohort")
        s.run(batches, n, rounds=3)
        runs[name] = s
    _assert_trees_equal(runs[jnp_preset].params, runs[fused_preset].params)
    _assert_trees_equal(runs[jnp_preset]._residuals,
                        runs[fused_preset]._residuals)
    # the residuals are genuinely live (gamma < 1 leaves mass behind)
    assert any(np.asarray(leaf).any() for leaf in
               jax.tree_util.tree_leaves(runs[fused_preset]._residuals))


def test_async_decode_gate_quarantines_poisoned_fused_wire():
    """The async engine's decode/quarantine gate holds for the fused int8
    wire: injected-NaN uploads are rejected and the global params stay
    finite, with the per-round accounting still balancing."""
    M = 10
    loss_fn, params, batches, n = _problem(M)
    st_ = strategy.get("fig5-fused-int8", hetero=HeteroModel(profile="ideal"),
                       error_feedback=True,
                       async_cfg=AsyncConfig(corrupt_rate=0.5))
    s = FederatedServer.from_strategy(st_, loss_fn, params, M, seed=7,
                                      engine="async")
    s.run(batches, n, rounds=3)
    assert sum(r.quarantined for r in s.history) > 0
    for leaf in jax.tree_util.tree_leaves(s.params):
        assert np.isfinite(np.asarray(leaf)).all()
    for r in s.history:
        assert r.arrivals + r.quarantined + r.timeouts + r.dropped \
            == r.num_sampled


def test_async_degenerates_to_sync_with_fused_codec():
    """Keystone degeneration holds on the fused wire: ideal fleet, default
    AsyncConfig — async == sync cohort engine bit-exact, params and EF
    residuals."""
    M = 8
    loss_fn, params, batches, n = _problem(M)
    st_ = strategy.get("fig5-fused", hetero=HeteroModel(profile="ideal"),
                       error_feedback=True, async_cfg=AsyncConfig())
    sync = FederatedServer.from_strategy(st_, loss_fn, params, M, seed=3,
                                         engine="cohort")
    sync.run(batches, n, rounds=3)
    bufd = FederatedServer.from_strategy(st_, loss_fn, params, M, seed=3,
                                         engine="async")
    bufd.run(batches, n, rounds=3)
    _assert_trees_equal(sync.params, bufd.params)
    _assert_trees_equal(sync._residuals, bufd._residuals)


# ---------------------------------------------------------------------------
# COO <-> bitmap crossover (DESIGN.md §10 density rule)
# ---------------------------------------------------------------------------
@settings(deadline=None, max_examples=60)
@given(st.integers(min_value=64, max_value=200_000),
       st.floats(min_value=0.002, max_value=0.6),
       st.booleans())
def test_bitmap_coo_crossover_property(n, gamma, quantize):
    """bitmap (ceil(n/8) + k*vb) beats COO (k*(4+vb)) exactly when
    ceil(n/8) < 4k — i.e. kept density above ~1/32, independent of the
    value width vb.  The analytic model must honour the exact rule and
    the documented density approximation away from the boundary."""
    k = min(max(1, round(gamma * n)), n)
    pc = ops.wirepath_bytes_moved(n, gamma, fused=True, wire="coo",
                                  quantize=quantize)["payload_bytes"]
    pb = ops.wirepath_bytes_moved(n, gamma, fused=True, wire="bitmap",
                                  quantize=quantize)["payload_bytes"]
    assert (pb < pc) == ((n + 7) // 8 < 4 * k)
    if 32 * k >= n + 8:          # safely above the crossover
        assert pb < pc
    if 32 * k <= n - 8:          # safely below
        assert pc < pb


def test_bitmap_coo_crossover_on_real_wire_bytes():
    """The same crossover measured on the REAL codecs' wire_bytes: at 1%
    density COO is smaller, at 20% the bitmap wire is smaller."""
    tree = {"e": _rand((8192,), 9)}
    for gamma, bitmap_wins in ((0.01, False), (0.2, True)):
        masked = mask_pytree(jax.random.PRNGKey(4), tree,
                             MaskingConfig(gamma=gamma, mode="selective"))
        coo = SparseCodec(gamma=gamma).wire_bytes(masked)
        bmp = BitmapCodec(gamma=gamma).wire_bytes(masked)
        assert (bmp < coo) == bitmap_wins


# ---------------------------------------------------------------------------
# Malformed bitmap payloads: the loud-failure contract
# ---------------------------------------------------------------------------
def _good_payload():
    masked = jnp.zeros((20,)).at[jnp.asarray([2, 7, 13])].set(
        jnp.asarray([1.0, -2.0, 3.0]))
    return encode_bitmap(masked, 4)


def test_encode_bitmap_rejects_bad_budget():
    masked = jnp.ones((8,))
    with pytest.raises(ValueError, match="needs k >= 1"):
        encode_bitmap(masked, 0)
    with pytest.raises(ValueError, match="exceeds tensor size"):
        encode_bitmap(masked, 9)


def test_decode_bitmap_roundtrip_and_loud_failures():
    p = _good_payload()
    dec = decode_bitmap(p)
    np.testing.assert_array_equal(
        np.asarray(dec),
        np.asarray(jnp.zeros((20,)).at[jnp.asarray([2, 7, 13])].set(
            jnp.asarray([1.0, -2.0, 3.0]))))

    with pytest.raises(ValueError, match="missing keys"):
        decode_bitmap({k: v for k, v in p.items() if k != "bitmap"})
    with pytest.raises(ValueError, match="must be uint8"):
        decode_bitmap({**p, "bitmap": p["bitmap"].astype(jnp.int32)})
    with pytest.raises(ValueError, match="must be 1-D"):
        decode_bitmap({**p, "values": p["values"][None, :]})
    with pytest.raises(ValueError, match="negative shape"):
        decode_bitmap({**p, "shape": np.asarray([-20], np.int32)})
    with pytest.raises(ValueError, match="expected"):
        decode_bitmap({**p, "bitmap": p["bitmap"][:-1]})
    with pytest.raises(ValueError, match="value slots"):
        decode_bitmap({**p, "values": jnp.zeros((0,))})
    with pytest.raises(ValueError, match="value slots"):
        decode_bitmap({**p, "values": jnp.zeros((21,))})

    stray = np.asarray(p["bitmap"]).copy()
    stray[2] |= 1 << 7                      # bit 23 >= size 20: padding
    with pytest.raises(ValueError, match="trailing"):
        decode_bitmap({**p, "bitmap": jnp.asarray(stray)})

    full = np.asarray([0xFF, 0xFF, 0x0F], np.uint8)   # popcount 20 > k=4
    with pytest.raises(ValueError, match="popcount"):
        decode_bitmap({**p, "bitmap": jnp.asarray(full)})

    with pytest.raises(ValueError, match="non-finite"):
        decode_bitmap({**p, "values": p["values"].at[0].set(jnp.nan)})
