"""Federated-learning integration tests: the paper's claims in miniature.

These train real (tiny) models on CPU, so sizes are kept deliberately small;
they assert the *comparative* structure of the paper's results, not absolute
accuracies."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ClientConfig, DynamicSampling, FederatedConfig,
                        FederatedServer, MaskingConfig, StaticSampling)
from repro.core.client import client_update
from repro.core.federated import fedavg_aggregate
from repro.data import class_gaussian_images, iid_partition_images
from repro.models import (classifier_accuracy, classifier_loss, init_lenet,
                          lenet_forward)


def _setup(num_clients=8, batch=16, image_size=10, seed=0):
    data = class_gaussian_images(num_train=num_clients * 64, num_test=256,
                                 image_size=image_size, noise=0.5, seed=seed)
    xs, ys, n = iid_partition_images(data.train_x, data.train_y, num_clients,
                                     batch, seed=seed)
    batches = (jnp.asarray(xs), jnp.asarray(ys))
    loss_fn = classifier_loss(lenet_forward)
    params = init_lenet(jax.random.PRNGKey(seed), image_size)
    eval_fn = classifier_accuracy(lenet_forward)
    eval_data = (jnp.asarray(data.test_x), jnp.asarray(data.test_y))
    return loss_fn, params, batches, n, eval_fn, eval_data


def _run(schedule, masking, rounds=8, seed=0, error_feedback=False, lr=0.05):
    loss_fn, params, batches, n, eval_fn, eval_data = _setup(seed=seed)
    cfg = FederatedConfig(
        num_clients=8,
        client=ClientConfig(local_epochs=1, learning_rate=lr,
                            masking=masking),
        error_feedback=error_feedback)
    server = FederatedServer(loss_fn, schedule, cfg, params,
                             eval_fn=jax.jit(eval_fn))
    server.run(batches, n, rounds, eval_every=rounds, eval_data=eval_data)
    return server


def test_federated_training_learns():
    # lr tuned so this seeded deterministic run clears the bar with margin
    # (lr=0.08 landed at 0.379, a hair under 0.4; 0.12 reaches ~0.64).
    s = _run(StaticSampling(initial_rate=1.0), MaskingConfig(mode="none"),
             rounds=16, lr=0.12)
    assert s.history[-1].mean_loss < s.history[0].mean_loss
    assert s.summary()["final_eval"] > 0.4        # 10-class task, 4x chance


def test_dynamic_sampling_saves_transport():
    st = _run(StaticSampling(initial_rate=1.0), MaskingConfig(mode="none"),
              seed=1)
    dy = _run(DynamicSampling(initial_rate=1.0, beta=0.2),
              MaskingConfig(mode="none"), seed=1)
    assert dy.total_transport_units() < 0.8 * st.total_transport_units()
    # and still learns
    assert dy.history[-1].mean_loss < dy.history[0].mean_loss


def test_dynamic_sampling_uses_fewer_clients_over_time():
    dy = _run(DynamicSampling(initial_rate=1.0, beta=0.3),
              MaskingConfig(mode="none"))
    sampled = [r.num_sampled for r in dy.history]
    # t starts at 1 (Alg. 3): round 1 already decays to round(8*e^-0.3)=6
    assert sampled[0] == 6
    assert sampled[-1] == 2        # floor of two clients (paper §4.1)
    assert all(a >= b for a, b in zip(sampled, sampled[1:]))


@pytest.mark.parametrize("mode", ["random", "selective"])
def test_masked_training_still_learns(mode):
    s = _run(StaticSampling(initial_rate=1.0),
             MaskingConfig(mode=mode, gamma=0.3), rounds=10)
    assert s.history[-1].mean_loss < s.history[0].mean_loss


def test_selective_beats_random_at_small_gamma():
    """Paper Fig. 4: at small masking rate (gamma = fraction KEPT), random
    masking collapses while selective masking keeps training."""
    rand_loss = []
    sel_loss = []
    for seed in (0, 1, 2):
        r = _run(StaticSampling(initial_rate=1.0),
                 MaskingConfig(mode="random", gamma=0.1), rounds=10,
                 seed=seed)
        s = _run(StaticSampling(initial_rate=1.0),
                 MaskingConfig(mode="selective", gamma=0.1), rounds=10,
                 seed=seed)
        rand_loss.append(r.history[-1].mean_loss)
        sel_loss.append(s.history[-1].mean_loss)
    assert np.mean(sel_loss) < np.mean(rand_loss), (sel_loss, rand_loss)


def test_transport_bytes_metering():
    dense = _run(StaticSampling(initial_rate=1.0), MaskingConfig(mode="none"),
                 rounds=2)
    masked = _run(StaticSampling(initial_rate=1.0),
                  MaskingConfig(mode="selective", gamma=0.1), rounds=2)
    assert masked.total_transport_bytes() < 0.35 * dense.total_transport_bytes()


def test_error_feedback_improves_small_gamma():
    """Beyond-paper: DGC-style residual accumulation recovers most of the
    loss gap at gamma=0.1."""
    base = _run(StaticSampling(initial_rate=1.0),
                MaskingConfig(mode="selective", gamma=0.05), rounds=10)
    ef = _run(StaticSampling(initial_rate=1.0),
              MaskingConfig(mode="selective", gamma=0.05), rounds=10,
              error_feedback=True)
    assert ef.history[-1].mean_loss <= base.history[-1].mean_loss * 1.05


def test_upload_semantics_delta_equals_zero_when_unmasked():
    """With no masking, "delta" and "zero" upload semantics give identical
    aggregates (sanity for the Alg. 4 literal path)."""
    loss_fn, params, batches, n, _, _ = _setup()
    key = jax.random.PRNGKey(0)
    for upload in ("delta", "zero"):
        cfg = ClientConfig(local_epochs=1, learning_rate=0.05,
                           masking=MaskingConfig(mode="none"), upload=upload)
        up, _, _, _ = client_update(
            loss_fn, params, jax.tree.map(lambda b: b[0], batches), key, cfg)
        agg = fedavg_aggregate(params, jax.tree.map(lambda u: u[None], up),
                               jnp.ones((1,)), upload)
        if upload == "delta":
            ref = agg
    got = agg
    flat_a = jax.tree_util.tree_leaves(ref)
    flat_b = jax.tree_util.tree_leaves(got)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_fed_pod_round_runs_and_learns():
    """launch/fedtrain make_fed_round (the pod-scale jit form) on CPU with a
    reduced arch: loss decreases over rounds, participation respected."""
    from repro.configs import get_arch
    from repro.launch.fedtrain import FedPodConfig, make_fed_round
    from repro.models import transformer as tr

    cfg = get_arch("qwen2-1.5b").reduced()
    C, S, b, T = 4, 2, 2, 32
    fed_cfg = FedPodConfig(num_clients=C, local_steps=S, learning_rate=0.5,
                           gamma=0.3)
    fed_round = jax.jit(make_fed_round(cfg, fed_cfg))
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (C, S, b, T), 0, cfg.vocab_size)
    batches = {"tokens": toks, "labels": jnp.roll(toks, -1, -1)}
    n_samples = jnp.ones((C,), jnp.float32)
    part = jnp.asarray([1.0, 1.0, 1.0, 0.0])

    losses = []
    for t in range(3):
        params, m = fed_round(params, batches, n_samples, part,
                              jax.random.fold_in(key, t))
        assert int(m["num_sampled"]) == 3
        losses.append(float(m["mean_loss"]))
    assert losses[-1] < losses[0]
