"""Byzantine-robust aggregators (repro.core.robust; DESIGN.md §9).

Three layers: construction-time knob validation, the algebraic invariants
the engine equivalences rely on (honest-fleet degeneration to FedAvg,
zero-weight rows exactly absent), and the breakdown-point property — a
fleet with f = 0.3 amplified sign-flip adversaries trains DOWN under the
robust rules while plain FedAvg climbs.  The cohort-vs-oracle and
async-degeneration properties for the registered robust presets live in
tests/test_strategy.py and tests/test_async.py.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FederatedServer, strategy
from repro.core.attacks import AttackModel
from repro.core.federated import fedavg_aggregate
from repro.core.robust import (coordinate_median, krum, multi_krum,
                               norm_filter, trimmed_mean)
from repro.core.sampling import ImportanceSampler


@functools.lru_cache()
def _problem(num_clients, dim=8, classes=3, num_batches=2, batch=4, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (num_clients, num_batches, batch, dim))
    y = jax.random.randint(jax.random.fold_in(key, 1),
                           (num_clients, num_batches, batch), 0, classes)

    def loss_fn(params, data):
        xb, yb = data
        logp = jax.nn.log_softmax(xb @ params["w"] + params["b"])
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

    params = {"w": 0.1 * jax.random.normal(jax.random.fold_in(key, 2),
                                           (dim, classes)),
              "b": jnp.zeros((classes,))}
    n = np.ones((num_clients,), np.float32)
    return loss_fn, params, (x, y), n


_G = {"w": jnp.zeros((4,), jnp.float32)}
_UPS = {"w": jnp.array([[1.0, 2.0, 3.0, 4.0],
                        [100.0, -5.0, 0.0, 7.0],
                        [0.5, 0.5, 0.5, 0.5]])}
_W = jnp.array([1.0, 0.0, 2.0])

ROBUST_FACTORIES = {
    "coordinate_median": coordinate_median,
    "trimmed_mean(0.2)": lambda: trimmed_mean(0.2),
    "krum(0)": lambda: krum(0),
    "multi_krum(1,2)": lambda: multi_krum(1, 2),
    "norm_filter(5.0)": lambda: norm_filter(5.0),
}


# ---------------------------------------------------------------------------
# construction-time validation
# ---------------------------------------------------------------------------
def test_factory_args_validated_at_construction():
    with pytest.raises(ValueError, match="max_norm"):
        strategy.clipped_fedavg(-1.0)
    with pytest.raises(ValueError, match="max_norm"):
        strategy.clipped_fedavg(0.0)
    with pytest.raises(ValueError, match="beta"):
        trimmed_mean(0.5)
    with pytest.raises(ValueError, match="beta"):
        trimmed_mean(-0.1)
    with pytest.raises(ValueError, match="f"):
        krum(-1)
    with pytest.raises(ValueError, match="f"):
        multi_krum(-2, 1)
    with pytest.raises(ValueError, match="m"):
        multi_krum(1, 0)
    with pytest.raises(ValueError, match="max_norm"):
        norm_filter(0.0)


def test_get_aggregator_registry():
    assert strategy.get_aggregator("fedavg").name == "fedavg"
    assert strategy.get_aggregator("trimmed_mean", 0.2).name == \
        "trimmed_mean(0.2)"
    assert not strategy.get_aggregator("krum", 1).ht_compatible
    with pytest.raises(KeyError, match="unknown aggregator"):
        strategy.get_aggregator("median-of-means")


# ---------------------------------------------------------------------------
# honest-fleet degeneration
# ---------------------------------------------------------------------------
def test_trimmed_mean_zero_beta_is_fedavg_bit_exact():
    """beta = 0 returns the fedavg fn ITSELF — degeneration by identity."""
    assert trimmed_mean(0.0).fn is fedavg_aggregate


def test_median_equals_fedavg_at_single_client():
    g = {"w": jnp.asarray([0.25, -1.5], jnp.float32)}
    u = {"w": jnp.asarray([[0.125, 3.75]], jnp.float32)}
    w = jnp.asarray([7.0])
    med = coordinate_median().fn(g, u, w, "delta")
    avg = fedavg_aggregate(g, u, w, "delta")
    np.testing.assert_array_equal(np.asarray(med["w"]), np.asarray(avg["w"]))


def test_weighted_median_and_trim_examples():
    """Hand-checked values: weights [1, 0, 2] over rows [1..4], junk,
    [0.5]*4 — the zero-weight row never matters, the w=2 row holds the
    median, and a 0.2-trim clips one third of the heavy row's mass."""
    med = coordinate_median().fn(_G, _UPS, _W, "delta")
    np.testing.assert_array_equal(np.asarray(med["w"]),
                                  np.full((4,), 0.5, np.float32))
    tm = trimmed_mean(0.2).fn(_G, _UPS, _W, "delta")
    # per coord: sorted masses trim 0.6 off each tail of total 3.0
    expect = []
    for c in range(4):
        vals = np.asarray(_UPS["w"])[:, c]
        order = np.argsort(vals, kind="stable")
        ws = np.asarray(_W)[order]
        cum = np.cumsum(ws)
        kept = np.clip(np.minimum(cum, 2.4) - np.maximum(cum - ws, 0.6),
                       0.0, None)
        expect.append((kept * vals[order]).sum() / kept.sum())
    np.testing.assert_allclose(np.asarray(tm["w"]), expect, rtol=1e-6)


def test_krum_picks_central_candidate_and_filter_drops_outlier():
    out = krum(0).fn(_G, _UPS, _W, "delta")
    # row 1 (the 100-valued outlier) has weight 0 -> candidates are rows
    # 0 and 2; both score d(0,2), argmin tie breaks to row 0.
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(_UPS["w"])[0])
    nf = norm_filter(5.0).fn(_G, _UPS, jnp.ones((3,)), "delta")
    # rows 0 (norm ~5.48) and 1 are rejected; only row 2 survives
    np.testing.assert_array_equal(np.asarray(nf["w"]),
                                  np.asarray(_UPS["w"])[2])


# ---------------------------------------------------------------------------
# zero-weight rows are absent (the oracle-equivalence contract)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(ROBUST_FACTORIES))
def test_zero_weight_rows_are_exactly_absent(name):
    """Appending arbitrary finite zero-weight rows — the oracle's
    non-participants, post-quarantine — must not change a single bit."""
    agg = ROBUST_FACTORIES[name]()
    junk = {"w": jnp.array([[9e9, -3.0, 2.0, 1.0],
                            [7.0, 7.0, 7.0, 7.0]])}
    ups2 = {"w": jnp.concatenate([_UPS["w"], junk["w"]])}
    w2 = jnp.concatenate([_W, jnp.zeros((2,))])
    base = agg.fn(_G, _UPS, _W, "delta")
    padded = agg.fn(_G, ups2, w2, "delta")
    np.testing.assert_array_equal(np.asarray(base["w"]),
                                  np.asarray(padded["w"]))


def test_empty_round_is_noop():
    w0 = jnp.zeros((3,))
    for name in sorted(ROBUST_FACTORIES):
        out = ROBUST_FACTORIES[name]().fn(_G, _UPS, w0, "delta")
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.zeros((4,), np.float32),
                                      err_msg=name)


# ---------------------------------------------------------------------------
# HT-compat matrix: Krum x Horvitz-Thompson rejected at build time
# ---------------------------------------------------------------------------
def test_krum_with_ht_sampler_raises_at_build_time():
    loss_fn, params, _, _ = _problem(8)
    st = strategy.get("fig3-importance").replace(
        aggregator=multi_krum(1, 2))
    with pytest.raises(TypeError, match="Horvitz-Thompson"):
        strategy.build_round(st, loss_fn, 8, form="full")
    # the weighted-rank rules DO accept HT weights
    ok = strategy.get("fig3-importance").replace(
        aggregator=coordinate_median())
    strategy.build_round(ok, loss_fn, 8, form="full")
    assert isinstance(ImportanceSampler().normalize, bool)


# ---------------------------------------------------------------------------
# breakdown point: one aggregation step, then a short training run
# ---------------------------------------------------------------------------
def test_sign_flip_below_breakdown_cannot_move_median():
    """30% of the weight mass uploads -4u: the weighted median per
    coordinate is still an honest value, while the FedAvg mean flips sign
    (strength 4 > (1-f)/f ≈ 2.33)."""
    rows = jnp.concatenate([jnp.ones((7, 5)), -4.0 * jnp.ones((3, 5))])
    g = {"w": jnp.zeros((5,), jnp.float32)}
    w = jnp.ones((10,))
    med = coordinate_median().fn(g, {"w": rows}, w, "delta")
    np.testing.assert_array_equal(np.asarray(med["w"]), np.ones((5,)))
    tm = trimmed_mean(0.3).fn(g, {"w": rows}, w, "delta")
    np.testing.assert_allclose(np.asarray(tm["w"]), np.ones((5,)),
                               rtol=1e-5)
    avg = fedavg_aggregate(g, {"w": rows}, w, "delta")
    assert float(np.asarray(avg["w"])[0]) < 0.0  # ascent direction
    mk = multi_krum(3, 4).fn(g, {"w": rows}, w, "delta")
    np.testing.assert_allclose(np.asarray(mk["w"]), np.ones((5,)),
                               rtol=1e-5)


def test_breakdown_training_run_bounded_vs_unbounded():
    """6 attacked rounds, dense uploads: the median-aggregated model's
    loss stays at-or-below its start while plain FedAvg's climbs — the
    chaos criterion in miniature (the full curve grid lives in
    benchmarks/robust_agg.py)."""
    M = 10
    loss_fn, params, batches, n = _problem(M)
    attack = AttackModel(kind="sign_flip", fraction=0.3, strength=4.0)
    finals = {}
    for name, agg in [("fedavg", strategy.FEDAVG),
                      ("median", coordinate_median())]:
        st = strategy.get("fig3", learning_rate=0.3).replace(
            attack=attack, aggregator=agg)
        s = FederatedServer.from_strategy(st, loss_fn, params, M, seed=1)
        s.run(batches, n, rounds=6)
        assert any(r.adversarial > 0 for r in s.history)
        finals[name] = [r.mean_loss for r in s.history]
    assert finals["median"][-1] < finals["median"][0]
    assert finals["fedavg"][-1] > finals["fedavg"][0]
