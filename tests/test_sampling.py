"""Sampling schedules (paper §3.2 / §4.1) + transport cost (Eq. 6) +
client samplers (uniform / importance / threshold, DESIGN.md §5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st

from repro.core.sampling import (DynamicSampling, ImportanceSampler,
                                 StaticSampling, ThresholdSampler,
                                 UniformSampler, cumulative_transport,
                                 get_sampler, participation_mask,
                                 rounds_for_budget, sample_clients,
                                 transmit_probabilities, transport_cost)


def test_static_rate_constant():
    s = StaticSampling(initial_rate=0.3)
    for t in [1, 10, 100]:
        assert float(s.rate(t)) == pytest.approx(0.3)


def test_dynamic_rate_matches_eq3():
    s = DynamicSampling(initial_rate=1.0, beta=0.1)
    for t in [1, 5, 31]:
        assert float(s.rate(t)) == pytest.approx(np.exp(-0.1 * t), rel=1e-6)


def test_min_clients_floor():
    s = DynamicSampling(initial_rate=1.0, beta=2.0, min_clients=2)
    assert int(s.num_clients(100, 100)) == 2


def test_num_clients_capped_at_registered():
    s = StaticSampling(initial_rate=1.0)
    assert int(s.num_clients(1, 8)) == 8


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 50),
       st.sampled_from([0.01, 0.1, 0.5]))
@settings(max_examples=20, deadline=None)
def test_participation_mask_exact_m(seed, t, beta):
    M = 64
    s = DynamicSampling(initial_rate=1.0, beta=beta)
    mask = participation_mask(jax.random.PRNGKey(seed), s, t, M)
    assert mask.shape == (M,)
    assert int(mask.sum()) == int(s.num_clients(t, M))
    assert set(np.unique(np.asarray(mask))) <= {0.0, 1.0}


def test_sample_clients_unique():
    s = StaticSampling(initial_rate=0.5)
    ids = sample_clients(jax.random.PRNGKey(0), s, 1, 20)
    assert len(set(np.asarray(ids).tolist())) == 10


def test_transport_cost_eq6_static():
    # static: f = gamma * C
    s = StaticSampling(initial_rate=0.4)
    assert transport_cost(s, gamma=0.5, rounds=10) == pytest.approx(0.2)


def test_transport_cost_eq6_dynamic():
    s = DynamicSampling(initial_rate=1.0, beta=0.1)
    expect = 0.3 / 50 * sum(np.exp(-0.1 * t) for t in range(1, 51))
    assert transport_cost(s, 0.3, 50) == pytest.approx(expect, rel=1e-5)


def test_paper_claim_rounds_for_budget():
    """Paper §5.2: with beta=0.1 dynamic trains ~31 rounds for the budget
    that static spends in 10 — in the paper's own (Eq. 6, rate-based,
    t from 0) accounting: sum_{t=0..30} e^{-0.1 t} ~= 10.04."""
    rates = np.exp(-0.1 * np.arange(0, 31))
    assert rates.sum() == pytest.approx(10.0, rel=0.02)

    # With integer client counts and the paper's 2-client floor (our
    # deployable accounting) the break-even lands later — still far past
    # static's 10 rounds, which is the claim that matters.
    M = 100
    static = StaticSampling(initial_rate=1.0)
    dynamic = DynamicSampling(initial_rate=1.0, beta=0.1, min_clients=2)
    budget = cumulative_transport(static, 1.0, 10, M)     # 10 * M
    r = rounds_for_budget(dynamic, 1.0, M, budget)
    assert r >= 31, r


# ---- cohort bucketing API (DESIGN.md §3.5) --------------------------------
def test_bucket_ladder_powers_of_two_capped_at_m():
    s = DynamicSampling(initial_rate=1.0, beta=0.1, min_clients=2)
    assert s.bucket_ladder(1024) == (2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)
    assert s.bucket_ladder(8) == (2, 4, 8)
    # non-power-of-two M: ladder still caps at (and includes) M
    assert s.bucket_ladder(12) == (2, 4, 8, 12)
    assert s.bucket_ladder(1) == (1,)
    # min_clients floors the smallest bucket
    assert DynamicSampling(min_clients=5).bucket_ladder(64)[0] == 8


def test_bucket_for_smallest_fitting():
    s = DynamicSampling(initial_rate=1.0, beta=0.1, min_clients=2)
    assert s.bucket_for(3, 1024) == 4
    assert s.bucket_for(4, 1024) == 4
    assert s.bucket_for(5, 1024) == 8
    assert s.bucket_for(1000, 1024) == 1024
    assert s.bucket_for(9, 12) == 12


@given(st.integers(1, 40), st.sampled_from([0.01, 0.1, 0.5]),
       st.sampled_from([7, 16, 100]))
@settings(max_examples=20, deadline=None)
def test_num_clients_host_matches_traced(t, beta, M):
    s = DynamicSampling(initial_rate=1.0, beta=beta, min_clients=2)
    assert s.num_clients_host(t, M) == int(s.num_clients(t, M))


def test_round_buckets_cover_and_shrink():
    M = 64
    s = DynamicSampling(initial_rate=1.0, beta=0.3, min_clients=2)
    plan = s.round_buckets(12, M)
    ladder = set(s.bucket_ladder(M))
    for m, bucket in plan:
        assert bucket in ladder and bucket >= m
    buckets = [b for _, b in plan]
    assert buckets[0] == M            # round 1 still near-full participation
    assert buckets[-1] == 2           # annealed to the floor bucket
    assert all(a >= b for a, b in zip(buckets, buckets[1:]))


def test_dynamic_cheaper_than_static_long_run():
    M = 50
    st_ = StaticSampling(initial_rate=1.0)
    dy = DynamicSampling(initial_rate=1.0, beta=0.05)
    assert cumulative_transport(dy, 1.0, 100, M) < \
        cumulative_transport(st_, 1.0, 100, M)


# ---- client samplers (DESIGN.md §5) ---------------------------------------
def test_get_sampler():
    assert isinstance(get_sampler("uniform"), UniformSampler)
    assert isinstance(get_sampler("importance"), ImportanceSampler)
    assert get_sampler("threshold", slack=3.0).slack == 3.0
    with pytest.raises(ValueError, match="unknown sampler"):
        get_sampler("bogus")
    with pytest.raises(ValueError, match="exploration"):
        ImportanceSampler(exploration=0.0)
    with pytest.raises(ValueError, match="slack"):
        ThresholdSampler(slack=0.5)


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 30))
@settings(max_examples=15, deadline=None)
def test_uniform_sampler_bit_identical_to_schedule_path(seed, t):
    """The default sampler IS the current schedule-only path: same key =>
    the exact participation_mask draw, weights = mask * n_samples."""
    M = 32
    sched = DynamicSampling(initial_rate=1.0, beta=0.15, min_clients=2)
    key = jax.random.PRNGKey(seed)
    n = jnp.asarray(np.random.default_rng(seed).uniform(1, 5, M), jnp.float32)
    part, weights = UniformSampler().select(key, sched, jnp.float32(t), M, n)
    ref = participation_mask(key, sched, jnp.float32(t), M)
    np.testing.assert_array_equal(np.asarray(part), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(weights), np.asarray(ref * n))


def test_importance_probabilities_valid_distribution():
    """p is a distribution: >= exploration floor, sums to 1, tracks norms."""
    smp = ImportanceSampler(exploration=0.2)
    norms = jnp.asarray([0.0, 1.0, 3.0, 0.5, 0.0, 2.0, 0.1, 1.4])
    p = np.asarray(smp.probabilities(norms))
    assert p.sum() == pytest.approx(1.0, rel=1e-6)
    assert (p >= 0.2 / 8 - 1e-7).all()
    assert p[2] == p.max() and p[2] > p[3] > p[0]


@given(st.integers(1, 16), st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_transmit_probabilities_waterfill(m, seed):
    """Sum of transmit probs == m, probs in (0, 1], high norms saturate."""
    M = 16
    norms = np.random.default_rng(seed).uniform(0.01, 3.0, M)
    p = np.asarray(transmit_probabilities(jnp.asarray(norms), m))
    assert p.sum() == pytest.approx(m, rel=1e-4)
    assert (p > 0).all() and (p <= 1.0 + 1e-6).all()
    if m < M:
        # monotone in the norms: a larger norm never transmits less often
        order = np.argsort(norms)
        assert (np.diff(p[order]) >= -1e-6).all()
    else:
        np.testing.assert_allclose(p, 1.0)


@pytest.mark.parametrize("sampler_name", ["importance", "threshold"])
def test_adaptive_sampler_aggregation_unbiased(sampler_name):
    """E[sum_i w_i u_i] == sum_i (n_i/n) u_i over selection seeds, for
    fixed uploads and arbitrary tracked norms (statistical tolerance)."""
    M = 12
    sched = StaticSampling(initial_rate=0.5, min_clients=2)
    rng = np.random.default_rng(3)
    norms = jnp.asarray(rng.uniform(0.05, 2.0, M), jnp.float32)
    u = jnp.asarray(rng.normal(size=(M,)), jnp.float32)
    n = jnp.asarray(rng.uniform(1.0, 4.0, M), jnp.float32)
    target = float(jnp.sum(n / n.sum() * u))

    smp = get_sampler(sampler_name)
    assert smp.adaptive and not smp.normalize
    sel = jax.jit(lambda k: smp.select(k, sched, jnp.float32(2.0), M, n,
                                       norms))
    ests = []
    for seed in range(3000):
        part, w = sel(jax.random.PRNGKey(seed))
        w = np.asarray(w)
        part = np.asarray(part)
        assert (w[part == 0] == 0).all()       # weights live on participants
        ests.append(float(w @ np.asarray(u)))
    stderr = np.std(ests) / np.sqrt(len(ests))
    assert abs(np.mean(ests) - target) < 4 * stderr + 1e-4, \
        (np.mean(ests), target, stderr)


def test_threshold_sampler_respects_cohort_bucket():
    """Participant count never exceeds the sampler's advertised bucket."""
    M = 16
    sched = DynamicSampling(initial_rate=0.8, beta=0.1, min_clients=2)
    smp = ThresholdSampler()
    norms = jnp.asarray(np.random.default_rng(0).uniform(0.1, 2.0, M),
                        jnp.float32)
    n = jnp.ones((M,), jnp.float32)
    for t in range(1, 8):
        m = sched.num_clients_host(t, M)
        bucket = smp.cohort_bucket(sched, m, M)
        assert bucket in sched.bucket_ladder(M)
        for seed in range(30):
            part, _ = smp.select(jax.random.PRNGKey(seed * 97 + t), sched,
                                 jnp.float32(t), M, n, norms)
            assert int(np.asarray(part).sum()) <= bucket
