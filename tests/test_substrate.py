"""Substrate tests: compression/byte accounting, checkpointing, optimizers,
data pipeline."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.core.compression import (decode_sparse, encode_sparse,
                                    payload_bytes, pytree_payload_bytes)
from repro.data import (class_gaussian_images, iid_partition_images,
                        markov_text, noniid_partition_images, partition_text)
from repro.optim import (adafactor, adam, adamw, apply_updates,
                         clip_by_global_norm, sgd)
from repro.optim.schedules import cosine_decay, warmup_cosine


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------
def test_payload_bytes_auto_picks_cheaper():
    b_small, enc_small = payload_bytes(10_000, 0.01)    # coord wins
    assert enc_small == "coordinate"
    b_big, enc_big = payload_bytes(10_000, 0.5)         # bitmap wins
    assert enc_big == "bitmap"
    assert b_small == round(0.01 * 10_000) * 8
    assert b_big == 5000 * 4 + 1250


def test_payload_dense_at_gamma_1():
    b, enc = payload_bytes(1000, 1.0)
    assert enc == "dense" and b == 4000


@given(st.integers(1, 5000), st.sampled_from([0.05, 0.3, 0.9]))
@settings(max_examples=30, deadline=None)
def test_payload_never_exceeds_dense(n, gamma):
    b, _ = payload_bytes(n, gamma)
    assert b <= n * 4 + (n + 7) // 8


def test_sparse_roundtrip():
    x = jnp.zeros((64,)).at[jnp.asarray([3, 17, 50])].set(
        jnp.asarray([1.5, -2.0, 0.25])).reshape(8, 8)
    payload = encode_sparse(x, k=3)
    back = decode_sparse(payload)
    np.testing.assert_allclose(back, x)


def test_pytree_payload_accounts_small_leaves_dense():
    tree = {"big": jnp.zeros((1024,)), "small": jnp.zeros((16,))}
    stats = pytree_payload_bytes(tree, gamma=0.1, min_leaf_size=256)
    assert stats.dense_bytes == (1024 + 16) * 4
    expected_sparse = payload_bytes(1024, 0.1)[0] + 16 * 4
    assert stats.sparse_bytes == expected_sparse


def test_pytree_payload_reports_per_encoding_split():
    """Mixed uploads (coordinate big leaves + dense small ones) must report
    the byte split per encoding, not just the last leaf's choice."""
    tree = {"big": jnp.zeros((10_000,)), "small": jnp.zeros((16,))}
    stats = pytree_payload_bytes(tree, gamma=0.01, min_leaf_size=256)
    assert stats.encoding == "mixed"
    assert set(stats.encoding_bytes) == {"coordinate", "dense"}
    assert stats.encoding_bytes["dense"] == 16 * 4
    assert sum(stats.encoding_bytes.values()) == stats.sparse_bytes
    # single-encoding tree keeps a concrete label
    solo = pytree_payload_bytes({"w": jnp.zeros((4096,))}, gamma=0.5,
                                min_leaf_size=256)
    assert solo.encoding == "bitmap"
    assert solo.encoding_bytes == {"bitmap": solo.sparse_bytes}


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), 7, tree, extra={"note": "hi"})
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step, extra = restore_checkpoint(str(tmp_path), like)
    assert step == 7 and extra["note"] == "hi"
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_checkpoint_latest_and_shape_mismatch(tmp_path):
    tree = {"a": jnp.ones((2,))}
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"a": jnp.ones((3,))})


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------
def _quad_min(opt, steps=300):
    target = jnp.asarray([1.0, -2.0, 3.0])
    p = {"w": jnp.zeros(3)}
    s = opt.init(p)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda q: jnp.sum((q["w"] - target) ** 2))(p)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s

    for _ in range(steps):
        p, s = step(p, s)
    return float(jnp.max(jnp.abs(p["w"] - target)))


@pytest.mark.parametrize("opt", [sgd(0.1), sgd(0.05, momentum=0.9),
                                 adam(0.1), adamw(0.1, weight_decay=0.0),
                                 adafactor(0.3)])
def test_optimizers_minimise_quadratic(opt):
    assert _quad_min(opt) < 0.05


def test_adafactor_state_is_factored():
    opt = adafactor(0.1)
    p = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
    s = opt.init(p)
    assert s["v"]["w"]["vr"].shape == (64,)
    assert s["v"]["w"]["vc"].shape == (32,)
    assert s["v"]["b"]["v"].shape == (32,)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-4)


def test_schedules():
    cd = cosine_decay(1.0, 100)
    assert float(cd(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(cd(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)
    wc = warmup_cosine(1.0, 10, 100)
    assert float(wc(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(wc(jnp.asarray(10))) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_synthetic_images_learnable_stats():
    d = class_gaussian_images(num_train=512, num_test=128, image_size=8,
                              seed=0)
    assert d.train_x.shape == (512, 8, 8, 1)
    assert set(np.unique(d.train_y)) <= set(range(10))
    # classes differ in mean (separable signal exists)
    m0 = d.train_x[d.train_y == 0].mean(0)
    m1 = d.train_x[d.train_y == 1].mean(0)
    assert np.abs(m0 - m1).max() > 0.3


def test_markov_text_nonuniform():
    d = markov_text(num_train=20_000, num_test=1000, vocab_size=64, seed=0)
    counts = np.bincount(d.train_tokens, minlength=64)
    assert counts.max() > 3 * max(counts.min(), 1)   # Zipf-ish, not uniform


def test_iid_partition_shapes_and_coverage():
    d = class_gaussian_images(num_train=640, num_test=64, image_size=8)
    xs, ys, n = iid_partition_images(d.train_x, d.train_y, 10, 16)
    assert xs.shape == (10, 4, 16, 8, 8, 1)
    assert ys.shape == (10, 4, 16)
    np.testing.assert_array_equal(n, np.full(10, 64.0))


def test_noniid_partition_is_label_skewed():
    d = class_gaussian_images(num_train=2000, num_test=64, image_size=8)
    xs, ys, _ = noniid_partition_images(d.train_x, d.train_y, 10, 10,
                                        shards_per_client=2)
    labels_per_client = [len(np.unique(ys[c])) for c in range(10)]
    assert np.mean(labels_per_client) <= 4      # pathological skew


def test_partition_text_windows():
    d = markov_text(num_train=10_000, vocab_size=64)
    x, y, n = partition_text(d.train_tokens, 4, 8, 16)
    assert x.shape[0] == 4 and x.shape[-1] == 16
    np.testing.assert_array_equal(x[0, 0, 0, 1:], y[0, 0, 0, :-1])


# ---------------------------------------------------------------------------
# int8 quantised uploads (beyond-paper)
# ---------------------------------------------------------------------------
def test_int8_roundtrip_error_bounded():
    from repro.core.compression import dequantize_int8, quantize_int8
    x = jax.random.normal(jax.random.PRNGKey(0), (4096,))
    back = dequantize_int8(quantize_int8(x))
    # symmetric int8: max error <= scale/2 = max|x| / 254
    assert float(jnp.abs(back - x).max()) <= float(jnp.abs(x).max()) / 254 + 1e-9


def test_int8_preserves_masked_zeros():
    from repro.core.compression import dequantize_int8, quantize_int8
    from repro.core.masking import selective_mask_threshold
    x = selective_mask_threshold(
        jax.random.normal(jax.random.PRNGKey(1), (2048,)), 0.1)
    back = dequantize_int8(quantize_int8(x))
    np.testing.assert_array_equal(np.asarray(back == 0), np.asarray(x == 0))


def test_int8_quantized_federated_round_still_learns():
    """Masked + int8-quantised uploads keep the federated round convergent."""
    from repro.core.compression import dequantize_pytree, quantize_pytree
    from repro.core.masking import MaskingConfig, mask_pytree
    from repro.models import classifier_loss, init_lenet, lenet_forward
    from repro.data import class_gaussian_images, iid_partition_images
    import jax

    data = class_gaussian_images(num_train=256, num_test=64, image_size=8,
                                 noise=0.5, seed=0)
    xs, ys, _ = iid_partition_images(data.train_x, data.train_y, 4, 16)
    loss_fn = classifier_loss(lenet_forward)
    params = init_lenet(jax.random.PRNGKey(0), 8)
    key = jax.random.PRNGKey(1)

    losses = []
    for r in range(6):
        deltas = []
        for c in range(4):
            batch = (jnp.asarray(xs[c, 0]), jnp.asarray(ys[c, 0]))
            g = jax.grad(loss_fn)(params, batch)
            delta = jax.tree.map(lambda x: -0.1 * x, g)
            masked = mask_pytree(jax.random.fold_in(key, r * 4 + c), delta,
                                 MaskingConfig(mode="selective", gamma=0.3))
            deltas.append(dequantize_pytree(quantize_pytree(masked)))
        params = jax.tree.map(
            lambda p, *ds: p + sum(ds) / len(ds), params, *deltas)
        losses.append(float(loss_fn(params, (jnp.asarray(xs[0, 0]),
                                             jnp.asarray(ys[0, 0])))))
    assert losses[-1] < losses[0]
