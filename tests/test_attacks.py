"""Adversary injection (repro.core.attacks; DESIGN.md §9).

Covers the deterministic adversary assignment, each transform's unit
semantics (honest rows bit-exact pass-through), the promotion of the NaN
quarantine gate to the sync engines (satellite of PR 7: a poisoned upload
is zeroed and metered, in cohort and oracle alike), the cohort-vs-oracle
equality under every attack kind, and the async engine's composition of
attacks with its event-loop quarantine.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FederatedServer, strategy
from repro.core.attacks import AttackModel, attack_keys
from repro.core.hetero import HeteroModel


@functools.lru_cache()
def _problem(num_clients, dim=8, classes=3, num_batches=2, batch=4, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (num_clients, num_batches, batch, dim))
    y = jax.random.randint(jax.random.fold_in(key, 1),
                           (num_clients, num_batches, batch), 0, classes)

    def loss_fn(params, data):
        xb, yb = data
        logp = jax.nn.log_softmax(xb @ params["w"] + params["b"])
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

    params = {"w": 0.1 * jax.random.normal(jax.random.fold_in(key, 2),
                                           (dim, classes)),
              "b": jnp.zeros((classes,))}
    n = np.ones((num_clients,), np.float32)
    return loss_fn, params, (x, y), n


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# the model record
# ---------------------------------------------------------------------------
def test_attack_model_validation():
    with pytest.raises(ValueError, match="kind"):
        AttackModel(kind="bitflip")
    with pytest.raises(ValueError, match="fraction"):
        AttackModel(fraction=1.5)
    with pytest.raises(ValueError, match="strength"):
        AttackModel(strength=0.0)
    with pytest.raises(ValueError, match="sigma"):
        AttackModel(kind="gauss", fraction=0.5, sigma=-1.0)
    assert not AttackModel(fraction=0.0).active
    assert AttackModel(fraction=0.1).active


def test_adversary_mask_deterministic_and_sized():
    atk = AttackModel(kind="sign_flip", fraction=0.3, seed=11)
    m1, m2 = atk.adversary_mask(20), atk.adversary_mask(20)
    np.testing.assert_array_equal(m1, m2)
    assert m1.sum() == atk.num_adversaries(20) == 6
    # a different seed moves the assignment; fraction 0 empties it
    assert not np.array_equal(
        m1, AttackModel(kind="sign_flip", fraction=0.3, seed=12)
        .adversary_mask(20))
    assert AttackModel(fraction=0.0).adversary_mask(20).sum() == 0


@pytest.mark.parametrize("kind", ["sign_flip", "scale", "gauss", "zero",
                                  "nan"])
def test_apply_stacked_semantics(kind):
    """Adversary rows transform per kind; honest rows are bit-exact."""
    atk = AttackModel(kind=kind, fraction=0.5, strength=3.0, sigma=2.0)
    u = {"w": jnp.arange(12, dtype=jnp.float32).reshape(4, 3) + 1.0}
    adv = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    keys = attack_keys(jax.random.PRNGKey(0), 4)
    out = np.asarray(atk.apply_stacked(
        u, adv, keys if atk.needs_keys else None)["w"])
    ref = np.asarray(u["w"])
    np.testing.assert_array_equal(out[[1, 3]], ref[[1, 3]])  # honest rows
    if kind == "sign_flip":
        np.testing.assert_array_equal(out[[0, 2]], -3.0 * ref[[0, 2]])
    elif kind == "scale":
        np.testing.assert_array_equal(out[[0, 2]], 3.0 * ref[[0, 2]])
    elif kind == "zero":
        np.testing.assert_array_equal(out[[0, 2]], np.zeros((2, 3)))
    elif kind == "nan":
        assert np.isnan(out[[0, 2]]).all()
    else:  # gauss: replaced, deterministic in the keys
        assert not np.array_equal(out[[0, 2]], ref[[0, 2]])
        again = np.asarray(atk.apply_stacked(u, adv, keys)["w"])
        np.testing.assert_array_equal(out, again)


def test_gauss_requires_keys():
    atk = AttackModel(kind="gauss", fraction=0.5)
    with pytest.raises(ValueError, match="keys"):
        atk.apply_stacked({"w": jnp.ones((2, 3))}, jnp.asarray([1.0, 0.0]))


# ---------------------------------------------------------------------------
# sync engines: NaN quarantine promoted from async (satellite)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["cohort", "full"])
def test_sync_nan_quarantine_keeps_params_finite_and_meters(engine):
    """A 40% NaN-uploading fleet under plain fedavg: without the decode
    gate every round would poison Θ; with it the params stay finite, the
    poisoned rows are metered in RoundRecord.quarantined, and EF residuals
    of quarantined clients stay at their round-entry state (zeros)."""
    M = 10
    loss_fn, params, batches, n = _problem(M, dim=32, classes=10)
    st = strategy.get("fig5", error_feedback=True).replace(
        attack=AttackModel(kind="nan", fraction=0.4))
    s = FederatedServer.from_strategy(st, loss_fn, params, M, seed=2,
                                      engine=engine)
    s.run(batches, n, rounds=4)
    for leaf in jax.tree_util.tree_leaves(s.params):
        assert np.isfinite(np.asarray(leaf)).all()
    assert sum(r.quarantined for r in s.history) > 0
    assert s.history[0].quarantined == s.history[0].adversarial == 4
    adv = st.attack.adversary_mask(M).astype(bool)
    for leaf in jax.tree_util.tree_leaves(s._residuals):
        np.testing.assert_array_equal(
            np.asarray(leaf)[adv], np.zeros_like(np.asarray(leaf)[adv]))
    summ = s.summary()
    assert summ["quarantined"] == sum(r.quarantined for r in s.history)
    assert summ["attack"].startswith("nan")
    assert summ["adversarial_uploads"] > 0


@pytest.mark.parametrize("kind", ["sign_flip", "scale", "gauss", "zero",
                                  "nan"])
def test_cohort_matches_oracle_under_every_attack_kind(kind):
    """Both sync engines agree bit-exactly whatever the adversaries send —
    including the keyed (gauss) and non-finite (nan) transforms."""
    M = 12
    loss_fn, params, batches, n = _problem(M)
    st = strategy.get("fig5", error_feedback=True).replace(
        attack=AttackModel(kind=kind, fraction=0.25, strength=2.0,
                           sigma=1.5))
    runs = {}
    for engine in ("cohort", "full"):
        s = FederatedServer.from_strategy(st, loss_fn, params, M, seed=5,
                                          engine=engine)
        s.run(batches, n, rounds=5)
        runs[engine] = s
    _assert_trees_equal(runs["cohort"].params, runs["full"].params)
    _assert_trees_equal(runs["cohort"]._residuals, runs["full"]._residuals)
    assert ([ (r.quarantined, r.adversarial) for r in runs["cohort"].history]
            == [(r.quarantined, r.adversarial) for r in runs["full"].history])


# ---------------------------------------------------------------------------
# async engine: attacks compose with the event-loop quarantine
# ---------------------------------------------------------------------------
def test_async_quarantines_nan_attack():
    """The nan attack rides the dispatch sweep into the async engine's
    existing decode gate: adversary uploads are quarantined event-by-event,
    params stay finite, and the Byzantine accounting lands in the stats."""
    M = 10
    loss_fn, params, batches, n = _problem(M)
    st = strategy.get("fig3", hetero=HeteroModel(profile="mobile")).replace(
        attack=AttackModel(kind="nan", fraction=0.3))
    s = FederatedServer.from_strategy(st, loss_fn, params, M, seed=9,
                                      engine="async")
    s.run(batches, n, rounds=4)
    for leaf in jax.tree_util.tree_leaves(s.params):
        assert np.isfinite(np.asarray(leaf)).all()
    assert sum(r.quarantined for r in s.history) > 0
    assert sum(r.adversarial for r in s.history) > 0
    assert s.summary()["attack"] == "nan(f=0.3)"
