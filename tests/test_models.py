"""Model-zoo unit tests: attention oracle equivalence, rwkv/ssm recurrence
vs step-by-step references, decode==forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import NEG_INF


# ---------------------------------------------------------------------------
# flash attention vs naive oracle
# ---------------------------------------------------------------------------
def naive_attention(q, k, v, attn="full", window=0, cap=0.0):
    B, T, H, D = q.shape
    KV = k.shape[2]
    kk = jnp.repeat(k, H // KV, axis=2)
    vv = jnp.repeat(v, H // KV, axis=2)
    logits = jnp.einsum("bthd,bshd->bhts", q, kk) * D ** -0.5
    if cap > 0:
        logits = cap * jnp.tanh(logits / cap)
    S = k.shape[1]
    qp = jnp.arange(T)[:, None]
    kp = jnp.arange(S)[None]
    m = kp <= qp
    if attn == "sliding":
        m &= kp > qp - window
    if attn == "chunked":
        m &= (kp // window) == (qp // window)
    logits = jnp.where(m[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, -1)
    return jnp.einsum("bhts,bshd->bthd", p, vv)


@pytest.mark.parametrize("attn,window", [("full", 0), ("sliding", 7),
                                         ("chunked", 8)])
@pytest.mark.parametrize("blocks", [(16, 8), (64, 64), (5, 3)])
def test_flash_attention_matches_oracle(attn, window, blocks):
    bq, bkv = blocks
    key = jax.random.PRNGKey(0)
    B, T, H, KV, D = 2, 33, 4, 2, 8
    q = jax.random.normal(key, (B, T, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, KV, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, KV, D))
    got = attn_lib.flash_attention(q, k, v, attn=attn, window=window,
                                   block_q=bq, block_kv=bkv)
    want = naive_attention(q, k, v, attn, window)
    np.testing.assert_allclose(got, want, atol=2e-6)


def test_flash_attention_softcap():
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 17, 2, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 17, 2, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 17, 2, 8))
    got = attn_lib.flash_attention(q, k, v, softcap_val=5.0, block_q=8,
                                   block_kv=4)
    want = naive_attention(q, k, v, cap=5.0)
    np.testing.assert_allclose(got, want, atol=2e-6)


# ---------------------------------------------------------------------------
# decode attention == incremental flash
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("attn,window,slots", [("full", 0, 16),
                                               ("sliding", 5, 5),
                                               ("chunked", 4, 4)])
def test_decode_matches_full_attention(attn, window, slots):
    """Feeding tokens one-by-one through decode_attention must equal the
    full-sequence attention at every step."""
    key = jax.random.PRNGKey(1)
    B, T, H, KV, D = 2, 12, 4, 2, 8
    q = jax.random.normal(key, (B, T, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, KV, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, KV, D))
    want = naive_attention(q, k, v, attn, window)

    cache = attn_lib.init_kv_cache(B, T, KV, D, jnp.float32, attn=attn,
                                   window=window)
    for t in range(T):
        got_t, cache = attn_lib.decode_attention(
            q[:, t:t + 1], k[:, t:t + 1], v[:, t:t + 1], cache,
            attn=attn, window=window)
        np.testing.assert_allclose(got_t[:, 0], want[:, t], atol=2e-5,
                                   err_msg=f"step {t}")


# ---------------------------------------------------------------------------
# wkv6: chunked form vs step-by-step recurrence
# ---------------------------------------------------------------------------
def wkv6_naive(r, k, v, logw, u, state):
    B, T, H, D = r.shape
    ys = []
    S = state.astype(jnp.float32)
    for t in range(T):
        rt, kt, vt = r[:, t], k[:, t], v[:, t]
        wt = jnp.exp(logw[:, t])
        y = jnp.einsum("bhd,bhde->bhe", rt, S) + \
            jnp.sum(rt * u[None] * kt, -1, keepdims=True) * vt
        S = wt[..., None] * S + jnp.einsum("bhd,bhe->bhde", kt, vt)
        ys.append(y)
    return jnp.stack(ys, 1), S


@pytest.mark.parametrize("T", [8, 64, 96])
def test_wkv6_chunked_matches_recurrence(T):
    key = jax.random.PRNGKey(7)
    B, H, D = 2, 3, 8
    r = jax.random.normal(key, (B, T, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, D))
    # realistic decay range: logw in (-6, -0.01)
    logw = -jnp.exp(jax.random.uniform(
        jax.random.fold_in(key, 3), (B, T, H, D), minval=-4.0, maxval=1.5))
    u = 0.1 * jax.random.normal(jax.random.fold_in(key, 4), (H, D))
    s0 = jax.random.normal(jax.random.fold_in(key, 5), (B, H, D, D))

    y_ref, s_ref = wkv6_naive(r, k, v, logw, u, s0)
    y, s = rwkv_lib.wkv6_chunked(r, k, v, logw, u, s0)
    np.testing.assert_allclose(y, y_ref, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(s, s_ref, atol=1e-3, rtol=1e-3)


def test_wkv6_step_matches_naive():
    key = jax.random.PRNGKey(8)
    B, H, D = 2, 2, 4
    s = jax.random.normal(key, (B, H, D, D))
    r = jax.random.normal(jax.random.fold_in(key, 1), (B, 1, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, 1, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, 1, H, D))
    logw = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 4),
                                      (B, 1, H, D)))
    u = jnp.zeros((H, D))
    y1, s1 = rwkv_lib.wkv6_step(r, k, v, logw, u, s)
    y2, s2 = wkv6_naive(r, k, v, logw, u, s)
    np.testing.assert_allclose(y1, y2, atol=1e-5)
    np.testing.assert_allclose(s1, s2, atol=1e-5)


# ---------------------------------------------------------------------------
# ssm: associative-scan form vs step form
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("T", [4, 33, 128])
def test_ssm_scan_matches_steps(T):
    key = jax.random.PRNGKey(9)
    d_model, d_inner, N = 16, 16, 4
    params = ssm_lib.init_ssm_params(key, d_model, d_inner, N, jnp.float32)
    B = 2
    xz = jax.random.normal(jax.random.fold_in(key, 1), (B, T, 2 * d_inner))
    h0 = jnp.zeros((B, d_inner, N))
    y_scan, hT_scan = ssm_lib.ssm_forward(params, xz, h0)

    h = h0
    ys = []
    for t in range(T):
        y_t, h = ssm_lib.ssm_step(params, xz[:, t:t + 1], h)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_scan, y_step, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(hT_scan, h, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# forward == step-by-step decode for the full model
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "gemma2-2b", "rwkv6-1.6b",
                                  "hymba-1.5b"])
def test_decode_consistent_with_forward(arch):
    """Greedy logits from token-by-token decode must match the training
    forward pass at every position (serve == train numerics)."""
    import dataclasses
    from repro.configs import get_arch
    from repro.models import transformer as tr
    cfg = dataclasses.replace(get_arch(arch).reduced(),
                              compute_dtype="float32")
    key = jax.random.PRNGKey(0)
    params = tr.init_params(key, cfg, "float32")
    T = 16
    toks = jax.random.randint(jax.random.fold_in(key, 1), (2, T), 0,
                              cfg.vocab_size)
    logits_fwd, _ = tr.forward(params, cfg, toks)
    logits_fwd = logits_fwd[..., :cfg.vocab_size]

    state = tr.init_decode_state(cfg, 2, T + 1, "float32")
    outs = []
    for t in range(T):
        lg, state = tr.decode_step(params, cfg, state, toks[:, t:t + 1])
        outs.append(lg)
    logits_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_fwd, np.float32), np.asarray(logits_dec),
        atol=2e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# flash custom-VJP gradients vs autodiff of the naive oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("attn,window,cap", [("full", 0, 0.0),
                                             ("sliding", 7, 0.0),
                                             ("chunked", 8, 0.0),
                                             ("full", 0, 8.0)])
def test_flash_vjp_matches_autodiff(attn, window, cap):
    key = jax.random.PRNGKey(0)
    B, T, H, KV, D = 2, 35, 4, 2, 8
    q = jax.random.normal(key, (B, T, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, KV, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, KV, D))

    def f_flash(q, k, v):
        return jnp.sum(jnp.sin(attn_lib.flash_attention(
            q, k, v, attn=attn, window=window, softcap_val=cap,
            block_q=16, block_kv=8)))

    def f_naive(q, k, v):
        return jnp.sum(jnp.sin(naive_attention(q, k, v, attn, window, cap)))

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=3e-6)


def test_moe_padded_experts_never_routed():
    """moe_pad_experts rounds E up for expert-parallel sharding; padded
    experts must receive zero routing mass and zero capacity slots."""
    from repro.models import moe as moe_lib
    key = jax.random.PRNGKey(0)
    E_real, E_pad = 5, 8
    params = moe_lib.init_moe_params(key, 16, E_pad, 32, 0, True, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, 16))
    y, aux = moe_lib.moe_ffn(params, x, topk=2, real_experts=E_real)
    assert y.shape == x.shape and np.isfinite(float(aux))
    # spy on routing: recompute the router decision
    logits = x.reshape(-1, 16) @ params["router"]
    logits = jnp.where(jnp.arange(E_pad) < E_real, logits, -1e30)
    _, ids = jax.lax.top_k(jax.nn.softmax(logits, -1), 2)
    assert int(ids.max()) < E_real


def test_moe_padding_preserves_output_vs_unpadded():
    """With identical real-expert weights, padded and unpadded MoE agree."""
    from repro.models import moe as moe_lib
    key = jax.random.PRNGKey(1)
    params8 = moe_lib.init_moe_params(key, 16, 8, 32, 0, True, jnp.float32)
    # build a 5-expert param set from the first 5 experts
    params5 = dict(params8)
    params5["router"] = params8["router"][:, :5]
    params5["wi"] = params8["wi"][:5]
    params5["wg"] = params8["wg"][:5]
    params5["wo"] = params8["wo"][:5]
    x = jax.random.normal(jax.random.fold_in(key, 2), (2, 8, 16))
    y8, _ = moe_lib.moe_ffn(params8, x, topk=2, real_experts=5)
    y5, _ = moe_lib.moe_ffn(params5, x, topk=2, real_experts=0)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y5), atol=2e-5)
