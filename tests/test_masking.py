"""Masking semantics (paper §3.2.1 / §4.2): exact-sort oracle vs the
TPU-native threshold bisection, plus property tests via hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st

from repro.core.masking import (MaskingConfig, mask_pytree, random_mask,
                                selective_mask_exact,
                                selective_mask_threshold, threshold_for_topk)


def test_exact_topk_keeps_k_largest():
    x = jnp.asarray([0.1, -5.0, 3.0, -0.2, 4.0, 0.05])
    out = selective_mask_exact(x, gamma=0.5)
    np.testing.assert_allclose(out, [0.0, -5.0, 3.0, 0.0, 4.0, 0.0])


def test_exact_topk_tie_handling():
    x = jnp.ones((10,))
    out = selective_mask_exact(x, gamma=0.3)
    assert int(jnp.sum(out != 0)) == 3


@pytest.mark.parametrize("gamma", [0.1, 0.3, 0.5, 0.9])
def test_threshold_matches_exact_on_distinct_values(gamma):
    key = jax.random.PRNGKey(42)
    x = jax.random.normal(key, (4096,))           # ties ~impossible
    a = selective_mask_exact(x, gamma)
    b = selective_mask_threshold(x, gamma, iters=40)
    np.testing.assert_allclose(a, b, rtol=0, atol=0)


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([0.05, 0.2, 0.5, 0.8]),
       st.sampled_from([64, 257, 1024, 4096]))
@settings(max_examples=25, deadline=None)
def test_threshold_count_within_tolerance(seed, gamma, n):
    """Property: bisection keeps <= k entries and >= k * (1-eps) for
    continuous inputs."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    out = selective_mask_threshold(x, gamma, iters=40)
    k = max(1, round(gamma * n))
    kept = int(jnp.sum(out != 0))
    assert kept <= k
    assert kept >= int(0.95 * k) - 1


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([0.1, 0.5]))
@settings(max_examples=10, deadline=None)
def test_threshold_selects_largest_magnitudes(seed, gamma):
    """Property: every kept entry's |value| >= every dropped entry's |value|."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (512,))
    out = selective_mask_threshold(x, gamma, iters=40)
    kept = jnp.abs(x)[out != 0]
    dropped = jnp.abs(x)[out == 0]
    if kept.size and dropped.size:
        assert float(kept.min()) >= float(dropped.max())


def test_threshold_for_topk_invariant():
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (1000,)))
    for k in [1, 10, 100, 999]:
        tau = threshold_for_topk(x, jnp.asarray(k), iters=40)
        assert int(jnp.sum(x >= tau)) <= k


def test_random_mask_exact_count():
    key = jax.random.PRNGKey(0)
    x = jnp.ones((1000,))
    out = random_mask(key, x, gamma=0.3)
    assert int(jnp.sum(out != 0)) == 300


def test_random_mask_unbiased_mean():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(jax.random.PRNGKey(2), (200,))
    outs = jnp.stack([random_mask(jax.random.fold_in(key, i), x, 0.5)
                      for i in range(300)])
    np.testing.assert_allclose(outs.mean(0), 0.5 * x, atol=0.2)


def test_mask_pytree_small_leaves_pass_dense():
    key = jax.random.PRNGKey(0)
    tree = {"w": jax.random.normal(key, (64, 64)),
            "b": jax.random.normal(key, (8,))}
    cfg = MaskingConfig(gamma=0.1, mode="selective", min_leaf_size=256)
    out = mask_pytree(key, tree, cfg)
    np.testing.assert_allclose(out["b"], tree["b"])   # too small: dense
    assert int(jnp.sum(out["w"] != 0)) <= round(0.1 * 64 * 64)


def test_mask_pytree_mode_none_identity():
    key = jax.random.PRNGKey(0)
    tree = {"w": jax.random.normal(key, (32, 32))}
    out = mask_pytree(key, tree, MaskingConfig(gamma=0.5, mode="none"))
    np.testing.assert_allclose(out["w"], tree["w"])


def test_masking_is_jittable_and_vmappable():
    xs = jax.random.normal(jax.random.PRNGKey(0), (4, 512))
    f = jax.jit(jax.vmap(lambda x: selective_mask_threshold(x, 0.2)))
    out = f(xs)
    assert out.shape == xs.shape
    for i in range(4):
        b = selective_mask_threshold(xs[i], 0.2)
        np.testing.assert_allclose(out[i], b, atol=1e-7)


# ---------------------------------------------------------------------------
# Segmented whole-pytree masking (ops.topk_mask_pytree, DESIGN.md §3.4)
# ---------------------------------------------------------------------------
def _pytree_for(seed, small=True):
    key = jax.random.PRNGKey(seed)
    tree = {
        "odd": jax.random.normal(jax.random.fold_in(key, 0), (300, 77)),
        "square": jax.random.normal(jax.random.fold_in(key, 1), (128, 128)),
        "cube": jax.random.normal(jax.random.fold_in(key, 2), (8, 8, 65)),
        "vec": jax.random.normal(jax.random.fold_in(key, 3), (1000,)),
    }
    if small:
        tree["bias"] = jax.random.normal(jax.random.fold_in(key, 4), (8,))
    return tree


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([0.05, 0.2, 0.5, 0.8]))
@settings(max_examples=8, deadline=None)
def test_topk_mask_pytree_property_vs_sort_oracle(seed, gamma):
    """Per leaf: kept <= k, within the documented bracket tolerance of k, and
    every kept magnitude >= every dropped magnitude (the sort-oracle order).
    Covers padded/odd-sized leaves and small-dense passthrough."""
    from repro.kernels import ops
    tree = _pytree_for(seed)
    out = ops.topk_mask_pytree(tree, gamma, interpret=True)
    for name, x in tree.items():
        o = out[name]
        assert o.shape == x.shape and o.dtype == x.dtype
        if x.size < 256:                       # small leaf: dense passthrough
            np.testing.assert_allclose(np.asarray(o), np.asarray(x))
            continue
        k = max(1, round(gamma * x.size))
        kept = np.asarray(o != 0).reshape(-1)
        mags = np.abs(np.asarray(x, np.float32)).reshape(-1)
        assert kept.sum() <= k
        assert kept.sum() >= int(0.9 * k) - 2
        if kept.any() and (~kept).any():
            assert mags[kept].min() >= mags[~kept].max() - 1e-6
        # surviving values are passed through untouched
        np.testing.assert_allclose(np.asarray(o).reshape(-1)[kept],
                                   np.asarray(x).reshape(-1)[kept])


def test_topk_mask_pytree_exact_on_separated_magnitudes():
    """Magnitudes separated by more than the documented ~1% relative
    tolerance (geometric, ratio 1.05) must match the exact sort oracle
    (selective_mask_exact) bit-for-bit on every leaf."""
    from repro.kernels import ops
    key = jax.random.PRNGKey(7)
    tree = {}
    for i, n in enumerate([512, 300, 257]):
        base = jnp.power(1.05, jnp.arange(n, dtype=jnp.float32))
        sign = jnp.where(jnp.arange(n) % 2 == 0, 1.0, -1.0)
        tree[f"l{i}"] = (base * sign)[
            jax.random.permutation(jax.random.fold_in(key, i), n)]
    out = ops.topk_mask_pytree(tree, 0.25, interpret=True)
    for name, x in tree.items():
        want = selective_mask_exact(x, 0.25)
        np.testing.assert_allclose(np.asarray(out[name]), np.asarray(want))


def test_topk_mask_pytree_bf16_and_scan_safety():
    from repro.kernels import ops
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0),
                                   (64, 64)).astype(jnp.bfloat16)}

    def body(c, _):
        return ops.topk_mask_pytree(c, 0.2, interpret=True), None

    out, _ = jax.lax.scan(body, tree, None, length=2)
    assert out["w"].dtype == jnp.bfloat16
    assert int(jnp.sum(out["w"] != 0)) <= round(0.2 * 64 * 64)


def test_mask_pytree_use_kernel_routes_segmented():
    """mask_pytree(selective, use_kernel=True) must go through the segmented
    path and agree with the per-leaf jnp bisection within the bin tolerance."""
    key = jax.random.PRNGKey(3)
    tree = _pytree_for(3)
    cfg_jnp = MaskingConfig(gamma=0.1, mode="selective", use_kernel=False)
    cfg_seg = MaskingConfig(gamma=0.1, mode="selective", use_kernel=True)
    a = mask_pytree(key, tree, cfg_jnp)
    b = mask_pytree(key, tree, cfg_seg)
    for name in tree:
        ka = int(jnp.sum(a[name] != 0))
        kb = int(jnp.sum(b[name] != 0))
        n = tree[name].size
        if n < cfg_seg.min_leaf_size:
            np.testing.assert_allclose(np.asarray(b[name]),
                                       np.asarray(tree[name]))
        else:
            k = max(1, round(0.1 * n))
            assert abs(ka - kb) <= max(2, int(0.05 * k)), (name, ka, kb)


def test_selective_mask_threshold_kernel_route():
    x = jax.random.normal(jax.random.PRNGKey(5), (4096,))
    a = selective_mask_exact(x, 0.2)
    b = selective_mask_threshold(x, 0.2, use_kernel=True)
    k = round(0.2 * 4096)
    kept = int(jnp.sum(b != 0))
    assert kept <= k and kept >= int(0.9 * k) - 2
    # clearly-kept entries agree with the oracle
    both = (np.asarray(a != 0) & np.asarray(b != 0))
    np.testing.assert_allclose(np.asarray(b)[both], np.asarray(a)[both])


def test_fed_pod_use_kernel_matches_jnp_path():
    from repro.launch.fedtrain import FedPodConfig, mask_deltas
    key = jax.random.PRNGKey(9)
    deltas = {"w": jax.random.normal(key, (2, 40, 40)),
              "v": jax.random.normal(jax.random.fold_in(key, 1), (2, 1000))}
    cfg_a = FedPodConfig(num_clients=2, gamma=0.2, use_kernel=False)
    cfg_b = FedPodConfig(num_clients=2, gamma=0.2, use_kernel=True)
    a = mask_deltas(key, deltas, cfg_a)
    b = mask_deltas(key, deltas, cfg_b)
    for name in deltas:
        for c in range(2):
            n = deltas[name][c].size
            k = max(1, round(0.2 * n))
            ka = int(jnp.sum(a[name][c] != 0))
            kb = int(jnp.sum(b[name][c] != 0))
            assert kb <= k and kb >= int(0.9 * k) - 2
            assert abs(ka - kb) <= max(2, int(0.05 * k))


def test_fed_pod_use_kernel_keeps_per_layer_granularity():
    """Alg. 4 masks per LAYER: a stacked (C, G, d) leaf with one quiet layer
    (uniformly 100x smaller deltas) must still keep ~gamma*d entries of that
    layer on BOTH paths — whole-leaf top-k would zero it out entirely."""
    from repro.launch.fedtrain import FedPodConfig, mask_deltas
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (2, 3, 1024))
    x = x.at[:, 1].multiply(0.01)                        # quiet layer
    deltas = {"stack": x}
    gamma = 0.25
    for use_kernel in (False, True):
        cfg = FedPodConfig(num_clients=2, gamma=gamma, use_kernel=use_kernel)
        out = mask_deltas(key, deltas, cfg)["stack"]
        for c in range(2):
            for g in range(3):
                kept = int(jnp.sum(out[c, g] != 0))
                k = round(gamma * 1024)
                assert kept <= k
                assert kept >= int(0.9 * k) - 2, (use_kernel, c, g, kept)


def test_topk_mask_pytree_slab_rows_rounding():
    """A slab_rows that is not a chunk multiple must behave like the rounded
    value — not silently skip the slab tail (kept count would exceed k)."""
    from repro.kernels import ops
    x = {"w": jax.random.normal(jax.random.PRNGKey(1), (70000,))}
    out = ops.topk_mask_pytree(x, 0.1, interpret=True, slab_rows=40)
    k = round(0.1 * 70000)
    kept = int(jnp.sum(out["w"] != 0))
    assert kept <= k
    assert kept >= int(0.9 * k) - 2


def test_topk_mask_pytree_tie_semantics_documented():
    """Threshold selection keeps ALL ties at tau (documented caveat): a
    constant leaf keeps every entry; the oracle would keep exactly k."""
    from repro.kernels import ops
    out = ops.topk_mask_pytree({"ones": jnp.ones((1024,))}, 0.1,
                               interpret=True)
    assert int(jnp.sum(out["ones"] != 0)) == 1024


def test_selective_mask_threshold_kernel_iters_tightens():
    """iters maps to refine sweeps on the kernel route: more iters must not
    loosen the kept-count bound."""
    x = jax.random.normal(jax.random.PRNGKey(6), (4096,))
    k = round(0.2 * 4096)
    for iters in (24, 48):
        out = selective_mask_threshold(x, 0.2, iters=iters, use_kernel=True)
        kept = int(jnp.sum(out != 0))
        assert kept <= k and kept >= int(0.95 * k) - 2, (iters, kept)


def test_fed_pod_threshold_mask_matches_core():
    """launch/fedtrain._threshold_mask (client/layer-stacked) agrees with the
    per-leaf core implementation."""
    from repro.launch.fedtrain import _threshold_mask
    x = jax.random.normal(jax.random.PRNGKey(3), (3, 2, 257))   # (C, G, n)
    out = _threshold_mask(x, 0.25, iters=40)
    for c in range(3):
        for g in range(2):
            ref = selective_mask_threshold(x[c, g], 0.25, iters=40)
            np.testing.assert_allclose(out[c, g], ref, atol=1e-7)
