"""Masking semantics (paper §3.2.1 / §4.2): exact-sort oracle vs the
TPU-native threshold bisection, plus property tests via hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.masking import (MaskingConfig, mask_pytree, random_mask,
                                selective_mask_exact,
                                selective_mask_threshold, threshold_for_topk)


def test_exact_topk_keeps_k_largest():
    x = jnp.asarray([0.1, -5.0, 3.0, -0.2, 4.0, 0.05])
    out = selective_mask_exact(x, gamma=0.5)
    np.testing.assert_allclose(out, [0.0, -5.0, 3.0, 0.0, 4.0, 0.0])


def test_exact_topk_tie_handling():
    x = jnp.ones((10,))
    out = selective_mask_exact(x, gamma=0.3)
    assert int(jnp.sum(out != 0)) == 3


@pytest.mark.parametrize("gamma", [0.1, 0.3, 0.5, 0.9])
def test_threshold_matches_exact_on_distinct_values(gamma):
    key = jax.random.PRNGKey(42)
    x = jax.random.normal(key, (4096,))           # ties ~impossible
    a = selective_mask_exact(x, gamma)
    b = selective_mask_threshold(x, gamma, iters=40)
    np.testing.assert_allclose(a, b, rtol=0, atol=0)


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([0.05, 0.2, 0.5, 0.8]),
       st.sampled_from([64, 257, 1024, 4096]))
@settings(max_examples=25, deadline=None)
def test_threshold_count_within_tolerance(seed, gamma, n):
    """Property: bisection keeps <= k entries and >= k * (1-eps) for
    continuous inputs."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    out = selective_mask_threshold(x, gamma, iters=40)
    k = max(1, round(gamma * n))
    kept = int(jnp.sum(out != 0))
    assert kept <= k
    assert kept >= int(0.95 * k) - 1


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([0.1, 0.5]))
@settings(max_examples=10, deadline=None)
def test_threshold_selects_largest_magnitudes(seed, gamma):
    """Property: every kept entry's |value| >= every dropped entry's |value|."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (512,))
    out = selective_mask_threshold(x, gamma, iters=40)
    kept = jnp.abs(x)[out != 0]
    dropped = jnp.abs(x)[out == 0]
    if kept.size and dropped.size:
        assert float(kept.min()) >= float(dropped.max())


def test_threshold_for_topk_invariant():
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (1000,)))
    for k in [1, 10, 100, 999]:
        tau = threshold_for_topk(x, jnp.asarray(k), iters=40)
        assert int(jnp.sum(x >= tau)) <= k


def test_random_mask_exact_count():
    key = jax.random.PRNGKey(0)
    x = jnp.ones((1000,))
    out = random_mask(key, x, gamma=0.3)
    assert int(jnp.sum(out != 0)) == 300


def test_random_mask_unbiased_mean():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(jax.random.PRNGKey(2), (200,))
    outs = jnp.stack([random_mask(jax.random.fold_in(key, i), x, 0.5)
                      for i in range(300)])
    np.testing.assert_allclose(outs.mean(0), 0.5 * x, atol=0.2)


def test_mask_pytree_small_leaves_pass_dense():
    key = jax.random.PRNGKey(0)
    tree = {"w": jax.random.normal(key, (64, 64)),
            "b": jax.random.normal(key, (8,))}
    cfg = MaskingConfig(gamma=0.1, mode="selective", min_leaf_size=256)
    out = mask_pytree(key, tree, cfg)
    np.testing.assert_allclose(out["b"], tree["b"])   # too small: dense
    assert int(jnp.sum(out["w"] != 0)) <= round(0.1 * 64 * 64)


def test_mask_pytree_mode_none_identity():
    key = jax.random.PRNGKey(0)
    tree = {"w": jax.random.normal(key, (32, 32))}
    out = mask_pytree(key, tree, MaskingConfig(gamma=0.5, mode="none"))
    np.testing.assert_allclose(out["w"], tree["w"])


def test_masking_is_jittable_and_vmappable():
    xs = jax.random.normal(jax.random.PRNGKey(0), (4, 512))
    f = jax.jit(jax.vmap(lambda x: selective_mask_threshold(x, 0.2)))
    out = f(xs)
    assert out.shape == xs.shape
    for i in range(4):
        b = selective_mask_threshold(xs[i], 0.2)
        np.testing.assert_allclose(out[i], b, atol=1e-7)


def test_fed_pod_threshold_mask_matches_core():
    """launch/fedtrain._threshold_mask (client/layer-stacked) agrees with the
    per-leaf core implementation."""
    from repro.launch.fedtrain import _threshold_mask
    x = jax.random.normal(jax.random.PRNGKey(3), (3, 2, 257))   # (C, G, n)
    out = _threshold_mask(x, 0.25, iters=40)
    for c in range(3):
        for g in range(2):
            ref = selective_mask_threshold(x[c, g], 0.25, iters=40)
            np.testing.assert_allclose(out[c, g], ref, atol=1e-7)
