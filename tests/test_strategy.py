"""FedStrategy API (repro.core.strategy): registry semantics, per-preset
cohort-vs-oracle bit-exactness, and the legacy-kwargs deprecation shim.

Acceptance contract of the strategy redesign:

* every registry preset runs bit-identically under ``engine="cohort"`` and
  the full-population oracle (the DESIGN.md §3.5 guarantee survives the
  codec/aggregator threading);
* ``FederatedServer.from_strategy(strategy.get("fig5"), ...)`` reproduces
  the legacy ``(loss_fn, schedule, cfg, ...)`` server's round records with
  params bit-identical, while transport is now the codec's exact wire
  bytes;
* the legacy kwargs still work — behind a ``DeprecationWarning``.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ClientConfig, DynamicSampling, FederatedConfig,
                        FederatedServer, StaticSampling)
from repro.core import strategy
from repro.core.codecs import ChainCodec, IdentityCodec, SparseCodec
from repro.core.strategy import (FEDAVG, Aggregator, FedStrategy, MaskPolicy,
                                 build_round, clipped_fedavg, default_codec)


@functools.lru_cache()
def _problem(num_clients, dim=8, classes=3, num_batches=2, batch=4, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (num_clients, num_batches, batch, dim))
    y = jax.random.randint(jax.random.fold_in(key, 1),
                           (num_clients, num_batches, batch), 0, classes)

    def loss_fn(params, data):
        xb, yb = data
        logp = jax.nn.log_softmax(xb @ params["w"] + params["b"])
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

    params = {"w": 0.1 * jax.random.normal(jax.random.fold_in(key, 2),
                                           (dim, classes)),
              "b": jnp.zeros((classes,))}
    n = np.ones((num_clients,), np.float32)
    return loss_fn, params, (x, y), n


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_presets_present():
    assert {"dense-baseline", "fig3", "fig4", "fig5",
            "fig5-int8"} <= set(strategy.names())
    for name in strategy.names():
        st = strategy.get(name)
        assert isinstance(st, FedStrategy) and st.name == name


def test_registry_unknown_name():
    with pytest.raises(KeyError, match="unknown strategy"):
        strategy.get("does-not-exist")


def test_registry_rejects_duplicate():
    with pytest.raises(ValueError, match="already registered"):
        strategy.register(strategy.get("fig5"))


def test_get_with_overrides():
    st = strategy.get("fig5", learning_rate=0.2, error_feedback=True)
    assert st.learning_rate == 0.2 and st.error_feedback
    # the registered preset is untouched (frozen record semantics)
    assert strategy.get("fig5").learning_rate != 0.2


def test_masking_override_rederives_codec():
    """Overriding masking without a codec keeps COO slots consistent with
    the new gamma — including int8 chaining for quantised presets."""
    st = strategy.get("fig5", masking=MaskPolicy.selective(0.25))
    assert isinstance(st.codec, SparseCodec) and st.codec.gamma == 0.25

    dense = strategy.get("fig5", masking=MaskPolicy.none())
    assert isinstance(dense.codec, IdentityCodec)

    q = strategy.get("fig5-int8", masking=MaskPolicy.selective(0.25))
    assert isinstance(q.codec, ChainCodec)
    assert q.codec.stages[0].gamma == 0.25


def test_preset_expectations():
    fig3 = strategy.get("fig3")
    assert isinstance(fig3.sampling, DynamicSampling)
    assert fig3.masking.mode == "none"
    assert isinstance(fig3.codec, IdentityCodec)

    fig4 = strategy.get("fig4")
    assert isinstance(fig4.sampling, StaticSampling)
    assert fig4.masking.mode == "selective" and fig4.masking.gamma == 0.1
    assert isinstance(fig4.codec, SparseCodec)

    fig5 = strategy.get("fig5")
    assert isinstance(fig5.sampling, DynamicSampling)
    assert fig5.masking.mode == "selective"
    assert isinstance(fig5.codec, SparseCodec)


def test_mask_policy_validation():
    with pytest.raises(ValueError, match="mode"):
        MaskPolicy(mode="bogus")
    with pytest.raises(ValueError, match="backend"):
        MaskPolicy.selective(0.5, backend="cuda")
    with pytest.raises(ValueError, match="gamma"):
        MaskPolicy.selective(0.0)
    mc = MaskPolicy.selective(0.3, backend="kernel").masking_config()
    assert mc.mode == "selective" and mc.use_kernel
    assert MaskPolicy.from_masking_config(mc) == MaskPolicy.selective(
        0.3, backend="kernel")


def test_default_codec_matches_policy():
    assert isinstance(default_codec(MaskPolicy.none()), IdentityCodec)
    sc = default_codec(MaskPolicy.selective(0.3, min_leaf_size=64))
    assert isinstance(sc, SparseCodec)
    assert sc.gamma == 0.3 and sc.min_leaf_size == 64
    chained = default_codec(MaskPolicy.selective(0.3), quantized=True)
    assert isinstance(chained, ChainCodec)


# ---------------------------------------------------------------------------
# every preset: cohort engine == full oracle, bit-exact
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("preset", strategy.names())
def test_preset_cohort_matches_oracle(preset):
    """The §3.5 bit-exactness guarantee survives under every registered
    strategy: same params, residual state, and history either engine.
    dim=128 makes the weight leaf big enough (512 > min_leaf_size=256)
    that the sparse COO wire actually engages in-round."""
    M = 16
    loss_fn, params, batches, n = _problem(M, dim=128, classes=4)
    st = strategy.get(preset, learning_rate=0.1, error_feedback=True)

    servers = {}
    for engine in ("full", "cohort"):
        s = FederatedServer.from_strategy(st, loss_fn, params, M, seed=5,
                                          engine=engine)
        s.run(batches, n, rounds=6)
        servers[engine] = s

    full, cohort = servers["full"], servers["cohort"]
    _assert_trees_equal(full.params, cohort.params)
    _assert_trees_equal(full._residuals, cohort._residuals)
    assert [r.num_sampled for r in full.history] == \
        [r.num_sampled for r in cohort.history]
    np.testing.assert_allclose(
        [r.mean_loss for r in full.history],
        [r.mean_loss for r in cohort.history], rtol=1e-5, atol=1e-6)
    assert full.total_transport_bytes() == cohort.total_transport_bytes()
    ladder = st.sampling.bucket_ladder(M)
    assert all(r.cohort_size in ladder and r.cohort_size >= r.num_sampled
               for r in cohort.history)


# ---------------------------------------------------------------------------
# from_strategy vs the deprecated kwargs shim
# ---------------------------------------------------------------------------
def test_from_strategy_reproduces_legacy_kwargs_server():
    """strategy.get("fig5") == the legacy (schedule, cfg) construction:
    params bit-identical round by round; transport now reported as the
    codec's exact wire bytes; the old path emits a DeprecationWarning."""
    M = 8
    loss_fn, params, batches, n = _problem(M)
    st = strategy.get("fig5")

    new = FederatedServer.from_strategy(st, loss_fn, params, M, seed=9)
    new.run(batches, n, rounds=5)

    legacy_cfg = FederatedConfig(
        num_clients=M,
        client=ClientConfig(local_epochs=st.local_epochs,
                            learning_rate=st.learning_rate,
                            masking=st.masking.masking_config()))
    with pytest.warns(DeprecationWarning, match="from_strategy"):
        old = FederatedServer(loss_fn, st.sampling, legacy_cfg, params,
                              seed=9)
    old.run(batches, n, rounds=5)

    _assert_trees_equal(new.params, old.params)
    assert [(r.round, r.num_sampled, r.mean_loss, r.cohort_size)
            for r in new.history] == \
        [(r.round, r.num_sampled, r.mean_loss, r.cohort_size)
         for r in old.history]

    # transport is the codec's exact wire byte count, not an estimate
    wire = st.codec.wire_bytes(params)
    assert new.client_upload_bytes == wire
    for rec in new.history:
        assert rec.transport_bytes == rec.num_sampled * wire
    assert new.total_transport_bytes() == old.total_transport_bytes()


def test_server_summary_transport_from_codec():
    """summary()["transport_bytes"] is codec-metered: identity counts full
    dense bytes; the fig4 sparse wire shrinks it accordingly."""
    M = 8
    loss_fn, params, batches, n = _problem(M)
    dense_bytes = sum(np.asarray(leaf).nbytes
                      for leaf in jax.tree_util.tree_leaves(params))

    s = FederatedServer.from_strategy(strategy.get("dense-baseline"),
                                      loss_fn, params, M, seed=1)
    s.run(batches, n, rounds=2)
    summ = s.summary()
    assert summ["codec"] == "identity"
    assert summ["client_upload_bytes"] == dense_bytes
    assert summ["transport_bytes"] == sum(
        r.num_sampled for r in s.history) * dense_bytes

    s4 = FederatedServer.from_strategy(strategy.get("fig4"), loss_fn,
                                       params, M, seed=1)
    s4.run(batches, n, rounds=2)
    assert s4.summary()["codec"].startswith("sparse")
    assert s4.summary()["client_upload_bytes"] == \
        strategy.get("fig4").codec.wire_bytes(params)


# ---------------------------------------------------------------------------
# build_round forms + aggregator plug point
# ---------------------------------------------------------------------------
def test_build_round_forms_agree():
    M = 8
    loss_fn, params, batches, n = _problem(M)
    st = strategy.get("fig5", learning_rate=0.1)
    residuals = jax.tree.map(
        lambda p: jnp.zeros((M,) + p.shape, p.dtype), params)
    n = jnp.asarray(n)
    key = jax.random.PRNGKey(0)
    t = jnp.asarray(1.0)

    full = jax.jit(build_round(st, loss_fn, M, form="full"))
    scan = jax.jit(build_round(st, loss_fn, M, form="scan", cohort_size=M))
    p_f, _, m_f = full(params, residuals, batches, n, t, key)
    p_s, _, m_s = scan(params, residuals, batches, n, t[None], key[None])
    _assert_trees_equal(p_f, p_s)
    assert int(m_f["num_sampled"]) == int(np.asarray(m_s["num_sampled"])[0])

    with pytest.raises(ValueError, match="cohort_size"):
        build_round(st, loss_fn, M, form="cohort")
    with pytest.raises(ValueError, match="unknown round form"):
        build_round(st, loss_fn, M, form="bogus")


def test_clipped_fedavg_aggregator():
    """clipped_fedavg: norm-clips per client, leaves small uploads alone,
    and keeps zero rows zero (cohort-equivalence requirement)."""
    agg = clipped_fedavg(1.0)
    assert isinstance(agg, Aggregator) and "clipped" in agg.name
    g = {"w": jnp.zeros((4,))}
    uploads = {"w": jnp.stack([jnp.asarray([3.0, 0.0, 0.0, 0.0]),
                               jnp.asarray([0.1, 0.0, 0.0, 0.0]),
                               jnp.zeros((4,))])}
    w = jnp.asarray([1.0, 1.0, 0.0])
    out = agg.fn(g, uploads, w, "delta")
    # client 0 clipped 3.0 -> 1.0; client 1 untouched; zero row inert
    np.testing.assert_allclose(np.asarray(out["w"]),
                               [(1.0 + 0.1) / 2, 0, 0, 0], rtol=1e-6)

    # and it is available through the strategy surface end to end
    M = 8
    loss_fn, params, batches, n = _problem(M)
    st = strategy.get("fig3", aggregator=clipped_fedavg(10.0),
                      learning_rate=0.1)
    s = FederatedServer.from_strategy(st, loss_fn, params, M, seed=2)
    s.run(batches, n, rounds=2)
    assert s.history[-1].mean_loss < s.history[0].mean_loss * 1.5


def test_fedavg_is_default_aggregator():
    assert strategy.get("fig5").aggregator is FEDAVG


def test_legacy_four_arg_aggregator_compat():
    """A custom Aggregator registered against the PR-4 4-arg fn signature
    keeps working wherever self-normalized weights suffice (plain AND
    hetero rounds); pairing it with a Horvitz-Thompson sampler fails fast
    at build time instead of silently re-normalizing debiased weights."""
    from repro.core.hetero import HeteroModel
    from repro.core.sampling import ImportanceSampler
    from repro.core.federated import fedavg_aggregate

    def legacy_fn(g, uploads, weights, upload_semantics):
        return fedavg_aggregate(g, uploads, weights, upload_semantics)

    legacy = Aggregator("legacy-fedavg", legacy_fn)
    M = 4
    loss_fn, params, batches, n = _problem(M)
    st = strategy.get("fig3", aggregator=legacy, learning_rate=0.1)
    s = FederatedServer.from_strategy(st, loss_fn, params, M, seed=1)
    s.run(batches, n, rounds=2)                       # plain path: fine

    het = st.replace(hetero=HeteroModel(profile="mobile"))
    build_round(het, loss_fn, M, form="full")         # normalize=True: fine

    with pytest.raises(TypeError, match="normalize"):
        build_round(st.replace(sampler=ImportanceSampler()), loss_fn, M,
                    form="full")


def test_error_feedback_absorbs_wire_loss():
    """With a lossy codec + error feedback, the wire's quantisation error
    re-enters the residual.  Invariant (full participation, uniform
    weights, "delta" semantics): the residual gap between the lossless and
    lossy runs equals, on average over clients, the parameter gap —
    i.e. no mass is silently discarded on the wire."""
    M = 4
    loss_fn, params, batches, n = _problem(M, dim=128, classes=4)
    residuals = jax.tree.map(
        lambda p: jnp.zeros((M,) + p.shape, p.dtype), params)
    nj = jnp.asarray(n)
    key = jax.random.PRNGKey(11)
    t = jnp.asarray(1.0)

    sampling = StaticSampling(initial_rate=1.0, min_clients=2)
    lossless = strategy.get("fig5", sampling=sampling, error_feedback=True,
                            learning_rate=0.1)
    lossy = strategy.get("fig5-int8", sampling=sampling,
                         error_feedback=True, learning_rate=0.1)

    p_a, r_a, _ = jax.jit(build_round(lossless, loss_fn, M, form="full"))(
        params, residuals, batches, nj, t, key)
    p_b, r_b, _ = jax.jit(build_round(lossy, loss_fn, M, form="full"))(
        params, residuals, batches, nj, t, key)

    # int8 wire really is lossy here, and the residual moved to absorb it
    gap = [np.asarray(a) - np.asarray(b)
           for a, b in zip(jax.tree_util.tree_leaves(p_a),
                           jax.tree_util.tree_leaves(p_b))]
    assert max(np.abs(g).max() for g in gap) > 0
    for (la, lb), dp in zip(zip(jax.tree_util.tree_leaves(r_a),
                                jax.tree_util.tree_leaves(r_b)),
                            gap):
        mean_res_gap = np.asarray(jnp.mean(lb - la, axis=0))
        np.testing.assert_allclose(mean_res_gap, dp, rtol=1e-5, atol=1e-6)
