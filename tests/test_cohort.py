"""Cohort execution engine (DESIGN.md §3.5) equivalence tests.

The cohort engine must be a pure execution optimization: running only the
sampled m_t clients (padded to a static bucket) has to produce the SAME
round results as the full-population vmap oracle — params, residuals,
mean_loss, num_sampled — across bucket boundaries and with error feedback
on/off.  Params/residuals are compared bit-exactly: the cohort keeps ids
sorted ascending and the oracle's extra terms are exact zeros, so the
weighted reductions agree to the ulp.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st

from repro.core import (ClientConfig, DynamicSampling, FederatedConfig,
                        FederatedServer, MaskingConfig, StaticSampling)
from repro.core.federated import (cohort_select, make_cohort_round,
                                  make_cohort_scan, make_federated_round)
from repro.core.sampling import participation_mask


@functools.lru_cache()
def _problem(num_clients, dim=8, classes=3, num_batches=2, batch=4, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (num_clients, num_batches, batch, dim))
    y = jax.random.randint(jax.random.fold_in(key, 1),
                           (num_clients, num_batches, batch), 0, classes)

    def loss_fn(params, data):
        xb, yb = data
        logp = jax.nn.log_softmax(xb @ params["w"] + params["b"])
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

    params = {"w": 0.1 * jax.random.normal(jax.random.fold_in(key, 2),
                                           (dim, classes)),
              "b": jnp.zeros((classes,))}
    n = jnp.ones((num_clients,), jnp.float32)
    return loss_fn, params, (x, y), n


def _zero_residuals(params, num_clients):
    return jax.tree.map(
        lambda p: jnp.zeros((num_clients,) + p.shape, p.dtype), params)


def _assert_trees_equal(a, b, exact=True):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        if exact:
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        else:
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       rtol=1e-6, atol=1e-7)


def test_cohort_select_matches_participation_mask():
    """Same key => cohort valid-members are exactly the oracle's mask."""
    sched = DynamicSampling(initial_rate=0.9, beta=0.2, min_clients=2)
    for t in range(1, 6):
        key = jax.random.PRNGKey(t)
        mask = participation_mask(key, sched, jnp.float32(t), 16)
        m = sched.num_clients_host(t, 16)
        bucket = sched.bucket_for(m, 16)
        ids, valid = cohort_select(key, sched, jnp.float32(t), 16, bucket)
        got = np.zeros(16, np.float32)
        got[np.asarray(ids)] = np.asarray(valid)
        np.testing.assert_array_equal(got, np.asarray(mask))
        assert int(valid.sum()) == m


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=5, max_value=16),
       st.floats(min_value=0.0, max_value=0.4),
       st.booleans())
def test_cohort_round_matches_oracle(num_clients, beta, error_feedback):
    """Property: every round's (params, residuals, mean_loss, num_sampled)
    from the cohort engine matches the full-vmap oracle, across the bucket
    boundaries the decaying schedule walks through."""
    loss_fn, params, batches, n = _problem(num_clients)
    sched = DynamicSampling(initial_rate=1.0, beta=beta, min_clients=2)
    cfg = FederatedConfig(
        num_clients=num_clients,
        client=ClientConfig(local_epochs=1, learning_rate=0.1,
                            masking=MaskingConfig(mode="selective",
                                                  gamma=0.4)),
        error_feedback=error_feedback)
    oracle = jax.jit(make_federated_round(loss_fn, sched, cfg))

    p_o = p_c = params
    r_o = r_c = _zero_residuals(params, num_clients)
    key = jax.random.PRNGKey(int(num_clients * 7 + beta * 100))
    buckets_seen = set()
    for t in range(1, 7):
        key, sub = jax.random.split(key)
        t_arg = jnp.asarray(t, jnp.float32)
        m = sched.num_clients_host(t, num_clients)
        bucket = sched.bucket_for(m, num_clients)
        buckets_seen.add(bucket)
        p_o, r_o, met_o = oracle(p_o, r_o, batches, n, t_arg, sub)
        if bucket >= num_clients:
            fn = oracle
        else:
            fn = jax.jit(make_cohort_round(loss_fn, sched, cfg, bucket))
        p_c, r_c, met_c = fn(p_c, r_c, batches, n, t_arg, sub)

        assert int(met_o["num_sampled"]) == int(met_c["num_sampled"]) == m
        np.testing.assert_allclose(float(met_o["mean_loss"]),
                                   float(met_c["mean_loss"]),
                                   rtol=1e-6, atol=1e-6)
        _assert_trees_equal(p_o, p_c)
        _assert_trees_equal(r_o, r_c)
    if beta > 0.2:      # the schedule actually crossed a bucket boundary
        assert len(buckets_seen) > 1, buckets_seen


def test_cohort_scan_matches_round_loop():
    """The lax.scan fast path is the same program as the per-round loop."""
    M = 8
    loss_fn, params, batches, n = _problem(M)
    sched = StaticSampling(initial_rate=0.5, min_clients=2)
    cfg = FederatedConfig(
        num_clients=M,
        client=ClientConfig(local_epochs=1, learning_rate=0.1,
                            masking=MaskingConfig(mode="selective",
                                                  gamma=0.4)),
        error_feedback=True)
    bucket = sched.bucket_for(sched.num_clients_host(1, M), M)
    round_fn = jax.jit(make_cohort_round(loss_fn, sched, cfg, bucket))
    scan_fn = jax.jit(make_cohort_scan(loss_fn, sched, cfg, bucket))

    keys = jax.random.split(jax.random.PRNGKey(3), 4)
    ts = jnp.arange(1, 5, dtype=jnp.float32)
    p, r = params, _zero_residuals(params, M)
    losses = []
    for t, k in zip(ts, keys):
        p, r, met = round_fn(p, r, batches, n, t, k)
        losses.append(float(met["mean_loss"]))
    p_s, r_s, met_s = scan_fn(params, _zero_residuals(params, M), batches,
                              n, ts, keys)
    _assert_trees_equal(p, p_s)
    _assert_trees_equal(r, r_s)
    np.testing.assert_allclose(np.asarray(met_s["mean_loss"]),
                               np.asarray(losses), rtol=1e-6)


def test_server_engines_match():
    """FederatedServer end-to-end: engine="cohort" (with scan segments)
    reproduces engine="full" histories and final params; cohort-aware
    records expose the decaying executed cohort and compile/steady split."""
    M = 16
    loss_fn, params, batches, n = _problem(M)
    sched = DynamicSampling(initial_rate=1.0, beta=0.25, min_clients=2)

    servers = {}
    for engine in ("full", "cohort"):
        cfg = FederatedConfig(
            num_clients=M,
            client=ClientConfig(local_epochs=1, learning_rate=0.1,
                                masking=MaskingConfig(mode="selective",
                                                      gamma=0.4)),
            error_feedback=True)
        s = FederatedServer(loss_fn, sched, cfg, params, seed=5,
                            engine=engine)
        s.run(batches, np.asarray(n), rounds=8)
        servers[engine] = s

    full, cohort = servers["full"], servers["cohort"]
    _assert_trees_equal(full.params, cohort.params)
    assert [r.num_sampled for r in full.history] == \
        [r.num_sampled for r in cohort.history]
    np.testing.assert_allclose(
        [r.mean_loss for r in full.history],
        [r.mean_loss for r in cohort.history], rtol=1e-5, atol=1e-6)

    # cohort-aware records: executed cohort decays with c(t) and is always
    # a bucket >= m_t; the full engine stays flat at M
    coh = [r.cohort_size for r in cohort.history]
    assert all(r.cohort_size == M for r in full.history)
    assert all(b >= r.num_sampled for b, r in zip(coh, cohort.history))
    assert coh[-1] < M and all(a >= b for a, b in zip(coh, coh[1:]))
    assert all(b in sched.bucket_ladder(M) for b in coh)
    # flop proxy tracks the executed cohort, not the registered population
    assert cohort.history[-1].flop_proxy < full.history[-1].flop_proxy

    # compile_s is metered on bucket-change rounds only; wall_s elsewhere
    changes = [i for i in range(len(coh)) if i == 0 or coh[i] != coh[i - 1]]
    for i, r in enumerate(cohort.history):
        if i in changes:
            assert r.compile_s > 0.0
        else:
            assert r.compile_s == 0.0


def test_server_full_rate_uses_oracle_program():
    """At rate 1.0 the only bucket is M, so the cohort engine dispatches the
    oracle program — one compile for the whole run."""
    M = 8
    loss_fn, params, batches, n = _problem(M)
    cfg = FederatedConfig(
        num_clients=M,
        client=ClientConfig(local_epochs=1, learning_rate=0.1,
                            masking=MaskingConfig(mode="none")))
    s = FederatedServer(loss_fn, StaticSampling(initial_rate=1.0), cfg,
                        params, engine="cohort")
    s.run(batches, np.asarray(n), rounds=4)
    assert len(s._compiled) == 1
    assert all(r.cohort_size == M for r in s.history)
    assert sum(1 for r in s.history if r.compile_s > 0) == 1


def test_sharded_cohort_fed_round_matches_full():
    """launch/fedtrain.make_cohort_fed_round on a 1-device mesh reproduces
    the full pod round when the cohort covers the participants."""
    from repro.configs import get_arch
    from repro.launch.fedtrain import (FedPodConfig, make_cohort_fed_round,
                                       make_fed_round)
    from repro.models import transformer as tr

    cfg = get_arch("qwen2-1.5b").reduced()
    C, S, b, T = 4, 2, 2, 32
    fed_cfg = FedPodConfig(num_clients=C, local_steps=S, learning_rate=0.5,
                           gamma=0.3)
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (C, S, b, T), 0, cfg.vocab_size)
    batches = {"tokens": toks, "labels": jnp.roll(toks, -1, -1)}
    n_samples = jnp.ones((C,), jnp.float32)
    part = jnp.asarray([1.0, 0.0, 1.0, 1.0])

    full = jax.jit(make_fed_round(cfg, fed_cfg))
    p_f, m_f = full(params, batches, n_samples, part, key)
    cohort = jax.jit(make_cohort_fed_round(cfg, fed_cfg, cohort_size=4,
                                           mesh=mesh))
    ids = jnp.arange(4, dtype=jnp.int32)
    p_c, m_c = cohort(params, batches, n_samples, ids, part, key)

    assert int(m_f["num_sampled"]) == int(m_c["num_sampled"]) == 3
    np.testing.assert_allclose(float(m_f["mean_loss"]),
                               float(m_c["mean_loss"]), rtol=1e-6)
    for a, b2 in zip(jax.tree_util.tree_leaves(p_f),
                     jax.tree_util.tree_leaves(p_c)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b2, np.float32),
                                   rtol=1e-3, atol=1e-4)


COHORT_SHARD_CHECK = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
import jax.numpy as jnp
import numpy as np
from repro.configs import get_arch
from repro.launch.fedtrain import (FedPodConfig, make_cohort_fed_round,
                                   make_fed_round)
from repro.models import transformer as tr

cfg = get_arch("qwen2-1.5b").reduced()
C, S, b, T = 16, 1, 1, 16          # 16 registered clients, cohort of 8
fed_cfg = FedPodConfig(num_clients=C, local_steps=S, learning_rate=0.5,
                       gamma=0.3)
mesh = jax.sharding.Mesh(np.asarray(jax.devices()), ("data",))
params = tr.init_params(jax.random.PRNGKey(0), cfg)
key = jax.random.PRNGKey(1)
toks = jax.random.randint(key, (C, S, b, T), 0, cfg.vocab_size)
batches = {"tokens": toks, "labels": jnp.roll(toks, -1, -1)}
n_samples = jnp.ones((C,), jnp.float32)

# participants: 5 of the 16 clients; cohort buffer of 8 (1 client/device)
ids = jnp.asarray([1, 3, 4, 7, 9, 12, 13, 15], jnp.int32)
valid = jnp.asarray([1, 1, 0, 1, 0, 1, 0, 1], jnp.float32)
part = jnp.zeros((C,)).at[ids].set(valid)

full = jax.jit(make_fed_round(cfg, fed_cfg))
p_f, m_f = full(params, batches, n_samples, part, key)
cohort = jax.jit(make_cohort_fed_round(cfg, fed_cfg, cohort_size=8,
                                       mesh=mesh, client_axis="data"))
p_c, m_c = cohort(params, batches, n_samples, ids, valid, key)

dmax = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                 - c.astype(jnp.float32))))
           for a, c in zip(jax.tree_util.tree_leaves(p_f),
                           jax.tree_util.tree_leaves(p_c)))
print(json.dumps({"num_sampled_full": float(m_f["num_sampled"]),
                  "num_sampled_cohort": float(m_c["num_sampled"]),
                  "loss_full": float(m_f["mean_loss"]),
                  "loss_cohort": float(m_c["mean_loss"]),
                  "dparams_max": dmax}))
"""


def test_sharded_cohort_round_subprocess_8dev():
    """shard_map cohort round on 8 forced host devices (1 cohort client per
    device) matches the full-population pod round: same participants, same
    loss, params within bf16-reduction-order tolerance."""
    import json
    import os
    import subprocess
    import sys

    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", COHORT_SHARD_CHECK], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["num_sampled_full"] == rec["num_sampled_cohort"] == 5.0
    np.testing.assert_allclose(rec["loss_full"], rec["loss_cohort"],
                               rtol=1e-5)
    assert rec["dparams_max"] < 2e-3, rec


def test_cohort_round_rejects_bad_bucket():
    loss_fn, params, batches, n = _problem(8)
    cfg = FederatedConfig(num_clients=8, client=ClientConfig())
    with pytest.raises(ValueError):
        make_cohort_round(loss_fn, StaticSampling(), cfg, 0)
    with pytest.raises(ValueError):
        make_cohort_round(loss_fn, StaticSampling(), cfg, 9)
    with pytest.raises(ValueError):
        make_cohort_scan(loss_fn, StaticSampling(), cfg, 9)
