"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single real
CPU device (the 512-device override belongs to launch/dryrun.py only)."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
