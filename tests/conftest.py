"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single real
CPU device (the 512-device override belongs to launch/dryrun.py only).

Also hosts the ``hypothesis`` fallback: clean containers don't ship
hypothesis, and a hard module-level import would error the WHOLE test module
at collection.  Test modules import ``given / settings / st`` from here; when
hypothesis is missing they degrade to a deterministic mini property-runner
(bounded cross-product of strategy samples) so every non-property test — and
a fixed-sample version of each property test — still runs.
"""

import itertools

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    _MAX_COMBOS = 12

    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

    class st:  # noqa: N801 — mirrors `strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            span = max_value - min_value
            vals = [min_value, max_value,
                    min_value + span // 3,
                    min_value + (2 * span) // 3,
                    min_value + span // 7]
            return _Strategy(dict.fromkeys(vals))

        @staticmethod
        def sampled_from(seq):
            return _Strategy(seq)

        @staticmethod
        def floats(min_value, max_value):
            mid = 0.5 * (min_value + max_value)
            return _Strategy(dict.fromkeys([min_value, mid, max_value]))

        @staticmethod
        def booleans():
            return _Strategy([False, True])

    def given(*strategies):
        def deco(fn):
            combos = list(itertools.product(*(s.samples for s in strategies)))
            # ceil stride so the kept combos span the whole cross-product
            # (a floor stride would only ever run the head of it).
            stride = -(-len(combos) // _MAX_COMBOS)
            combos = combos[::stride][:_MAX_COMBOS]

            def runner():
                for combo in combos:
                    fn(*combo)

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner
        return deco

    def settings(**_kwargs):
        return lambda fn: fn
