"""Per-architecture smoke tests (spec deliverable f): a REDUCED variant of
each assigned family runs one train step and one decode step on CPU, with
shape and finiteness assertions.  Full configs are exercised only via the
dry-run."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_arch, supports_shape
from repro.models import transformer as tr
from repro.models.transformer import padded_vocab
from repro.optim import sgd, apply_updates


def _batch(cfg, key, B=2, T=32):
    audio = cfg.modality == "audio_stub" and cfg.num_codebooks > 1
    shape = (B, cfg.num_codebooks, T) if audio else (B, T)
    toks = jax.random.randint(key, shape, 0, cfg.vocab_size)
    labels = jnp.roll(toks, -1, axis=-1)
    batch = {"tokens": toks, "labels": labels}
    if cfg.modality == "vision_stub":
        batch["prefix_embeds"] = 0.02 * jax.random.normal(
            key, (B, cfg.num_prefix_embeddings, cfg.d_model))
    return batch


def test_all_archs_have_configs():
    assert len(ARCH_IDS) == 10
    families = {get_arch(a).family for a in ARCH_IDS}
    assert families == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_arch(arch)
    assert cfg.source, f"{arch} must cite its source"
    assert cfg.num_layers >= 24 and cfg.d_model >= 1536
    # reduced variant obeys the smoke limits
    r = cfg.reduced()
    assert r.d_model <= 512 and (not r.moe_experts or r.moe_experts <= 4)
    assert r.num_layers <= 2 * len(cfg.layer_pattern)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """One SGD step on the reduced config: loss finite & decreases over two
    steps, grads finite, output shapes right."""
    cfg = get_arch(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = tr.init_params(key, cfg)
    batch = _batch(cfg, jax.random.fold_in(key, 1))

    logits, aux = tr.forward(params, cfg, batch["tokens"],
                             batch.get("prefix_embeds"))
    B = batch["tokens"].shape[0]
    T = 32
    audio = cfg.modality == "audio_stub" and cfg.num_codebooks > 1
    if audio:
        assert logits.shape == (B, T, cfg.num_codebooks, cfg.vocab_size)
    elif cfg.modality == "vision_stub":
        assert logits.shape == (B, T + cfg.num_prefix_embeddings,
                                padded_vocab(cfg))
    else:
        assert logits.shape == (B, T, padded_vocab(cfg))
    assert not bool(jnp.isnan(logits).any()), "NaN logits"

    opt = sgd(0.1)
    state = opt.init(params)

    @jax.jit
    def step(p, s, b):
        loss, g = jax.value_and_grad(lambda q: tr.lm_loss(q, cfg, b))(p)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s, loss

    losses = []
    for i in range(3):
        params, state, loss = step(params, state, batch)
        assert np.isfinite(float(loss)), f"step {i} loss not finite"
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"loss did not decrease: {losses}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_arch(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = tr.init_params(key, cfg, cfg.param_dtype_serve)
    B = 2
    state = tr.init_decode_state(cfg, B, 16)
    audio = cfg.modality == "audio_stub" and cfg.num_codebooks > 1
    tok_shape = (B, cfg.num_codebooks, 1) if audio else (B, 1)

    step = jax.jit(lambda p, s, t: tr.decode_step(p, cfg, s, t))
    for t in range(3):
        tok = jax.random.randint(jax.random.fold_in(key, t), tok_shape, 0,
                                 cfg.vocab_size)
        logits, state = step(params, state, tok)
        want = (B, 1, cfg.num_codebooks, cfg.vocab_size) if audio \
            else (B, 1, cfg.vocab_size)
        assert logits.shape == want
        assert not bool(jnp.isnan(logits).any())
    assert int(state.position) == 3


def test_shape_applicability_rules():
    """long_500k only for sub-quadratic archs (DESIGN.md)."""
    long = INPUT_SHAPES["long_500k"]
    allowed = {a for a in ARCH_IDS if supports_shape(get_arch(a), long)}
    assert allowed == {"rwkv6-1.6b", "hymba-1.5b", "gemma2-2b",
                       "llama4-maverick-400b-a17b"}
    for a in ARCH_IDS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert supports_shape(get_arch(a), INPUT_SHAPES[s])


def test_padded_vocab_sharding():
    for a in ARCH_IDS:
        assert padded_vocab(get_arch(a)) % 256 == 0
