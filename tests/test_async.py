"""Async buffered-aggregation engine (DESIGN.md §8).

The keystone property: with instant arrivals (ideal fleet), buffer
K = m_t and no injected faults, ``engine="async"`` is BIT-exact vs the
sync cohort engine — params, error-feedback residuals and sampler norm
EMAs — across presets including the adaptive samplers.  On top of that:
staleness discounting changes the math when flushes stack, deadlines cut
rounds gracefully (untouched EF state for the cut clients), retries
recover dropped uploads, the quarantine gate keeps NaN payloads out of
the global model AND out of the quarantined clients' own EF residuals,
and the full server state round-trips through the checkpoint layer
mid-run bit-exactly.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FederatedServer, strategy
from repro.core.async_engine import AsyncConfig, AsyncRoundRunner
from repro.core.client import local_update_flops
from repro.core.federated import _split_round_key
from repro.core.hetero import HeteroModel
from repro.core.sampling import ThresholdSampler


@functools.lru_cache()
def _problem(num_clients, dim=8, classes=3, num_batches=2, batch=4, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (num_clients, num_batches, batch, dim))
    y = jax.random.randint(jax.random.fold_in(key, 1),
                           (num_clients, num_batches, batch), 0, classes)

    def loss_fn(params, data):
        xb, yb = data
        logp = jax.nn.log_softmax(xb @ params["w"] + params["b"])
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

    params = {"w": 0.1 * jax.random.normal(jax.random.fold_in(key, 2),
                                           (dim, classes)),
              "b": jnp.zeros((classes,))}
    n = np.ones((num_clients,), np.float32)
    return loss_fn, params, (x, y), n


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _trees_differ(a, b):
    return any(not np.array_equal(np.asarray(la), np.asarray(lb))
               for la, lb in zip(jax.tree_util.tree_leaves(a),
                                 jax.tree_util.tree_leaves(b)))


IDEAL = HeteroModel(profile="ideal")


# ---------------------------------------------------------------------------
# AsyncConfig validation
# ---------------------------------------------------------------------------
def test_asyncconfig_validation():
    with pytest.raises(ValueError, match="buffer_size / buffer_frac"):
        AsyncConfig(buffer_size=4, buffer_frac=0.5)
    with pytest.raises(ValueError, match="buffer_size"):
        AsyncConfig(buffer_size=0)
    with pytest.raises(ValueError, match="buffer_frac"):
        AsyncConfig(buffer_frac=1.5)
    with pytest.raises(ValueError, match="staleness_beta"):
        AsyncConfig(staleness_beta=-0.1)
    with pytest.raises(ValueError, match="deadline_s / deadline_quantile"):
        AsyncConfig(deadline_s=1.0, deadline_quantile=0.9)
    with pytest.raises(ValueError, match="deadline_s"):
        AsyncConfig(deadline_s=0.0)
    with pytest.raises(ValueError, match="deadline_quantile"):
        AsyncConfig(deadline_quantile=0.0)
    with pytest.raises(ValueError, match="max_retries"):
        AsyncConfig(max_retries=-1)
    with pytest.raises(ValueError, match="corrupt_rate"):
        AsyncConfig(corrupt_rate=2.0)


def test_buffer_for():
    assert AsyncConfig().buffer_for(7) == 7          # default: K = m_t
    assert AsyncConfig(buffer_size=3).buffer_for(7) == 3
    assert AsyncConfig(buffer_frac=0.5).buffer_for(7) == 4  # ceil
    assert AsyncConfig(buffer_frac=0.01).buffer_for(7) == 1


def test_server_rejects_unknown_engine():
    loss_fn, params, _, _ = _problem(4)
    with pytest.raises(ValueError, match="unknown engine"):
        FederatedServer.from_strategy(strategy.get("fig3"), loss_fn, params,
                                      4, engine="buffered")


# ---------------------------------------------------------------------------
# THE keystone: instant arrivals + K = m_t + no faults == sync, bit-exact
# ---------------------------------------------------------------------------
KEYSTONE_CASES = {
    "fig3": lambda: strategy.get("fig3", hetero=IDEAL, error_feedback=True),
    "fig5": lambda: strategy.get("fig5", hetero=IDEAL, error_feedback=True),
    "fig3-importance": lambda: strategy.get(
        "fig3-importance", hetero=IDEAL, error_feedback=True),
    "fig3+threshold": lambda: strategy.get(
        "fig3", hetero=IDEAL, error_feedback=True,
        sampler=ThresholdSampler()),
    # Byzantine presets: the degeneration must hold under ACTIVE attacks
    # too — adversary rows are injected in the shared dispatch sweep, so
    # both engines aggregate the identical attacked payload.
    "byzantine-signflip": lambda: strategy.get(
        "byzantine-signflip", hetero=IDEAL, error_feedback=True),
    "robust-median": lambda: strategy.get(
        "robust-median", hetero=IDEAL, error_feedback=True),
    "robust-krum": lambda: strategy.get(
        "robust-krum", hetero=IDEAL, error_feedback=True),
}


@pytest.mark.parametrize("case", sorted(KEYSTONE_CASES))
def test_async_degenerates_to_sync_bit_exact(case):
    """Ideal fleet, default AsyncConfig (K = m_t, no deadline, no faults):
    every round is dispatch + ONE flush of everyone at staleness zero, and
    the run is bit-identical to the sync cohort engine — including the
    adaptive samplers' norm trackers and the EF residual state.

    The systematic version of this keystone lives in
    tests/test_equivalence.py (preset x engine x store vs the full/dense
    oracle); this test is kept for the hand-picked codec/sampler cases
    it compares engine-to-engine rather than against the oracle."""
    M = 10
    loss_fn, params, batches, n = _problem(M)
    st = KEYSTONE_CASES[case]().replace(async_cfg=AsyncConfig())

    sync = FederatedServer.from_strategy(st, loss_fn, params, M, seed=3,
                                         engine="cohort")
    sync.run(batches, n, rounds=6)
    bufd = FederatedServer.from_strategy(st, loss_fn, params, M, seed=3,
                                         engine="async")
    bufd.run(batches, n, rounds=6)

    _assert_trees_equal(sync.params, bufd.params)
    _assert_trees_equal(sync._residuals, bufd._residuals)
    if st.sampler.adaptive:
        np.testing.assert_array_equal(np.asarray(sync._norms),
                                      np.asarray(bufd._norms))
    for r in bufd.history:
        assert r.mean_staleness == 0.0
        assert r.flushes <= 1
        assert r.timeouts == 0 and r.retries == 0 and r.quarantined == 0
        assert r.arrivals == r.num_sampled
    # loss metric is computed host-side for async: close, not bitwise
    np.testing.assert_allclose([r.mean_loss for r in sync.history],
                               [r.mean_loss for r in bufd.history],
                               rtol=1e-5, atol=1e-7, equal_nan=True)


# ---------------------------------------------------------------------------
# staleness discounting
# ---------------------------------------------------------------------------
def test_staleness_discount_engages_and_changes_math():
    """K = 1 on the mobile fleet: every distinct arrival time is its own
    flush, so later arrivals carry staleness > 0 — and a nonzero beta must
    change the resulting params vs beta = 0 (the discount is real).

    Uses the importance (Horvitz-Thompson, absolute-weight) preset on
    purpose: all rows of one flush share the same staleness, so a
    sum-normalizing FedAvg aggregator cancels the discount exactly — it
    only binds under absolute weights (documented in DESIGN.md §8)."""
    M = 10
    loss_fn, params, batches, n = _problem(M)
    runs = {}
    for beta in (0.0, 1.0):
        st = strategy.get("fig3-importance",
                          hetero=HeteroModel(profile="mobile"),
                          async_cfg=AsyncConfig(buffer_size=1,
                                                staleness_beta=beta,
                                                max_retries=0))
        s = FederatedServer.from_strategy(st, loss_fn, params, M, seed=4,
                                          engine="async")
        s.run(batches, n, rounds=3)
        runs[beta] = s
    hist = runs[1.0].history
    assert any(r.flushes > 1 for r in hist)
    assert any(r.mean_staleness > 0 for r in hist)
    assert _trees_differ(runs[0.0].params, runs[1.0].params)


# ---------------------------------------------------------------------------
# deadlines: graceful degradation
# ---------------------------------------------------------------------------
def test_deadline_cuts_round_and_leaves_ef_state_untouched():
    """A median-arrival deadline on the mobile fleet times out the slow
    half; EF residuals advance ONLY for applied uploads — every other
    client's residual row is exactly its round-entry state (zeros here).
    The model is sized so its weight leaf clears ``min_leaf_size`` —
    otherwise masking and the COO codec ship it dense and every residual
    is identically zero."""
    M = 12
    loss_fn, params, batches, n = _problem(M, dim=32, classes=10)
    st = strategy.get("fig5", hetero=HeteroModel(profile="mobile"),
                      error_feedback=True,
                      async_cfg=AsyncConfig(deadline_quantile=0.5,
                                            max_retries=0))
    s = FederatedServer.from_strategy(st, loss_fn, params, M, seed=6,
                                      engine="async")
    s.run(batches, n, rounds=1)
    rec = s.history[0]
    assert rec.timeouts > 0
    assert rec.arrivals + rec.timeouts + rec.dropped == rec.num_sampled
    # every nonzero residual row belongs to an applied upload
    row_nonzero = np.zeros((M,), bool)
    for leaf in jax.tree_util.tree_leaves(s._residuals):
        flat = np.asarray(leaf).reshape(M, -1)
        row_nonzero |= (flat != 0).any(axis=1)
    assert int(row_nonzero.sum()) == rec.arrivals
    # the simulated round clock stops at the deadline, not the straggler
    times = s._async.traits.client_time_s(
        float(local_update_flops(batches, sum(p.size for p in
                                              jax.tree_util.tree_leaves(params)),
                                 st.client_config())),
        s.client_upload_bytes)
    assert rec.sim_round_s <= float(np.max(times))


# ---------------------------------------------------------------------------
# retry / backoff
# ---------------------------------------------------------------------------
def test_retry_recovers_drops_and_accounting_balances():
    """On the flaky fleet retries fire (and permanently-dropped uploads
    only exist once the retry budget is exhausted); with the budget at 0
    no retry is ever scheduled.  Either way the per-round event accounting
    balances: sends = arrivals + quarantined + timeouts + retries +
    dropped."""
    M = 12
    loss_fn, params, batches, n = _problem(M)
    for max_retries in (0, 3):
        st = strategy.get("fig3",
                          hetero=HeteroModel(profile="flaky-mobile"),
                          async_cfg=AsyncConfig(max_retries=max_retries,
                                                backoff_s=0.1))
        s = FederatedServer.from_strategy(st, loss_fn, params, M, seed=8,
                                          engine="async")
        s.run(batches, n, rounds=6)
        summ = s.summary()
        for rec in s.history:
            sends = rec.transport_bytes // s.client_upload_bytes
            assert sends == (rec.arrivals + rec.quarantined + rec.timeouts
                             + rec.retries + rec.dropped)
        if max_retries == 0:
            assert summ["retries"] == 0
            assert summ["dropped_uploads"] > 0
        else:
            assert summ["retries"] > 0


# ---------------------------------------------------------------------------
# quarantine: the acceptance invariant
# ---------------------------------------------------------------------------
def test_quarantine_protects_global_model_and_ef_residuals():
    """Injected-NaN uploads are rejected at the decode gate: the global
    params stay finite and — the acceptance criterion — every corrupted
    client's EF residual row is bit-identical to its round-entry state.
    With the gate off, the same round poisons the params (negative
    control)."""
    M = 12
    loss_fn, params, batches, n = _problem(M, dim=32, classes=10)
    base = strategy.get("fig5", hetero=IDEAL, error_feedback=True)
    acfg = AsyncConfig(corrupt_rate=0.5)

    runner = AsyncRoundRunner(base.replace(async_cfg=acfg), loss_fn, M)
    residuals = jax.tree.map(
        lambda p: jnp.zeros((M,) + p.shape, p.dtype), params)
    flops = float(local_update_flops(
        batches, sum(p.size for p in jax.tree_util.tree_leaves(params)),
        base.client_config()))
    key = jax.random.PRNGKey(42)
    m = base.sampling.num_clients_host(1, M)
    bucket = base.sampler.cohort_bucket(base.sampling, m, M)
    new_p, new_r, _, stats = runner.run_round(
        params, residuals, None, batches, jnp.asarray(n), 1, key,
        cohort_size=bucket, flops=flops,
        wire_bytes=base.codec.wire_bytes(params))
    assert stats["quarantined"] > 0
    assert stats["arrivals"] > 0
    for leaf in jax.tree_util.tree_leaves(new_p):
        assert np.isfinite(np.asarray(leaf)).all()

    # replay the engine's corrupt draw (first consumption of the host rng,
    # seeded from the round's drop subkey) to find the poisoned clients
    _, _, drop_key = _split_round_key(key, True)
    rng = np.random.default_rng(
        [int(x) for x in np.asarray(drop_key, np.uint32).ravel()])
    corrupt = rng.random(M) < acfg.corrupt_rate
    assert int(corrupt.sum()) >= stats["quarantined"]
    for leaf, old in zip(jax.tree_util.tree_leaves(new_r),
                         jax.tree_util.tree_leaves(residuals)):
        np.testing.assert_array_equal(np.asarray(leaf)[corrupt],
                                      np.asarray(old)[corrupt])
    # applied clients DID advance their residuals (gamma < 1 leaves mass)
    row_nonzero = np.zeros((M,), bool)
    for leaf in jax.tree_util.tree_leaves(new_r):
        flat = np.asarray(leaf).reshape(M, -1)
        row_nonzero |= (flat != 0).any(axis=1)
    assert int(row_nonzero.sum()) == stats["arrivals"]

    # negative control: gate off -> the same poisoned round breaks params
    runner_off = AsyncRoundRunner(
        base.replace(async_cfg=dataclasses.replace(acfg, quarantine=False)),
        loss_fn, M)
    poisoned, _, _, stats_off = runner_off.run_round(
        params, residuals, None, batches, jnp.asarray(n), 1, key,
        cohort_size=bucket, flops=flops,
        wire_bytes=base.codec.wire_bytes(params))
    assert stats_off["quarantined"] == 0
    assert any(not np.isfinite(np.asarray(leaf)).all()
               for leaf in jax.tree_util.tree_leaves(poisoned))


# ---------------------------------------------------------------------------
# crash-resume: checkpoint round-trip mid-run, bit-exact continuation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["cohort", "async"])
def test_crash_resume_bit_exact(engine, tmp_path):
    """8 straight rounds == 4 rounds + save_state + restore into a FRESH
    server (different seed: everything live comes from the checkpoint) +
    4 more rounds — params, EF residuals and norm EMAs bit-identical, and
    the resumed history continues the round numbering."""
    M = 10
    loss_fn, params, batches, n = _problem(M)
    st = strategy.get("fig3-importance", hetero=IDEAL, error_feedback=True,
                      async_cfg=AsyncConfig())

    full = FederatedServer.from_strategy(st, loss_fn, params, M, seed=7,
                                         engine=engine)
    full.run(batches, n, rounds=8)

    first = FederatedServer.from_strategy(st, loss_fn, params, M, seed=7,
                                          engine=engine)
    first.run(batches, n, rounds=4)
    first.save_state(str(tmp_path))

    resumed = FederatedServer.from_strategy(st, loss_fn, params, M,
                                            seed=999, engine=engine)
    step = resumed.restore_state(str(tmp_path))
    assert step == 4 and resumed._round == 4
    resumed.run(batches, n, rounds=4)

    _assert_trees_equal(full.params, resumed.params)
    _assert_trees_equal(full._residuals, resumed._residuals)
    np.testing.assert_array_equal(np.asarray(full._norms),
                                  np.asarray(resumed._norms))
    assert [r.round for r in resumed.history] == [5, 6, 7, 8]
    np.testing.assert_allclose(
        [r.mean_loss for r in full.history[4:]],
        [r.mean_loss for r in resumed.history], rtol=1e-6, equal_nan=True)
