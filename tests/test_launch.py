"""Launch-layer tests: HLO analyzer correctness, sharding rules, and a
subprocess mini dry-run on 8 forced host devices (the in-process test
session keeps its single real device)."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from repro.launch import hlo as hlo_lib

HLO_SAMPLE = """\
HloModule test

%body (arg: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %arg = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[8,8] get-tuple-element(%arg), index=1
  %w = f32[8,8] constant({...})
  %dot.1 = f32[8,8] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %dot.1)
}

%addc (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%cond (arg2: (s32[], f32[8,8])) -> pred[] {
  %arg2 = (s32[], f32[8,8]) parameter(0)
  %i2 = s32[] get-tuple-element(%arg2), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i2, %c), direction=LT
}

ENTRY %main (p0: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %p0)
  %while.1 = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %ag = f32[16,8] all-gather(%p0), channel_id=1, replica_groups=[2,2]<=[4], dimensions={0}
  %ar = f32[8,8] all-reduce(%p0), channel_id=2, to_apply=%addc
  ROOT %out = f32[8,8] get-tuple-element(%while.1), index=1
}
"""


def test_hlo_analyzer_trip_counts_and_flops():
    stats = hlo_lib.analyze(HLO_SAMPLE)
    # dot inside the while body: 2*8*8*8 flops, x5 trip count
    assert stats.flops == 5 * 2 * 8 * 8 * 8


def test_hlo_analyzer_collectives():
    stats = hlo_lib.analyze(HLO_SAMPLE)
    assert stats.per_collective["all-gather"] == 16 * 8 * 4      # output bytes
    assert stats.per_collective["all-reduce"] == 2 * 8 * 8 * 4   # 2x operand
    assert stats.collective_count == {"all-gather": 1, "all-reduce": 1}


def test_hlo_analyzer_real_program():
    def f(x, ws):
        def body(c, w):
            return c @ w, ()
        y, _ = jax.lax.scan(body, x, ws)
        return y
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    txt = jax.jit(f).lower(x, ws).compile().as_text()
    stats = hlo_lib.analyze(txt)
    assert stats.flops == 7 * 2 * 32 * 64 * 64


def test_param_spec_rules():
    from jax.sharding import PartitionSpec as P
    from repro.launch.shardings import param_spec

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 4}

    m = FakeMesh()
    assert param_spec("['attn']['wq']", (64, 64), m) == P(("data",), "model")
    assert param_spec("['attn']['wo']", (64, 64), m) == P("model", ("data",))
    assert param_spec("['embed']", (256, 64), m) == P("model", ("data",))
    # MoE expert-parallel when E divides the axis
    assert param_spec("['moe']['wi']", (8, 64, 64), m) == \
        P("model", ("data",), None)
    # ... ff-TP fallback when it does not
    assert param_spec("['moe']['wi']", (6, 64, 64), m) == \
        P(None, ("data",), "model")
    # non-divisible dims fall back to replicated
    assert param_spec("['attn']['wq']", (63, 64), m) == P(None, "model")
    assert param_spec("['norm1']['scale']", (64,), m) == P()


def test_input_shapes_cover_assignment():
    from repro.configs import INPUT_SHAPES
    s = INPUT_SHAPES
    assert s["train_4k"].seq_len == 4096 and s["train_4k"].global_batch == 256
    assert s["prefill_32k"].seq_len == 32768 and s["prefill_32k"].global_batch == 32
    assert s["decode_32k"].global_batch == 128 and s["decode_32k"].is_decode
    assert s["long_500k"].seq_len == 524288 and s["long_500k"].global_batch == 1


MINI_DRYRUN = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
import jax.numpy as jnp
from repro.configs import get_arch
from repro.configs.base import InputShape
from repro.launch import shardings as sh, steps as steps_lib
from repro.launch import hlo as hlo_lib

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_arch("qwen2-1.5b").reduced()
shape = InputShape("mini", 64, 8, "train")
hints = steps_lib.mesh_hints(mesh)
pspecs = steps_lib.params_specs(cfg, "float32")
psh = sh.params_shardings(pspecs, mesh)
step = steps_lib.make_train_step(cfg, hints=hints)
opt = jax.eval_shape(step.optimizer.init, pspecs)
osh = sh.params_shardings_like(opt, psh, mesh)
batch = steps_lib.batch_specs(cfg, shape)
bsh = sh.batch_shardings(batch, mesh)
fn = jax.jit(step, in_shardings=(psh, osh, bsh), out_shardings=(psh, osh, None))
with mesh:
    compiled = fn.lower(pspecs, opt, batch).compile()
stats = hlo_lib.analyze(compiled.as_text())
mem = compiled.memory_analysis()
print(json.dumps({"flops": stats.flops,
                  "coll": stats.collective_bytes,
                  "temp": mem.temp_size_in_bytes}))
"""


def test_mini_dryrun_subprocess():
    """Compile the reduced qwen2-1.5b train step on a 2x4 forced-device mesh:
    proves the sharding rules + hints produce a lowerable SPMD program with
    collectives, without touching the test session's device count."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", MINI_DRYRUN], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
    assert rec["coll"] > 0          # FSDP/TP must produce collectives


def test_fed_layout():
    from repro.launch.fedtrain import fed_layout

    class SP:
        axis_names = ("data", "model")

    class MP:
        axis_names = ("pod", "data", "model")

    assert fed_layout(SP()) == ("data", ())
    assert fed_layout(MP()) == ("pod", ("data",))
