"""docs/api.md must not rot: extract every fenced ``python`` snippet and
execute it (each in a fresh namespace — snippets are self-contained by
contract).  CI runs this module as its own docs job on every push."""

import pathlib
import re

import pytest

DOC = pathlib.Path(__file__).resolve().parents[1] / "docs" / "api.md"
SNIPPETS = re.findall(r"```python\n(.*?)```", DOC.read_text(), re.S)


def _first_line(src: str) -> str:
    return next((ln for ln in src.splitlines() if ln.strip()), "")[:60]


def test_doc_has_snippets():
    """The reference documents every entry point with runnable code."""
    assert len(SNIPPETS) >= 9, f"only {len(SNIPPETS)} snippets found"


@pytest.mark.parametrize(
    "idx", range(len(SNIPPETS)),
    ids=[f"{i}:{_first_line(s)}" for i, s in enumerate(SNIPPETS)])
def test_snippet_executes(idx):
    """Each fenced python block runs green in isolation."""
    src = SNIPPETS[idx]
    code = compile(src, f"{DOC.name}[snippet {idx}]", "exec")
    exec(code, {"__name__": f"docs_snippet_{idx}"})
