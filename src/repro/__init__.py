"""repro: communication-efficient federated learning in JAX (Ji et al. 2020)."""

__version__ = "1.0.0"
