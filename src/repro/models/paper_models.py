"""The paper's client models (§5.1.3): LeNet (MNIST), VGG (CIFAR-10), and a
GRU language model with tied embeddings (WikiText-2).

Pure-JAX functional implementations over plain dict pytrees so the federated
core (masking per leaf, FedAvg) applies without adapters.  ``*_loss`` take
``(params, batch)`` with ``batch = (x, y)`` — the signature the federated
round expects.

Shapes are parameterised so the benchmarks can match the synthetic data
(14x14 stand-in MNIST; 16x16x3 stand-in CIFAR) while the real dimensions
remain available.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


# ---------------------------------------------------------------------------
# conv helpers
# ---------------------------------------------------------------------------
def _conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    fan_in = kh * kw * cin
    w = fan_in ** -0.5 * jax.random.truncated_normal(
        key, -2.0, 2.0, (kh, kw, cin, cout), jnp.float32)
    return {"w": w.astype(dtype), "b": jnp.zeros((cout,), dtype)}


def _conv(p, x, stride=1, padding="SAME"):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _maxpool(x, size=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, size, size, 1), (1, size, size, 1),
        "VALID")


def _avgpool_all(x):
    return jnp.mean(x, axis=(1, 2))


# ---------------------------------------------------------------------------
# LeNet (paper §5.2, MNIST)
# ---------------------------------------------------------------------------
def init_lenet(key, image_size: int = 28, channels: int = 1,
               num_classes: int = 10) -> dict:
    ks = jax.random.split(key, 5)
    # two conv+pool stages then two dense layers (LeNet-5 shape)
    s = image_size // 4
    return {
        "conv1": _conv_init(ks[0], 5, 5, channels, 6),
        "conv2": _conv_init(ks[1], 5, 5, 6, 16),
        "fc1": {"w": dense_init(ks[2], (s * s * 16, 120), jnp.float32),
                "b": jnp.zeros((120,), jnp.float32)},
        "fc2": {"w": dense_init(ks[3], (120, 84), jnp.float32),
                "b": jnp.zeros((84,), jnp.float32)},
        "out": {"w": dense_init(ks[4], (84, num_classes), jnp.float32),
                "b": jnp.zeros((num_classes,), jnp.float32)},
    }


def lenet_forward(params: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.relu(_conv(params["conv1"], x))
    h = _maxpool(h)
    h = jax.nn.relu(_conv(params["conv2"], h))
    h = _maxpool(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["fc1"]["w"] + params["fc1"]["b"])
    h = jax.nn.relu(h @ params["fc2"]["w"] + params["fc2"]["b"])
    return h @ params["out"]["w"] + params["out"]["b"]


# ---------------------------------------------------------------------------
# VGG (paper §5.2.4, CIFAR-10).  Width-scalable; width=1.0 ~ VGG-16-lite.
# ---------------------------------------------------------------------------
def init_vgg(key, image_size: int = 32, channels: int = 3,
             num_classes: int = 10,
             widths: Sequence[int] = (32, 64, 128, 128)) -> dict:
    ks = jax.random.split(key, len(widths) * 2 + 2)
    p = {}
    cin = channels
    for i, w in enumerate(widths):
        p[f"conv{i}a"] = _conv_init(ks[2 * i], 3, 3, cin, w)
        p[f"conv{i}b"] = _conv_init(ks[2 * i + 1], 3, 3, w, w)
        cin = w
    p["fc"] = {"w": dense_init(ks[-2], (cin, 256), jnp.float32),
               "b": jnp.zeros((256,), jnp.float32)}
    p["out"] = {"w": dense_init(ks[-1], (256, num_classes), jnp.float32),
                "b": jnp.zeros((num_classes,), jnp.float32)}
    return p


def vgg_forward(params: dict, x: jax.Array) -> jax.Array:
    h = x
    i = 0
    while f"conv{i}a" in params:
        h = jax.nn.relu(_conv(params[f"conv{i}a"], h))
        h = jax.nn.relu(_conv(params[f"conv{i}b"], h))
        if min(h.shape[1], h.shape[2]) >= 2:
            h = _maxpool(h)
        i += 1
    h = _avgpool_all(h)
    h = jax.nn.relu(h @ params["fc"]["w"] + params["fc"]["b"])
    return h @ params["out"]["w"] + params["out"]["b"]


def classifier_loss(forward_fn):
    def loss(params, batch):
        x, y = batch
        logits = forward_fn(params, x)
        lp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(lp, y[:, None], axis=1))
    return loss


def classifier_accuracy(forward_fn):
    def acc(params, batch):
        x, y = batch
        return jnp.mean(jnp.argmax(forward_fn(params, x), -1) == y)
    return acc


# ---------------------------------------------------------------------------
# GRU language model, tied embeddings (paper §5.3)
# ---------------------------------------------------------------------------
def init_gru_lm(key, vocab: int, d_embed: int = 128, d_hidden: int = 128,
                tied: bool = True) -> dict:
    ks = jax.random.split(key, 8)
    d = d_hidden
    p = {
        "embed": (d_embed ** -0.5 * jax.random.normal(
            ks[0], (vocab, d_embed))).astype(jnp.float32),
        # GRU: update z, reset r, candidate n
        "wz": dense_init(ks[1], (d_embed + d, d), jnp.float32),
        "wr": dense_init(ks[2], (d_embed + d, d), jnp.float32),
        "wn": dense_init(ks[3], (d_embed + d, d), jnp.float32),
        "bz": jnp.zeros((d,), jnp.float32),
        "br": jnp.zeros((d,), jnp.float32),
        "bn": jnp.zeros((d,), jnp.float32),
        "proj": dense_init(ks[4], (d, d_embed), jnp.float32),
    }
    if not tied:
        p["head"] = dense_init(ks[5], (d_embed, vocab), jnp.float32)
    return p


def gru_lm_forward(params: dict, tokens: jax.Array) -> jax.Array:
    """tokens: (B, T) -> logits (B, T, V)."""
    B, T = tokens.shape
    d = params["bz"].shape[0]
    e = params["embed"][tokens]                      # (B, T, de)

    def step(h, xt):
        hx = jnp.concatenate([xt, h], axis=-1)
        z = jax.nn.sigmoid(hx @ params["wz"] + params["bz"])
        r = jax.nn.sigmoid(hx @ params["wr"] + params["br"])
        hxr = jnp.concatenate([xt, r * h], axis=-1)
        n = jnp.tanh(hxr @ params["wn"] + params["bn"])
        h = (1 - z) * n + z * h
        return h, h

    h0 = jnp.zeros((B, d), jnp.float32)
    _, hs = jax.lax.scan(step, h0, e.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2)                       # (B, T, d)
    out = hs @ params["proj"]
    if "head" in params:
        return out @ params["head"]
    return out @ params["embed"].T                   # tied


def gru_lm_loss(params: dict, batch) -> jax.Array:
    x, y = batch
    logits = gru_lm_forward(params, x)
    lp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(lp, y[..., None], axis=-1))


def perplexity(params: dict, batch) -> jax.Array:
    return jnp.exp(gru_lm_loss(params, batch))
