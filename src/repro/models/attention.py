"""Attention: GQA with RoPE, memory-efficient (flash-style) train/prefill
path, single-token decode path with full / sliding(ring-buffer) / chunked KV
caches.

Design notes (DESIGN.md §6):

* Train/prefill never materialises the (T, S) logit matrix for the full
  sequence.  ``flash_attention`` scans over KV blocks with an online softmax
  (running max / running sum), so peak memory is O(T · block_kv) per head —
  this is what lets ``prefill_32k`` lower without a terabyte intermediate.
* GQA KV heads are broadcast to the full Q-head count *inside* the scan body
  (one block at a time), so every activation carries the H axis — which the
  "model" mesh axis shards cleanly (H is a multiple of the axis size for all
  assigned archs), instead of the awkward (KV, groups) factorisation.
* Visibility (causal / sliding / chunked) is a predicate over *logical
  positions*, passed as explicit ``q_pos`` / ``k_pos`` arrays.  The same
  predicate drives the decode path's ring-buffer masking, so windowed decode
  needs no special-case attention math.
* Decode: one token against a cache laid out (B, S, KV, D), computed as a
  direct KV-grouped einsum (logits are only (B, H, S)); with the cache
  sequence-sharded (long_500k) the softmax reductions become psums that XLA
  SPMD inserts automatically.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# masks over logical positions
# ---------------------------------------------------------------------------
def visibility(q_pos: jax.Array, k_pos: jax.Array, attn: str,
               window: int) -> jax.Array:
    """(Tq, Tk) bool.  k_pos < 0 marks an invalid (empty/padded) slot."""
    q = q_pos[:, None]
    k = k_pos[None, :]
    vis = (k <= q) & (k >= 0)
    if attn == "sliding" and window > 0:
        vis &= k > q - window
    elif attn == "chunked" and window > 0:
        vis &= (k // window) == (q // window)
    return vis


# ---------------------------------------------------------------------------
# flash-style attention (train / prefill)
# ---------------------------------------------------------------------------
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, attn: str = "full", window: int = 0,
                    softcap_val: float = 0.0, scale: Optional[float] = None,
                    q_offset: int = 0, block_q: int = 2048,
                    block_kv: int = 2048, hints=None) -> jax.Array:
    """Two-level blocked online-softmax attention with a flash (recompute)
    backward — see models/flash_vjp.py for the algorithm and memory notes.

    q: (B, T, H, D);  k, v: (B, S, KV, D) with H a multiple of KV (GQA).
    Returns (B, T, H, D).  Causal; query positions are ``q_offset + [0..T)``
    and key positions ``[0..S)``.

    Sharding modes via ``hints``:
      * head-sharded (H %% model == 0): classic Megatron attention; Q blocks
        split the (unsharded) T axis.
      * sequence-sharded (otherwise):   q/acc keep T on "model"; no Q
        blocking (per-device T is already small) and K/V gather once.
    """
    from repro.models.flash_vjp import flash_core
    B, T, H, D = q.shape
    S = k.shape[1]
    scale = scale if scale is not None else D ** -0.5

    head_sharded = (hints is not None and hints.model_size > 1
                    and H % hints.model_size == 0)
    if head_sharded:
        block_q = min(block_q, T)
    else:
        block_q = T                              # seq mode: no Q blocking
    block_kv = min(block_kv, S)

    nq = -(-T // block_q)
    nkv = -(-S // block_kv)
    pad_q = nq * block_q - T
    pad_kv = nkv * block_kv - S
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))

    out = flash_core(q, k, v, attn, window, softcap_val, float(scale),
                     q_offset, block_q, block_kv, T, S, hints)
    return out[:, :T]


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------
class KVCache(NamedTuple):
    """k, v: (B, S_cache, KV, D).  ``index``: logical position of next token.

    Full layers: S_cache = max_seq (append-at-index).
    Sliding/chunked layers: S_cache = window slots (ring buffer).
    """
    k: jax.Array
    v: jax.Array
    index: jax.Array  # () int32


def init_kv_cache(batch: int, max_seq: int, kv_heads: int, head_dim: int,
                  dtype, *, attn: str = "full", window: int = 0) -> KVCache:
    slots = window if (attn in ("sliding", "chunked") and window) else max_seq
    slots = min(slots, max_seq)
    shape = (batch, slots, kv_heads, head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))


def cache_positions(cache: KVCache, attn: str, window: int) -> jax.Array:
    """Logical position held by each cache slot *after* the current token
    (at position cache.index) has been written; empty slots -> -1."""
    slots = cache.k.shape[1]
    pos = cache.index                    # position of the token being decoded
    slot_ids = jnp.arange(slots)
    if attn in ("sliding", "chunked") and window:
        # slot s holds the largest p <= pos with p % slots == s
        logical = pos - ((pos - slot_ids) % slots)
        return jnp.where(logical >= 0, logical, -1)
    return jnp.where(slot_ids <= pos, slot_ids, -1)


def decode_attention(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                     cache: KVCache, *, attn: str = "full", window: int = 0,
                     softcap_val: float = 0.0, scale: Optional[float] = None,
                     hints=None) -> tuple[jax.Array, KVCache]:
    """One-token attention.  q: (B, 1, H, D); k_new/v_new: (B, 1, KV, D).
    With ``hints``, logits/cache stay sequence-sharded over "model"."""
    from repro.models.hints import apply_seq
    B, _, H, D = q.shape
    KV = k_new.shape[2]
    groups = H // KV
    scale = scale if scale is not None else D ** -0.5
    slots = cache.k.shape[1]
    pos = cache.index

    slot = pos % slots   # full cache: pos < slots so this is pos itself
    k_cache = jax.lax.dynamic_update_slice(
        cache.k, k_new.astype(cache.k.dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache.v, v_new.astype(cache.v.dtype), (0, slot, 0, 0))
    new_cache = KVCache(k_cache, v_cache, pos + 1)

    k_pos = cache_positions(new_cache._replace(index=pos), attn, window)
    vis = visibility(pos[None], k_pos, attn, window)[0]          # (S,)

    qf = (q * jnp.asarray(scale, q.dtype)).reshape(B, KV, groups, D)
    kf = k_cache.transpose(0, 2, 3, 1)                           # (B,KV,D,S)
    kf = apply_seq(hints, kf, t_axis=3)
    logits = jnp.einsum("bgqd,bgds->bgqs", qf.astype(kf.dtype), kf,
                        preferred_element_type=jnp.float32)      # (B,KV,g,S)
    logits = apply_seq(hints, logits, t_axis=3)
    if softcap_val > 0.0:
        logits = softcap_val * jnp.tanh(logits / softcap_val)
    logits = jnp.where(vis[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    vf = v_cache.transpose(0, 2, 1, 3)                           # (B,KV,S,D)
    vf = apply_seq(hints, vf, t_axis=2)
    out = jnp.einsum("bgqs,bgsd->bgqd", p.astype(vf.dtype), vf,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, H, D).astype(q.dtype)
    return out, new_cache


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------
def init_attn_params(key, d_model: int, num_heads: int, num_kv: int,
                     head_dim: int, qkv_bias: bool, dtype) -> dict:
    from repro.models.common import dense_init
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d_model, num_heads * head_dim), dtype),
        "wk": dense_init(ks[1], (d_model, num_kv * head_dim), dtype),
        "wv": dense_init(ks[2], (d_model, num_kv * head_dim), dtype),
        "wo": dense_init(ks[3], (num_heads * head_dim, d_model), dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((num_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((num_kv * head_dim,), dtype)
    return p


def project_qkv(params: dict, x: jax.Array, num_heads: int, num_kv: int,
                head_dim: int, positions: jax.Array, rope_theta: float,
                compute_dtype) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, T, d) -> q (B,T,H,D), k/v (B,T,KV,D), RoPE applied.
    ``positions``: (T,) logical positions for RoPE."""
    B, T, _ = x.shape
    xc = x.astype(compute_dtype)
    q = xc @ params["wq"].astype(compute_dtype)
    k = xc @ params["wk"].astype(compute_dtype)
    v = xc @ params["wv"].astype(compute_dtype)
    if "bq" in params:
        q = q + params["bq"].astype(compute_dtype)
        k = k + params["bk"].astype(compute_dtype)
        v = v + params["bv"].astype(compute_dtype)
    q = q.reshape(B, T, num_heads, head_dim)
    k = k.reshape(B, T, num_kv, head_dim)
    v = v.reshape(B, T, num_kv, head_dim)
    if rope_theta > 0:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def out_proj(params: dict, attn_out: jax.Array, compute_dtype) -> jax.Array:
    B, T, H, D = attn_out.shape
    return (attn_out.reshape(B, T, H * D).astype(compute_dtype)
            @ params["wo"].astype(compute_dtype))
