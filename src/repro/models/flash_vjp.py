"""Flash attention with a memory-correct custom VJP.

XLA's autodiff of the blocked-attention scan saves every block's probability
matrix for the backward pass — (nq, nkv, B, H, bq, bkv) fp32, measured
4.3 GB/layer on qwen2-72b/train_4k — defeating the point of the blocking.
This module implements the FlashAttention-2 backward: save only
(q, k, v, out, lse) and recompute p per block while accumulating
(dq, dk, dv).  Residuals are O(B*T*H*D); the backward adds one extra pass
over the blocks (the standard flash trade).

Semantics (masks over logical positions, GQA, softcap) are shared with
``attention.visibility``; gradients are validated against jax autodiff of
the naive oracle in tests/test_models.py.
"""

from __future__ import annotations

import functools
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _visibility(q_pos, k_pos, attn, window):
    q = q_pos[:, None]
    k = k_pos[None, :]
    vis = (k <= q) & (k >= 0) & (q >= 0)
    if attn == "sliding" and window > 0:
        vis &= k > q - window
    elif attn == "chunked" and window > 0:
        vis &= (k // window) == (q // window)
    return vis


def _blocks(x, n, b, axis1_shape):
    """(B, S, KV, D) -> (n, B, KV, b, D)"""
    B, S, KV, D = x.shape
    return x.reshape(B, n, b, KV, D).transpose(1, 0, 3, 2, 4)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10, 11, 12))
def flash_core(q, k, v, attn: str, window: int, softcap_val: float,
               scale: float, q_offset: int, block_q: int, block_kv: int,
               t_real: int, s_real: int, hints):
    """q: (B,T,H,D); k,v: (B,S,KV,D) already padded to block multiples;
    rows/slots beyond t_real/s_real are padding (position -1, fully
    masked in fwd AND bwd).  Returns (B,T,H,D)."""
    out, _ = _flash_fwd(q, k, v, attn, window, softcap_val, scale, q_offset,
                        block_q, block_kv, t_real, s_real, hints)
    return out


def _apply_hints(hints, x, h_axis, t_axis):
    if hints is None:
        return x
    from repro.models.hints import apply_qkv
    return apply_qkv(hints, x, h_axis=h_axis, t_axis=t_axis)


def _flash_fwd(q, k, v, attn, window, softcap_val, scale, q_offset,
               block_q, block_kv, t_real, s_real, hints):
    B, T, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    groups = H // KV
    nq, nkv = T // block_q, S // block_kv

    qf = (q * jnp.asarray(scale, q.dtype)).transpose(0, 2, 1, 3)  # (B,H,T,D)
    qf = _apply_hints(hints, qf, 1, 2)
    qb_all = qf.reshape(B, H, nq, block_q, D).transpose(2, 0, 1, 3, 4)
    kb_all = _blocks(k, nkv, block_kv, S)          # (n,B,KV,bk,D)
    vb_all = _blocks(v, nkv, block_kv, S)

    idx_q = jnp.arange(nq * block_q)
    q_pos_all = jnp.where(idx_q < t_real, q_offset + idx_q, -1) \
        .reshape(nq, block_q)
    idx_k = jnp.arange(nkv * block_kv)
    k_pos_all = jnp.where(idx_k < s_real, idx_k, -1).reshape(nkv, block_kv)

    def q_body(_, qblk):
        qb, q_pos = qblk

        def kv_body(carry, kvblk):
            acc, m, lsum = carry
            kb, vb, k_pos = kvblk
            kb = jnp.repeat(kb, groups, axis=1)    # (B,H,bk,D)
            vb = jnp.repeat(vb, groups, axis=1)
            logits = jnp.einsum("bhtd,bhkd->bhtk", qb, kb,
                                preferred_element_type=jnp.float32)
            if softcap_val > 0.0:
                logits = softcap_val * jnp.tanh(logits / softcap_val)
            mask = _visibility(q_pos, k_pos, attn, window)
            logits = jnp.where(mask[None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = lsum * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhtk,bhkd->bhtd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, H, block_q, D), jnp.float32)
        m0 = jnp.full((B, H, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, block_q), jnp.float32)
        (acc, m, lsum), _ = jax.lax.scan(kv_body, (acc0, m0, l0),
                                         (kb_all, vb_all, k_pos_all))
        l_safe = jnp.maximum(lsum, 1e-30)
        out_b = acc / l_safe[..., None]
        lse_b = m + jnp.log(l_safe)                # (B,H,bq)
        return None, (out_b, lse_b)

    _, (out_blocks, lse_blocks) = jax.lax.scan(q_body, None,
                                               (qb_all, q_pos_all))
    out = out_blocks.transpose(1, 2, 0, 3, 4).reshape(B, H, T, D)
    out = _apply_hints(hints, out, 1, 2)
    lse = lse_blocks.transpose(1, 2, 0, 3).reshape(B, H, T)
    return (out.transpose(0, 2, 1, 3).astype(q.dtype),
            (q, k, v, out.astype(q.dtype), lse))


def _flash_bwd(attn, window, softcap_val, scale, q_offset, block_q, block_kv,
               t_real, s_real, hints, res, g):
    q, k, v, out_bhtd, lse = res                   # out_bhtd: (B,H,T,D)
    B, T, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    groups = H // KV
    nq, nkv = T // block_q, S // block_kv
    f32 = jnp.float32

    qf = (q * jnp.asarray(scale, q.dtype)).transpose(0, 2, 1, 3)
    qf = _apply_hints(hints, qf, 1, 2)
    do = g.transpose(0, 2, 1, 3)                   # (B,H,T,D)
    do = _apply_hints(hints, do.astype(f32), 1, 2)
    # delta_t = sum_d do_t * out_t   (flash2 trick)
    delta = jnp.sum(do * out_bhtd.astype(f32), axis=-1)      # (B,H,T)

    qb_all = qf.reshape(B, H, nq, block_q, D).transpose(2, 0, 1, 3, 4)
    dob_all = do.reshape(B, H, nq, block_q, D).transpose(2, 0, 1, 3, 4)
    lse_all = lse.reshape(B, H, nq, block_q).transpose(2, 0, 1, 3)
    dl_all = delta.reshape(B, H, nq, block_q).transpose(2, 0, 1, 3)
    kb_all = _blocks(k, nkv, block_kv, S)          # (n,B,KV,bk,D)
    vb_all = _blocks(v, nkv, block_kv, S)
    idx_q = jnp.arange(nq * block_q)
    q_pos_all = jnp.where(idx_q < t_real, q_offset + idx_q, -1) \
        .reshape(nq, block_q)
    idx_k = jnp.arange(nkv * block_kv)
    k_pos_all = jnp.where(idx_k < s_real, idx_k, -1).reshape(nkv, block_kv)

    def q_body(carry, qblk):
        dk_acc, dv_acc = carry                     # (nkv,B,KV,bk,D) f32
        qb, dob, lse_b, dl_b, q_pos = qblk

        def kv_body(dq_acc, kvblk):
            kb, vb, k_pos, dk_a, dv_a = kvblk
            kbe = jnp.repeat(kb, groups, axis=1)   # (B,H,bk,D)
            vbe = jnp.repeat(vb, groups, axis=1)
            logits_raw = jnp.einsum("bhtd,bhkd->bhtk", qb, kbe,
                                    preferred_element_type=f32)
            if softcap_val > 0.0:
                th = jnp.tanh(logits_raw / softcap_val)
                logits = softcap_val * th
            else:
                logits = logits_raw
            mask = _visibility(q_pos, k_pos, attn, window)
            logits = jnp.where(mask[None, None], logits, NEG_INF)
            p = jnp.exp(logits - lse_b[..., None])           # (B,H,bq,bk)
            p = jnp.where(mask[None, None], p, 0.0)  # padded q rows: lse is
            # degenerate (-inf - -inf), exp gives 1 — zero them explicitly.
            dp = jnp.einsum("bhtd,bhkd->bhtk", dob, vbe.astype(f32),
                            preferred_element_type=f32)
            ds = p * (dp - dl_b[..., None])
            if softcap_val > 0.0:
                ds = ds * (1.0 - jnp.square(th))
            ds = jnp.where(mask[None, None], ds, 0.0)

            dq_acc = dq_acc + jnp.einsum(
                "bhtk,bhkd->bhtd", ds.astype(kbe.dtype), kbe,
                preferred_element_type=f32)
            dv_blk = jnp.einsum("bhtk,bhtd->bhkd", p.astype(dob.dtype), dob,
                                preferred_element_type=f32)
            dk_blk = jnp.einsum("bhtk,bhtd->bhkd", ds.astype(qb.dtype), qb,
                                preferred_element_type=f32)
            # GQA: fold head groups back onto KV heads
            dv_blk = dv_blk.reshape(B, KV, groups, block_kv, D).sum(2)
            dk_blk = dk_blk.reshape(B, KV, groups, block_kv, D).sum(2)
            return dq_acc, (dk_a + dk_blk, dv_a + dv_blk)

        dq0 = jnp.zeros((B, H, block_q, D), f32)
        dq_b, (dk_new, dv_new) = jax.lax.scan(
            kv_body, dq0, (kb_all, vb_all, k_pos_all, dk_acc, dv_acc))
        return (dk_new, dv_new), dq_b

    dk0 = jnp.zeros((nkv, B, KV, block_kv, D), f32)
    dv0 = jnp.zeros((nkv, B, KV, block_kv, D), f32)
    (dk_blocks, dv_blocks), dq_blocks = jax.lax.scan(
        q_body, (dk0, dv0), (qb_all, dob_all, lse_all, dl_all, q_pos_all))

    dq = dq_blocks.transpose(1, 2, 0, 3, 4).reshape(B, H, T, D)
    dq = (dq * scale).transpose(0, 2, 1, 3).astype(q.dtype)
    dk = dk_blocks.transpose(1, 0, 3, 2, 4).reshape(B, S, KV, D).astype(k.dtype)
    dv = dv_blocks.transpose(1, 0, 3, 2, 4).reshape(B, S, KV, D).astype(v.dtype)
    return dq, dk, dv


flash_core.defvjp(
    lambda q, k, v, attn, window, softcap_val, scale, q_offset, block_q,
    block_kv, t_real, s_real, hints: _flash_fwd(
        q, k, v, attn, window, softcap_val, scale, q_offset, block_q,
        block_kv, t_real, s_real, hints),
    _flash_bwd)
