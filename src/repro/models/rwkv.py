"""RWKV6 "Finch" blocks [arXiv:2404.05892]: data-dependent per-channel decay
time-mix (wkv6) + squared-ReLU channel-mix.

TPU adaptation (DESIGN.md §6): the recurrence

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (per head, S in R^{D x D})
    y_t = r_t^T S_{t-1} + (r_t . u . k_t) v_t

is evaluated in **chunked parallel form**: a ``lax.scan`` over time chunks of
``CHUNK`` tokens carrying S; within a chunk, contributions are dense matmuls
against log-domain cumulative decays.  This replaces a T-step scalar scan
with T/CHUNK MXU-friendly steps — the standard linear-attention chunking.

Numerics: cumulative decays are kept in log space and the in-chunk division
``k_s / W_s`` is fused as ``exp(logW_t - logW_s)`` inside the pair matrix, so
nothing overflows even for strongly-decaying channels; fp32 throughout the
recurrence.  Correctness is property-tested against the step-by-step scan
oracle (tests/test_models.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init

CHUNK = 64


def init_rwkv_params(key, d_model: int, head_dim: int, d_ff: int, dtype) -> dict:
    """Time-mix (r,k,v,w,g projections + u bonus + output) and channel-mix."""
    H = d_model // head_dim
    ks = jax.random.split(key, 10)
    p = {
        # time-mix lerp coefficients (token shift): one per projection
        "mu_r": jnp.full((d_model,), 0.5, dtype),
        "mu_k": jnp.full((d_model,), 0.5, dtype),
        "mu_v": jnp.full((d_model,), 0.5, dtype),
        "mu_w": jnp.full((d_model,), 0.5, dtype),
        "mu_g": jnp.full((d_model,), 0.5, dtype),
        "wr": dense_init(ks[0], (d_model, d_model), dtype),
        "wk": dense_init(ks[1], (d_model, d_model), dtype),
        "wv": dense_init(ks[2], (d_model, d_model), dtype),
        # decay: low-rank data-dependent part + channel bias (Finch)
        "ww1": dense_init(ks[3], (d_model, 64), dtype),
        "ww2": dense_init(ks[4], (64, d_model), dtype),
        "w_bias": jnp.full((d_model,), -5.0, dtype),   # decay ~ exp(-exp(-5+x))
        "wg": dense_init(ks[5], (d_model, d_model), dtype),
        "u": (0.1 * jax.random.normal(ks[6], (H, head_dim))).astype(dtype),
        "wo": dense_init(ks[7], (d_model, d_model), dtype),
        # channel-mix
        "mu_ck": jnp.full((d_model,), 0.5, dtype),
        "ck": dense_init(ks[8], (d_model, d_ff), dtype),
        "cv": dense_init(ks[9], (d_ff, d_model), dtype),
    }
    return p


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """shift right by one along T; position 0 takes ``prev`` (B, 1, d)."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _lerp(x, xs, mu):
    return x + (xs - x) * mu


def _pick_chunk(T: int) -> int:
    c = min(CHUNK, T)
    while T % c:
        c //= 2
    return max(c, 1)


def wkv6_chunked(r, k, v, logw, u, state):
    """Chunked wkv6. r,k,v: (B, T, H, D); logw: (B, T, H, D) = log decay
    in (-inf, 0); u: (H, D); state: (B, H, D, D).
    Returns (y (B,T,H,D), final state)."""
    B, T, H, D = r.shape
    CHUNK = _pick_chunk(T)
    n = T // CHUNK
    rc = r.reshape(B, n, CHUNK, H, D).transpose(1, 0, 3, 2, 4)  # (n,B,H,C,D)
    kc = k.reshape(B, n, CHUNK, H, D).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, n, CHUNK, H, D).transpose(1, 0, 3, 2, 4)
    lw = logw.reshape(B, n, CHUNK, H, D).transpose(1, 0, 3, 2, 4)

    causal = jnp.tril(jnp.ones((CHUNK, CHUNK), jnp.bool_), k=-1)  # strict

    def body(S, blk):
        rb, kb, vb, lwb = blk                     # (B,H,C,D)
        cum = jnp.cumsum(lwb, axis=2)             # logW_t = sum_{s<=t} lw_s
        cum_prev = cum - lwb                      # logW_{t-1} (excl. current)
        # state contribution: q'_t = r_t * exp(logW_{t-1})
        q_state = rb * jnp.exp(cum_prev)
        y_state = jnp.einsum("bhtd,bhde->bhte", q_state, S)
        # intra-chunk: pair decay exp(logW_{t-1} - logW_s) for s < t
        # logits_{t,s} = sum_d r_t[d] k_s[d] exp(cum_prev[t,d] - cum[s,d])
        # computed as einsum over d with the pair decay folded per (t,s,d):
        # A[t,s] = sum_d (r_t[d] e^{cum_prev[t,d]}) (k_s[d] e^{-cum[s,d]})
        k_adj = kb * jnp.exp(-cum)
        A = jnp.einsum("bhtd,bhsd->bhts", q_state, k_adj)
        A = jnp.where(causal[None, None], A, 0.0)
        y_intra = jnp.einsum("bhts,bhsd->bhtd", A, vb)
        # diagonal (bonus) term: (r_t . u . k_t) v_t
        diag = jnp.sum(rb * u[None, :, None, :] * kb, axis=-1, keepdims=True)
        y = y_state + y_intra + diag * vb
        # state update: S' = diag(e^{cum_T}) S + sum_s diag(e^{cum_T - cum_s}) k_s v_s^T
        wtot = cum[:, :, -1:, :]                   # (B,H,1,D)
        k_carry = kb * jnp.exp(wtot - cum)
        S_new = jnp.exp(wtot.squeeze(2))[..., None] * S + \
            jnp.einsum("bhsd,bhse->bhde", k_carry, vb)
        return S_new, y

    state, yc = jax.lax.scan(body, state.astype(jnp.float32),
                             (rc.astype(jnp.float32), kc.astype(jnp.float32),
                              vc.astype(jnp.float32), lw.astype(jnp.float32)))
    y = yc.transpose(1, 0, 3, 2, 4).reshape(B, T, H, D)
    return y, state


def wkv6_step(r, k, v, logw, u, state):
    """Single-token recurrence (decode). r,k,v,logw: (B, 1, H, D)."""
    rb = r[:, 0].astype(jnp.float32)
    kb = k[:, 0].astype(jnp.float32)
    vb = v[:, 0].astype(jnp.float32)
    w = jnp.exp(logw[:, 0].astype(jnp.float32))    # (B,H,D)
    y = jnp.einsum("bhd,bhde->bhe", rb, state) + \
        jnp.sum(rb * u[None] * kb, -1, keepdims=True) * vb
    state = w[..., None] * state + jnp.einsum("bhd,bhe->bhde", kb, vb)
    return y[:, None], state


def rwkv_time_mix(params: dict, x: jax.Array, head_dim: int,
                  state: jax.Array, shift_prev: jax.Array,
                  *, decode: bool = False, hints=None):
    """x: (B, T, d). Returns (out, new_state, new_shift_prev)."""
    B, T, d = x.shape
    H = d // head_dim
    f32 = jnp.float32
    xs = _token_shift(x, shift_prev) if not decode else shift_prev
    xr = _lerp(x, xs, params["mu_r"])
    xk = _lerp(x, xs, params["mu_k"])
    xv = _lerp(x, xs, params["mu_v"])
    xw = _lerp(x, xs, params["mu_w"])
    xg = _lerp(x, xs, params["mu_g"])

    from repro.models.hints import apply_feature
    r = apply_feature(hints, (xr @ params["wr"]).reshape(B, T, H, head_dim), 2)
    k = apply_feature(hints, (xk @ params["wk"]).reshape(B, T, H, head_dim), 2)
    v = apply_feature(hints, (xv @ params["wv"]).reshape(B, T, H, head_dim), 2)
    g = jax.nn.silu(xg @ params["wg"])
    # Finch decay: w = exp(-exp(bias + tanh(x ww1) ww2)) in (0, 1)
    wexp = params["w_bias"].astype(f32) + \
        jnp.tanh(xw.astype(f32) @ params["ww1"].astype(f32)) @ \
        params["ww2"].astype(f32)
    logw = apply_feature(hints, -jnp.exp(jnp.clip(wexp, -12.0, 4.0))
                         .reshape(B, T, H, head_dim), 2)

    r_, k_, v_ = (a.transpose(0, 1, 2, 3) for a in (r, k, v))
    if decode:
        y, state = wkv6_step(r_, k_, v_, logw, params["u"].astype(f32), state)
    else:
        y, state = wkv6_chunked(r_, k_, v_, logw, params["u"].astype(f32), state)
    y = y.reshape(B, T, d).astype(x.dtype) * g
    out = y @ params["wo"]
    new_prev = x[:, -1:]
    return out, state, new_prev


def rwkv_channel_mix(params: dict, x: jax.Array, shift_prev: jax.Array,
                     *, decode: bool = False):
    xs = _token_shift(x, shift_prev) if not decode else shift_prev
    xk = _lerp(x, xs, params["mu_ck"])
    h = jnp.square(jax.nn.relu(xk @ params["ck"]))
    return h @ params["cv"], x[:, -1:]
