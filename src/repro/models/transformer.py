"""Generic decoder: every assigned architecture is this module driven by an
``ArchConfig`` (configs/base.py).  No per-arch model code.

Structure
---------
* Params are nested dicts.  Layers are grouped by the config's
  ``layer_pattern``: ``params["layers"]`` is a *tuple* (one entry per pattern
  position) of stacked trees whose leaves carry a leading ``num_groups``
  axis.  Forward scans over groups (``jax.lax.scan`` + ``jax.checkpoint``),
  so the HLO is depth-independent (MaxText-style stacked scan).
* ``forward``    — train / prefill: tokens -> logits (B, T, V).
* ``decode_step``— one token against a ``DecodeState`` (KV caches with
  ring buffers on windowed layers, wkv/ssm states on recurrent layers).
* Modality stubs: ``vision_stub`` prepends precomputed patch embeddings
  (B, P, d); ``audio_stub`` consumes (B, K, T) codebook token grids and
  emits (B, T, K, V) logits (MusicGen).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.common import (dense_init, embed_init, init_norm,
                                 norm_apply, softcap)

PyTree = Any


def _dt(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def padded_vocab(cfg: ArchConfig) -> int:
    """Vocab padded to a multiple of 256 so the vocab axis shards over the
    "model" mesh axis (Megatron-style; e.g. internvl2 92553 -> 92672).
    Padded ids are never used as labels; their logits train to -inf."""
    return -(-cfg.vocab_size // 256) * 256


# ===========================================================================
# init
# ===========================================================================
def _init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], (d_model, d_ff), dtype),
         "wo": dense_init(ks[1], (d_ff, d_model), dtype)}
    if gated:
        p["wg"] = dense_init(ks[2], (d_model, d_ff), dtype)
    return p


def _init_layer(key, cfg: ArchConfig, spec: LayerSpec, dtype) -> dict:
    ks = jax.random.split(key, 4)
    p = {"norm1": init_norm(cfg.d_model, cfg.norm, dtype)}
    if spec.kind == "rwkv":
        p["time_mix"] = rwkv_lib.init_rwkv_params(
            ks[0], cfg.d_model, cfg.rwkv_head_dim, cfg.d_ff, dtype)
        p["norm2"] = init_norm(cfg.d_model, cfg.norm, dtype)
        return p
    # attention (attn / hymba share it)
    p["attn"] = attn_lib.init_attn_params(
        ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
        cfg.resolved_head_dim, cfg.qkv_bias, dtype)
    if spec.kind == "hymba":
        p["ssm"] = ssm_lib.init_ssm_params(
            ks[1], cfg.d_model, cfg.d_model, cfg.ssm_state, dtype)
    if spec.mlp != "none":
        p["norm2"] = init_norm(cfg.d_model, cfg.norm, dtype)
        if spec.mlp == "moe":
            p["moe"] = moe_lib.init_moe_params(
                ks[2], cfg.d_model, cfg.padded_experts,
                cfg.moe_d_ff or cfg.d_ff,
                cfg.moe_shared_d_ff, cfg.gated_mlp, dtype)
        else:
            p["mlp"] = _init_mlp(ks[2], cfg.d_model, cfg.d_ff,
                                 cfg.gated_mlp, dtype)
    return p


def init_params(key: jax.Array, cfg: ArchConfig,
                dtype_name: Optional[str] = None) -> PyTree:
    dtype = _dt(dtype_name or cfg.param_dtype_train)
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    params: dict = {}
    pv = padded_vocab(cfg)
    if cfg.modality == "audio_stub" and cfg.num_codebooks > 1:
        params["embed"] = embed_init(
            k_embed, cfg.num_codebooks * cfg.vocab_size, cfg.d_model, dtype)
    else:
        params["embed"] = embed_init(k_embed, pv, cfg.d_model, dtype)

    layer_params = []
    for p_idx, spec in enumerate(cfg.layer_pattern):
        keys = jax.random.split(
            jax.random.fold_in(k_layers, p_idx), cfg.num_groups)
        stacked = jax.vmap(
            lambda k: _init_layer(k, cfg, spec, dtype))(keys)
        layer_params.append(stacked)
    params["layers"] = tuple(layer_params)

    params["final_norm"] = init_norm(cfg.d_model, cfg.norm, dtype)
    if not cfg.tie_embeddings:
        if cfg.modality == "audio_stub" and cfg.num_codebooks > 1:
            params["lm_head"] = dense_init(
                k_head, (cfg.d_model, cfg.num_codebooks * cfg.vocab_size), dtype)
        else:
            params["lm_head"] = dense_init(
                k_head, (cfg.d_model, pv), dtype)
    return params


def param_count(params: PyTree) -> int:
    import numpy as np
    return int(sum(np.prod(leaf.shape)
                   for leaf in jax.tree_util.tree_leaves(params)))


# ===========================================================================
# layer application (shared train/prefill)
# ===========================================================================
def _mlp_apply(p: dict, x: jax.Array, act: str, gated: bool, cdt) -> jax.Array:
    h = x.astype(cdt) @ p["wi"].astype(cdt)
    h = jax.nn.silu(h) if act == "silu" else jax.nn.gelu(h)
    if gated:
        h = h * (x.astype(cdt) @ p["wg"].astype(cdt))
    return h @ p["wo"].astype(cdt)


def _apply_layer(cfg: ArchConfig, spec: LayerSpec, p: dict, x: jax.Array,
                 positions: jax.Array, recur_state, cdt,
                 hints=None) -> Tuple[jax.Array, Any, jax.Array]:
    """Returns (x, new_recur_state, aux_loss)."""
    from repro.models.hints import apply_seq, apply_grad_bf16
    aux = jnp.zeros((), jnp.float32)
    # Megatron-style sequence parallelism between blocks: the residual
    # stream (and thus the per-group remat checkpoint) is T-sharded over
    # "model"; attention/MLP re-shard internally as needed.
    x = apply_seq(hints, x, t_axis=1)

    if spec.kind == "rwkv":
        h = norm_apply(x, p["norm1"], cfg.norm)
        y, wkv_state, shift1 = rwkv_lib.rwkv_time_mix(
            p["time_mix"], h, cfg.rwkv_head_dim,
            recur_state["wkv"], recur_state["shift1"], hints=hints)
        # constrain the block output to the T-sharded residual layout BEFORE
        # the add: partial sums from the row-parallel matmul then lower to a
        # reduce-scatter instead of a full all-reduce (§Perf hillclimb 1).
        x = x + apply_grad_bf16(hints, apply_seq(hints, y, 1)).astype(x.dtype)
        h = norm_apply(x, p["norm2"], cfg.norm)
        y, shift2 = rwkv_lib.rwkv_channel_mix(
            p["time_mix"], h, recur_state["shift2"])
        x = x + apply_grad_bf16(hints, apply_seq(hints, y, 1)).astype(x.dtype)
        return x, {"wkv": wkv_state, "shift1": shift1, "shift2": shift2}, aux

    h = norm_apply(x, p["norm1"], cfg.norm)
    q, k, v = attn_lib.project_qkv(
        p["attn"], h, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim,
        positions, cfg.rope_theta, cdt)
    a = attn_lib.flash_attention(
        q, k, v, attn=spec.attn, window=spec.window,
        softcap_val=cfg.attn_softcap,
        q_offset=0, hints=hints)
    y = attn_lib.out_proj(p["attn"], a, cdt)

    new_state = recur_state
    if spec.kind == "hymba":
        xz = h.astype(cdt) @ p["ssm"]["w_in"].astype(cdt)
        s, hT = ssm_lib.ssm_forward(p["ssm"], xz, recur_state["ssm"],
                                    hints=hints)
        s = s.astype(cdt) @ p["ssm"]["w_out"].astype(cdt)
        y = 0.5 * (y + s)
        new_state = {"ssm": hT}
    # reduce-scatter (not all-reduce) the row-parallel block output
    x = x + apply_grad_bf16(hints, apply_seq(hints, y, 1)).astype(x.dtype)

    if spec.mlp != "none":
        h = norm_apply(x, p["norm2"], cfg.norm)
        if spec.mlp == "moe":
            y, aux = moe_lib.moe_ffn(
                p["moe"], h.astype(cdt), topk=cfg.moe_topk, act=cfg.act,
                gated=cfg.gated_mlp, hints=hints)
        else:
            y = _mlp_apply(p["mlp"], h, cfg.act, cfg.gated_mlp, cdt)
        x = x + apply_grad_bf16(hints, apply_seq(hints, y, 1)).astype(x.dtype)
    return x, new_state, aux


def _init_recur_state(cfg: ArchConfig, spec: LayerSpec, batch: int,
                      stacked: bool = True):
    """Per-layer recurrent state template (zeros); leading group axis if
    ``stacked``."""
    g = (cfg.num_groups,) if stacked else ()
    if spec.kind == "rwkv":
        H = cfg.d_model // cfg.rwkv_head_dim
        return {
            "wkv": jnp.zeros(g + (batch, H, cfg.rwkv_head_dim,
                                  cfg.rwkv_head_dim), jnp.float32),
            "shift1": jnp.zeros(g + (batch, 1, cfg.d_model), jnp.float32),
            "shift2": jnp.zeros(g + (batch, 1, cfg.d_model), jnp.float32),
        }
    if spec.kind == "hymba":
        return {"ssm": jnp.zeros(g + (batch, cfg.d_model, cfg.ssm_state),
                                 jnp.float32)}
    return {}


# ===========================================================================
# forward (train / prefill)
# ===========================================================================
def _lookup(embed: jax.Array, ids: jax.Array, cdt, hints) -> jax.Array:
    """Embedding lookup.  With hints (sharded execution) this is a one-hot
    contraction instead of a gather: XLA SPMD cannot partition a row gather
    from a vocab-sharded table (it all-gathers the full 5 GB embedding on
    qwen2-72b), but it partitions the dot cleanly — each device contracts
    against its vocab shard and the psum of partials is the (B,T,d)
    activation (Megatron vocab-parallel embedding)."""
    if hints is None or hints.model_size <= 1:
        return embed[ids].astype(cdt)
    from repro.models.hints import apply_batch, apply_feature
    V = embed.shape[0]
    onehot = (ids[..., None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1,) * ids.ndim + (V,),
                                       ids.ndim)).astype(cdt)
    # batch over dp, vocab over model; without the anchor XLA all-gathers
    # the one-hot over the batch axis to match the FSDP-sharded table.
    onehot = apply_feature(hints, onehot, onehot.ndim - 1)
    e = jnp.einsum("...v,vd->...d", onehot, embed.astype(cdt))
    return apply_batch(hints, e)


def embed_tokens(params: PyTree, cfg: ArchConfig, tokens: jax.Array,
                 cdt, hints=None) -> jax.Array:
    """text: (B, T) -> (B, T, d).  audio_stub: (B, K, T) -> summed embeds."""
    if cfg.modality == "audio_stub" and cfg.num_codebooks > 1:
        B, K, T = tokens.shape
        offsets = (jnp.arange(K) * cfg.vocab_size)[None, :, None]
        e = _lookup(params["embed"], (tokens + offsets).reshape(B, K * T),
                    cdt, hints)
        e = e.reshape(B, K, T, -1).sum(axis=1)
    else:
        e = _lookup(params["embed"], tokens, cdt, hints)
    if cfg.name.startswith("gemma2"):
        e = e * jnp.asarray(cfg.d_model ** 0.5, e.dtype)
    return e.astype(cdt)


def forward(params: PyTree, cfg: ArchConfig, tokens: jax.Array,
            prefix_embeds: Optional[jax.Array] = None,
            *, remat: bool = True, hints=None) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits, aux_loss).

    tokens: (B, T) int32 — or (B, K, T) for multi-codebook audio.
    prefix_embeds: (B, P, d) for vision_stub — prepended, logits for those
    positions are returned too (callers slice them off the loss).
    """
    from repro.models.hints import apply_seq
    cdt = _dt(cfg.compute_dtype)
    x = embed_tokens(params, cfg, tokens, cdt, hints)
    B = x.shape[0]
    if cfg.modality == "vision_stub":
        if prefix_embeds is None:
            raise ValueError(f"{cfg.name} requires prefix_embeds")
        x = jnp.concatenate([prefix_embeds.astype(cdt), x], axis=1)
    T = x.shape[1]
    positions = jnp.arange(T)

    pattern = cfg.layer_pattern
    span = cfg.remat_span if cfg.num_groups % max(cfg.remat_span, 1) == 0 \
        else 1
    recur0 = tuple(_init_recur_state(cfg, s, B) for s in pattern)

    layers = params["layers"]
    if span > 1:
        # checkpoint every `span` groups: reshape the stacked leaves from
        # (G, ...) to (G/span, span, ...); the body loops the span inline.
        layers = jax.tree.map(
            lambda x: x.reshape((x.shape[0] // span, span) + x.shape[1:]),
            layers)
        recur0 = jax.tree.map(
            lambda x: x.reshape((x.shape[0] // span, span) + x.shape[1:]),
            recur0)

    def group_body(carry, xs):
        x, aux = carry
        layer_ps, recur = xs
        for s_idx in range(span):
            for p_idx, spec in enumerate(pattern):
                lp = layer_ps[p_idx] if span == 1 else \
                    jax.tree.map(lambda x: x[s_idx], layer_ps[p_idx])
                rc = recur[p_idx] if span == 1 else \
                    jax.tree.map(lambda x: x[s_idx], recur[p_idx])
                x, _, a = _apply_layer(cfg, spec, lp, x,
                                       positions, rc, cdt, hints)
                aux = aux + a
        return (x, aux), None

    x = apply_seq(hints, x, t_axis=1)
    body = jax.checkpoint(group_body) if remat else group_body
    # Recurrent state is *per layer* (each group's layers own their state);
    # pass the stacked zero states as scanned inputs.
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (layers, recur0))

    from repro.models.hints import apply_batch
    x = apply_batch(hints, x)      # ungather T before the vocab-parallel head
    x = norm_apply(x, params["final_norm"], cfg.norm)
    if cfg.tie_embeddings:
        logits = x.astype(cdt) @ params["embed"].T.astype(cdt)
    else:
        logits = x.astype(cdt) @ params["lm_head"].astype(cdt)
    from repro.models.hints import apply_feature
    logits = apply_feature(hints, logits, 2)     # vocab-parallel head
    if cfg.logit_softcap > 0:
        logits = softcap(logits, cfg.logit_softcap)

    if cfg.modality == "audio_stub" and cfg.num_codebooks > 1:
        logits = logits.reshape(B, T, cfg.num_codebooks, cfg.vocab_size)
    return logits, aux


# ===========================================================================
# loss / train step
# ===========================================================================
def cross_entropy(logits: jax.Array, labels: jax.Array,
                  weights: Optional[jax.Array] = None) -> jax.Array:
    """Weighted mean CE without gathering over the vocab axis: the correct-
    class logit is extracted with a fused iota==label contraction, so vocab-
    (model-)sharded logits never all-gather, and ignored positions (weight
    0, e.g. the VLM vision prefix) are masked instead of sliced — slicing a
    sequence-sharded logits tensor forces a full reshard (DESIGN.md §6)."""
    V = logits.shape[-1]
    # No explicit logits.astype(f32): a materialised fp32 copy of the
    # (B, T, V) logits costs 3+ GB/device on the big-vocab archs.  The
    # converts below fuse into the reductions.
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1))
    lse = m.astype(jnp.float32) + jnp.log(jnp.sum(
        jnp.exp((logits - m[..., None]).astype(jnp.float32)), axis=-1))
    onehot = (labels[..., None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1,) * labels.ndim + (V,),
                                       labels.ndim))
    correct = jnp.sum(jnp.where(onehot, logits, 0).astype(jnp.float32),
                      axis=-1)
    nll = lse - correct
    if weights is None:
        return jnp.mean(nll)
    w = weights.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


def lm_loss(params: PyTree, cfg: ArchConfig, batch: dict,
            hints=None) -> jax.Array:
    """batch: {"tokens": (B,T)|(B,K,T), "labels": same, optional
    "prefix_embeds": (B,P,d)}.  Cross-entropy, mean over tokens (audio:
    also over codebooks); vlm prefix positions excluded."""
    logits, aux = forward(params, cfg, batch["tokens"],
                          batch.get("prefix_embeds"), hints=hints)
    labels = batch["labels"]
    weights = None
    if cfg.modality == "vision_stub":
        # prefix positions contribute weight 0 (masked, never sliced)
        P = batch["prefix_embeds"].shape[1]
        B = labels.shape[0]
        labels = jnp.concatenate(
            [jnp.zeros((B, P), labels.dtype), labels], axis=1)
        weights = jnp.concatenate(
            [jnp.zeros((B, P), jnp.float32),
             jnp.ones((B, labels.shape[1] - P), jnp.float32)], axis=1)
    if cfg.modality == "audio_stub" and cfg.num_codebooks > 1:
        # logits (B, T, K, V); labels (B, K, T)
        labels = labels.transpose(0, 2, 1)
    return cross_entropy(logits, labels, weights) + cfg.router_aux_coef * aux


# ===========================================================================
# decode
# ===========================================================================
class DecodeState(NamedTuple):
    """Per-pattern-position stacked (num_groups leading axis) caches."""
    caches: Tuple[Any, ...]      # per pattern position: KVCache or recur dict
    position: jax.Array          # () int32 — next token's position


def init_decode_state(cfg: ArchConfig, batch: int, max_seq: int,
                      dtype_name: Optional[str] = None) -> DecodeState:
    dtype = _dt(dtype_name or cfg.param_dtype_serve)
    caches = []
    for spec in cfg.layer_pattern:
        if spec.kind == "rwkv" or spec.kind == "hymba":
            st = _init_recur_state(cfg, spec, batch)
            if spec.kind == "hymba":
                kv = jax.vmap(lambda _: attn_lib.init_kv_cache(
                    batch, max_seq, cfg.num_kv_heads, cfg.resolved_head_dim,
                    dtype, attn=spec.attn, window=spec.window))(
                    jnp.arange(cfg.num_groups))
                st = {"ssm": st["ssm"], "kv": kv}
            caches.append(st)
        else:
            kv = jax.vmap(lambda _: attn_lib.init_kv_cache(
                batch, max_seq, cfg.num_kv_heads, cfg.resolved_head_dim,
                dtype, attn=spec.attn, window=spec.window))(
                jnp.arange(cfg.num_groups))
            caches.append(kv)
    return DecodeState(tuple(caches), jnp.zeros((), jnp.int32))


def _decode_layer(cfg: ArchConfig, spec: LayerSpec, p: dict, x: jax.Array,
                  cache, pos: jax.Array, cdt, hints=None):
    if spec.kind == "rwkv":
        # shift buffers hold the previous token's *normed* layer inputs
        # (time-mix sees norm1(x_{t-1}), channel-mix sees norm2-input).
        h1 = norm_apply(x, p["norm1"], cfg.norm)
        y, wkv, _ = rwkv_lib.rwkv_time_mix(
            p["time_mix"], h1, cfg.rwkv_head_dim, cache["wkv"],
            cache["shift1"], decode=True)
        x = x + y.astype(x.dtype)
        h2 = norm_apply(x, p["norm2"], cfg.norm)
        y, _ = rwkv_lib.rwkv_channel_mix(
            p["time_mix"], h2, cache["shift2"], decode=True)
        x = x + y.astype(x.dtype)
        return x, {"wkv": wkv, "shift1": h1.astype(cache["shift1"].dtype),
                   "shift2": h2.astype(cache["shift2"].dtype)}

    h = norm_apply(x, p["norm1"], cfg.norm)
    kv_cache = cache["kv"] if spec.kind == "hymba" else cache
    q, k, v = attn_lib.project_qkv(
        p["attn"], h, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim,
        pos[None], cfg.rope_theta, cdt)
    a, kv_cache = attn_lib.decode_attention(
        q, k, v, kv_cache, attn=spec.attn, window=spec.window,
        softcap_val=cfg.attn_softcap, hints=hints)
    y = attn_lib.out_proj(p["attn"], a, cdt)

    if spec.kind == "hymba":
        xz = h.astype(cdt) @ p["ssm"]["w_in"].astype(cdt)
        s, hT = ssm_lib.ssm_step(p["ssm"], xz, cache["ssm"])
        s = s.astype(cdt) @ p["ssm"]["w_out"].astype(cdt)
        y = 0.5 * (y + s)
        new_cache = {"ssm": hT, "kv": kv_cache}
    else:
        new_cache = kv_cache
    x = x + y.astype(x.dtype)

    if spec.mlp != "none":
        h = norm_apply(x, p["norm2"], cfg.norm)
        if spec.mlp == "moe":
            y, _ = moe_lib.moe_ffn(p["moe"], h.astype(cdt), topk=cfg.moe_topk,
                                   act=cfg.act, gated=cfg.gated_mlp,
                                   real_experts=cfg.moe_experts)
        else:
            y = _mlp_apply(p["mlp"], h, cfg.act, cfg.gated_mlp, cdt)
        x = x + y.astype(x.dtype)
    return x, new_cache


def decode_step(params: PyTree, cfg: ArchConfig, state: DecodeState,
                tokens: jax.Array, hints=None) -> Tuple[jax.Array, DecodeState]:
    """One decode step.  tokens: (B, 1) int32 (or (B, K, 1) audio).
    Returns (logits (B, 1, V) or (B, 1, K, V), new state)."""
    cdt = _dt(cfg.compute_dtype)
    x = embed_tokens(params, cfg, tokens, cdt, hints)
    B = x.shape[0]
    pos = state.position
    pattern = cfg.layer_pattern

    # The group loop is UNROLLED (python loop, static indices) so cache
    # writes are .at[g].set(...) chains XLA can alias in place with donated
    # state; a lax.scan would force xs+ys double buffering of the caches
    # (measured 3x the KV cache footprint on musicgen decode_32k).
    caches = list(state.caches)
    for gi in range(cfg.num_groups):
        for p_idx, spec in enumerate(pattern):
            p_g = jax.tree.map(lambda x: x[gi], params["layers"][p_idx])
            c_g = jax.tree.map(lambda x: x[gi], caches[p_idx])
            x, nc = _decode_layer(cfg, spec, p_g, x, c_g, pos, cdt, hints)
            caches[p_idx] = jax.tree.map(
                lambda buf, new: buf.at[gi].set(new), caches[p_idx], nc)
    new_caches = tuple(caches)

    x = norm_apply(x, params["final_norm"], cfg.norm)
    if cfg.tie_embeddings:
        logits = x.astype(cdt) @ params["embed"].T.astype(cdt)
    else:
        logits = x.astype(cdt) @ params["lm_head"].astype(cdt)
    if cfg.logit_softcap > 0:
        logits = softcap(logits, cfg.logit_softcap)
    if cfg.modality == "audio_stub" and cfg.num_codebooks > 1:
        logits = logits.reshape(B, 1, cfg.num_codebooks, cfg.vocab_size)
    else:
        logits = logits[..., :cfg.vocab_size]    # drop vocab padding
    return logits.astype(jnp.float32), DecodeState(new_caches, pos + 1)
