"""Sharding hints: optional ``with_sharding_constraint`` anchors inside the
model so the SPMD partitioner never falls back to replicating attention.

Why: tensor-parallel attention wants the head axis sharded over "model", but
several assigned archs have head counts not divisible by 16 (qwen2-1.5b: 12,
gemma2: 8, hymba: 25, llama4/qwen2.5: 40).  Without anchors XLA replicates
the whole attention computation over the model axis (measured 21x FLOP
inflation on qwen2-1.5b).  With hints we pick, per tensor:

  1. head-sharded  (H % model == 0)      — classic Megatron attention;
  2. sequence-sharded (T % model == 0)   — context parallelism for the rest;
  3. replicated    (neither divides)     — tiny shapes only.

``hints=None`` (the default everywhere) is a no-op: CPU tests and the
single-device paths never touch jax.sharding.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Hints:
    dp: Tuple[str, ...] = ("data",)   # batch axes
    model: str = "model"
    model_size: int = 1

    def _ok(self, dim: int) -> bool:
        return self.model_size > 1 and dim % self.model_size == 0

    def qkv(self, x: jax.Array, h_axis: int, t_axis: int) -> jax.Array:
        """Constrain an activation with a head axis and a seq axis."""
        spec = [None] * x.ndim
        spec[0] = self.dp if self.dp else None
        if self._ok(x.shape[h_axis]):
            spec[h_axis] = self.model
        elif self._ok(x.shape[t_axis]):
            spec[t_axis] = self.model
        return jax.lax.with_sharding_constraint(x, P(*spec))

    def seq(self, x: jax.Array, t_axis: int) -> jax.Array:
        spec = [None] * x.ndim
        spec[0] = self.dp if self.dp else None
        if self._ok(x.shape[t_axis]):
            spec[t_axis] = self.model
        return jax.lax.with_sharding_constraint(x, P(*spec))

    def batch_only(self, x: jax.Array) -> jax.Array:
        spec = [None] * x.ndim
        spec[0] = self.dp if self.dp else None
        return jax.lax.with_sharding_constraint(x, P(*spec))

    def feature(self, x: jax.Array, f_axis: int) -> jax.Array:
        """Batch over dp, feature dim over model (if divisible)."""
        spec = [None] * x.ndim
        spec[0] = self.dp if self.dp else None
        if self._ok(x.shape[f_axis]):
            spec[f_axis] = self.model
        return jax.lax.with_sharding_constraint(x, P(*spec))


def apply_qkv(hints: Optional[Hints], x: jax.Array, h_axis: int,
              t_axis: int) -> jax.Array:
    return hints.qkv(x, h_axis, t_axis) if hints is not None else x


def apply_seq(hints: Optional[Hints], x: jax.Array, t_axis: int) -> jax.Array:
    return hints.seq(x, t_axis) if hints is not None else x


def apply_batch(hints: Optional[Hints], x: jax.Array) -> jax.Array:
    return hints.batch_only(x) if hints is not None else x


def apply_feature(hints: Optional[Hints], x: jax.Array,
                  f_axis: int) -> jax.Array:
    return hints.feature(x, f_axis) if hints is not None else x


# ---------------------------------------------------------------------------
# bf16 gradient-communication barrier
# ---------------------------------------------------------------------------
@jax.custom_vjp
def grad_bf16(x: jax.Array) -> jax.Array:
    """Identity whose COTANGENT is rounded to bfloat16.  Placed on a block
    output, the backward partial sums of the row-parallel matmuls (and the
    weight-grad reductions fed by them) are computed and ALL-REDUCED in
    bf16 instead of fp32 — halving the dominant backward collective bytes
    (§Perf hillclimb 1).  Standard practice (bf16 gradient all-reduce)."""
    return x


def _grad_bf16_fwd(x):
    return x, None


def _grad_bf16_bwd(_, g):
    import jax.numpy as jnp
    return (g.astype(jnp.bfloat16),)


grad_bf16.defvjp(_grad_bf16_fwd, _grad_bf16_bwd)


def apply_grad_bf16(hints: Optional[Hints], x: jax.Array) -> jax.Array:
    """Only active under sharded execution (hints present): single-device
    tests keep exact fp32 gradients."""
    return grad_bf16(x) if hints is not None else x
