"""Shared model building blocks: norms, RoPE, init, dtype handling.

Parameters are plain nested dicts of jnp arrays (pytree-native so the
federated masking in ``repro.core`` applies per-leaf with no adapter layer).
Every init function takes an explicit PRNG key and dtype.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "rmsnorm", "layernorm", "norm_apply", "init_norm",
    "rope_frequencies", "apply_rope", "softcap",
    "dense_init", "embed_init",
]


# ---- norms ---------------------------------------------------------------
def init_norm(d: int, kind: str, dtype) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def norm_apply(x: jax.Array, params: dict, kind: str) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    return layernorm(x, params["scale"], params["bias"])


# ---- rotary position embeddings -------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, D); positions: broadcastable to (..., T)."""
    freqs = rope_frequencies(x.shape[-1], theta)                    # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs       # (..., T, D/2)
    angles = angles[..., None, :]                                    # (..., T, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma2 logit soft-capping: cap * tanh(x / cap)."""
    if cap <= 0.0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---- init ------------------------------------------------------------------
def dense_init(key: jax.Array, shape: Tuple[int, ...], dtype,
               scale: float | None = None) -> jax.Array:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (std * jax.random.truncated_normal(
        key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int, dtype) -> jax.Array:
    """std = d^-0.5 keeps tied-head logits O(1) at init (gemma2 rescales
    the input side by sqrt(d) itself)."""
    return (d ** -0.5 * jax.random.truncated_normal(
        key, -2.0, 2.0, (vocab, d), jnp.float32)).astype(dtype)
