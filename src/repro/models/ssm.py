"""Selective SSM (Mamba-style) branch for the Hymba hybrid heads
[arXiv:2411.13676].

Recurrence (per channel c, state dim n):

    h_t = exp(dt_t * A_c) * h_{t-1} + dt_t * B_t[n] * x_t[c]
    y_t[c] = sum_n C_t[n] * h_t[c, n] + D_c * x_t[c]

with data-dependent B_t, C_t, dt_t (selective scan).  On TPU the linear
recurrence is evaluated with ``jax.lax.associative_scan`` inside time chunks
(a ``lax.scan`` over chunks bounds the transient (B, C, d, N) tensors),
which maps onto the VPU as a log-depth tree instead of a T-step serial loop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init

SSM_CHUNK = 512


def init_ssm_params(key, d_model: int, d_inner: int, state: int, dtype) -> dict:
    ks = jax.random.split(key, 6)
    dt_rank = max(8, d_inner // 16)
    # A initialised to -[1..N] per channel (S4D-real), stored as log(-A)
    a0 = jnp.tile(jnp.arange(1, state + 1, dtype=jnp.float32)[None],
                  (d_inner, 1))
    return {
        "w_in": dense_init(ks[0], (d_model, 2 * d_inner), dtype),   # x and gate
        "w_bcdt": dense_init(ks[1], (d_inner, 2 * state + dt_rank), dtype),
        "w_dt": dense_init(ks[2], (dt_rank, d_inner), dtype),
        "dt_bias": jnp.full((d_inner,), -2.0, dtype),   # softplus(-2) ~ 0.13
        "log_a": jnp.log(a0).astype(dtype),
        "d_skip": jnp.ones((d_inner,), dtype),
        "w_out": dense_init(ks[3], (d_inner, d_model), dtype),
    }


def _selective_terms(params, xz):
    """Shared by scan/step: returns (x, z, a (decay), bx (input), C)."""
    state = params["log_a"].shape[1]
    f32 = jnp.float32
    x, z = jnp.split(xz, 2, axis=-1)                    # (..., d_inner) each
    bcdt = x.astype(f32) @ params["w_bcdt"].astype(f32)
    Bm, Cm, dt_lr = (bcdt[..., :state], bcdt[..., state:2 * state],
                     bcdt[..., 2 * state:])
    dt = jax.nn.softplus(dt_lr @ params["w_dt"].astype(f32) +
                         params["dt_bias"].astype(f32))  # (..., d_inner)
    A = -jnp.exp(params["log_a"].astype(f32))           # (d_inner, N)
    a = jnp.exp(dt[..., None] * A[None])                # (..., d_inner, N)
    bx = (dt * x.astype(f32))[..., None] * Bm[..., None, :]  # (..., d, N)
    return x, z, a, bx, Cm


def ssm_forward(params: dict, xz: jax.Array, h0: jax.Array, hints=None):
    """xz: (B, T, 2*d_inner) pre-projected; h0: (B, d_inner, N).
    Returns (y (B, T, d_inner-projected to d via w_out outside), h_T)."""
    from repro.models.hints import apply_feature
    B, T, _ = xz.shape
    xz = apply_feature(hints, xz, 2)
    x, z, a, bx, Cm = _selective_terms(params, xz)      # a,bx: (B,T,d,N)
    a = apply_feature(hints, a, 2)
    bx = apply_feature(hints, bx, 2)

    chunk = min(SSM_CHUNK, T)
    while T % chunk:
        chunk //= 2
    n = T // chunk

    def body(h, blk):
        ab, bxb, cb = blk                               # (B, chunk, d, N) / C
        # prepend carry as a pseudo-step: h_t = a_t h_{t-1} + bx_t
        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br
        a_all, b_all = jax.lax.associative_scan(
            combine, (ab, bxb), axis=1)
        h_seq = a_all * h[:, None] + b_all              # (B, chunk, d, N)
        # contract with C HERE: stacking h_seq across chunks would
        # materialise a (B, T, d, N) = N x activation-sized tensor
        # (§Perf hillclimb 2: 6.7 GB/layer on hymba prefill_32k).
        y_blk = jnp.einsum("btdn,btn->btd", h_seq, cb)
        return h_seq[:, -1], y_blk

    a_c = a.reshape(B, n, chunk, *a.shape[2:]).transpose(1, 0, 2, 3, 4)
    bx_c = bx.reshape(B, n, chunk, *bx.shape[2:]).transpose(1, 0, 2, 3, 4)
    c_c = Cm.reshape(B, n, chunk, -1).transpose(1, 0, 2, 3)
    hT, y_blocks = jax.lax.scan(body, h0.astype(jnp.float32),
                                (a_c, bx_c, c_c))
    y = y_blocks.transpose(1, 0, 2, 3).reshape(B, T, -1)

    y = y + params["d_skip"].astype(jnp.float32) * x.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return y.astype(xz.dtype), hT


def ssm_step(params: dict, xz: jax.Array, h: jax.Array):
    """Decode: xz (B, 1, 2*d_inner), h (B, d_inner, N)."""
    x, z, a, bx, Cm = _selective_terms(params, xz[:, 0])
    h = a * h + bx                                      # (B, d, N)
    y = jnp.einsum("bdn,bn->bd", h, Cm)
    y = y + params["d_skip"].astype(jnp.float32) * x.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return y[:, None].astype(xz.dtype), h
