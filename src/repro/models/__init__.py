"""Model zoo: generic decoder (all assigned archs) + the paper's models."""

from repro.models.transformer import (
    init_params, forward, lm_loss, decode_step, init_decode_state,
    DecodeState, param_count,
)
from repro.models.paper_models import (
    init_lenet, lenet_forward, init_vgg, vgg_forward,
    init_gru_lm, gru_lm_forward, gru_lm_loss, perplexity,
    classifier_loss, classifier_accuracy,
)
