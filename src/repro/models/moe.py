"""Mixture-of-Experts FFN: top-k routing with grouped, capacity-bounded
dense dispatch (the Mesh-TF / MaxText formulation — fully static shapes, so
it jits, scans and shards; XLA SPMD inserts the all-to-all when experts are
sharded over the "model" axis).

Tokens are processed in groups of ``group_size``; each group dispatches to a
per-expert capacity of ``ceil(group_size * topk / E * capacity_factor)``.
Overflow tokens are dropped (their combine weight is zero) — the standard
trade for static shapes; the router aux loss keeps load balanced so drops
stay rare.

Shared experts (Qwen-MoE, Llama-4) run densely on every token and are fused
into a single wide FFN.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def init_moe_params(key, d_model: int, num_experts: int, d_ff: int,
                    shared_d_ff: int, gated: bool, dtype) -> dict:
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], (d_model, num_experts), jnp.float32),
        "wi": dense_init(ks[1], (num_experts, d_model, d_ff), dtype),
        "wo": dense_init(ks[2], (num_experts, d_ff, d_model), dtype),
    }
    if gated:
        p["wg"] = dense_init(ks[3], (num_experts, d_model, d_ff), dtype)
    if shared_d_ff:
        p["shared_wi"] = dense_init(ks[4], (d_model, shared_d_ff), dtype)
        p["shared_wo"] = dense_init(ks[5], (shared_d_ff, d_model), dtype)
        if gated:
            p["shared_wg"] = dense_init(ks[6], (d_model, shared_d_ff), dtype)
    return p


def _act(x, name):
    return jax.nn.silu(x) if name == "silu" else jax.nn.gelu(x)


def moe_ffn(params: dict, x: jax.Array, *, topk: int, act: str = "silu",
            gated: bool = True, capacity_factor: float = 1.25,
            group_size: int = 512, hints=None,
            real_experts: int = 0) -> Tuple[jax.Array, jax.Array]:
    """x: (B, T, d) -> (out (B, T, d), aux_loss ()).

    Routing in fp32; expert compute in x.dtype.  With ``hints`` the expert
    axis of the dispatched activations shards over "model" when the expert
    count divides it (expert parallelism; the dispatch einsum becomes the
    all-to-all), otherwise experts stay data-local and the per-expert ffn
    dim is the tensor-parallel axis (launch/shardings.py picks the matching
    weight layout).
    """
    from repro.models.hints import apply_batch, apply_feature
    B, T, d = x.shape
    E = params["router"].shape[1]
    n_tok = B * T
    xf = x.reshape(n_tok, d)

    g = min(group_size, n_tok)
    n_groups = -(-n_tok // g)
    pad = n_groups * g - n_tok
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    xg = apply_batch(hints, xf.reshape(n_groups, g, d))

    logits = (xg.astype(jnp.float32) @ params["router"])        # (G, g, E)
    if real_experts and real_experts < E:
        # padded experts (E rounded up for expert-parallel sharding) are
        # never routable
        logits = jnp.where(jnp.arange(E) < real_experts, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, topk)          # (G, g, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))                           # (E,)
    one_hot_top1 = jax.nn.one_hot(expert_ids[..., 0], E)
    ce = jnp.mean(one_hot_top1, axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # capacity from the REAL expert count: padding E for sharding must not
    # shrink per-expert buffers (test_moe_padding_preserves_output...)
    cap = max(1, int(g * topk / (real_experts or E) * capacity_factor))

    # position of each (token, slot) within its expert's capacity buffer
    onehot = jax.nn.one_hot(expert_ids, E, dtype=jnp.int32)     # (G, g, k, E)
    flat = onehot.reshape(n_groups, g * topk, E)
    pos_in_expert = jnp.cumsum(flat, axis=1) * flat - 1         # (G, g*k, E)
    pos = jnp.max(pos_in_expert, axis=-1).reshape(n_groups, g, topk)
    keep = (pos < cap) & (pos >= 0)
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # dispatch/combine tensors: (G, g, E, cap) one-hot over (expert, slot).
    # Built slot-by-slot (k is small) so the (G,g,k,E,cap) intermediate is
    # never materialised.
    combine = jnp.zeros((n_groups, g, E, cap), x.dtype)
    dispatch = jnp.zeros((n_groups, g, E, cap), x.dtype)
    for s in range(topk):
        e_oh = jax.nn.one_hot(expert_ids[..., s], E, dtype=x.dtype)
        c_oh = jax.nn.one_hot(jnp.where(keep[..., s], pos[..., s], cap),
                              cap + 1, dtype=x.dtype)[..., :-1]
        d_s = e_oh[..., :, None] * c_oh[..., None, :]            # (G,g,E,cap)
        dispatch = dispatch + d_s
        combine = combine + d_s * gate_vals[..., s, None, None].astype(x.dtype)

    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg.astype(x.dtype))
    xe = apply_feature(hints, xe, 1)            # expert-parallel if E divides
    h = jnp.einsum("gecd,edf->gecf", xe, params["wi"].astype(x.dtype))
    if gated:
        gate_h = jnp.einsum("gecd,edf->gecf", xe,
                            params["wg"].astype(x.dtype))
        h = _act(h, act) * gate_h
    else:
        h = _act(h, act)
    ye = jnp.einsum("gecf,efd->gecd", h, params["wo"].astype(x.dtype))
    ye = apply_feature(hints, ye, 1)
    y = apply_batch(hints, jnp.einsum("gtec,gecd->gtd", combine, ye))

    y = y.reshape(n_groups * g, d)[:n_tok]

    if "shared_wi" in params:
        hs = xf[:n_tok].astype(x.dtype) @ params["shared_wi"].astype(x.dtype)
        if gated:
            hs = _act(hs, act) * (xf[:n_tok].astype(x.dtype)
                                  @ params["shared_wg"].astype(x.dtype))
        else:
            hs = _act(hs, act)
        y = y + hs @ params["shared_wo"].astype(x.dtype)

    return y.reshape(B, T, d).astype(x.dtype), aux.astype(jnp.float32)
