"""Post-optimization HLO text analyzer for the roofline (DESIGN.md §Roofline).

Why not ``compiled.cost_analysis()``: XLA's cost analysis counts a while-loop
body ONCE, so a scanned 80-layer model under-reports FLOPs by ~80x (verified
empirically — see EXPERIMENTS.md §Dry-run).  This module parses the
post-SPMD-partitioning HLO text, propagates ``known_trip_count`` multipliers
through the call graph (while bodies x n, fusions/calls/conditionals x 1),
and accumulates:

* ``flops``            — 2 * |output| * contraction size for every dot
                         (+ convolutions), x multiplier.  Dots are >99% of
                         model FLOPs for every assigned arch.
* ``collective_bytes`` — per collective family, bytes moved per device:
                         all-gather: output bytes; reduce-scatter/all-to-all/
                         collective-permute: operand bytes; all-reduce:
                         2 x operand bytes (ring = RS + AG).
* ``hbm_bytes``        — HBM traffic model: every materialising top-level
                         instruction (fusion, dot, copy, ...) reads its
                         operands and writes its output once.

All quantities are PER DEVICE (the post-partitioning module is the
per-device program).
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "s2": 1, "u2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
_SKIP_BYTES = ("parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "call", "after-all",
               "partition-id", "replica-id", "iota")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s*->\s*(.+?)\s*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALL_ATTRS = (
    ("body", re.compile(r"body=%?([\w.\-]+)")),
    ("condition", re.compile(r"condition=%?([\w.\-]+)")),
    ("calls", re.compile(r"calls=%?([\w.\-]+)")),
    ("to_apply", re.compile(r"to_apply=%?([\w.\-]+)")),
    ("branches", re.compile(r"branch_computations=\{([^}]*)\}")),
)


def _parse_shapes(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dt, dims))
    return out


def _bytes_of(shapes: List[Tuple[str, List[int]]]) -> int:
    return sum(_DTYPE_BYTES[dt] * math.prod(dims) for dt, dims in shapes)


def _elems_of(shapes: List[Tuple[str, List[int]]]) -> int:
    return sum(math.prod(dims) for dt, dims in shapes)


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    line: str                                  # full attribute text
    out_shapes: List[Tuple[str, List[int]]]
    operand_names: List[str]
    called: List[Tuple[str, str]]
    trip_count: int = 1


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instruction]
    shapes: Dict[str, List[Tuple[str, List[int]]]]   # symbol table


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    # collective bytes assuming bf16 communication survives on TPU: the CPU
    # proxy physically upcasts bf16 dot operands to fp32 and "promotes"
    # bf16 all-reduces (to_apply=%add..._promoted), doubling every model-
    # path collective.  fp32 collectives in the model region count at half.
    collective_bytes_bf16comm: float = 0.0
    per_collective: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_count: Dict[str, int] = dataclasses.field(default_factory=dict)
    dot_flops_top: List[Tuple[float, str]] = dataclasses.field(
        default_factory=list)


def parse_computations(hlo: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        m = _COMP_RE.match(line)
        if m:
            cur = Computation(m.group(2), [], {})
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            # computation parameters: "name: f32[...]" pairs
            for pm in re.finditer(r"([\w.\-]+)\s*:\s*([^,)]+(?:\([^)]*\))?)",
                                  m.group(3)):
                cur.shapes[pm.group(1)] = _parse_shapes(pm.group(2))
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if not im or "=" not in line:
            continue
        name, rest = im.group(1), im.group(2)
        shapes_src = rest.split(", metadata=")[0]
        opm = re.search(r"\b([a-z][a-z0-9\-]*)\(", shapes_src)
        if not opm:
            continue
        opcode = opm.group(1)
        head = shapes_src[:opm.start()]
        args = shapes_src[opm.start() + len(opcode) + 1:]
        depth = 1
        end = 0
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_names = _OPERAND_RE.findall(args[:end])
        out_shapes = _parse_shapes(head)
        called = []
        for kind, rex in _CALL_ATTRS:
            cm = rex.search(rest)
            if cm:
                if kind == "branches":
                    for b in cm.group(1).split(","):
                        called.append(("branch", b.strip().lstrip("%")))
                else:
                    called.append((kind, cm.group(1)))
        trip = 1
        tm = _TRIP_RE.search(rest)
        if tm:
            trip = int(tm.group(1))
        cur.shapes[name] = out_shapes
        cur.instrs.append(Instruction(name, opcode, rest, out_shapes,
                                      operand_names, called, trip))
    return comps, entry


def _dot_flops(instr: Instruction, table: Dict) -> float:
    if not instr.out_shapes or not instr.operand_names:
        return 0.0
    out_elems = _elems_of(instr.out_shapes[:1])
    dm = re.search(r"lhs_contracting_dims=\{([^}]*)\}", instr.line)
    if not dm:
        return 0.0
    lhs_shapes = table.get(instr.operand_names[0], [])
    if not lhs_shapes:
        return 0.0
    lhs_dims = lhs_shapes[0][1]
    contraction = 1
    for di in dm.group(1).split(","):
        if di.strip():
            idx = int(di)
            if idx < len(lhs_dims):
                contraction *= lhs_dims[idx]
    return 2.0 * out_elems * contraction


def _conv_flops(instr: Instruction, table: Dict) -> float:
    if len(instr.operand_names) < 2 or not instr.out_shapes:
        return 0.0
    out_elems = _elems_of(instr.out_shapes[:1])
    rhs_shapes = table.get(instr.operand_names[1], [])
    if not rhs_shapes:
        return 0.0
    rhs = rhs_shapes[0][1]
    if not rhs:
        return 0.0
    kernel_elems = math.prod(rhs)
    cout = rhs[-1]
    return 2.0 * out_elems * kernel_elems / max(cout, 1)


def _multipliers(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    """Topological accumulation of call-count multipliers (HLO is a DAG)."""
    edges: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
    for cname, comp in comps.items():
        for instr in comp.instrs:
            for kind, callee in instr.called:
                if callee in comps:
                    factor = instr.trip_count if kind == "body" else 1
                    edges[cname].append((callee, factor))

    topo: List[str] = []
    state: Dict[str, int] = {}
    stack = [(entry, iter(edges.get(entry, ())))]
    state[entry] = 1
    while stack:
        node, it = stack[-1]
        advanced = False
        for callee, _ in it:
            if state.get(callee, 0) == 0:
                state[callee] = 1
                stack.append((callee, iter(edges.get(callee, ()))))
                advanced = True
                break
        if not advanced:
            topo.append(node)
            state[node] = 2
            stack.pop()

    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    for cname in reversed(topo):
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for callee, factor in edges.get(cname, ()):
            mult[callee] += m * factor
    return mult


def analyze(hlo: str, top_k_dots: int = 12) -> HloStats:
    comps, entry = parse_computations(hlo)
    if not entry:
        raise ValueError("no ENTRY computation found")
    mult = _multipliers(comps, entry)

    # computations whose instructions are NOT materialised individually
    # (fusion bodies, reduction lambdas): exclude from the HBM traffic model.
    # The *calling* fusion instruction in the parent already accounts its
    # operand/output bytes once.
    fused: set = set()
    for comp in comps.values():
        for instr in comp.instrs:
            for kind, callee in instr.called:
                if kind in ("calls", "to_apply"):
                    fused.add(callee)

    stats = HloStats()
    per_coll: Dict[str, float] = defaultdict(float)
    coll_count: Dict[str, int] = defaultdict(int)
    dots: List[Tuple[float, str]] = []

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        table = comp.shapes
        for instr in comp.instrs:
            op = instr.opcode
            if op == "dot":
                f = _dot_flops(instr, table) * m
                stats.flops += f
                dots.append((f, f"{cname}/{instr.name}"))
            elif op.startswith("convolution"):
                stats.flops += _conv_flops(instr, table) * m
            base = op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                operand_b = sum(_bytes_of(table.get(n, []))
                                for n in instr.operand_names)
                out_b = _bytes_of(instr.out_shapes)
                if base == "all-gather":
                    b = out_b
                elif base == "all-reduce":
                    b = 2 * operand_b
                else:
                    b = operand_b
                stats.collective_bytes += b * m
                # TPU-adjusted: halve f32 model-path collectives (the CPU
                # emitter upcast them from bf16); optimizer-state reductions
                # (norm/update op_names) stay full-width.
                f32_only = all(
                    sh and all(dt == "f32" for dt, _ in sh)
                    for sh in (table.get(n) for n in instr.operand_names)
                    if sh is not None) and bool(instr.operand_names)
                opt_path = any(t in instr.line for t in
                               ("clip_by_global_norm", "adafactor", "adam",
                                "_opt_update"))
                factor = 0.5 if (f32_only and not opt_path) else 1.0
                stats.collective_bytes_bf16comm += b * m * factor
                per_coll[base] += b * m
                coll_count[base] += int(m)
            if (cname not in fused and op
                    and not any(op.startswith(s) for s in _SKIP_BYTES)):
                rb = sum(_bytes_of(table.get(n, []))
                         for n in instr.operand_names)
                stats.hbm_bytes += (rb + _bytes_of(instr.out_shapes)) * m

    dots.sort(key=lambda t: -t[0])
    stats.dot_flops_top = dots[:top_k_dots]
    stats.per_collective = dict(per_coll)
    stats.collective_count = dict(coll_count)
    return stats
