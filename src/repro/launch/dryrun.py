"""Multi-pod dry-run: prove every (arch x input-shape x mesh) lowers and
compiles, and capture the roofline terms (DESIGN.md, EXPERIMENTS.md §Dry-run).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
      --shape train_4k [--multi-pod] [--fed] [--out results/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Writes one JSON per combo with memory_analysis, parsed HLO stats (flops /
hbm bytes / collective bytes per device), and the derived roofline terms.
"""

# The dry-run (and ONLY the dry-run) needs 512 placeholder devices — set
# BEFORE any other import; jax locks the device count on first init.
import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_arch, get_shape, supports_shape
from repro.launch import hlo as hlo_lib
from repro.launch import shardings as sh
from repro.launch import steps as steps_lib
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh, num_chips)

HBM_PER_CHIP = 16e9      # v5e


# ---------------------------------------------------------------------------
# analytic model FLOPs (roofline denominator: MODEL_FLOPS = 6*N*D train,
# 2*N*D inference; MoE uses N_active)
# ---------------------------------------------------------------------------
def param_counts(cfg) -> dict:
    specs = steps_lib.params_specs(cfg)
    total = 0
    routed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(specs)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        p = jax.tree_util.keystr(path)
        if "moe" in p and ("'wi'" in p or "'wg'" in p or "'wo'" in p):
            routed += n
    active = total - routed
    if cfg.moe_experts:
        active += routed * cfg.moe_topk / cfg.moe_experts
    return {"total": total, "routed": routed, "active": int(active)}


def model_flops(cfg, shape) -> float:
    pc = param_counts(cfg)
    n_active = pc["active"]
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n_active * tokens


# ---------------------------------------------------------------------------
# lower + compile one combo
# ---------------------------------------------------------------------------
def lower_combo(arch_id: str, shape_name: str, *, multi_pod: bool = False,
                fed: bool = False, fsdp: bool = True, remat: bool = True):
    """Returns (lowered, compiled, meta)."""
    cfg = get_arch(arch_id)
    shape = get_shape(shape_name)
    if not supports_shape(cfg, shape):
        raise ValueError(f"{arch_id} skips {shape_name} "
                         "(DESIGN.md §Shape-applicability)")
    mesh = make_production_mesh(multi_pod=multi_pod)

    if fed:
        from repro.launch import fedtrain
        return fedtrain.lower_fed_round(cfg, shape, mesh)

    hints = steps_lib.mesh_hints(mesh)
    if shape.mode == "train":
        pspecs = steps_lib.params_specs(cfg, cfg.param_dtype_train)
        psh = sh.params_shardings(pspecs, mesh, fsdp=fsdp)
        step = steps_lib.make_train_step(cfg, remat=remat, hints=hints,
                                         param_shardings=psh)
        opt_specs = jax.eval_shape(step.optimizer.init, pspecs)
        osh = sh.params_shardings_like(opt_specs, psh, mesh)
        batch = steps_lib.batch_specs(cfg, shape)
        bsh = sh.batch_shardings(batch, mesh)
        fn = jax.jit(step,
                     in_shardings=(psh, osh, bsh),
                     out_shardings=(psh, osh, sh.replicated({"loss": 0.0, "grad_norm": 0.0}, mesh)),
                     donate_argnums=(0, 1))
        with mesh:
            lowered = fn.lower(pspecs, opt_specs, batch)
    elif shape.mode == "prefill":
        pspecs = steps_lib.params_specs(cfg, cfg.param_dtype_serve)
        psh = sh.params_shardings(pspecs, mesh, fsdp=fsdp)
        step = steps_lib.make_prefill_step(cfg, hints=hints)
        batch = steps_lib.batch_specs(cfg, shape)
        bsh = sh.batch_shardings(batch, mesh)
        fn = jax.jit(step, in_shardings=(psh, bsh),
                     out_shardings=sh.batch_shardings(
                         jax.ShapeDtypeStruct(
                             (shape.global_batch, cfg.vocab_size), jnp.float32),
                         mesh))
        with mesh:
            lowered = fn.lower(pspecs, batch)
    else:  # decode
        pspecs = steps_lib.params_specs(cfg, cfg.param_dtype_serve)
        psh = sh.params_shardings(pspecs, mesh, fsdp=fsdp)
        step = steps_lib.make_serve_step(cfg, hints=hints)
        state = steps_lib.decode_state_specs(cfg, shape)
        ssh = sh.decode_state_shardings(state, mesh)
        batch = steps_lib.batch_specs(cfg, shape)
        bsh = sh.batch_shardings(batch, mesh)
        fn = jax.jit(step, in_shardings=(psh, ssh, bsh),
                     out_shardings=(sh.batch_shardings(
                         jax.eval_shape(lambda: jnp.zeros(
                             (shape.global_batch, 1, cfg.vocab_size),
                             jnp.float32)), mesh), ssh),
                     donate_argnums=(1,))
        with mesh:
            lowered = fn.lower(pspecs, state, batch)

    compiled = lowered.compile()
    return lowered, compiled, {"mesh": mesh}


# ---------------------------------------------------------------------------
# roofline record
# ---------------------------------------------------------------------------
def roofline_record(arch_id: str, shape_name: str, compiled, mesh,
                    *, fed: bool = False) -> dict:
    cfg = get_arch(arch_id)
    shape = get_shape(shape_name)
    chips = num_chips(mesh)

    mem = compiled.memory_analysis()
    stats = hlo_lib.analyze(compiled.as_text())
    ca = compiled.cost_analysis() or {}

    t_compute = stats.flops / PEAK_FLOPS_BF16
    t_memory = stats.hbm_bytes / HBM_BW
    t_collective = stats.collective_bytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    t_coll_adj = stats.collective_bytes_bf16comm / ICI_BW
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    pc = param_counts(cfg)
    per_dev_bytes = (mem.argument_size_in_bytes + mem.temp_size_in_bytes +
                     mem.output_size_in_bytes - mem.alias_size_in_bytes)
    rec = {
        "arch": arch_id, "shape": shape_name, "mode": shape.mode,
        "fed": fed, "chips": chips,
        "mesh": dict(zip(mesh.axis_names, [int(s) for s in mesh.devices.shape])),
        "params_total": pc["total"], "params_active": pc["active"],
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_bytes": per_dev_bytes,
            "fits_hbm": bool(per_dev_bytes <= HBM_PER_CHIP),
        },
        "per_device": {
            "hlo_flops": stats.flops,
            "hbm_bytes": stats.hbm_bytes,
            "collective_bytes": stats.collective_bytes,
            "per_collective": stats.per_collective,
            "collective_count": stats.collective_count,
        },
        "xla_cost_analysis": {k: float(v) for k, v in ca.items()
                              if isinstance(v, (int, float))
                              and k in ("flops", "bytes accessed")},
        "roofline": {
            **terms,
            "collective_s_bf16comm": t_coll_adj,
            "dominant": dominant,
            "model_flops_global": mf,
            "model_flops_per_device": mf / chips,
            "useful_flop_fraction": (mf / chips) / max(stats.flops, 1.0),
        },
        "top_dots": [[f, n] for f, n in stats.dot_flops_top[:8]],
    }
    return rec


def run_combo(arch_id: str, shape_name: str, *, multi_pod: bool,
              fed: bool = False, out_dir: str = "results/dryrun",
              fsdp: bool = True, remat: bool = True,
              save_hlo: bool = False) -> dict:
    t0 = time.time()
    lowered, compiled, meta = lower_combo(
        arch_id, shape_name, multi_pod=multi_pod, fed=fed, fsdp=fsdp,
        remat=remat)
    rec = roofline_record(arch_id, shape_name, compiled, meta["mesh"],
                          fed=fed)
    rec["compile_s"] = time.time() - t0
    rec["multi_pod"] = multi_pod
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch_id}__{shape_name}__{'mp' if multi_pod else 'sp'}" + \
        ("__fed" if fed else "")
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    if save_hlo:
        with open(os.path.join(out_dir, tag + ".hlo.txt"), "w") as f:
            f.write(compiled.as_text())
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fed", action="store_true",
                    help="lower the federated round (paper technique) "
                         "instead of the standard train step")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                if supports_shape(get_arch(a), get_shape(s)):
                    combos.append((a, s))
    else:
        combos = [(args.arch, args.shape)]

    failures = []
    for a, s in combos:
        try:
            rec = run_combo(a, s, multi_pod=args.multi_pod, fed=args.fed,
                            out_dir=args.out, fsdp=not args.no_fsdp,
                            remat=not args.no_remat, save_hlo=args.save_hlo)
            r = rec["roofline"]
            print(f"OK  {a:28s} {s:12s} chips={rec['chips']} "
                  f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
                  f"coll={r['collective_s']:.4f}s dom={r['dominant']} "
                  f"fits={rec['memory']['fits_hbm']} "
                  f"compile={rec['compile_s']:.0f}s", flush=True)
        except Exception as e:  # noqa: BLE001 - report and continue
            failures.append((a, s, repr(e)))
            print(f"FAIL {a} {s}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")


if __name__ == "__main__":
    main()
