"""Sharding rules: map every param / batch / cache leaf to a PartitionSpec.

Strategy (DESIGN.md §6):

* **Params (standard training)** — 2D "FSDP x TP": the contraction-side
  dimension shards over the data axes (ZeRO-style), the output-side feature
  dimension over "model" (tensor parallel: heads / ff / experts / vocab).
  Divisibility is checked per leaf; non-divisible dims fall back to
  replicated (e.g. hymba's 25-head q projection keeps d=1600 on model via
  the 1600/16=100 column split instead).
* **Params (federated round)** — model-axis sharding ONLY: each client (a
  data-axis slice) holds the full model (paper semantics); see
  launch/fedtrain.py.
* **Batch** — leading dim over all data axes (("pod","data") multi-pod).
* **Decode caches** — batch over data axes when divisible, cache sequence
  dim over "model" (KV head counts are not generally divisible by 16;
  sequence always is).
"""

from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes

PyTree = Any


def _div(n: int, mesh, axes) -> bool:
    if axes is None:
        return True
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return n % size == 0


def _maybe(mesh, shape, *spec):
    """PartitionSpec with per-dim divisibility fallback to None."""
    out = []
    for dim, axes in zip(shape, spec):
        out.append(axes if _div(dim, mesh, axes) else None)
    return P(*out)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
_COL = ("wq", "wk", "wv", "wi", "wg", "w_in", "ww1", "lm_head", "ck",
        "shared_wi", "shared_wg")          # (d_in, features): TP on features
_ROW = ("wo", "w_out", "ww2", "cv", "proj", "shared_wo", "w_dt")
                                           # (features, d_out): TP on features
_SQUARE = ("wr",)                          # rwkv d->d


def param_spec(path: str, shape, mesh, *, fsdp: bool = True,
               fsdp_axes=None) -> P:
    """Sharding spec for one param leaf, keyed on its pytree path."""
    dp = fsdp_axes if fsdp_axes is not None else data_axes(mesh)
    fs = dp if fsdp else None              # FSDP axes (or replicate)
    leaf = re.split(r"[./\[\]']+", path.strip("."))
    leaf = [s for s in leaf if s][-1]

    if len(shape) == 0 or max(shape) < 1024 and len(shape) == 1:
        return P()
    if leaf == "embed":
        return _maybe(mesh, shape, "model", fs)
    if leaf == "router":
        return _maybe(mesh, shape, fs, None)
    if leaf in ("wi", "wg", "wo") and len(shape) == 3:          # MoE (E,a,b)
        if _div(shape[0], mesh, "model"):
            return _maybe(mesh, shape, "model", fs, None)       # expert-parallel
        return (_maybe(mesh, shape, None, fs, "model") if leaf != "wo"
                else _maybe(mesh, shape, None, "model", fs))    # ff TP
    if leaf in _COL or leaf in _SQUARE:
        return _maybe(mesh, shape, fs, "model")
    if leaf in _ROW:
        return _maybe(mesh, shape, "model", fs)
    if leaf in ("bq", "bk", "bv") and len(shape) == 1:
        return _maybe(mesh, shape, "model")
    if leaf in ("w_bcdt",):
        return _maybe(mesh, shape, "model", None)
    if leaf in ("log_a", "d_skip", "dt_bias") and shape[0] >= 1024:
        return _maybe(mesh, shape, "model", *([None] * (len(shape) - 1)))
    return P()  # norms, mu_*, u, small leaves: replicated


def _with_group_axis(spec: P, leaf_ndim: int, stacked_ndim: int) -> P:
    """Prepend Nones for the leading (num_groups,) stack axes."""
    pad = stacked_ndim - leaf_ndim
    return P(*([None] * pad + list(spec) + [None] * (leaf_ndim - len(spec))))


def params_shardings(params: PyTree, mesh, *, fsdp: bool = True,
                     fsdp_axes=None) -> PyTree:
    """NamedSharding tree matching ``params``.  Layer stacks (leading
    num_groups axis) get the per-layer rule shifted right by one."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        pstr = jax.tree_util.keystr(path)
        shape = leaf.shape
        in_stack = "layers" in pstr
        base_shape = shape[1:] if in_stack and len(shape) >= 1 else shape
        spec = param_spec(pstr, base_shape, mesh, fsdp=fsdp,
                          fsdp_axes=fsdp_axes)
        if in_stack:
            spec = _with_group_axis(spec, len(base_shape), len(shape))
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# batch / cache
# ---------------------------------------------------------------------------
def batch_shardings(batch: PyTree, mesh) -> PyTree:
    dp = data_axes(mesh)

    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if _div(leaf.shape[0], mesh, dp):
            return NamedSharding(mesh, P(dp))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map(one, batch)


def decode_state_shardings(state: PyTree, mesh) -> PyTree:
    """KV caches (G, B, S, KV, D): B over data axes if divisible, S over
    'model'.  Recurrent states (G, B, ...): B over data, feature over model
    when divisible."""
    dp = data_axes(mesh)

    def one(leaf):
        shape = leaf.shape
        if leaf.ndim <= 1:
            return NamedSharding(mesh, P())
        spec = [None] * leaf.ndim
        # leading axis is the group stack; batch sits at axis 1
        if _div(shape[1], mesh, dp):
            spec[1] = dp
        if leaf.ndim >= 4:
            # KVCache (G,B,S,KV,D) or wkv state (G,B,H,D,D) / ssm (G,B,d,N)
            if _div(shape[2], mesh, "model") and shape[2] >= 64:
                spec[2] = "model"
        elif leaf.ndim == 3 and _div(shape[2], mesh, "model") and shape[2] >= 1024:
            spec[2] = "model"
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map(one, state)


def replicated(tree: PyTree, mesh) -> PyTree:
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree)


def params_shardings_like(opt_state: PyTree, param_shardings: PyTree,
                          mesh) -> PyTree:
    """Optimizer-state shardings: moment trees (mu/nu/velocity) mirror the
    param shardings; Adafactor's factored moments ("v": {vr, vc}) inherit
    the matching dims of the param spec; scalars (count) replicate."""
    def _fac(sh, leaf):
        if not isinstance(leaf, dict):
            return sh
        if "v" in leaf:
            return {"v": sh}
        nd = leaf["vr"].ndim + 1
        spec = tuple(sh.spec) + (None,) * (nd - len(sh.spec))
        vr = P(*spec[:-1])
        vc = P(*(spec[:-2] + spec[-1:])) if nd >= 2 else P()
        return {"vr": NamedSharding(mesh, vr),
                "vc": NamedSharding(mesh, vc)}

    out = {}
    for k, v in opt_state.items():
        if k in ("mu", "nu", "velocity") and v is not None:
            out[k] = param_shardings
        elif k == "v" and v is not None:
            out[k] = jax.tree_util.tree_map(
                _fac, param_shardings, v,
                is_leaf=lambda x: isinstance(x, dict) and
                ("vr" in x or "v" in x))
        elif v is None:
            out[k] = None
        else:
            out[k] = jax.tree_util.tree_map(
                lambda _: NamedSharding(mesh, P()), v)
    return out
