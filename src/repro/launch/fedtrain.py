"""The paper's federated round at pod scale (DESIGN.md §3.3/§6).

Mapping (cross-silo FL on a TPU pod):

* **Clients = data-axis slices.**  Single pod: the 16-wide "data" axis is the
  client axis (16 clients, each model-sharded 16-way over "model").
  Multi-pod: the "pod" axis is the client axis (2 silos), and each client's
  model shards over ("data","model") = 256 chips — this is how a 72B/400B
  client fits (a 400B client cannot live on 16 chips; a silo of 256 can).
* **Upload = masked weighted reduction.**  The paper's TCP upload becomes the
  cross-client weighted sum; selective masking runs on every client's delta
  *before* the reduction.  Everything is expressed as jnp over a
  client-leading axis under ``jax.jit`` — the SPMD partitioner emits the
  all-reduces over "model" (distributed threshold counts) and the
  reduce/all-gather over the client axis (the aggregation) that a hand-rolled
  shard_map would contain.
* **Distributed threshold top-k.**  The bisection counts are sum-reductions,
  so they work transparently on model-sharded leaves — each client finds the
  *global* per-layer threshold of its delta without gathering it.

Participation (dynamic sampling, Alg. 3) enters as a 0/1 weight vector
computed on the host from the schedule — shapes stay static.  Non-uniform
client samplers (DESIGN.md §5) reuse the same plumbing: run
``ClientSampler.select`` eagerly on the host (it is plain (M,)-shaped jnp)
and pass the returned *weights* as the participation vector with
``FedPodConfig.normalize=False`` — the round then uses them as the final
Horvitz-Thompson aggregation coefficients instead of re-normalizing.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.core.codecs import roundtrip_stacked, with_axis0_slices
from repro.launch import shardings as sh
from repro.launch import steps as steps_lib
from repro.models import transformer as tr

PyTree = Any


@dataclasses.dataclass(frozen=True)
class FedPodConfig:
    """Pod-round configuration.  Prefer :meth:`from_strategy` — one
    ``repro.core.strategy.FedStrategy`` supplies masking, codec, and client
    hyperparameters; the loose kwargs remain for scripts that predate the
    strategy API."""

    num_clients: int
    local_steps: int = 2          # local SGD steps per round (E epochs)
    learning_rate: float = 0.01
    gamma: float = 0.1            # fraction of params kept (paper default)
    masking: str = "selective"    # selective | random | none
    bisect_iters: int = 16
    min_leaf_size: int = 256
    # Route selective masking through the segmented Pallas subsystem
    # (ops.topk_mask_pytree): ~4 HBM sweeps per client for the WHOLE model
    # instead of O(L * iters) — off by default because the pure-jnp bisection
    # below is what the SPMD partitioner auto-shards over "model".
    use_kernel: bool = False
    # Wire codec (repro.core.codecs.UploadCodec): every client's masked
    # delta is round-tripped through its encode -> wire pytree -> decode
    # INSIDE the shard, so what enters the cross-client psum is exactly
    # what survived the wire.  None = dense (identity) upload.
    codec: Any = None
    # True (default): the participation vector is a 0/1 mask, weighted by
    # n_samples and re-normalized to sum 1 (self-normalized FedAvg).
    # False: the participation vector already IS the final aggregation
    # weights (a non-uniform ClientSampler's Horvitz-Thompson coefficients,
    # computed host-side) — used as given, n_samples ignored.
    normalize: bool = True

    @classmethod
    def from_strategy(cls, strategy, num_clients: int,
                      local_steps: int = 2) -> "FedPodConfig":
        """Collapse a FedStrategy onto the pod round: mask policy, codec,
        learning rate and the sampler's weight semantics come from the
        strategy record.  Sparse codec stages are re-budgeted to the pod
        masks' per-first-axis-slice top-k granularity
        (``with_axis0_slices``) so the wire never truncates a
        within-budget upload."""
        mp = strategy.masking
        return cls(num_clients=num_clients, local_steps=local_steps,
                   learning_rate=strategy.learning_rate, gamma=mp.gamma,
                   masking=mp.mode, bisect_iters=mp.bisect_iters,
                   min_leaf_size=mp.min_leaf_size,
                   use_kernel=mp.backend == "kernel",
                   codec=with_axis0_slices(strategy.codec),
                   normalize=strategy.sampler.normalize)


def _threshold_mask(delta: jax.Array, gamma: float, iters: int) -> jax.Array:
    """Vectorised threshold-bisection top-|delta| mask over the LAST
    ndim-leading dims; works on (C, G, ...) stacks (per client, per layer —
    Alg. 4 line 9's per-layer loop).  Pure sums/compares: auto-shardable."""
    lead = delta.shape[:2] if delta.ndim > 2 else delta.shape[:1]
    flat = delta.reshape(lead + (-1,)).astype(jnp.float32)
    n = flat.shape[-1]
    k = jnp.asarray(max(1, int(round(gamma * n))), jnp.int32)
    mag = jnp.abs(flat)
    hi = jnp.max(mag, axis=-1, keepdims=True) + 1e-12
    lo = jnp.zeros_like(hi)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        count = jnp.sum(mag >= mid, axis=-1, keepdims=True)
        lo = jnp.where(count > k, mid, lo)
        hi = jnp.where(count > k, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    keep = (mag >= hi).astype(delta.dtype)
    return (flat.astype(delta.dtype) * keep).reshape(delta.shape)


def _random_mask(key: jax.Array, delta: jax.Array, gamma: float) -> jax.Array:
    """Exact-count random mask per last-dims block, matching
    ``_threshold_mask``'s granularity (per first-axis slice for stacked
    leaves).  Exact counts — not Bernoulli — so every upload fits the
    sparse wire's ``max(1, round(gamma * slice))`` slot budget instead of
    overflowing it on roughly half the draws."""
    lead = delta.shape[:2] if delta.ndim > 2 else delta.shape[:1]
    flat = delta.reshape(lead + (-1,))
    n = flat.shape[-1]
    k = max(1, int(round(gamma * n)))
    scores = jax.random.uniform(key, flat.shape).reshape(-1, n)
    # Single top_k pass per slice (as in core.masking.random_mask), not a
    # double-argsort ranking.
    _, idx = jax.lax.top_k(-scores, k)
    rows = jnp.arange(scores.shape[0])[:, None]
    keep = jnp.zeros(scores.shape, delta.dtype).at[rows, idx].set(1)
    return (flat * keep.reshape(flat.shape)).reshape(delta.shape)


def mask_deltas(key: jax.Array, deltas: PyTree, cfg: FedPodConfig) -> PyTree:
    """deltas: client-stacked pytree (leading C axis per leaf)."""
    if cfg.masking == "none" or cfg.gamma >= 1.0:
        return deltas
    if cfg.masking == "selective" and cfg.use_kernel:
        from repro.kernels import ops as kops

        def one_client(tree):
            # Match _threshold_mask's granularity exactly: leaves big enough
            # to mask (same per-client min_leaf_size gate) select top-k per
            # FIRST-axis slice for ndim >= 2 leaves (Alg. 4's per-layer loop
            # on stacked/layered arrays), per whole leaf for vectors.  Each
            # slice becomes its own segment of ONE packed sweep set.
            leaves, treedef = jax.tree_util.tree_flatten(tree)
            out = list(leaves)
            segments, layout = [], []
            for i, leaf in enumerate(leaves):
                if leaf.size < cfg.min_leaf_size:
                    continue
                if leaf.ndim >= 2:
                    layout.append((i, leaf.shape[0]))
                    segments.extend(list(leaf))
                else:
                    layout.append((i, 0))
                    segments.append(leaf)
            if segments:
                from repro.core.masking import _refine_sweeps_for
                masked = kops.topk_mask_pytree(
                    tuple(segments), cfg.gamma, min_leaf_size=0,
                    refine_sweeps=_refine_sweeps_for(cfg.bisect_iters))
                pos = 0
                for i, g in layout:
                    if g:
                        out[i] = jnp.stack(masked[pos:pos + g])
                        pos += g
                    else:
                        out[i] = masked[pos]
                        pos += 1
            return jax.tree_util.tree_unflatten(treedef, out)

        # One segmented whole-model sweep set per client (leaf-count
        # independent); lax.map keeps a single kernel trace for all clients.
        return jax.lax.map(one_client, deltas)
    leaves, treedef = jax.tree_util.tree_flatten(deltas)
    keys = jax.random.split(key, len(leaves))
    out = []
    for leaf, lk in zip(leaves, keys):
        per_client = leaf.size // leaf.shape[0]
        if per_client < cfg.min_leaf_size:
            out.append(leaf)
        elif cfg.masking == "random":
            out.append(_random_mask(lk, leaf, cfg.gamma))
        else:
            out.append(_threshold_mask(leaf, cfg.gamma, cfg.bisect_iters))
    return jax.tree_util.tree_unflatten(treedef, out)


def _make_local_update(arch: ArchConfig, cfg: FedPodConfig,
                       hints=None) -> Callable:
    """E local SGD steps on one client's batch: ``(delta, mean_loss)``.
    Shared by the full-population and cohort pod rounds — the cohort engine
    must be a pure execution optimization, so there is exactly one
    definition of the client-side math."""
    def loss_fn(params, batch):
        return tr.lm_loss(params, arch, batch, hints=hints)

    def local_update(params, client_batch):
        def sgd_step(p, b):
            loss, grads = jax.value_and_grad(loss_fn)(p, b)
            p = jax.tree.map(
                lambda x, g: (x - cfg.learning_rate * g).astype(x.dtype),
                p, grads)
            return p, loss
        local, losses = jax.lax.scan(sgd_step, params, client_batch)
        delta = jax.tree.map(lambda a, b: a - b, local, params)
        return delta, jnp.mean(losses)

    return local_update


def _weighted_upload(w: jax.Array, masked: PyTree) -> PyTree:
    """Client-axis weighted reduction of masked deltas.

    §Perf hillclimb 3: ship the masked deltas in bf16 — the upload
    (cross-client reduction) halves; the paper already quantises uploads
    ("compressed when uploaded", §3.2.1), bf16 is milder than its
    1-bit/ternary citations.  Accumulate in f32."""
    return jax.tree.map(
        lambda d: jnp.tensordot(w.astype(jnp.bfloat16),
                                d.astype(jnp.bfloat16), axes=(0, 0),
                                preferred_element_type=jnp.float32),
        masked)


def make_fed_round(arch: ArchConfig, cfg: FedPodConfig, hints=None) -> Callable:
    """Returns ``round(params, batches, n_samples, participation, key)``.

    batches: pytree with leading (C, local_steps, ...) axes.
    """
    local_update = _make_local_update(arch, cfg, hints=hints)

    def fed_round(params, batches, n_samples, participation, key):
        deltas, losses = jax.vmap(
            lambda b: local_update(params, b))(batches)
        masked = mask_deltas(key, deltas, cfg)
        # Each client's upload crosses the wire: encode -> wire pytree ->
        # decode through the strategy codec before the weighted reduction.
        masked = roundtrip_stacked(cfg.codec, masked)
        if cfg.normalize:
            w = participation * n_samples
            w = w / jnp.maximum(jnp.sum(w), 1e-12)
        else:
            w = participation          # pre-weighted (sampler coefficients)
        agg = _weighted_upload(w, masked)
        new_params = jax.tree.map(
            lambda p, a: (p + a.astype(p.dtype)), params, agg)
        active = (participation > 0).astype(jnp.float32)
        metrics = {
            "mean_loss": jnp.sum(losses * active)
            / jnp.maximum(jnp.sum(active), 1.0),
            "num_sampled": jnp.sum(active),
        }
        return new_params, metrics

    return fed_round


# ---------------------------------------------------------------------------
# cohort execution engine, pod form (DESIGN.md §3.5)
# ---------------------------------------------------------------------------
def make_cohort_fed_round(arch: ArchConfig, cfg: FedPodConfig,
                          cohort_size: int, mesh, client_axis: str = None,
                          hints=None) -> Callable:
    """Cohort-engine form of ``make_fed_round``: instead of running all
    ``cfg.num_clients`` registered clients and zero-weighting
    non-participants, gather only the sampled cohort (host-chosen ids,
    padded to the static ``cohort_size`` bucket) and ``shard_map`` the
    cohort axis over ``client_axis`` of ``mesh`` — each device runs
    ``cohort_size // mesh.shape[client_axis]`` clients and the upload is a
    per-device weighted partial sum followed by one psum.

    Returns ``round(params, batches, n_samples, cohort_ids, valid, key)``
    where ``batches`` has the full (C, local_steps, ...) registered-client
    leading axes, ``cohort_ids`` is int32 (cohort_size,) and ``valid`` is
    the 0/1 participation mask over the cohort (padding slots are 0) — or,
    with ``cfg.normalize=False``, the sampler's precomputed aggregation
    weights (nonzero = participant).

    Masking caveat: ``masking="random"`` draws its keep-masks per shard
    (``fold_in(key, axis_index)`` over shard-local rows), so its random
    draws differ from ``make_fed_round``'s full-leaf draws and vary with
    device count — inherent to drawing inside shard_map.  "selective" is
    deterministic in the deltas and matches the full round exactly.
    """
    from functools import partial

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    client_axis = client_axis or fed_layout(mesh)[0]
    n_dev = mesh.shape[client_axis]
    if cohort_size % n_dev != 0:
        raise ValueError(
            f"cohort_size {cohort_size} not divisible by mesh axis "
            f"{client_axis!r} ({n_dev})")

    local_update = _make_local_update(arch, cfg, hints=hints)

    @partial(shard_map, mesh=mesh,
             in_specs=(P(), P(client_axis), P(client_axis), P(client_axis),
                       P()),
             out_specs=(P(), P(), P()),
             check_rep=False)
    def cohort_shard(params, cohort_batches, w_shard, valid_shard, key):
        # Each shard: its slice of the cohort end-to-end — local SGD, mask,
        # codec wire round-trip, weighted partial aggregation — then ONE
        # f32 psum of model size.  The codec runs per client INSIDE the
        # shard_map body, so the bytes each client ships are exactly the
        # wire pytree the strategy meters.
        deltas, losses = jax.vmap(
            lambda b: local_update(params, b))(cohort_batches)
        shard_key = jax.random.fold_in(key, jax.lax.axis_index(client_axis))
        masked = mask_deltas(shard_key, deltas, cfg)
        masked = roundtrip_stacked(cfg.codec, masked)
        agg = jax.lax.psum(_weighted_upload(w_shard, masked), client_axis)
        loss_sum = jax.lax.psum(jnp.sum(losses * valid_shard), client_axis)
        valid_sum = jax.lax.psum(jnp.sum(valid_shard), client_axis)
        return agg, loss_sum, valid_sum

    def fed_round(params, batches, n_samples, cohort_ids, valid, key):
        cohort_batches = jax.tree.map(
            lambda x: jnp.take(x, cohort_ids, axis=0), batches)
        if cfg.normalize:
            w = valid * jnp.take(n_samples, cohort_ids)
            w = w / jnp.maximum(jnp.sum(w), 1e-12)
        else:
            w = valid                 # pre-weighted (sampler coefficients)
        valid01 = (valid > 0).astype(jnp.float32)
        agg, loss_sum, valid_sum = cohort_shard(
            params, cohort_batches, w, valid01, key)
        new_params = jax.tree.map(
            lambda p, a: (p + a.astype(p.dtype)), params, agg)
        metrics = {
            "mean_loss": loss_sum / jnp.maximum(valid_sum, 1.0),
            "num_sampled": valid_sum,
        }
        return new_params, metrics

    return fed_round


# ---------------------------------------------------------------------------
# dry-run entry (called by launch/dryrun.py with --fed)
# ---------------------------------------------------------------------------
def fed_layout(mesh) -> Tuple[str, tuple]:
    """(client_axis, model_fsdp_axes): single-pod -> clients on 'data';
    multi-pod -> clients on 'pod', model over ('data','model')."""
    if "pod" in mesh.axis_names:
        return "pod", ("data",)
    return "data", ()


def lower_fed_round(arch: ArchConfig, shape: InputShape, mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P

    client_axis, fsdp_axes = fed_layout(mesh)
    C = mesh.shape[client_axis]
    fed_cfg = FedPodConfig(num_clients=C)

    # param dtype: fp32 when a client replica fits its silo, else bf16
    # (noted in EXPERIMENTS.md §Dry-run for the affected archs).
    silo_chips = mesh.devices.size // C
    pc = steps_lib.params_specs(arch, "float32")
    import numpy as np
    n_params = sum(int(np.prod(leaf.shape))
                   for leaf in jax.tree_util.tree_leaves(pc))
    dtype = "float32" if 4 * n_params / silo_chips < 6e9 else "bfloat16"

    pspecs = steps_lib.params_specs(arch, dtype)
    psh = sh.params_shardings(pspecs, mesh, fsdp=bool(fsdp_axes),
                              fsdp_axes=fsdp_axes or None)

    B, T = shape.global_batch, shape.seq_len
    b_local = max(B // C, 1)
    if arch.modality == "audio_stub" and arch.num_codebooks > 1:
        tok = jax.ShapeDtypeStruct(
            (C, fed_cfg.local_steps, b_local, arch.num_codebooks, T), jnp.int32)
    else:
        tok = jax.ShapeDtypeStruct(
            (C, fed_cfg.local_steps, b_local, T), jnp.int32)
    batches = {"tokens": tok, "labels": tok}
    if arch.modality == "vision_stub":
        batches["prefix_embeds"] = jax.ShapeDtypeStruct(
            (C, fed_cfg.local_steps, b_local, arch.num_prefix_embeddings,
             arch.d_model), jnp.bfloat16)

    bsh = jax.tree.map(
        lambda leaf: NamedSharding(mesh, P(client_axis)), batches)
    vec_sh = NamedSharding(mesh, P())
    n_samples = jax.ShapeDtypeStruct((C,), jnp.float32)
    participation = jax.ShapeDtypeStruct((C,), jnp.float32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)

    from repro.models.hints import Hints
    hints = Hints(dp=(), model="model", model_size=int(mesh.shape["model"]))
    fed_round = make_fed_round(arch, fed_cfg, hints=hints)
    fn = jax.jit(
        fed_round,
        in_shardings=(psh, bsh, vec_sh, vec_sh, vec_sh),
        out_shardings=(psh, sh.replicated(
            {"mean_loss": 0.0, "num_sampled": 0.0}, mesh)),
        donate_argnums=(0,))
    with mesh:
        lowered = fn.lower(pspecs, batches, n_samples, participation, key)
    compiled = lowered.compile()
    return lowered, compiled, {"mesh": mesh, "fed_cfg": fed_cfg,
                               "param_dtype": dtype}
