"""Batched serving driver: prefill a batch of prompts, then decode tokens.

CPU demo (reduced arch):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16
On a pod the same script runs with --mesh 16x16 and a full arch.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import transformer as tr


def sample_tokens(logits: jax.Array, key, temperature: float = 0.0):
    """logits (B, 1, V) (or (B,1,K,V) audio) -> next tokens."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1).astype(jnp.int32)


def generate(cfg, params, prompts: jax.Array, gen_len: int,
             max_seq: int, temperature: float = 0.0, seed: int = 0,
             prefix_embeds=None):
    """prompts: (B, P) int32 (or (B, K, P) audio).  Greedy/temperature
    decode.  Prefill is decode-steps over the prompt (simple and exact);
    a blocked prefill is the obvious production extension."""
    audio = cfg.modality == "audio_stub" and cfg.num_codebooks > 1
    B = prompts.shape[0]
    P = prompts.shape[-1]
    state = tr.init_decode_state(cfg, B, max_seq)
    step = jax.jit(lambda p, s, t: tr.decode_step(p, cfg, s, t))
    key = jax.random.PRNGKey(seed)

    logits = None
    for i in range(P):
        tok = prompts[..., i:i + 1]
        logits, state = step(params, state, tok)

    out = []
    tok = sample_tokens(logits, key, temperature)
    if audio:
        tok = tok.transpose(0, 2, 1)        # (B,1,K) -> (B,K,1)
    out.append(tok)
    for i in range(gen_len - 1):
        key, sub = jax.random.split(key)
        logits, state = step(params, state, tok)
        tok = sample_tokens(logits, sub, temperature)
        if audio:
            tok = tok.transpose(0, 2, 1)
        out.append(tok)
    return jnp.concatenate(out, axis=-1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = tr.init_params(key, cfg, cfg.param_dtype_serve)
    if cfg.modality == "audio_stub" and cfg.num_codebooks > 1:
        prompts = jax.random.randint(
            key, (args.batch, cfg.num_codebooks, args.prompt_len), 0,
            cfg.vocab_size)
    else:
        prompts = jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size)

    t0 = time.time()
    toks = generate(cfg, params, prompts, args.gen,
                    args.prompt_len + args.gen + 1, args.temperature,
                    args.seed)
    dt = time.time() - t0
    n_gen = args.batch * args.gen
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({n_gen / dt:.1f} tok/s batch throughput)")
    print(np.asarray(toks)[0][..., :12])


if __name__ == "__main__":
    main()
