"""jit-able step functions + ShapeDtypeStruct input specs for the dry-run.

``input_specs(cfg, shape)`` returns stand-ins for every input of the step the
shape lowers (train_step for train_4k, forward for prefill, serve_step for
decode shapes) — weak-type-correct, shardable, no device allocation.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import transformer as tr
from repro.optim import (adafactor, adamw, apply_updates,
                         clip_by_global_norm, sgd)

PyTree = Any


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins)
# ---------------------------------------------------------------------------
def batch_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    B, T = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.mode == "train":
        if cfg.modality == "audio_stub" and cfg.num_codebooks > 1:
            toks = jax.ShapeDtypeStruct((B, cfg.num_codebooks, T), i32)
        else:
            toks = jax.ShapeDtypeStruct((B, T), i32)
        batch = {"tokens": toks, "labels": toks}
        if cfg.modality == "vision_stub":
            batch["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix_embeddings, cfg.d_model), jnp.bfloat16)
        return batch
    if shape.mode == "prefill":
        if cfg.modality == "audio_stub" and cfg.num_codebooks > 1:
            toks = jax.ShapeDtypeStruct((B, cfg.num_codebooks, T), i32)
        else:
            toks = jax.ShapeDtypeStruct((B, T), i32)
        batch = {"tokens": toks}
        if cfg.modality == "vision_stub":
            batch["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix_embeddings, cfg.d_model), jnp.bfloat16)
        return batch
    # decode: ONE new token against a seq_len KV cache
    if cfg.modality == "audio_stub" and cfg.num_codebooks > 1:
        toks = jax.ShapeDtypeStruct((B, cfg.num_codebooks, 1), i32)
    else:
        toks = jax.ShapeDtypeStruct((B, 1), i32)
    return {"tokens": toks}


def params_specs(cfg: ArchConfig, dtype_name: Optional[str] = None) -> PyTree:
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    return jax.eval_shape(
        lambda k: tr.init_params(k, cfg, dtype_name),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def decode_state_specs(cfg: ArchConfig, shape: InputShape) -> PyTree:
    return jax.eval_shape(
        lambda: tr.init_decode_state(cfg, shape.global_batch, shape.seq_len))


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------
def mesh_hints(mesh):
    """Sharding hints (models/hints.py) derived from a mesh; None for the
    single-device paths."""
    from repro.models.hints import Hints
    if mesh is None or "model" not in mesh.axis_names:
        return None
    dp = tuple(a for a in mesh.axis_names if a != "model")
    return Hints(dp=dp, model="model", model_size=int(mesh.shape["model"]))


def make_train_step(cfg: ArchConfig, *, learning_rate: float = 3e-4,
                    optimizer: str = "auto", clip_norm: float = 1.0,
                    remat: bool = True, hints=None,
                    param_shardings=None) -> Callable:
    if optimizer == "auto":
        # factored optimizer for >=70B models: Adam moments alone would
        # overflow 16 GB/chip on the single-pod mesh (EXPERIMENTS.md)
        big = cfg.num_layers * cfg.d_model ** 2 > 3e10 or \
            cfg.moe_experts >= 64
        optimizer = "adafactor" if big else "adamw"
    opt = {"adamw": adamw, "adafactor": adafactor,
           "sgd": sgd}[optimizer](learning_rate)

    cdt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
           "float16": jnp.float16}[cfg.compute_dtype]

    def loss_fn(params, batch):
        # fp32 master weights -> one bf16 cast per step: the FSDP
        # all-gathers then move bf16 (2x fewer collective bytes); grads
        # flow back to fp32 through the cast (standard mixed precision).
        # The cast output must be PINNED to the param sharding, otherwise
        # the partitioner hoists the convert past the all-gather and the
        # gathers move fp32 again (measured: identical collective bytes).
        def cast(p, sh=None):
            if p.dtype != jnp.float32 or p.ndim < 2:
                return p
            pc = p.astype(cdt)
            if sh is not None:
                pc = jax.lax.with_sharding_constraint(pc, sh)
            return pc
        if param_shardings is not None:
            params_c = jax.tree.map(cast, params, param_shardings)
        else:
            params_c = jax.tree.map(cast, params)
        return tr.lm_loss(params_c, cfg, batch, hints=hints)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    train_step.optimizer = opt
    return train_step


def make_prefill_step(cfg: ArchConfig, hints=None) -> Callable:
    def prefill(params, batch):
        logits, _ = tr.forward(params, cfg, batch["tokens"],
                               batch.get("prefix_embeds"), remat=False,
                               hints=hints)
        # return only the last position (what serving needs) to avoid a
        # (B, T, V) transfer
        return logits[:, -1].astype(jnp.float32)
    return prefill


def make_serve_step(cfg: ArchConfig, hints=None) -> Callable:
    def serve_step(params, state, batch):
        logits, state = tr.decode_step(params, cfg, state, batch["tokens"],
                                       hints=hints)
        return logits, state
    return serve_step
