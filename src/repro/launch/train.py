"""Pod-scale training driver.

Two modes:
* ``--mode standard``  — plain distributed LM training (AdamW, FSDP x TP).
* ``--mode federated`` — the paper's technique: federated rounds with
  dynamic sampling + selective masking (launch/fedtrain.py), clients mapped
  onto the mesh's client axis.

On this CPU container you run it with a tiny mesh / reduced arch, e.g.:

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
      --mesh 1x1 --steps 10 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch rwkv6-1.6b --reduced \
      --mode federated --rounds 5 --clients 4 --gamma 0.2 --beta 0.1

On a real pod the same script runs with ``--mesh 16x16`` (the production
mesh) and the full arch id.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.configs import get_arch
from repro.core.sampling import DynamicSampling, StaticSampling
from repro.data.synthetic import markov_text
from repro.launch import shardings as sh
from repro.launch import steps as steps_lib
from repro.launch.fedtrain import FedPodConfig, make_fed_round
from repro.models import transformer as tr


def make_mesh_arg(spec: str):
    dims = tuple(int(x) for x in spec.split("x"))
    names = {1: ("model",), 2: ("data", "model"),
             3: ("pod", "data", "model")}[len(dims)]
    return jax.make_mesh(dims, names)


def synth_batches(cfg, batch, seq, steps, seed=0):
    data = markov_text(num_train=(batch * seq + 1) * steps + 1,
                       vocab_size=min(cfg.vocab_size, 512), seed=seed)
    toks = data.train_tokens
    out = []
    for i in range(steps):
        w = toks[i * batch * seq:(i + 1) * batch * seq + 1]
        x = w[:-1].reshape(batch, seq) % cfg.vocab_size
        y = w[1:].reshape(batch, seq) % cfg.vocab_size
        out.append({"tokens": jnp.asarray(x), "labels": jnp.asarray(y)})
    return out


def run_standard(args, cfg, mesh):
    step = steps_lib.make_train_step(cfg, learning_rate=args.lr)
    params = tr.init_params(jax.random.PRNGKey(args.seed), cfg)
    opt_state = step.optimizer.init(params)
    psh = sh.params_shardings(params, mesh)
    osh = sh.params_shardings_like(opt_state, psh, mesh)
    batches = synth_batches(cfg, args.batch, args.seq, args.steps, args.seed)
    bsh = sh.batch_shardings(batches[0], mesh)
    fn = jax.jit(step, in_shardings=(psh, osh, bsh),
                 out_shardings=(psh, osh, None), donate_argnums=(0, 1))
    with mesh:
        params = jax.device_put(params, psh)
        opt_state = jax.device_put(opt_state, osh)
        for i, b in enumerate(batches):
            t0 = time.time()
            params, opt_state, m = fn(params, opt_state,
                                      jax.device_put(b, bsh))
            print(f"step {i}: loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"dt={time.time() - t0:.2f}s", flush=True)
    if args.ckpt:
        save_checkpoint(args.ckpt, args.steps, params)
    return params


def run_federated(args, cfg, mesh):
    C = args.clients
    fed_cfg = FedPodConfig(num_clients=C, local_steps=args.local_steps,
                           learning_rate=args.lr, gamma=args.gamma,
                           masking=args.masking)
    schedule = (DynamicSampling(initial_rate=args.init_rate, beta=args.beta)
                if args.beta > 0 else StaticSampling(initial_rate=args.init_rate))
    fed_round = make_fed_round(cfg, fed_cfg)

    params = tr.init_params(jax.random.PRNGKey(args.seed), cfg)
    data = synth_batches(cfg, C * args.batch, args.seq,
                         args.local_steps * args.rounds, args.seed)
    n_samples = jnp.ones((C,), jnp.float32)
    key = jax.random.PRNGKey(args.seed + 1)
    fn = jax.jit(fed_round)

    with mesh:
        for t in range(1, args.rounds + 1):
            key, k_part, k_mask = jax.random.split(key, 3)
            from repro.core.sampling import participation_mask
            part = participation_mask(k_part, schedule, t, C)
            sl = data[(t - 1) * args.local_steps: t * args.local_steps]
            toks = jnp.stack([b["tokens"] for b in sl], 0)   # (S, C*b, T)
            labs = jnp.stack([b["labels"] for b in sl], 0)
            S = toks.shape[0]
            batches = {
                "tokens": toks.reshape(S, C, args.batch, args.seq)
                .transpose(1, 0, 2, 3),
                "labels": labs.reshape(S, C, args.batch, args.seq)
                .transpose(1, 0, 2, 3),
            }
            t0 = time.time()
            params, m = fn(params, batches, n_samples, part, k_mask)
            print(f"round {t}: sampled={int(m['num_sampled'])}/{C} "
                  f"loss={float(m['mean_loss']):.4f} "
                  f"transport={float(m['num_sampled']) * fed_cfg.gamma:.2f} "
                  f"model-units dt={time.time() - t0:.2f}s", flush=True)
    if args.ckpt:
        save_checkpoint(args.ckpt, args.rounds, params)
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", default="standard",
                    choices=["standard", "federated"])
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--gamma", type=float, default=0.1)
    ap.add_argument("--beta", type=float, default=0.0)
    ap.add_argument("--init-rate", type=float, default=1.0)
    ap.add_argument("--masking", default="selective",
                    choices=["selective", "random", "none"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_mesh_arg(args.mesh)
    if args.mode == "standard":
        run_standard(args, cfg, mesh)
    else:
        run_federated(args, cfg, mesh)


if __name__ == "__main__":
    main()
