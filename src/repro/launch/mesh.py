"""Production meshes (DESIGN.md §6).

Target: TPU v5e.  Single pod = 16x16 = 256 chips, axes ("data", "model").
Multi-pod = 2 pods = 512 chips, axes ("pod", "data", "model") — the "pod"
axis carries only data parallelism (DCN-friendly: one gradient/params
reduction per step crosses pods).

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import and only then builds the mesh.
"""

from __future__ import annotations

import jax

# v5e hardware constants used by the roofline (benchmarks/roofline.py)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (~per-chip effective)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cohort_mesh(num_devices: int | None = None):
    """1-D mesh carrying the cohort engine's client axis (DESIGN.md §3.5):
    each device runs cohort_size / num_devices clients of the padded cohort
    buffer.  Uses all local devices by default.  Bucket sizes from
    ``SamplingSchedule.bucket_ladder`` are powers of two *except the top
    bucket M itself*, so a power-of-two device count divides every bucket
    below full participation; full-participation rounds on a non-power-of-two
    M belong on the oracle path (the server dispatches them there)."""
    import numpy as np
    devs = jax.devices()
    if num_devices is not None:
        devs = devs[:num_devices]
    return jax.sharding.Mesh(np.asarray(devs), ("clients",))


def data_axes(mesh) -> tuple:
    """The batch/FSDP axes: everything except 'model'."""
    return tuple(a for a in mesh.axis_names if a != "model")


def num_chips(mesh) -> int:
    return int(mesh.devices.size)
