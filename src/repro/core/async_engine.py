"""Asynchronous buffered aggregation with a failure model (DESIGN.md §8).

The sync engines (``repro.core.federated``) end every round with a barrier:
aggregate once, after everyone.  On the simulated fleets of
``repro.core.hetero`` that barrier waits for the straggler.  This module is
the FedBuff-style alternative: the server consumes the round's upload
*arrival stream* (``hetero.arrival_stream``) as a time-ordered event queue
and applies a **buffer** of K uploads whenever it fills — clients that
arrive after a flush land in the next one, discounted by how stale their
base model has become.

Anatomy of one async round (:meth:`AsyncRoundRunner.run_round`):

1. **dispatch** — the engine-shared client-side sweep
   (``federated.make_cohort_compute``): selection → cohort gather → local
   updates → wire round-trip, one jitted program.  Identical bits to the
   sync cohort engine by construction.
2. **event loop** (host) — arrivals pop off a heap in ``(time, client)``
   order.  The failure model perturbs the fault-free stream:

   * *drops* — each transmission is lost with the fleet's per-client
     ``drop_rate``; lost uploads retry with exponential backoff
     (``backoff_s * 2^attempt`` + the client's re-upload wire time) up to
     ``max_retries`` times.  Horvitz-Thompson weights divide by the
     *policy* survival probability ``1 - q^(R+1)`` — the retry-aware
     analogue of the sync engine's ``1 - q`` — so the flushed sum stays
     unbiased (deadline censoring is the documented residual bias).
   * *deadline* — an absolute ``deadline_s`` or a ``deadline_quantile`` of
     the selected cohort's fault-free arrival times.  The first event past
     the deadline cuts the round: whatever arrived is aggregated, every
     pending client counts as a timeout, and — the graceful-degradation
     invariant — its error-feedback residual is left untouched, exactly as
     the sync engine treats a dropped upload.
   * *quarantine* — a validation gate at the codec decode boundary
     rejects uploads whose decoded payload contains NaN/Inf.  Quarantined
     rows never enter a flush, never update norm EMAs, and keep their
     round-entry residuals: a poisoned client cannot poison the global
     model or its own EF state.  (``corrupt_rate`` injects such payloads
     for chaos testing; with ``quarantine=False`` they propagate, which is
     the negative control.)

3. **flushes** — every time the buffer holds >= K arrivals the server
   applies one aggregation step over the buffered rows with weights
   ``w_i / (1 + s_i)^beta``: the sampler's weight debiased by staleness
   ``s_i`` = number of flushes applied since client i pulled the model.
   All events carrying the *same* timestamp drain before the buffer is
   checked, so simultaneous arrivals (the ``ideal`` fleet) form a single
   flush.  Leftovers flush once at round close.

**Keystone equivalence** (property-tested in tests/test_async.py): with
instant arrivals (ideal fleet), buffer K = m_t and no injected faults, the
round degenerates to dispatch + one flush of everyone at staleness 0 — and
is **bit-exact** vs the sync cohort engine (params, EF residuals, norm
EMAs).  Every ingredient preserves bits: the sweep is the shared compute,
the single flush multiplies weights by exactly ``1.0`` (staleness discount
at s=0, survival at q=0), and the masked ``jnp.where`` row-cleaning /
state commits pass untouched rows through unchanged.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codecs import IdentityCodec
from repro.core.federated import (_active_attack, _resolve_policies,
                                  _row_l2, _split_round_key, _wire_feedback,
                                  make_cohort_compute, make_store_compute,
                                  make_store_selection)
from repro.core.hetero import HeteroModel, arrival_stream

PyTree = Any

__all__ = ["AsyncConfig", "AsyncRoundRunner"]


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """The async engine's knobs: buffering, staleness, and the failure model.

    ``buffer_size`` fixes the flush threshold K; ``buffer_frac`` sizes it as
    a fraction of the round's m_t instead (at most one may be set; unset
    means K = m_t, the FedBuff-degenerates-to-sync point).
    ``staleness_beta`` is the exponent of the ``1/(1+s)^beta`` discount.
    Deadlines: ``deadline_s`` (absolute seconds) or ``deadline_quantile``
    (quantile of the cohort's fault-free arrival times; at most one).
    ``max_retries`` / ``backoff_s`` bound the retransmission policy;
    ``jitter_sigma`` adds per-round lognormal arrival jitter;
    ``corrupt_rate`` injects NaN payloads (chaos testing) and
    ``quarantine`` turns the decode-boundary validation gate on/off.

    ``max_round_stale`` switches staleness from flush-distance to
    **cross-round** distance (DESIGN.md §11.1): with S > 0, uploads cut by
    the deadline are not dropped but *carried* into subsequent rounds and
    applied with weight ``w/(1+s)^beta`` where ``s = t' - version[i]`` is
    the number of rounds since client i pulled its base model (the
    client-state store's per-client version vector).  An upload older than
    S rounds expires as a timeout; a carried upload superseded by a fresh
    dispatch of the same client is discarded.  S = 0 (default) keeps the
    original within-round flush-count staleness bit-identically.
    """

    buffer_size: int | None = None
    buffer_frac: float | None = None
    staleness_beta: float = 0.5
    deadline_s: float | None = None
    deadline_quantile: float | None = None
    max_retries: int = 2
    backoff_s: float = 0.5
    jitter_sigma: float = 0.0
    corrupt_rate: float = 0.0
    quarantine: bool = True
    max_round_stale: int = 0

    def __post_init__(self):
        """Reject contradictory or out-of-range knob combinations."""
        if self.buffer_size is not None and self.buffer_frac is not None:
            raise ValueError("set at most one of buffer_size / buffer_frac")
        if self.buffer_size is not None and self.buffer_size < 1:
            raise ValueError(f"buffer_size must be >= 1, got {self.buffer_size}")
        if self.buffer_frac is not None and not 0.0 < self.buffer_frac <= 1.0:
            raise ValueError(
                f"buffer_frac must be in (0, 1], got {self.buffer_frac}")
        if self.staleness_beta < 0.0:
            raise ValueError(
                f"staleness_beta must be >= 0, got {self.staleness_beta}")
        if self.deadline_s is not None and self.deadline_quantile is not None:
            raise ValueError(
                "set at most one of deadline_s / deadline_quantile")
        if self.deadline_s is not None and self.deadline_s <= 0.0:
            raise ValueError(f"deadline_s must be > 0, got {self.deadline_s}")
        if (self.deadline_quantile is not None
                and not 0.0 < self.deadline_quantile <= 1.0):
            raise ValueError(
                f"deadline_quantile must be in (0, 1], got "
                f"{self.deadline_quantile}")
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_s < 0.0:
            raise ValueError(f"backoff_s must be >= 0, got {self.backoff_s}")
        if self.jitter_sigma < 0.0:
            raise ValueError(
                f"jitter_sigma must be >= 0, got {self.jitter_sigma}")
        if not 0.0 <= self.corrupt_rate <= 1.0:
            raise ValueError(
                f"corrupt_rate must be in [0, 1], got {self.corrupt_rate}")
        if self.max_round_stale < 0:
            raise ValueError(
                f"max_round_stale must be >= 0, got {self.max_round_stale}")

    def buffer_for(self, m: int) -> int:
        """Flush threshold K for a round expecting ``m`` participants."""
        if self.buffer_size is not None:
            return self.buffer_size
        if self.buffer_frac is not None:
            return max(1, int(np.ceil(self.buffer_frac * m)))
        return max(1, m)


class AsyncRoundRunner:
    """Per-strategy async round executor: owns the compiled-program caches
    and the fleet traits; :meth:`run_round` runs one buffered round.

    The jitted surface is three small programs per cohort bucket — the
    engine-shared dispatch sweep, the per-flush aggregation, and the
    round-close state commit — each AOT-compiled once per (bucket, aval)
    signature with the compile time metered out of the round clock, same
    discipline as ``FederatedServer``'s sync paths.
    """

    def __init__(self, strategy, loss_fn, num_clients: int,
                 async_cfg: AsyncConfig | None = None, store=None):
        self.strategy = strategy
        self.loss_fn = loss_fn
        self.num_clients = num_clients
        acfg = async_cfg
        if acfg is None:
            acfg = getattr(strategy, "async_cfg", None)
        self.acfg = acfg if acfg is not None else AsyncConfig()
        # The client-state store (DESIGN.md §11).  A sharded store reroutes
        # dispatch through the store-form sweep (residual gather/scatter
        # outside the program); cross-round staleness needs the store's
        # per-client version vector either way.
        self.store = store
        self._crossround = self.acfg.max_round_stale > 0
        if self._crossround and store is None:
            raise ValueError(
                "max_round_stale > 0 (cross-round staleness) requires a "
                "ClientStateStore — the per-client model-version state "
                "lives there")
        # In-flight uploads carried across round boundaries (cross-round
        # mode): one dict per upload with its payload/residual rows, base
        # weight, dispatch round and remaining lateness.
        self._pending: list = []
        self.schedule = strategy.sampling
        self.smp = strategy.sampler
        self.cfg = strategy.federated_config(num_clients)
        # FedDyn drift rides the store on BOTH backends (dense included) so
        # run_round's signature stays drift-free; commits go through
        # store.scatter(..., tree="drift") with the applied-rows mask.
        self._uses_drift = self.cfg.client.objective.uses_drift
        if self._uses_drift:
            if store is None:
                raise ValueError(
                    f"strategy {strategy.name!r} carries FedDyn drift "
                    "state; the async engine needs a ClientStateStore "
                    "built with extra_trees={'drift': ...}")
            if "drift" not in store.trees:
                raise ValueError(
                    "async engine with a FedDyn objective requires the "
                    "store to hold a 'drift' tree (extra_trees=)")
        # The clock/fault traits: an explicit fleet, or ideal (instant
        # arrivals, no drops) when the strategy has no hetero model.  The
        # ROUND KEY split still mirrors the sync engine's, which branches
        # on whether hetero is set — bit-exactness depends on it.
        hetero = strategy.hetero if strategy.hetero is not None \
            else HeteroModel(profile="ideal")
        self.traits = hetero.client_traits(num_clients)
        self._with_drop = strategy.hetero is not None
        _, self._agg_fn = _resolve_policies(
            strategy.codec, strategy.aggregator, self.smp.normalize)
        # Mirrors roundtrip_stacked's pass-through condition — the static
        # analogue of the sync engines' `wired is not uploads` check.
        self._wire_feedback = not (strategy.codec is None
                                   or isinstance(strategy.codec, IdentityCodec))
        self._inject = self.acfg.corrupt_rate > 0.0
        # Byzantine adversaries (DESIGN.md §9): the dispatch sweep hands us
        # the attacked payload; its non-finite rows (e.g. the "nan" attack)
        # land in the same quarantine gate as corrupt_rate injections.
        self.attack = _active_attack(getattr(strategy, "attack", None))
        self._adv = (self.attack.adversary_mask(num_clients)
                     if self.attack is not None else None)
        # Per-client probability that ALL max_retries+1 transmissions drop;
        # HT weights divide by its complement (exact 1.0 on no-drop fleets).
        q = np.asarray(self.traits.drop_rate, np.float64)
        self._survival = (1.0 - q ** (self.acfg.max_retries + 1)).astype(
            np.float32)
        self._compute_fns: Dict[int, Any] = {}
        self._select_fns: Dict[int, Any] = {}
        self._store_compute = None
        self._aot_cache: Dict[Any, Any] = {}

    # ---- compiled-program plumbing ----------------------------------------
    def _aot(self, name: str, fn, args) -> Tuple[Any, float]:
        """AOT-compile ``fn`` for ``args``' avals (cached); returns
        ``(compiled, compile_seconds)`` with 0.0 on cache hits."""
        avals = tuple(
            (tuple(leaf.shape), str(leaf.dtype))
            for leaf in jax.tree_util.tree_leaves(args))
        cache_key = (name, avals)
        hit = self._aot_cache.get(cache_key)
        if hit is not None:
            return hit, 0.0
        t0 = time.perf_counter()
        compiled = jax.jit(fn).lower(*args).compile()
        dt = time.perf_counter() - t0
        self._aot_cache[cache_key] = compiled
        return compiled, dt

    def _compute_fn(self, bucket: int):
        """The engine-shared dispatch sweep for one cohort bucket."""
        fn = self._compute_fns.get(bucket)
        if fn is None:
            fn = make_cohort_compute(
                self.loss_fn, self.schedule, self.cfg, bucket,
                codec=self.strategy.codec, sampler=self.smp,
                attack=self.attack)
            self._compute_fns[bucket] = fn
        return fn

    def _select_fn(self, bucket: int):
        """Store-form selection head for one cohort bucket (sharded
        dispatch only — the residual gather happens OUTSIDE the program,
        through ``self.store``)."""
        fn = self._select_fns.get(bucket)
        if fn is None:
            fn = make_store_selection(self.schedule, self.cfg, bucket,
                                      sampler=self.smp)
            self._select_fns[bucket] = fn
        return fn

    def _store_compute_fn(self):
        """Store-form sweep on pre-gathered cohort residual rows."""
        if self._store_compute is None:
            self._store_compute = make_store_compute(
                self.loss_fn, self.cfg, codec=self.strategy.codec,
                attack=self.attack)
        return self._store_compute

    # ---- jitted round pieces ----------------------------------------------
    def _gate_impl(self, wired, corrupt_c):
        """Chaos injection + the quarantine gate's validity check: returns
        ``(wired, finite_rows)`` where row i is finite iff every element of
        cohort member i's decoded upload is."""
        if self._inject:
            def poison(u):
                cm = corrupt_c.reshape((-1,) + (1,) * (u.ndim - 1))
                return jnp.where(cm > 0, jnp.full_like(u, jnp.nan), u)
            wired = jax.tree.map(poison, wired)
        finite = None
        for leaf in jax.tree_util.tree_leaves(wired):
            ok = jnp.all(jnp.isfinite(leaf.astype(jnp.float32)),
                         axis=tuple(range(1, leaf.ndim)))
            finite = ok if finite is None else finite & ok
        return wired, finite.astype(jnp.float32)

    def _flush_impl(self, params, wired, w_flush, keep):
        """One buffer flush: aggregate the rows with nonzero ``w_flush``.

        ``keep`` masks rows allowed to touch the arithmetic at all —
        non-finite rows are *zeroed out*, not just zero-weighted, because
        ``0 * NaN`` is NaN: a quarantined payload must not reach the sum
        even with weight 0.  On all-finite rounds ``keep`` is all-ones and
        the ``where`` is a bit-exact pass-through.
        """
        def clean(u):
            km = keep.reshape((-1,) + (1,) * (u.ndim - 1))
            return jnp.where(km > 0, u, jnp.zeros_like(u))

        cleaned = jax.tree.map(clean, wired)
        return self._agg_fn(params, cleaned, w_flush, self.cfg.client.upload)

    def _close_impl(self, residuals, norms, cohort_ids, cohort_res, new_res,
                    uploads, wired, payload, applied_c):
        """Round-close state commit: EF residuals advance and norm EMAs
        update only for cohort rows whose upload was APPLIED (arrived
        before the deadline, survived quarantine, entered a flush) —
        timeouts, permanent drops and quarantined rows keep their
        round-entry state, the async analogue of the sync engines'
        arrived-mask gating.  EF wire-loss feedback stays on the HONEST
        (uploads, wired) pair (a residual reflects what the client failed
        to ship, not what an attacker forged); the norm tracker observes
        ``payload`` — what the server actually saw."""
        if self.cfg.error_feedback:
            if self._wire_feedback:
                new_res = _wire_feedback(new_res, uploads, wired)

            def scatter(old, new, old_cohort):
                am = applied_c.reshape((-1,) + (1,) * (new.ndim - 1))
                kept = jnp.where(am > 0, new, old_cohort)
                return old.at[cohort_ids].set(kept)

            residuals = jax.tree.map(scatter, residuals, new_res, cohort_res)
        if self.smp.adaptive:
            obs = _row_l2(payload)
            old_c = jnp.take(norms, cohort_ids)
            upd = jnp.where(applied_c > 0,
                            (1.0 - self.smp.ema) * old_c + self.smp.ema * obs,
                            old_c)
            norms = norms.at[cohort_ids].set(upd)
        return residuals, norms

    def _close_rows_impl(self, norms, cohort_ids, new_res, uploads, wired,
                         payload, applied_c):
        """Cohort-level round close for the store-form path: same math as
        :meth:`_close_impl`, but the commit-masked scatter is the store's
        job — this just finalizes the residual candidate rows (wire-loss
        feedback folded in) and the cohort's norm-EMA rows."""
        if self.cfg.error_feedback and self._wire_feedback:
            new_res = _wire_feedback(new_res, uploads, wired)
        norm_upd = None
        if self.smp.adaptive:
            obs = _row_l2(payload)
            old_c = jnp.take(norms, cohort_ids)
            norm_upd = jnp.where(
                applied_c > 0,
                (1.0 - self.smp.ema) * old_c + self.smp.ema * obs, old_c)
        return new_res, norm_upd

    # ---- the round --------------------------------------------------------
    def run_round(self, params, residuals, norms, client_batches, n_samples,
                  t: int, key, *, cohort_size: int, flops: float,
                  wire_bytes: int):
        """Run one async buffered round.

        Same state contract as the sync round programs — returns
        ``(params, residuals, norms, stats)`` with ``norms`` passed through
        (possibly ``None``) for non-adaptive samplers — plus the host-side
        ``stats`` dict the server turns into a ``RoundRecord``.
        ``cohort_size`` must upper-bound the sampler's participant count
        for round ``t`` (use ``ClientSampler.cohort_bucket``).

        On a sharded store the dispatch reroutes through the store-form
        sweep (``residuals`` is ignored — pass None) and ALL state commits
        go through ``self.store``; the returned ``residuals`` is None.  In
        cross-round mode (``max_round_stale > 0``) deadline-cut uploads
        are carried into later rounds instead of timing out — see
        :class:`AsyncConfig`.
        """
        acfg = self.acfg
        M = self.num_clients
        compile_s = 0.0
        sharded = self.store is not None and self.store.kind != "dense"

        # 1. dispatch: identical key split + client-side sweep to the sync
        # cohort engine.  The sharded path runs the same math split at the
        # store boundary (selection → store gather → cohort-shaped sweep).
        sample_key, mask_key, drop_key = _split_round_key(
            jnp.asarray(key), self._with_drop)
        t_arr = jnp.asarray(t, jnp.float32)
        if sharded:
            sel_args = (norms, n_samples, t_arr, sample_key)
            sel, dt = self._aot(("select", cohort_size),
                                self._select_fn(cohort_size), sel_args)
            compile_s += dt
            part_dev, weights_dev, ids_dev = sel(*sel_args)
            ids_np = np.asarray(ids_dev)
            cohort_res = self.store.gather(ids_np)
            cohort_drift = (self.store.gather(ids_np, tree="drift")
                            if self._uses_drift else None)
            if callable(client_batches):
                cohort_batches = client_batches(ids_np)
            else:
                cohort_batches = jax.tree.map(
                    lambda x: jnp.take(x, ids_dev, axis=0), client_batches)
            cargs = (params, cohort_res, cohort_batches, ids_dev, mask_key,
                     cohort_drift)
            comp, dt = self._aot("store-compute", self._store_compute_fn(),
                                 cargs)
            compile_s += dt
            out = dict(comp(*cargs))
            out.update(part=part_dev, weights=weights_dev,
                       cohort_ids=ids_dev, cohort_res=cohort_res)
        else:
            drift_dense = (self.store.dense_view("drift")
                           if self._uses_drift else None)
            compute_args = (params, residuals, drift_dense, norms,
                            client_batches, n_samples, t_arr, sample_key,
                            mask_key)
            compute, dt = self._aot(("compute", cohort_size),
                                    self._compute_fn(cohort_size),
                                    compute_args)
            compile_s += dt
            out = compute(*compute_args)

        part = np.asarray(out["part"])
        cohort_ids = np.asarray(out["cohort_ids"])
        losses = np.asarray(out["losses"], np.float64)
        B = int(cohort_ids.shape[0])
        row_of = {int(cid): i for i, cid in enumerate(cohort_ids)}
        # Θ_t went out to this round's participants: record the model
        # version each carries — what cross-round staleness measures
        # against (s = 0 for everything applied within the round).
        if self.store is not None:
            self.store.mark_dispatched(np.flatnonzero(part > 0), t)

        # Host-side randomness (corrupt draws, arrival jitter, drop draws)
        # is seeded from the round's drop subkey so reruns are exact replays.
        seed_key = drop_key if drop_key is not None else key
        rng = np.random.default_rng(
            [int(x) for x in np.asarray(seed_key, np.uint32).ravel()])

        # 2. adversary payload + chaos injection + quarantine validity
        # flags.  ``payload`` is what the server decodes (attacked rows
        # perturbed, possibly NaN-poisoned); ``wired`` stays the honest
        # wire round-trip the EF state commit consumes.
        wired = out["wired"]
        payload = out["attacked"]
        corrupt = np.zeros((M,), np.float32)
        if self._inject:
            corrupt = (rng.random(M) < acfg.corrupt_rate).astype(np.float32)
        if self._inject or acfg.quarantine:
            gate_args = (payload, jnp.asarray(corrupt[cohort_ids]))
            gate, dt = self._aot("gate", self._gate_impl, gate_args)
            compile_s += dt
            payload, finite_dev = gate(*gate_args)
            finite_c = np.asarray(finite_dev)
        else:
            finite_c = np.ones((B,), np.float32)

        # 3. the arrival-time stream and its failure-model perturbation.
        first = list(arrival_stream(self.traits, part, flops, wire_bytes,
                                    rng=rng, jitter_sigma=acfg.jitter_sigma))
        deadline = np.inf
        if acfg.deadline_s is not None:
            deadline = float(acfg.deadline_s)
        elif acfg.deadline_quantile is not None and first:
            deadline = float(np.quantile(
                np.asarray([ts for ts, _ in first], np.float64),
                acfg.deadline_quantile))
        # Heap entries are ``(time, client, attempt, carried_idx)`` with
        # carried_idx = -1 for this round's own transmissions; cross-round
        # mode injects last rounds' still-in-flight uploads at their
        # remaining lateness.
        heap: list = [(ts, cid, 0, -1) for ts, cid in first]
        heapq.heapify(heap)

        q = np.asarray(self.traits.drop_rate, np.float64)
        resend = np.asarray(self.traits.upload_time_s(wire_bytes), np.float64)
        m_t = int(self.schedule.num_clients_host(t, M))
        K = acfg.buffer_for(m_t)

        # Sampler weights for the cohort rows, host-side; Horvitz-Thompson
        # weights are debiased by the retry policy's survival probability.
        base_w = np.asarray(out["weights"], np.float32)[cohort_ids]
        if not self.smp.normalize:
            base_w = base_w / self._survival[cohort_ids]
        keep_dev = jnp.asarray(finite_c if acfg.quarantine
                               else np.ones((B,), np.float32))

        applied_rows = np.zeros((B,), np.float32)
        buffer_rows: list = []       # ("cur", cohort_row) | ("carried", idx)
        carried_applied: list = []
        arrivals = timeouts = retries = quarantined = dropped = sends = 0
        flushes = 0
        staleness_sum = 0.0
        applied_times: list = []
        close_time = 0.0

        # Cross-round carry-in: last rounds' deadline-cut uploads re-enter
        # the event queue at their remaining lateness, unless superseded by
        # a fresh dispatch of the same client (it re-downloaded Θ and
        # recomputed — the in-flight upload is obsolete) or expired past
        # the max_round_stale window; both count as timeouts.
        carried_in: list = []
        if self._crossround and self._pending:
            participants = set(np.flatnonzero(part > 0).tolist())
            for e in self._pending:
                s = int(self.store.staleness(np.asarray([e["cid"]]), t)[0])
                if e["cid"] in participants or s > acfg.max_round_stale:
                    timeouts += 1
                    continue
                heapq.heappush(
                    heap, (e["lateness"], e["cid"], 0, len(carried_in)))
                carried_in.append(e)
            self._pending = []

        def carry_entry(row, cid, lateness):
            """Snapshot one cohort row as an in-flight cross-round upload:
            the decoded payload row (aggregation + norm observation), the
            finalized EF residual candidate (wire-loss feedback folded
            in), its base weight, quarantine flag and dispatch round."""
            res_row = None
            if self.cfg.error_feedback:
                if self._wire_feedback:
                    res_row = jax.tree.map(
                        lambda n, u, w: n[row] + (u[row] - w[row]),
                        out["new_res"], out["uploads"], wired)
                else:
                    res_row = jax.tree.map(lambda x: x[row], out["new_res"])
            drift_row = None
            if self._uses_drift:
                drift_row = jax.tree.map(lambda x: x[row], out["new_drift"])
            return {"cid": int(cid), "w": float(base_w[row]),
                    "finite": float(finite_c[row]), "round": int(t),
                    "lateness": float(lateness),
                    "payload": jax.tree.map(lambda x: x[row], payload),
                    "res": res_row, "drift": drift_row}

        def do_flush():
            """Aggregate the current buffer at the current staleness:
            flush-count discount in the classic mode, per-row round
            distance ``1/(1+s)^beta`` (s from the store's version vector)
            in cross-round mode, where carried rows join the same flush as
            this round's arrivals."""
            nonlocal params, flushes, staleness_sum, compile_s
            if not buffer_rows:
                return
            cur = [i for kind, i in buffer_rows if kind == "cur"]
            car = [i for kind, i in buffer_rows if kind == "carried"]
            member = np.zeros((B,), np.float32)
            member[cur] = 1.0
            if self._crossround:
                # Fresh rows pulled Θ this round: s = 0, discount exactly
                # 1.0 — the keystone degeneration survives cross-round
                # mode untouched.
                w_flush = jnp.asarray(base_w * member)
                flush_payload, keep = payload, keep_dev
                if car:
                    cids = np.asarray([carried_in[i]["cid"] for i in car])
                    s_car = self.store.staleness(cids, t).astype(np.float64)
                    d_car = 1.0 / (1.0 + s_car) ** acfg.staleness_beta
                    w_car = (np.asarray([carried_in[i]["w"] for i in car],
                                        np.float64) * d_car)
                    car_payload = jax.tree.map(
                        lambda *rows: jnp.stack(rows),
                        *[carried_in[i]["payload"] for i in car])
                    flush_payload = jax.tree.map(
                        lambda a, b: jnp.concatenate([a, b]),
                        payload, car_payload)
                    w_flush = jnp.concatenate(
                        [w_flush, jnp.asarray(w_car, jnp.float32)])
                    # carried rows were quarantine-gated at arrival, so
                    # every buffered one is finite
                    keep = jnp.concatenate(
                        [keep_dev, jnp.ones((len(car),), jnp.float32)])
                    staleness_sum += float(s_car.sum())
            else:
                s = flushes
                discount = np.float32(1.0 / (1.0 + s) ** acfg.staleness_beta)
                w_flush = jnp.asarray(base_w * member * discount)
                flush_payload, keep = payload, keep_dev
                staleness_sum += float(s) * len(buffer_rows)
            flush_args = (params, flush_payload, w_flush, keep)
            flush, dt = self._aot("flush", self._flush_impl, flush_args)
            compile_s += dt
            params = flush(*flush_args)
            applied_rows[cur] = 1.0
            carried_applied.extend(carried_in[i] for i in car)
            flushes += 1
            buffer_rows.clear()

        # 4. the event loop.
        while heap:
            t_now = heap[0][0]
            if t_now > deadline:
                # Deadline cut: the clients DID transmit (bytes were
                # spent); the server just stops listening.  Classic mode
                # times everything pending out; cross-round mode carries
                # it — this round's own rows snapshot their computed
                # upload, already-carried rows keep riding.
                while heap:
                    ev_t, cid, _, ci = heapq.heappop(heap)
                    if ci >= 0:
                        self._pending.append(
                            dict(carried_in[ci], lateness=ev_t - deadline))
                        continue
                    sends += 1
                    if self._crossround:
                        self._pending.append(carry_entry(
                            row_of[int(cid)], cid, ev_t - deadline))
                    else:
                        timeouts += 1
                close_time = max(close_time, deadline)
                break
            # Drain every event sharing this exact timestamp before any
            # flush check — simultaneous arrivals join the same flush,
            # which is what collapses the ideal fleet to one sync step.
            while heap and heap[0][0] == t_now:
                _, cid, attempt, ci = heapq.heappop(heap)
                if ci >= 0:
                    # A carried upload lands: no drop draw (its transport
                    # already happened last round), same quarantine gate.
                    e = carried_in[ci]
                    close_time = max(close_time, t_now)
                    if acfg.quarantine and e["finite"] == 0.0:
                        quarantined += 1
                        continue
                    arrivals += 1
                    applied_times.append(t_now)
                    buffer_rows.append(("carried", ci))
                    continue
                sends += 1
                if q[cid] > 0.0 and rng.random() < q[cid]:
                    if attempt < acfg.max_retries:
                        delay = (acfg.backoff_s * (2.0 ** attempt)
                                 + float(resend[cid]))
                        heapq.heappush(
                            heap, (t_now + delay, cid, attempt + 1, -1))
                        retries += 1
                    else:
                        dropped += 1
                    continue
                row = row_of[int(cid)]
                close_time = max(close_time, t_now)
                if acfg.quarantine and finite_c[row] == 0.0:
                    quarantined += 1
                    continue
                arrivals += 1
                applied_times.append(t_now)
                buffer_rows.append(("cur", row))
            if len(buffer_rows) >= K:
                do_flush()
        do_flush()  # leftovers (buffer below K at round close) flush once

        # 5. round-close state commit.  The sharded path finalizes
        # cohort-shaped rows and commits them through the store; the dense
        # path scatters into the full (M, …) arrays in-program, exactly as
        # before.
        applied_dev = jnp.asarray(applied_rows)
        if sharded:
            close_args = (norms, out["cohort_ids"], out["new_res"],
                          out["uploads"], wired, payload, applied_dev)
            close, dt = self._aot("close-rows", self._close_rows_impl,
                                  close_args)
            compile_s += dt
            rows, norm_upd = close(*close_args)
            if self.cfg.error_feedback:
                self.store.scatter(ids_np, rows, applied_rows, t)
            if self.smp.adaptive:
                self.store.update_norms(ids_np, norm_upd)
                norms = self.store.norms
            residuals = None
        else:
            close_args = (residuals, norms, out["cohort_ids"],
                          out["cohort_res"], out["new_res"], out["uploads"],
                          wired, payload, applied_dev)
            close, dt = self._aot("close", self._close_impl, close_args)
            compile_s += dt
            residuals, norms = close(*close_args)

        if self._uses_drift:
            # Drift commits through the store on BOTH backends: the same
            # commit-masked where→set the sync engines run in-program, so
            # run_round's signature stays drift-free.
            self.store.scatter(cohort_ids, out["new_drift"], applied_rows,
                               t, tree="drift")

        # Late commits for carried uploads applied this round: EF residual
        # and norm EMA advance at APPLY time.  Their owners were not
        # redispatched this round (supersession dropped those), so these
        # writes touch rows the round-close commit left untouched.
        for e in carried_applied:
            cid = e["cid"]
            if e["res"] is not None:
                if sharded:
                    self.store.scatter(
                        np.asarray([cid]),
                        jax.tree.map(lambda x: x[None], e["res"]),
                        np.ones((1,), np.float32), t)
                else:
                    residuals = jax.tree.map(
                        lambda old, r: old.at[cid].set(r),
                        residuals, e["res"])
            if self._uses_drift and e.get("drift") is not None:
                self.store.scatter(
                    np.asarray([cid]),
                    jax.tree.map(lambda x: x[None], e["drift"]),
                    np.ones((1,), np.float32), t, tree="drift")
            if self.smp.adaptive:
                obs = _row_l2(
                    jax.tree.map(lambda x: x[None], e["payload"]))[0]
                upd = ((1.0 - self.smp.ema) * norms[cid]
                       + self.smp.ema * obs)
                if sharded:
                    self.store.update_norms(np.asarray([cid]),
                                            jnp.asarray([upd]))
                    norms = self.store.norms
                else:
                    norms = norms.at[cid].set(upd)

        valid = part[cohort_ids].astype(np.float64)
        n_part = float(part.sum())
        n_applied = float(applied_rows.sum()) + len(carried_applied)
        mean_loss = (float((losses * valid).sum() / max(valid.sum(), 1.0))
                     if n_part > 0 else float("nan"))
        median_applied = (float(np.median(np.asarray(applied_times)))
                          if applied_times else 0.0)
        stats = {
            "mean_loss": mean_loss,
            "num_sampled": int(n_part),
            "adversarial": (int((part * self._adv).sum())
                            if self._adv is not None else 0),
            "arrivals": arrivals,
            "timeouts": timeouts,
            "retries": retries,
            "quarantined": quarantined,
            "dropped": dropped,
            "sends": sends,
            "flushes": flushes,
            "buffer_size": K,
            "carried": len(carried_applied),
            "pending": len(self._pending),
            "mean_staleness": (staleness_sum / n_applied
                               if n_applied > 0 else 0.0),
            "sim_round_s": close_time,
            "straggler_s": close_time - median_applied,
            "deadline_s": deadline if np.isfinite(deadline) else None,
            "compile_s": compile_s,
        }
        return params, residuals, norms, stats
