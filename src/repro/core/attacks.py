"""Byzantine adversary simulator: who attacks, and what they upload.

PR 6's failure model covers *benign* faults — stragglers, drops, the odd
NaN payload.  This module models the *adversarial* axis (DESIGN.md §9): a
fixed fraction ``f`` of the registered fleet is controlled by an attacker
and perturbs its uploads before they reach the server.

* :class:`AttackModel` — the analogue of :class:`repro.core.hetero.
  HeteroModel` for adversaries: a named attack kind plus knobs and a seed.
  Adversary assignment is deterministic in ``(seed, num_clients)`` so both
  execution engines (and repeated runs) agree on who is Byzantine.
* The transform applies at the **upload boundary** — post-mask,
  post-codec-roundtrip — inside the round program: the attacker controls
  the *payload the server decodes*, not the client's local training, so
  attacks ride the real wire path (a Gaussian attack ships dense noise
  even under a sparse codec, exactly what a protocol-violating client
  would do).

Attack kinds (the literature's standard zoo):

* ``sign_flip``  — upload ``-strength · u``: reversed (and optionally
  amplified) updates.  At ``strength > (1-f)/f`` the FedAvg mean becomes
  an ascent direction and plain averaging diverges.
* ``scale``      — upload ``strength · u``: model-replacement style
  amplification of the adversary's own update.
* ``gauss``      — replace the upload with ``N(0, sigma²)`` noise
  (per-client, per-round deterministic draws).
* ``zero``       — free-riders: upload nothing, claim participation.
* ``nan``        — poison the payload with NaN — the chaos kind the
  decode-boundary quarantine gate (sync and async engines) must absorb.

Threading: ``FedStrategy.attack`` carries the model into every round
builder in ``repro.core.federated`` and the async engine; the server
meters adversarial participation per round (``RoundRecord.adversarial``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["AttackModel", "attack_kinds", "attack_keys"]

ATTACK_KINDS = ("sign_flip", "scale", "gauss", "zero", "nan")

# fold_in tag deriving the per-round attack key stream from the round's
# mask key: both engines derive the identical stream without widening the
# round-key split (which would break bit-exactness of attack-free rounds).
_ATTACK_FOLD = 0xA77AC


def attack_kinds() -> tuple:
    """Attack kind names accepted by :class:`AttackModel`."""
    return ATTACK_KINDS


def attack_keys(mask_key: jax.Array, num_clients: int) -> jax.Array:
    """The round's per-client attack key rows, derived from ``mask_key``.

    ``fold_in`` with a fixed tag gives a stream independent of the mask
    draws; row i is client i's key in the oracle and is gathered by
    ``cohort_ids`` in the cohort/async engines — identical per client, so
    randomized attacks (``gauss``) preserve cohort-vs-oracle bit-exactness.
    """
    return jax.random.split(
        jax.random.fold_in(mask_key, _ATTACK_FOLD), num_clients)


@dataclasses.dataclass(frozen=True)
class AttackModel:
    """Which clients are Byzantine and what they upload (DESIGN.md §9).

    ``fraction`` of the registered fleet is adversarial — assignment is a
    deterministic draw in ``(seed, num_clients)``, mirroring
    :class:`repro.core.hetero.HeteroModel`'s trait draws.  ``strength``
    scales the ``sign_flip`` / ``scale`` transforms; ``sigma`` is the
    ``gauss`` replacement noise scale.  ``fraction=0`` disables the attack
    (the round builders then keep the attack-free program, bit-identical
    to a strategy with no attack at all).
    """

    kind: str = "sign_flip"
    fraction: float = 0.0
    strength: float = 1.0
    sigma: float = 1.0
    seed: int = 0

    def __post_init__(self):
        """Validate the attack kind and knob ranges."""
        if self.kind not in ATTACK_KINDS:
            raise ValueError(
                f"unknown attack kind {self.kind!r}; known: "
                f"{', '.join(ATTACK_KINDS)}")
        if not 0.0 <= self.fraction <= 1.0:
            raise ValueError(
                f"fraction must be in [0, 1], got {self.fraction}")
        if self.strength <= 0.0:
            raise ValueError(f"strength must be > 0, got {self.strength}")
        if self.sigma < 0.0:
            raise ValueError(f"sigma must be >= 0, got {self.sigma}")

    @property
    def active(self) -> bool:
        """Whether this model perturbs any upload at all."""
        return self.fraction > 0.0

    @property
    def needs_keys(self) -> bool:
        """Whether the transform consumes per-client PRNG keys."""
        return self.kind == "gauss"

    def num_adversaries(self, num_clients: int) -> int:
        """How many of ``num_clients`` clients are adversarial."""
        return int(round(self.fraction * num_clients))

    def adversary_mask(self, num_clients: int) -> np.ndarray:
        """The static 0/1 adversary assignment over all registered clients.

        Deterministic in ``(seed, num_clients)``: both execution engines
        close over the same vector, and reruns replay the same fleet.
        """
        mask = np.zeros((num_clients,), np.float32)
        k = self.num_adversaries(num_clients)
        if k > 0:
            rng = np.random.default_rng((self.seed, num_clients, 0xBAD))
            mask[rng.permutation(num_clients)[:k]] = 1.0
        return mask

    def apply_stacked(self, uploads, adv: jnp.ndarray,
                      keys: jax.Array | None = None):
        """Apply the attack to a client-stacked upload pytree.

        ``uploads`` leaves carry a leading client-row axis; ``adv`` is the
        matching 0/1 adversary mask over those rows (the full ``(M,)``
        vector in the oracle, the cohort gather elsewhere).  ``keys`` are
        the matching :func:`attack_keys` rows, required iff
        :attr:`needs_keys`.  Honest rows (``adv == 0``) pass through
        bit-exactly.
        """
        if self.kind == "gauss" and keys is None:
            raise ValueError("gauss attack requires per-client keys")

        def rows(mask, u):
            return mask.reshape((-1,) + (1,) * (u.ndim - 1))

        if self.kind == "sign_flip":
            s = jnp.asarray(self.strength, jnp.float32)
            return jax.tree.map(
                lambda u: jnp.where(rows(adv, u) > 0, (-s * u).astype(u.dtype),
                                    u), uploads)
        if self.kind == "scale":
            s = jnp.asarray(self.strength, jnp.float32)
            return jax.tree.map(
                lambda u: jnp.where(rows(adv, u) > 0, (s * u).astype(u.dtype),
                                    u), uploads)
        if self.kind == "zero":
            return jax.tree.map(
                lambda u: jnp.where(rows(adv, u) > 0, jnp.zeros_like(u), u),
                uploads)
        if self.kind == "nan":
            return jax.tree.map(
                lambda u: jnp.where(rows(adv, u) > 0,
                                    jnp.full_like(u, jnp.nan), u), uploads)

        # gauss: replace the row with N(0, sigma^2) draws.  Per-leaf
        # fold_in keeps leaves independent; per-row vmap keys keep clients
        # independent AND engine-agnostic (row key == client key).
        sigma = jnp.asarray(self.sigma, jnp.float32)
        leaves, treedef = jax.tree_util.tree_flatten(uploads)
        out = []
        for li, leaf in enumerate(leaves):
            leaf_keys = jax.vmap(lambda k, _li=li: jax.random.fold_in(
                k, _li))(keys)
            noise = jax.vmap(
                lambda k, _shape=leaf.shape[1:], _dt=leaf.dtype:
                jax.random.normal(k, _shape, _dt))(leaf_keys)
            out.append(jnp.where(rows(adv, leaf) > 0,
                                 (sigma * noise).astype(leaf.dtype), leaf))
        return jax.tree_util.tree_unflatten(treedef, out)
