"""Parameter masking (paper §3.2.1 random, §4.2 selective top-k).

Terminology follows the paper: the *masking rate* ``gamma`` is the fraction of
parameters KEPT (Fig. 4: "masking rate 0.1" discards 90%).

Two selection semantics are provided:

* ``selective_mask_exact``    — exact per-leaf top-k via sort (the paper's
  Alg. 4 as written; the jnp oracle).
* ``selective_mask_threshold``— TPU-native threshold-bisection top-k (see
  DESIGN.md §3.1): static shapes, scan/jit/pjit-safe, backed by the Pallas
  kernels in ``repro.kernels`` on TPU and by pure jnp elsewhere.

Both operate on a *delta* pytree (W_{t+1} - W_t per Alg. 4 line 11) and return
the masked delta plus bookkeeping for byte accounting.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = [
    "MaskingConfig",
    "random_mask",
    "selective_mask_exact",
    "selective_mask_threshold",
    "mask_pytree",
]


@dataclasses.dataclass(frozen=True)
class MaskingConfig:
    """gamma: fraction kept; mode: none|random|selective; min_leaf_size:
    leaves smaller than this (biases, norms) are always sent dense — masking a
    10-element bias saves nothing and harms convergence."""

    gamma: float = 1.0
    mode: str = "none"  # none | random | selective
    min_leaf_size: int = 256
    bisect_iters: int = 24
    use_kernel: bool = False  # route through the Pallas kernel path


def _kept_count(size: int, gamma: float) -> int:
    return max(1, int(round(gamma * size)))


def _refine_sweeps_for(iters: int) -> int:
    """Map a bisection iteration budget onto segmented refine sweeps: each
    multi-candidate sweep resolves ~4 bits of threshold vs 1 per bisection
    iter, so the default 24 iters ~ 2 sweeps, 48 iters ~ 4."""
    return max(2, min(4, iters // 12))


def random_mask(key: jax.Array, delta: jax.Array, gamma: float) -> jax.Array:
    """Paper Alg. 2: keep a Bernoulli(gamma) subset of entries.

    The paper's ``randi`` draws a fixed *proportion*; we use an exact-count
    random mask (permutation-based) so the kept fraction is deterministic —
    matters for fair byte accounting at small leaves.
    """
    flat = delta.reshape(-1)
    k = _kept_count(flat.size, gamma)
    scores = jax.random.uniform(key, flat.shape)
    # Single top_k pass (O(n log k)) instead of the double argsort ranking:
    # the k lowest-score positions form an exact-count uniform subset.
    _, idx = jax.lax.top_k(-scores, k)
    keep = jnp.zeros(flat.shape, delta.dtype).at[idx].set(1)
    return (flat * keep).reshape(delta.shape)


def selective_mask_exact(delta: jax.Array, gamma: float) -> jax.Array:
    """Paper Alg. 4: keep the k = gamma*|W| entries of largest |delta|.

    Exact semantics via full sort; O(n log n) — the reference/oracle path.
    """
    flat = delta.reshape(-1)
    k = _kept_count(flat.size, gamma)
    mag = jnp.abs(flat)
    # kth largest magnitude; keep strictly-greater plus enough ties.
    thresh = jnp.sort(mag)[flat.size - k]
    keep = mag >= thresh
    # Tie handling: if ties push the kept count above k, drop surplus ties by
    # index order to keep exactly k (matches a stable top-k).
    surplus = jnp.cumsum(keep) > k
    keep = keep & ~surplus
    return (flat * keep.astype(delta.dtype)).reshape(delta.shape)


def threshold_for_topk(mag: jax.Array, k: jax.Array, iters: int = 24) -> jax.Array:
    """Find tau such that count(mag >= tau) ≈ k by bisection.

    Pure element-wise compares + reductions (VPU friendly, static shapes).
    Accuracy: after ``iters`` halvings of [0, max], the kept count is within
    the number of entries falling in one 2^-iters-wide magnitude bin —
    property-tested against the sort oracle in tests/test_masking.py.
    """
    mag = mag.reshape(-1).astype(jnp.float32)
    hi = jnp.max(mag) + 1e-12
    lo = jnp.zeros_like(hi)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        count = jnp.sum(mag >= mid)
        # too many kept -> raise threshold (lo = mid); too few -> lower hi.
        lo = jnp.where(count > k, mid, lo)
        hi = jnp.where(count > k, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return hi  # hi always satisfies count(mag >= hi) <= k (conservative)


def selective_mask_threshold(delta: jax.Array, gamma: float,
                             iters: int = 24,
                             use_kernel: bool = False) -> jax.Array:
    """TPU-native selective masking: threshold-bisection top-k (DESIGN.md §3.1).

    When ``use_kernel`` is set, the array is routed through the segmented
    Pallas path (``ops.topk_mask_pytree`` on a single-leaf tree, DESIGN.md
    §3.4): interpret mode on CPU, compiled on TPU.  ``iters`` maps onto the
    number of multi-candidate refine sweeps (each sweep resolves ~4 bits of
    threshold, vs 1 bit per bisection iter), so higher ``iters`` still buys
    tighter thresholds on the kernel path.
    """
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.topk_mask_pytree(delta, gamma, min_leaf_size=0,
                                     refine_sweeps=_refine_sweeps_for(iters))
    flat = delta.reshape(-1)
    k = jnp.asarray(_kept_count(flat.size, gamma), jnp.int32)
    tau = threshold_for_topk(jnp.abs(flat), k, iters)
    keep = (jnp.abs(flat) >= tau).astype(delta.dtype)
    return (flat * keep).reshape(delta.shape)


def mask_pytree(key: jax.Array, delta: PyTree, cfg: MaskingConfig) -> PyTree:
    """Apply the configured masking to a delta pytree (Alg. 2/4 layer loop).

    Small leaves (< cfg.min_leaf_size) pass through dense.  Returns the masked
    delta pytree with the same structure/dtypes.

    Selective masking with ``cfg.use_kernel`` routes the WHOLE pytree through
    the segmented Pallas subsystem (``ops.topk_mask_pytree``, DESIGN.md
    §3.4): a leaf-count-independent ~4 HBM sweeps instead of the per-leaf
    O(L * iters) pipeline below.
    """
    if cfg.mode == "none" or cfg.gamma >= 1.0:
        return delta

    if cfg.mode == "selective" and cfg.use_kernel:
        from repro.kernels import ops as kops
        return kops.topk_mask_pytree(
            delta, cfg.gamma, min_leaf_size=cfg.min_leaf_size,
            refine_sweeps=_refine_sweeps_for(cfg.bisect_iters))

    leaves, treedef = jax.tree_util.tree_flatten(delta)
    keys = jax.random.split(key, len(leaves))
    out = []
    for leaf, leaf_key in zip(leaves, keys):
        if leaf.size < cfg.min_leaf_size:
            out.append(leaf)
        elif cfg.mode == "random":
            out.append(random_mask(leaf_key, leaf, cfg.gamma))
        elif cfg.mode == "selective":
            # use_kernel was handled by the whole-pytree route above; this
            # per-leaf loop is always the pure-jnp path.
            out.append(selective_mask_threshold(
                leaf, cfg.gamma, cfg.bisect_iters))
        else:
            raise ValueError(f"unknown masking mode {cfg.mode!r}")
    return jax.tree_util.tree_unflatten(treedef, out)
