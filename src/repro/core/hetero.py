"""Heterogeneous-client round simulator (DESIGN.md §5).

The paper's simulation treats every client as identical hardware on an
ideal network, so round wall-clock is just device execution time.  Real
federated fleets are nothing like that: client compute speeds span an order
of magnitude, uplinks are slow and high-latency, and a fraction of uploads
never arrives.  This module models that axis:

* :class:`HeteroModel` — a named profile (``ideal`` / ``mobile`` /
  ``flaky-mobile``) plus a seed; draws static per-client traits.
* :class:`ClientTraits` — the drawn per-client hardware/network vectors
  (compute FLOP/s, round-trip latency, uplink bits/s, upload drop rate).
* :func:`simulate_round` — given who participated / whose upload arrived
  and the per-client compute + wire-byte cost, the simulated round
  wall-clock (the straggler max), its straggler tail, and the dropped count.
* :func:`arrival_stream` — the per-round *arrival-time stream*: the same
  completion-time model as :func:`simulate_round`, but emitted as a
  time-ordered event sequence ``(arrival_s, client_id)`` the asynchronous
  buffered-aggregation engine (``repro.core.async_engine``) consumes
  instead of a round barrier.

Split of responsibilities: the *drop draws* run INSIDE the round program
(they change the aggregation and error-feedback gating, so both execution
engines must see identical draws — ``HeteroModel.drop_rates`` is closed
over by the round builders in ``repro.core.federated``), while the *clock*
is pure host-side metering here, fed by the participation masks the round
returns (``FederatedServer`` records ``sim_round_s`` / ``dropped`` per
round next to the measured ``wall_s``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

__all__ = ["ClientTraits", "HeteroModel", "simulate_round", "profile_names",
           "arrival_stream", "MAX_DROP_RATE"]

# Upload-loss probabilities are clamped here.  Horvitz-Thompson weights
# divide by the survival probability ``1 - q`` (``_apply_dropout`` in
# ``repro.core.federated``), so an unclamped q -> 1 would inflate a single
# client's weight without bound; at q <= 0.5 the inflation factor is <= 2.
# A fleet losing more than half its uploads is an outage, not a profile.
MAX_DROP_RATE = 0.5


@dataclasses.dataclass(frozen=True)
class ClientTraits:
    """Static per-client hardware/network draws (host-side numpy).

    ``flops_per_s`` — sustained client compute throughput; ``latency_s`` —
    fixed per-round overhead (connection + scheduling RTTs); ``uplink_bps``
    — upload bandwidth in bits/s; ``drop_rate`` — probability a finished
    upload is lost before the server sees it.
    """

    flops_per_s: np.ndarray
    latency_s: np.ndarray
    uplink_bps: np.ndarray
    drop_rate: np.ndarray

    def client_time_s(self, flops: float, upload_bytes: int) -> np.ndarray:
        """Per-client completion time for one round of ``flops`` local work
        followed by an ``upload_bytes`` upload."""
        return (self.latency_s + flops / self.flops_per_s
                + 8.0 * upload_bytes / self.uplink_bps)

    def upload_time_s(self, upload_bytes: int) -> np.ndarray:
        """Per-client wire time to (re)send an ``upload_bytes`` payload —
        the marginal cost of a retry, which resends cached bytes without
        recomputing the local update."""
        return 8.0 * upload_bytes / self.uplink_bps

    def arrival_times_s(self, flops: float, upload_bytes: int,
                        rng: np.random.Generator | None = None,
                        jitter_sigma: float = 0.0) -> np.ndarray:
        """Per-client first-attempt arrival times for one round.

        The static :meth:`client_time_s` base, optionally multiplied by a
        per-round lognormal jitter draw (``jitter_sigma > 0`` needs
        ``rng``) so repeated rounds do not always see the same straggler.
        """
        base = np.asarray(self.client_time_s(flops, upload_bytes),
                          np.float64)
        if jitter_sigma > 0.0:
            if rng is None:
                raise ValueError("jitter_sigma > 0 requires an rng")
            base = base * np.exp(rng.normal(0.0, jitter_sigma, base.shape))
        return base


# Named profiles: (median, lognormal sigma) per trait + drop rate.  Medians
# are deliberately round "systems" numbers, not measurements — the point is
# realistic *spread* (stragglers, slow uplinks), not calibration.
_PROFILES: Dict[str, Dict[str, tuple]] = {
    # every client identical, infinite-speed network, nothing dropped
    "ideal": {"flops": (1e10, 0.0), "latency": (0.0, 0.0),
              "uplink": (1e12, 0.0), "drop": 0.0},
    # phones: ~2 GFLOP/s median spread over ~an order of magnitude,
    # 100 ms overheads, ~8 Mbit/s uplinks, 5% of uploads lost
    "mobile": {"flops": (2e9, 0.6), "latency": (0.1, 0.5),
               "uplink": (8e6, 0.8), "drop": 0.05},
    # same fleet on a bad day: every fifth upload lost
    "flaky-mobile": {"flops": (2e9, 0.6), "latency": (0.1, 0.5),
                     "uplink": (8e6, 0.8), "drop": 0.2},
}


def profile_names() -> tuple:
    """Names accepted by :class:`HeteroModel` (sorted)."""
    return tuple(sorted(_PROFILES))


@dataclasses.dataclass(frozen=True)
class HeteroModel:
    """A named heterogeneity profile: which fleet the simulation runs on.

    ``dropout`` overrides the profile's upload-loss rate when set (the
    ``hetero-dropout`` strategy preset uses the profile default); whatever
    the source, the effective per-client rate is clamped at
    :data:`MAX_DROP_RATE` so debiasing weights stay bounded.  Draws are
    deterministic in ``(profile, seed, num_clients)`` so both execution
    engines and repeated runs see the same fleet.
    """

    profile: str = "mobile"
    seed: int = 0
    dropout: float | None = None

    def __post_init__(self):
        """Validate the profile name and dropout override."""
        if self.profile not in _PROFILES:
            raise ValueError(
                f"unknown hetero profile {self.profile!r}; known: "
                f"{', '.join(profile_names())}")
        if self.dropout is not None and not 0.0 <= self.dropout < 1.0:
            raise ValueError(f"dropout must be in [0, 1), got {self.dropout}")

    def client_traits(self, num_clients: int) -> ClientTraits:
        """Draw the static per-client trait vectors for this fleet."""
        spec = _PROFILES[self.profile]
        rng = np.random.default_rng((self.seed, num_clients, 0xFED))

        def lognormal(median, sigma):
            if sigma == 0.0:
                return np.full((num_clients,), median, np.float64)
            return median * np.exp(rng.normal(0.0, sigma, (num_clients,)))

        drop = self.dropout if self.dropout is not None else spec["drop"]
        # Clamp at MAX_DROP_RATE so the Horvitz-Thompson 1/(1-q) dropout
        # correction stays bounded (<= 2x) however lossy the override.
        drop = min(float(drop), MAX_DROP_RATE)
        return ClientTraits(
            flops_per_s=lognormal(*spec["flops"]),
            latency_s=lognormal(*spec["latency"]),
            uplink_bps=lognormal(*spec["uplink"]),
            drop_rate=np.full((num_clients,), drop, np.float64),
        )

    def drop_rates(self, num_clients: int) -> np.ndarray:
        """Per-client upload-loss probabilities — the only trait the round
        program itself consumes (the drop draw changes aggregation)."""
        return self.client_traits(num_clients).drop_rate


def simulate_round(traits: ClientTraits, part: np.ndarray,
                   arrived: np.ndarray, flops: float,
                   upload_bytes: int) -> Dict[str, float]:
    """Meter one round on the simulated fleet.

    ``part`` / ``arrived`` are the round's 0/1 masks over all registered
    clients (who computed+uploaded, whose upload the server received).  The
    server waits for every upload it receives, so the simulated round
    wall-clock is the max completion time over *arrived* clients — the
    straggler — and ``straggler_s`` is how far that max sits above the
    median arrival (the tail the cohort engine cannot hide).  Dropped
    uploads cost their clients the work but the server nothing extra under
    this model (loss is detected asynchronously).
    """
    part = np.asarray(part, bool)
    arrived = np.asarray(arrived, bool)
    times = np.asarray(traits.client_time_s(flops, upload_bytes))
    at = times[arrived]
    round_s = float(at.max()) if at.size else 0.0
    median_s = float(np.median(at)) if at.size else 0.0
    return {
        "sim_round_s": round_s,
        "straggler_s": round_s - median_s,
        "dropped": int(part.sum() - arrived.sum()),
    }


def arrival_stream(traits: ClientTraits, part: np.ndarray, flops: float,
                   upload_bytes: int, rng: np.random.Generator | None = None,
                   jitter_sigma: float = 0.0):
    """Yield this round's upload arrivals as time-ordered events.

    ``part`` is the 0/1 participation mask over all registered clients;
    each participant's first transmission completes at its
    :meth:`ClientTraits.arrival_times_s` draw.  Yields ``(arrival_s,
    client_id)`` sorted by ``(time, client id)`` — the deterministic tie
    break matters on the ``ideal`` fleet, where every arrival lands on the
    same instant.  Retries, drops and deadlines are the *consumer's* story
    (``repro.core.async_engine``); this is only the fault-free first-attempt
    stream the failure model perturbs.
    """
    times = traits.arrival_times_s(flops, upload_bytes, rng, jitter_sigma)
    ids = np.flatnonzero(np.asarray(part) > 0)
    for t_s, cid in sorted(zip(times[ids].tolist(), ids.tolist())):
        yield float(t_s), int(cid)
