"""Local objectives: what each client minimizes besides the task loss.

Under non-IID fleets the paper's two levers (dynamic sampling, selective
masking) cut bytes-per-round but *client drift* degrades bytes-to-target-
loss: each client's local optimum pulls Θ away from the population optimum,
so sparse rounds buy less progress.  This module adds the standard drift
corrections as a third strategy axis, ``FedStrategy.objective``:

* ``none``    — plain FedAvg local loss, **bit-identical to the historical
  path**: an inactive objective returns the caller's ``loss_fn`` object
  itself, so the traced program is literally unchanged (no ``+ 0·x`` term
  that could flip signed zeros through autodiff).
* ``prox(mu)`` — FedProx (Li et al.): local loss ``L(w) + (mu/2)·‖w − Θ_t‖²``.
  Stateless; pulls every local trajectory back toward the round's global
  model.
* ``dyn(alpha)`` — FedDyn (Acar et al.), client-side dynamic regularizer:
  local loss ``L(w) − ⟨h_k, w⟩ + (alpha/2)·‖w − Θ_t‖²`` with a **per-client
  drift vector** ``h_k`` updated after local training as
  ``h_k ← h_k − alpha·(θ_k − Θ_t)``.  The drift state is a second
  O(M × model) per-client array and rides the client-state store
  (``repro.core.client_store``) next to the EF residuals — same slot
  directory, same evict-to-zero semantics (DESIGN.md §12).

Degeneration contract (property-tested in tests/test_equivalence.py):
``prox(0.0)`` and ``dyn(0.0)`` are *inactive* — :meth:`localize` is a
Python-level identity and :attr:`uses_drift` is False, so they produce
bit-identical programs to ``none`` on every engine.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = ["LocalObjective"]


def _sq_dist(params: PyTree, anchor: PyTree) -> jnp.ndarray:
    """‖params − anchor‖² summed over every leaf (float32 accumulate)."""
    return sum(jnp.sum(jnp.square((p - a).astype(jnp.float32)))
               for p, a in zip(jax.tree_util.tree_leaves(params),
                               jax.tree_util.tree_leaves(anchor)))


def _inner(a: PyTree, b: PyTree) -> jnp.ndarray:
    """⟨a, b⟩ summed over every leaf (float32 accumulate)."""
    return sum(jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


@dataclasses.dataclass(frozen=True)
class LocalObjective:
    """The client-side objective axis (see module docstring).

    ``kind`` ∈ {"none", "prox", "dyn"}; ``mu`` is FedProx's proximal
    strength, ``alpha`` FedDyn's regularizer strength.  A zero strength
    makes the objective inactive — statically, at Python level — so the
    μ=0 / α=0 degenerations are bit-identical to ``none``.
    """

    kind: str = "none"          # none | prox | dyn
    mu: float = 0.0             # FedProx proximal strength
    alpha: float = 0.0          # FedDyn regularizer strength

    def __post_init__(self):
        if self.kind not in ("none", "prox", "dyn"):
            raise ValueError(f"unknown objective kind {self.kind!r}")
        if self.mu < 0.0:
            raise ValueError(f"mu must be >= 0, got {self.mu}")
        if self.alpha < 0.0:
            raise ValueError(f"alpha must be >= 0, got {self.alpha}")

    # ---- constructors ----------------------------------------------------
    @classmethod
    def none(cls) -> "LocalObjective":
        """Plain FedAvg local loss (the default)."""
        return cls()

    @classmethod
    def prox(cls, mu: float) -> "LocalObjective":
        """FedProx: ``L(w) + (mu/2)·‖w − Θ_t‖²``."""
        return cls(kind="prox", mu=mu)

    @classmethod
    def dyn(cls, alpha: float) -> "LocalObjective":
        """FedDyn (client-side): ``L(w) − ⟨h_k, w⟩ + (alpha/2)·‖w − Θ_t‖²``
        with per-client drift state ``h_k ← h_k − alpha·delta_k``."""
        return cls(kind="dyn", alpha=alpha)

    # ---- static properties ----------------------------------------------
    @property
    def active(self) -> bool:
        """True when the objective changes the local loss at all.  A zero
        strength is *inactive*: the degeneration contract requires the
        unmodified loss object, not a ``+ 0·x`` term."""
        if self.kind == "prox":
            return self.mu > 0.0
        if self.kind == "dyn":
            return self.alpha > 0.0
        return False

    @property
    def uses_drift(self) -> bool:
        """True when the objective carries per-client drift state the
        engines must thread (and the store must hold)."""
        return self.kind == "dyn" and self.alpha > 0.0

    # ---- the math --------------------------------------------------------
    def localize(self, loss_fn: Callable, global_params: PyTree,
                 drift: Optional[PyTree] = None) -> Callable:
        """The loss the client actually minimizes this round.

        Inactive objectives return ``loss_fn`` ITSELF (the same Python
        object), so the traced program is bit-identical to the plain path.
        ``drift`` is the client's ``h_k`` tree (required iff
        :attr:`uses_drift`).
        """
        if not self.active:
            return loss_fn
        if self.kind == "prox":
            mu = self.mu

            def prox_loss(params, batch):
                return (loss_fn(params, batch)
                        + 0.5 * mu * _sq_dist(params, global_params))

            return prox_loss

        if drift is None:
            raise ValueError(
                "dyn objective requires the client's drift state; thread "
                "it through stacked_client_update(stacked_drift=...)")
        alpha = self.alpha

        def dyn_loss(params, batch):
            return (loss_fn(params, batch)
                    - _inner(drift, params)
                    + 0.5 * alpha * _sq_dist(params, global_params))

        return dyn_loss

    def update_drift(self, drift: Optional[PyTree],
                     delta: PyTree) -> Optional[PyTree]:
        """Post-round drift update ``h ← h − alpha·delta`` where ``delta``
        is the client's HONEST pre-mask local delta (``θ_k − Θ_t``).
        Returns None when the objective carries no drift."""
        if not self.uses_drift:
            return None
        alpha = self.alpha
        return jax.tree.map(
            lambda h, d: (h - alpha * d.astype(h.dtype)).astype(h.dtype),
            drift, delta)
