"""Composable federated strategies: sampling × masking × codec × aggregation.

A *scenario* used to be threaded through five call sites as loose kwargs
(``make_federated_round(loss_fn, schedule, masking_cfg, use_kernel, ...)``,
``FederatedServer.__init__`` re-took the same set, ``FedPodConfig``
duplicated it again).  A :class:`FedStrategy` makes the scenario *data*:
one frozen record composing four pluggable policies —

* ``sampling``    — a :class:`repro.core.sampling.SamplingSchedule`
  (static / dynamic c(t));
* ``masking``     — a :class:`MaskPolicy` (none / random / selective top-k,
  jnp-bisection or segmented-Pallas-kernel backend);
* ``codec``       — a :class:`repro.core.codecs.UploadCodec` (identity /
  sparse COO / int8 / chained), the REAL encode → wire → decode transform
  the round applies to every client upload, with exact ``wire_bytes()``;
* ``aggregator``  — an :class:`Aggregator` (weighted fedavg now; clipped
  fedavg as the first registry alternative, trimmed-mean et al. slot in
  the same way);
* ``sampler``     — a :class:`repro.core.sampling.ClientSampler` picking
  WHICH m_t clients (uniform / importance / threshold) with unbiased
  aggregation weights (DESIGN.md §5);
* ``hetero``      — an optional :class:`repro.core.hetero.HeteroModel`
  putting the round on a heterogeneous simulated fleet (per-client
  compute/latency/bandwidth/dropout; DESIGN.md §5);
* ``async_cfg``   — an optional :class:`repro.core.async_engine.AsyncConfig`
  switching the server to FedBuff-style asynchronous buffered aggregation
  with a failure model (deadlines, retry/backoff, upload quarantine;
  DESIGN.md §8) when it runs with ``engine="async"``;
* ``attack``      — an optional :class:`repro.core.attacks.AttackModel`
  making a fixed fraction of the fleet Byzantine: adversary uploads are
  perturbed at the decode boundary of every engine (DESIGN.md §9), the
  scenario the robust aggregators in ``repro.core.robust`` are built for.

plus the client-side hyperparameters (local epochs, lr, momentum, upload
semantics, error feedback).  ``build_round`` turns a strategy into the
oracle / cohort / scan round program; ``FederatedServer.from_strategy``
runs it end-to-end.  The string registry (``register`` / ``get``) holds the
paper presets — ``"fig3"``, ``"fig4"``, ``"fig5"``, ``"dense-baseline"``
(plus ``"fig5-int8"`` for the chained wire, ``"fig3-importance"`` for
norm-adaptive selection, and ``"hetero-dropout"`` for the flaky-fleet
scenario) — so a new scenario is a registry entry, not a plumbing change.

Every preset preserves the cohort-vs-oracle bit-exactness guarantee of
DESIGN.md §3.5 (property-tested per preset in tests/test_strategy.py): the
codec round-trip is deterministic per upload, so running only the sampled
cohort still reproduces the full-population oracle to the bit.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.async_engine import AsyncConfig
from repro.core.attacks import AttackModel
from repro.core.client import ClientConfig
from repro.core.codecs import (BitmapCodec, ChainCodec, FusedSparseCodec,
                               IdentityCodec, Int8Codec, SparseCodec,
                               UploadCodec)
from repro.core.federated import (FederatedConfig, fedavg_aggregate,
                                  make_cohort_round, make_cohort_scan,
                                  make_federated_round, make_store_round)
from repro.core.hetero import HeteroModel
from repro.core.masking import MaskingConfig
from repro.core.objectives import LocalObjective
from repro.core.sampling import (ClientSampler, DynamicSampling,
                                 ImportanceSampler, SamplingSchedule,
                                 StaticSampling, UniformSampler)

PyTree = Any

__all__ = [
    "MaskPolicy",
    "Aggregator",
    "FEDAVG",
    "clipped_fedavg",
    "get_aggregator",
    "aggregator_names",
    "FedStrategy",
    "default_codec",
    "build_round",
    "register",
    "get",
    "names",
]


# ---------------------------------------------------------------------------
# mask policy
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MaskPolicy:
    """Which entries of the client delta survive the upload.

    ``backend`` selects the selective-top-k implementation: ``"jnp"`` is
    the pure threshold-bisection path (DESIGN.md §3.1), ``"kernel"`` routes
    the whole pytree through the segmented Pallas subsystem (§3.4).
    """

    mode: str = "none"          # none | random | selective
    gamma: float = 1.0          # fraction KEPT (paper's masking rate)
    backend: str = "jnp"        # jnp | kernel
    min_leaf_size: int = 256
    bisect_iters: int = 24

    def __post_init__(self):
        if self.mode not in ("none", "random", "selective"):
            raise ValueError(f"unknown masking mode {self.mode!r}")
        if self.backend not in ("jnp", "kernel"):
            raise ValueError(f"unknown masking backend {self.backend!r}")
        if not 0.0 < self.gamma <= 1.0:
            raise ValueError(f"gamma must be in (0, 1], got {self.gamma}")

    @classmethod
    def none(cls) -> "MaskPolicy":
        """Dense uploads: every delta entry survives."""
        return cls()

    @classmethod
    def random(cls, gamma: float, **kw) -> "MaskPolicy":
        """Keep a random ``gamma`` fraction of each maskable leaf."""
        return cls(mode="random", gamma=gamma, **kw)

    @classmethod
    def selective(cls, gamma: float, backend: str = "jnp", **kw) -> "MaskPolicy":
        """Keep the top-``gamma`` fraction by magnitude (paper Alg. 4)."""
        return cls(mode="selective", gamma=gamma, backend=backend, **kw)

    @classmethod
    def from_masking_config(cls, cfg: MaskingConfig) -> "MaskPolicy":
        """Lift a legacy :class:`MaskingConfig` into a policy record."""
        return cls(mode=cfg.mode, gamma=cfg.gamma,
                   backend="kernel" if cfg.use_kernel else "jnp",
                   min_leaf_size=cfg.min_leaf_size,
                   bisect_iters=cfg.bisect_iters)

    def masking_config(self) -> MaskingConfig:
        """Lower the policy back to the client-side :class:`MaskingConfig`."""
        return MaskingConfig(gamma=self.gamma, mode=self.mode,
                             min_leaf_size=self.min_leaf_size,
                             bisect_iters=self.bisect_iters,
                             use_kernel=self.backend == "kernel")


# ---------------------------------------------------------------------------
# aggregators
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Aggregator:
    """Server-side combination rule over stacked client uploads.

    ``fn(global_params, uploads, weights, upload_semantics, normalize=True)
    -> params`` with a leading client axis on every ``uploads`` leaf.
    ``normalize=False`` means the sampler already folded its inverse
    selection probabilities into ``weights`` (Horvitz-Thompson), so the fn
    must use them as-is rather than re-normalizing to sum 1.  Legacy fns
    without the ``normalize`` parameter still work under self-normalizing
    samplers; pairing one with a Horvitz-Thompson sampler raises a
    ``TypeError`` at round-build time.  Must treat zero-weight rows as
    absent (the cohort/oracle equivalence relies on the oracle's extra
    zero-weight clients being no-ops).

    ``ht_compatible=False`` declares the rule unable to honour HT weights
    at all (Krum-family: candidate selection ignores weight magnitudes);
    building a round that pairs such an aggregator with an HT sampler
    raises a ``TypeError`` (``repro.core.federated._resolve_policies``).
    """

    name: str
    fn: Callable[..., PyTree]
    ht_compatible: bool = True


FEDAVG = Aggregator("fedavg", fedavg_aggregate)


def clipped_fedavg(max_norm: float) -> Aggregator:
    """FedAvg over per-client norm-clipped uploads (robustness knob).

    Zero uploads stay zero after clipping, so the cohort-vs-oracle
    bit-exactness guarantee survives: the oracle's zero-weight rows clip to
    themselves and then drop out of the weighted sum exactly as before.
    """
    if max_norm <= 0.0:
        raise ValueError(
            f"clipped_fedavg: max_norm must be > 0, got {max_norm}")

    def agg(global_params, uploads, weights, upload_semantics,
            normalize=True):
        sq = sum(jnp.sum(jnp.square(u), axis=tuple(range(1, u.ndim)))
                 for u in jax.tree_util.tree_leaves(uploads))
        norm = jnp.sqrt(sq)
        factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
        clipped = jax.tree_util.tree_map(
            lambda u: u * factor.reshape((-1,) + (1,) * (u.ndim - 1)),
            uploads)
        return fedavg_aggregate(global_params, clipped, weights,
                                upload_semantics, normalize=normalize)

    return Aggregator(f"clipped_fedavg({max_norm})", agg)


# Imported AFTER Aggregator is defined: robust.py builds Aggregator
# records lazily via this module, so the import must not run at the top.
from repro.core import robust as _robust  # noqa: E402

_AGGREGATORS: Dict[str, Callable[..., Aggregator]] = {
    "fedavg": lambda: FEDAVG,
    "clipped_fedavg": clipped_fedavg,
    "coordinate_median": _robust.coordinate_median,
    "trimmed_mean": _robust.trimmed_mean,
    "krum": _robust.krum,
    "multi_krum": _robust.multi_krum,
    "norm_filter": _robust.norm_filter,
}


def get_aggregator(name: str, *args, **kwargs) -> Aggregator:
    """Build a registered aggregator by factory name (knobs as args:
    ``get_aggregator("trimmed_mean", 0.2)``)."""
    try:
        factory = _AGGREGATORS[name]
    except KeyError:
        raise KeyError(
            f"unknown aggregator {name!r}; registered: "
            f"{', '.join(aggregator_names())}") from None
    return factory(*args, **kwargs)


def aggregator_names() -> Tuple[str, ...]:
    """Sorted factory names accepted by :func:`get_aggregator`."""
    return tuple(sorted(_AGGREGATORS))


# ---------------------------------------------------------------------------
# the strategy record
# ---------------------------------------------------------------------------
def default_codec(masking: MaskPolicy, quantized: bool = False,
                  backend: str = "jnp", wire: str = "coo") -> UploadCodec:
    """The wire format a mask policy implies: dense uploads ship identity,
    masked uploads ship sparse COO sized to gamma; ``quantized`` chains
    int8 on the value payload.

    ``backend``/``wire`` select the codec axis (DESIGN.md §10):
    ``backend="jnp"`` picks the reference codecs (``SparseCodec`` for
    ``wire="coo"``, ``BitmapCodec`` for ``wire="bitmap"``, int8 chained on
    top when ``quantized``); ``backend="fused"`` picks the kernel-backed
    :class:`FusedSparseCodec`, which emits the same wire (bytes and decoded
    values) from one fused Pallas sweep.
    """
    if backend not in ("jnp", "fused"):
        raise ValueError(f"unknown codec backend {backend!r}")
    if wire not in ("coo", "bitmap"):
        raise ValueError(f"unknown wire format {wire!r}")
    if masking.mode == "none" or masking.gamma >= 1.0:
        base: UploadCodec = IdentityCodec()
        return ChainCodec((base, Int8Codec())) if quantized else base
    if backend == "fused":
        return FusedSparseCodec(gamma=masking.gamma,
                                min_leaf_size=masking.min_leaf_size,
                                quantized=quantized, wire=wire)
    if wire == "bitmap":
        base = BitmapCodec(gamma=masking.gamma,
                           min_leaf_size=masking.min_leaf_size)
    else:
        base = SparseCodec(gamma=masking.gamma,
                           min_leaf_size=masking.min_leaf_size)
    if quantized:
        return ChainCodec((base, Int8Codec()))
    return base


@dataclasses.dataclass(frozen=True)
class FedStrategy:
    """One federated-learning scenario as data (see module docstring)."""

    name: str
    sampling: SamplingSchedule
    masking: MaskPolicy = MaskPolicy()
    codec: UploadCodec = IdentityCodec()
    aggregator: Aggregator = FEDAVG
    sampler: ClientSampler = UniformSampler()
    hetero: HeteroModel | None = None
    async_cfg: AsyncConfig | None = None
    attack: AttackModel | None = None
    local_epochs: int = 1
    learning_rate: float = 0.05
    momentum: float = 0.0
    upload: str = "delta"       # delta | zero (Alg. 4 literal)
    error_feedback: bool = False
    objective: LocalObjective = LocalObjective()

    # ---- derived configs -------------------------------------------------
    def client_config(self) -> ClientConfig:
        """The per-client hyperparameter record this strategy implies."""
        return ClientConfig(local_epochs=self.local_epochs,
                            learning_rate=self.learning_rate,
                            momentum=self.momentum,
                            masking=self.masking.masking_config(),
                            upload=self.upload,
                            objective=self.objective)

    def federated_config(self, num_clients: int) -> FederatedConfig:
        """The population-level round config for ``num_clients`` clients."""
        return FederatedConfig(num_clients=num_clients,
                               client=self.client_config(),
                               error_feedback=self.error_feedback)

    # ---- functional updates ---------------------------------------------
    def replace(self, **overrides) -> "FedStrategy":
        """Functional field update (frozen-record ``dataclasses.replace``)."""
        return dataclasses.replace(self, **overrides)

    def with_masking(self, masking: MaskPolicy, **overrides) -> "FedStrategy":
        """Replace the mask policy AND re-derive a consistent codec (COO
        slot counts track gamma), preserving int8 chaining and the
        codec backend/wire axis of the current codec.  Pass ``codec=``
        explicitly to opt out."""
        if "codec" not in overrides:
            overrides["codec"] = default_codec(
                masking, quantized=_quantizes(self.codec),
                backend=_codec_backend(self.codec),
                wire=_codec_wire(self.codec))
        return dataclasses.replace(self, masking=masking, **overrides)

    @classmethod
    def from_components(cls, name: str, sampling: SamplingSchedule,
                        masking: MaskingConfig | MaskPolicy | None = None,
                        **overrides) -> "FedStrategy":
        """Build a strategy from the legacy (schedule, MaskingConfig) pair,
        deriving the matching codec — the shim behind the deprecated
        ``FederatedServer`` kwargs path and the benchmark helpers."""
        if masking is None:
            masking = MaskPolicy.none()
        elif isinstance(masking, MaskingConfig):
            masking = MaskPolicy.from_masking_config(masking)
        if "codec" not in overrides:
            overrides["codec"] = default_codec(masking)
        return cls(name=name, sampling=sampling, masking=masking, **overrides)


def _quantizes(codec: UploadCodec) -> bool:
    if isinstance(codec, Int8Codec):
        return True
    if isinstance(codec, FusedSparseCodec):
        return codec.quantized
    if isinstance(codec, ChainCodec):
        return any(_quantizes(s) for s in codec.stages)
    return False


def _codec_backend(codec: UploadCodec) -> str:
    """The ``default_codec`` backend axis a codec sits on."""
    if isinstance(codec, FusedSparseCodec):
        return "fused"
    if isinstance(codec, ChainCodec):
        if any(_codec_backend(s) == "fused" for s in codec.stages):
            return "fused"
    return "jnp"


def _codec_wire(codec: UploadCodec) -> str:
    """The ``default_codec`` wire axis a codec sits on (coo | bitmap)."""
    if isinstance(codec, BitmapCodec):
        return "bitmap"
    if isinstance(codec, FusedSparseCodec):
        return codec.wire
    if isinstance(codec, ChainCodec):
        if any(_codec_wire(s) == "bitmap" for s in codec.stages):
            return "bitmap"
    return "coo"


# ---------------------------------------------------------------------------
# round construction: one object -> the engine
# ---------------------------------------------------------------------------
def build_round(strategy: FedStrategy, loss_fn: Callable, num_clients: int,
                form: str = "full", cohort_size: int | None = None):
    """Build the round program a strategy describes.

    ``form``: ``"full"`` — the all-clients vmap oracle; ``"cohort"`` — the
    bucketed cohort engine (requires ``cohort_size``); ``"scan"`` — the
    lax.scan-over-rounds fast path (requires ``cohort_size``; a
    ``cohort_size == num_clients`` scan wraps the oracle); ``"store"`` —
    the round split at the client-state-store boundary (requires
    ``cohort_size``; returns a ``repro.core.federated.StoreRound`` whose
    residual gather/scatter run OUTSIDE the program, through a
    ``repro.core.client_store.ClientStateStore``).  The strategy's codec,
    aggregator, client sampler and hetero model are threaded into the
    round body, so every form runs the same math.  When
    ``strategy.sampler.adaptive`` the returned program takes/returns an
    extra ``norms`` state vector after ``residuals`` (see
    ``repro.core.federated.make_federated_round``).
    """
    if form not in ("full", "cohort", "scan", "store"):
        raise ValueError(f"unknown round form {form!r}")
    cfg = strategy.federated_config(num_clients)
    kw = dict(codec=strategy.codec, aggregator=strategy.aggregator,
              sampler=strategy.sampler, hetero=strategy.hetero,
              attack=strategy.attack)
    if form == "full":
        return make_federated_round(loss_fn, strategy.sampling, cfg, **kw)
    if cohort_size is None:
        raise ValueError(f"form={form!r} requires cohort_size")
    if form == "cohort":
        return make_cohort_round(loss_fn, strategy.sampling, cfg,
                                 cohort_size, **kw)
    if form == "store":
        return make_store_round(loss_fn, strategy.sampling, cfg,
                                cohort_size, **kw)
    return make_cohort_scan(loss_fn, strategy.sampling, cfg,
                            cohort_size, **kw)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, FedStrategy] = {}


def register(strategy: FedStrategy, overwrite: bool = False) -> FedStrategy:
    """Add a strategy to the registry under its ``name`` (and return it)."""
    if not overwrite and strategy.name in _REGISTRY:
        raise ValueError(f"strategy {strategy.name!r} already registered")
    _REGISTRY[strategy.name] = strategy
    return strategy


def names() -> Tuple[str, ...]:
    """Sorted names of every registered strategy preset."""
    return tuple(sorted(_REGISTRY))


def get(name: str, **overrides) -> FedStrategy:
    """Fetch a registered strategy, optionally specialized via field
    overrides.  Overriding ``masking`` without an explicit ``codec``
    re-derives the codec so COO slot counts stay consistent with gamma."""
    try:
        base = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown strategy {name!r}; registered: {', '.join(names())}"
        ) from None
    if "masking" in overrides and "codec" not in overrides:
        masking = overrides.pop("masking")
        return base.with_masking(masking, **overrides)
    if overrides:
        return dataclasses.replace(base, **overrides)
    return base


# ---- paper presets --------------------------------------------------------
# "dense-baseline": Alg. 1 — full participation, dense uploads.
register(FedStrategy(
    name="dense-baseline",
    sampling=StaticSampling(initial_rate=1.0, min_clients=2)))

# "fig3": dynamic sampling alone (Alg. 3, beta = 0.1), dense uploads.
register(FedStrategy(
    name="fig3",
    sampling=DynamicSampling(initial_rate=1.0, beta=0.1, min_clients=2)))

# "fig4": selective masking alone (Alg. 4) at the paper's gamma = 0.1,
# sparse COO wire.
register(FedStrategy.from_components(
    "fig4", StaticSampling(initial_rate=1.0, min_clients=2),
    MaskPolicy.selective(0.1)))

# "fig5": both levers combined (Alg. 3 + Alg. 4) at the Fig. 5 operating
# point (beta = 0.1, gamma = 0.5), sparse COO wire.
register(FedStrategy.from_components(
    "fig5", DynamicSampling(initial_rate=1.0, beta=0.1, min_clients=2),
    MaskPolicy.selective(0.5)))

# "fig5-int8": beyond-paper — fig5 with the COO value payload int8-quantised
# (4 -> 1 bytes/kept value on the wire; lossy, error <= scale/2 per entry).
register(get("fig5").with_masking(
    MaskPolicy.selective(0.5),
    codec=ChainCodec((SparseCodec(gamma=0.5), Int8Codec())),
    name="fig5-int8"))

# "fig5-fused": fig5's operating point on the kernel-backed wire path
# (DESIGN.md §10) — the COO payload is emitted by one fused Pallas sweep
# (FusedSparseCodec) instead of the jnp codec's three re-reads; wire bytes
# and decoded values are identical to fig5's, so the cohort==oracle
# bit-exactness discipline extends to the fused backend.
register(get("fig5").replace(
    name="fig5-fused",
    codec=default_codec(MaskPolicy.selective(0.5), backend="fused")))

# "fig5-fused-int8": the fused wire path with int8 values quantised IN the
# same sweep (the scale rides the stats sweep) — byte-identical to
# fig5-int8's ChainCodec((Sparse, Int8)) wire.
register(get("fig5").replace(
    name="fig5-fused-int8",
    codec=default_codec(MaskPolicy.selective(0.5), quantized=True,
                        backend="fused")))

# "fig5-bitmap": fig5 shipped over the 1-bit/coord membership bitmap wire —
# at gamma = 0.5, far above the 1/32 density crossover, bitmap membership
# costs n/8 bytes where COO indices cost 4*k = 2n (DESIGN.md §10).
register(get("fig5").replace(
    name="fig5-bitmap",
    codec=default_codec(MaskPolicy.selective(0.5), wire="bitmap")))

# "fig3-importance": beyond-paper — fig3's dynamic c(t) schedule, but the
# m_t clients are CHOSEN by tracked update-norm importance with unbiased
# Horvitz-Thompson reweighting (Optimal-Client-Sampling style, DESIGN.md
# §5) instead of uniformly.
register(FedStrategy(
    name="fig3-importance",
    sampling=DynamicSampling(initial_rate=1.0, beta=0.1, min_clients=2),
    sampler=ImportanceSampler()))

# "hetero-dropout": beyond-paper — full-participation dense rounds on the
# flaky-mobile fleet: lognormal compute/latency/uplink spread and 20% of
# uploads lost, metered as sim_round_s / dropped in the server records.
register(FedStrategy(
    name="hetero-dropout",
    sampling=StaticSampling(initial_rate=1.0, min_clients=2),
    hetero=HeteroModel(profile="flaky-mobile")))

# "async-mobile": beyond-paper — fig3's dynamic c(t) on the mobile fleet,
# aggregated asynchronously (DESIGN.md §8): flush every K = m_t/2 arrivals
# with the FedBuff staleness discount, cut the round at the 90th arrival
# percentile, retry lost uploads twice with backoff.
register(FedStrategy(
    name="async-mobile",
    sampling=DynamicSampling(initial_rate=1.0, beta=0.1, min_clients=2),
    hetero=HeteroModel(profile="mobile"),
    async_cfg=AsyncConfig(buffer_frac=0.5, staleness_beta=0.5,
                          deadline_quantile=0.9, max_retries=2,
                          backoff_s=0.5, jitter_sigma=0.25)))

# "async-crossround": beyond-paper — async-mobile with a HARSH deadline
# (median arrival) and cross-round staleness (DESIGN.md §11): uploads cut
# at the deadline stay in flight and land in a later round, discounted by
# w/(1+s)^beta where s counts ROUNDS since the client pulled Θ, expiring
# past s = 3.  Requires a ClientStateStore (any backend) for the
# per-client model-version vector.
register(FedStrategy(
    name="async-crossround",
    sampling=DynamicSampling(initial_rate=1.0, beta=0.1, min_clients=2),
    hetero=HeteroModel(profile="mobile"),
    async_cfg=AsyncConfig(buffer_frac=0.5, staleness_beta=0.5,
                          deadline_quantile=0.5, max_retries=2,
                          backoff_s=0.5, jitter_sigma=0.25,
                          max_round_stale=3)))

# "async-flaky": the same async engine on the flaky-mobile fleet with an
# aggressive deadline (75th percentile) and a deeper retry budget — the
# chaos scenario the quarantine/timeout accounting is sized for.
register(FedStrategy(
    name="async-flaky",
    sampling=DynamicSampling(initial_rate=1.0, beta=0.1, min_clients=2),
    hetero=HeteroModel(profile="flaky-mobile"),
    async_cfg=AsyncConfig(buffer_frac=0.5, staleness_beta=0.5,
                          deadline_quantile=0.75, max_retries=3,
                          backoff_s=0.5, jitter_sigma=0.25)))

# ---- local-objective presets (DESIGN.md §12) ------------------------------
# "fig5-prox": fig5's operating point with the FedProx proximal term
# (mu = 0.1): local loss L(w) + (mu/2)·||w − Θ_t||², damping client drift
# under heterogeneous data while leaving the wire path untouched.
register(get("fig5").replace(
    name="fig5-prox",
    objective=LocalObjective.prox(0.1)))

# "fig5-dyn": fig5 under FedDyn (alpha = 0.1): local loss
# L(w) − ⟨h_k, w⟩ + (alpha/2)·||w − Θ_t||² with the per-client drift
# vector h_k ← h_k − alpha·delta living in the client-state store
# (extra tree "drift"; DESIGN.md §12), updated on the HONEST pre-mask
# delta so masking never corrupts the drift dynamics.
register(get("fig5").replace(
    name="fig5-dyn",
    objective=LocalObjective.dyn(0.1)))

# "noniid-dyn": the non-IID flagship — fig5-dyn with importance-sampled
# client selection (norm-tracked, Horvitz-Thompson reweighted), the
# operating point benchmarks/noniid.py sweeps over Dirichlet partitions.
register(get("fig5-dyn").replace(
    name="noniid-dyn",
    sampler=ImportanceSampler()))

# ---- Byzantine-robustness presets (DESIGN.md §9) --------------------------
# All three run fig5's sparse operating point (beta = 0.1, gamma = 0.5, COO
# wire) with a deeper sampling floor: min_clients = 5 keeps every cohort an
# honest majority at f = 0.3 (late rounds of min_clients = 2 would hand a
# 30% fleet a coin-flip cohort majority, and Krum needs n >= f + 3
# candidates to score neighbours at all).
_ROBUST_SAMPLING = DynamicSampling(initial_rate=1.0, beta=0.1, min_clients=5)
# Amplified sign-flip: at strength = 4 and f = 0.3 the FedAvg mean is
# 0.7·u - 1.2·u = -0.5·u — an ascent direction, so plain averaging
# demonstrably diverges while the robust rules hold (benchmarks/robust_agg).
_SIGNFLIP = AttackModel(kind="sign_flip", fraction=0.3, strength=4.0)

# "byzantine-signflip": the attacked baseline — fig5 sparse uploads, 30%
# amplified sign-flip adversaries, PLAIN fedavg.  The control every robust
# preset is measured against.
register(get("fig5").replace(
    name="byzantine-signflip",
    sampling=_ROBUST_SAMPLING,
    attack=_SIGNFLIP))

# "robust-median": the same attacked fleet aggregated by the coordinate-wise
# weighted median (breakdown point 1/2 — f = 0.3 sign-flip cannot move it).
register(get("byzantine-signflip").replace(
    name="robust-median",
    aggregator=_robust.coordinate_median()))

# "robust-krum": the same attacked fleet under multi-Krum (f = 2 suspected
# Byzantine rows, average the m = 2 most central candidates) — the
# whole-vector geometric defence, immune to the median's per-coordinate
# sparse-support caveat (§9.4).
register(get("byzantine-signflip").replace(
    name="robust-krum",
    aggregator=_robust.multi_krum(f=2, m=2)))
