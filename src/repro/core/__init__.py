"""Paper core: dynamic sampling + selective masking for federated learning.

The composable surface is ``repro.core.strategy``: a ``FedStrategy`` record
(sampling × masking × codec × aggregation) plus a string registry of
presets — ``strategy.get("fig5")`` — consumed by
``FederatedServer.from_strategy`` / ``strategy.build_round``.
"""

from repro.core.sampling import (
    StaticSampling, DynamicSampling, SamplingSchedule,
    participation_mask, sample_clients, transport_cost,
    ClientSampler, UniformSampler, ImportanceSampler, ThresholdSampler,
    transmit_probabilities, get_sampler,
)
from repro.core.hetero import (
    ClientTraits, HeteroModel, simulate_round, profile_names,
)
from repro.core.attacks import AttackModel, attack_kinds
from repro.core.masking import (
    MaskingConfig, random_mask, selective_mask_exact,
    selective_mask_threshold, mask_pytree,
)
from repro.core.objectives import LocalObjective
from repro.core.client import (
    ClientConfig, client_update, local_sgd, stacked_client_update,
    local_update_flops,
)
from repro.core.federated import (
    FederatedConfig, make_federated_round, make_cohort_round,
    make_cohort_scan, cohort_select, fedavg_aggregate,
    make_store_selection, make_store_compute, make_store_round, StoreRound,
)
from repro.core.client_store import (
    ClientStateStore, DenseStore, ShardedStore, make_store,
)
from repro.core.server import FederatedServer, RoundRecord
from repro.core.compression import (
    payload_bytes, pytree_payload_bytes, encode_sparse, decode_sparse,
    quantize_int8, dequantize_int8,
)
from repro.core.codecs import (
    UploadCodec, IdentityCodec, SparseCodec, Int8Codec, ChainCodec,
)
from repro.core import strategy
from repro.core.strategy import (
    FedStrategy, MaskPolicy, Aggregator, build_round, get_aggregator,
)
from repro.core.robust import (
    coordinate_median, trimmed_mean, krum, multi_krum, norm_filter,
)
