"""Client sampling: how MANY clients per round, and WHICH ones.

Two orthogonal axes (DESIGN.md §5):

* :class:`SamplingSchedule` — the paper's axis: the participation *fraction*
  ``c(t)``.  Dynamic sampling anneals ``c(t) = C * exp(-beta * t)`` (Eq. 3),
  floored so at least ``min_clients`` clients participate; static sampling
  is the ``beta = 0`` special case but is kept as its own class because it
  is the paper's baseline (Alg. 1).
* :class:`ClientSampler` — beyond-paper: *which* ``m_t`` clients, chosen by
  tracked update importance (Chen & Horváth, *Optimal Client Sampling*;
  Ribero & Vikalo, threshold transmission), with aggregation weights that
  keep the weighted FedAvg *unbiased* (property-tested in
  ``tests/test_sampling.py``).  ``UniformSampler`` is the default and is
  bit-identical to the schedule-only path.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SamplingSchedule",
    "StaticSampling",
    "DynamicSampling",
    "sample_clients",
    "participation_mask",
    "transport_cost",
    "ClientSampler",
    "UniformSampler",
    "ImportanceSampler",
    "ThresholdSampler",
    "transmit_probabilities",
    "get_sampler",
]


@dataclasses.dataclass(frozen=True)
class SamplingSchedule:
    """Base schedule: fraction of the M registered clients used at round t."""

    initial_rate: float = 1.0
    min_clients: int = 2

    def rate(self, t) -> jnp.ndarray:
        """Participation fraction c(t) at round ``t`` (traced-friendly)."""
        raise NotImplementedError

    def num_clients(self, t, num_registered: int) -> jnp.ndarray:
        """m_t = max(round(c_t * M), min_clients), capped at M (Alg. 3 line 9)."""
        m = jnp.round(self.rate(t) * num_registered).astype(jnp.int32)
        floor = min(self.min_clients, num_registered)
        return jnp.clip(m, floor, num_registered)

    # ---- cohort bucketing (DESIGN.md §3.5) ------------------------------
    # m_t is a pure function of t, so the cohort buffer size for every round
    # is host-computable before dispatch.  Buffer sizes are drawn from a
    # small static ladder so the number of distinct compiled round programs
    # stays O(log M) as c(t) anneals, instead of one per distinct m_t.

    def num_clients_host(self, t: int, num_registered: int) -> int:
        """Python-int m_t for host-side bucket selection (no tracing)."""
        rate = float(np.asarray(self.rate(np.float32(t))))
        m = int(round(rate * num_registered))
        floor = min(self.min_clients, num_registered)
        return max(min(m, num_registered), floor)

    def bucket_ladder(self, num_registered: int) -> tuple:
        """Static set of cohort buffer sizes: powers of two >= min_clients,
        capped at (and always including) M = num_registered."""
        floor = max(1, min(self.min_clients, num_registered))
        b = 1
        while b < floor:
            b *= 2
        ladder = []
        while b < num_registered:
            ladder.append(b)
            b *= 2
        ladder.append(num_registered)
        return tuple(ladder)

    def bucket_for(self, m: int, num_registered: int) -> int:
        """Smallest ladder bucket that fits an m-client cohort."""
        for b in self.bucket_ladder(num_registered):
            if b >= m:
                return b
        return num_registered

    def round_buckets(self, rounds: int, num_registered: int,
                      start: int = 0) -> list:
        """Per-round (m_t, bucket) for t = start+1..start+rounds — the
        server's dispatch plan: consecutive equal buckets can share one
        compiled program and be folded into a single lax.scan segment.
        ``start`` offsets the plan for runs resumed from a checkpointed
        round counter (m_t is a pure function of the absolute t)."""
        out = []
        for t in range(start + 1, start + rounds + 1):
            m = self.num_clients_host(t, num_registered)
            out.append((m, self.bucket_for(m, num_registered)))
        return out


@dataclasses.dataclass(frozen=True)
class StaticSampling(SamplingSchedule):
    """Alg. 1: constant sampling fraction C."""

    def rate(self, t) -> jnp.ndarray:
        """Constant participation fraction C, independent of t."""
        return jnp.full_like(jnp.asarray(t, jnp.float32), self.initial_rate)


@dataclasses.dataclass(frozen=True)
class DynamicSampling(SamplingSchedule):
    """Alg. 3: c(t) = C * exp(-beta * t)  (Eq. 3)."""

    beta: float = 0.1

    def rate(self, t) -> jnp.ndarray:
        """Exponentially annealed participation fraction (Eq. 3)."""
        t = jnp.asarray(t, jnp.float32)
        return self.initial_rate * jnp.exp(-self.beta * t)


def sample_clients(key: jax.Array, schedule: SamplingSchedule, t: int,
                   num_registered: int) -> jax.Array:
    """Return the int32 ids of the clients participating in round ``t``.

    Uses a uniform random permutation — the paper accepts "the first m ACKs",
    which for simulation purposes is an unbiased random subset.
    Static-shape friendly only for fixed m; prefer :func:`participation_mask`
    inside jitted code.
    """
    m = int(schedule.num_clients(t, num_registered))
    perm = jax.random.permutation(key, num_registered)
    return perm[:m]


def participation_mask(key: jax.Array, schedule: SamplingSchedule, t,
                       num_registered: int) -> jax.Array:
    """0/1 float mask of shape (num_registered,) with exactly m_t ones.

    jit/scan-safe (static output shape): rank a random permutation and keep
    ranks < m_t.  This is the form used by the distributed (shard_map)
    federated round, where each client multiplies its contribution by its
    mask entry before the weighted psum.
    """
    m = schedule.num_clients(t, num_registered)
    scores = jax.random.uniform(key, (num_registered,))
    ranks = jnp.argsort(jnp.argsort(scores))  # rank of each client
    return (ranks < m).astype(jnp.float32)


def transport_cost(schedule: SamplingSchedule, gamma: float, rounds: int) -> float:
    """Paper Eq. 6: f(beta, gamma) = (gamma / R) * sum_t C*exp(-beta*t).

    Measured in units of one full-model single-client transfer, averaged per
    round.  For static sampling this reduces to gamma * C.
    """
    ts = np.arange(1, rounds + 1, dtype=np.float64)
    rates = np.asarray(jax.vmap(schedule.rate)(jnp.asarray(ts, jnp.float32)))
    return float(gamma * rates.sum() / rounds)


def cumulative_transport(schedule: SamplingSchedule, gamma: float,
                         rounds: int, num_registered: int) -> float:
    """Total client-model uploads over ``rounds``, in full-model units.

    Unlike Eq. 6 (a per-round average of the *rate*), this counts the actual
    integer number of clients per round times the kept fraction gamma —
    what a deployment would meter.
    """
    total = 0.0
    for t in range(1, rounds + 1):
        m = int(schedule.num_clients(t, num_registered))
        total += gamma * m
    return total


def rounds_for_budget(schedule: SamplingSchedule, gamma: float,
                      num_registered: int, budget: float) -> int:
    """How many rounds fit in ``budget`` full-model transfers (paper §5.2:
    'with a decay coefficient of 0.1 ... dynamic can update 31 epochs while
    static can only train 10')."""
    total, t = 0.0, 0
    while True:
        t += 1
        total += gamma * int(schedule.num_clients(t, num_registered))
        if total > budget:
            return t - 1
        if t > 1_000_000:  # pragma: no cover - safety
            return t


# ---------------------------------------------------------------------------
# Client samplers: WHICH m_t clients, with unbiased aggregation weights
# ---------------------------------------------------------------------------
# The schedule fixes HOW MANY clients round t uses; a ClientSampler picks
# WHICH ones and emits the per-client aggregation coefficients that keep the
# server's weighted FedAvg an unbiased estimator of the full-population
# update (DESIGN.md §5).  All selection math is (M,)-shaped jnp on the round
# key — cheap enough that BOTH the oracle and the cohort engine recompute it
# identically, which is what keeps cohort gathers bit-exact under
# non-uniform selection.


@dataclasses.dataclass(frozen=True)
class ClientSampler:
    """Base client-selection policy.

    Contract of :meth:`select`: return ``(part, weights)`` where ``part`` is
    a float 0/1 participation mask of shape ``(M,)`` (who computes and
    uploads this round) and ``weights`` are the aggregation coefficients
    handed to the :class:`repro.core.strategy.Aggregator`.  When
    ``normalize`` is True the aggregator re-normalizes ``weights`` to sum
    to 1 (the paper's self-normalized FedAvg); when False the weights are
    already Horvitz-Thompson-corrected so that
    ``E[sum_i weights_i * u_i] = sum_i (n_i / n) * u_i`` exactly.

    ``adaptive`` samplers consume ``norms`` — the server-tracked EMA of each
    client's observed (post-wire) update L2 norm — and the round program
    threads an updated norms vector back out as state.
    """

    name = "uniform"
    adaptive = False        # needs per-client norm feedback between rounds
    normalize = True        # aggregator re-normalizes weights to sum to 1
    ema = 0.5               # norm-tracker update rate (adaptive samplers)

    def cohort_bucket(self, schedule: SamplingSchedule, m: int,
                      num_registered: int) -> int:
        """Static cohort-buffer size for a round with nominal m participants.

        Host-side mirror of the traced participant cap: the cohort engine
        sizes its gather buffer with this, so it must upper-bound the number
        of ``part > 0`` clients :meth:`select` can emit for the same
        ``m``."""
        return schedule.bucket_for(m, num_registered)

    def select(self, key: jax.Array, schedule: SamplingSchedule, t,
               num_registered: int, n_samples: jnp.ndarray,
               norms: jnp.ndarray | None = None):
        """Draw round ``t``'s participants; see class docstring for the
        ``(part, weights)`` contract."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class UniformSampler(ClientSampler):
    """The paper's selection rule: m_t clients uniformly at random.

    Delegates to :func:`participation_mask` with the same key, so rounds
    built with the default sampler are bit-identical to the schedule-only
    path (property-tested in ``tests/test_sampling.py``).  Weights are the
    masked dataset sizes; the aggregator self-normalizes them (Eq. 2).
    """

    def select(self, key, schedule, t, num_registered, n_samples, norms=None):
        """Uniform m_t-subset: ``part`` from :func:`participation_mask`,
        weights = ``part * n_samples`` (self-normalized downstream)."""
        part = participation_mask(key, schedule, t, num_registered)
        return part, part * n_samples


@dataclasses.dataclass(frozen=True)
class ImportanceSampler(ClientSampler):
    """Importance sampling by tracked update norm (Optimal-Client-Sampling
    style), exactly unbiased via with-replacement draws.

    Round ``t`` draws ``m_t`` client slots i.i.d. from
    ``p_i ∝ (1 - exploration) * norm_i / Σ norm + exploration / M`` (the
    exploration floor keeps every p_i > 0 so the correction below never
    divides by zero and unseen clients keep getting observed).  A client
    drawn ``c_i`` times uploads once and is counted with weight
    ``c_i * n_i / (n * m_t * p_i)`` — the classic importance-sampled FedAvg
    estimator, unbiased for ANY p:  ``E[c_i] = m_t * p_i``, so
    ``E[Σ w_i u_i] = Σ (n_i/n) u_i`` (property-tested over seeds in
    ``tests/test_sampling.py``).  Distinct participants ≤ m_t, so the
    schedule's cohort bucket still fits.
    """

    name = "importance"
    adaptive = True
    normalize = False
    exploration: float = 0.1
    ema: float = 0.5

    def __post_init__(self):
        """Validate the exploration mixing coefficient."""
        if not 0.0 < self.exploration <= 1.0:
            raise ValueError(
                f"exploration must be in (0, 1], got {self.exploration}")

    def probabilities(self, norms: jnp.ndarray) -> jnp.ndarray:
        """Selection distribution over clients: normalized tracked norms
        mixed with a uniform exploration floor (valid distribution: >= 0,
        sums to 1, every entry >= exploration / M)."""
        norms = jnp.maximum(jnp.asarray(norms, jnp.float32), 0.0)
        m = norms.shape[0]
        p = norms / jnp.maximum(jnp.sum(norms), 1e-12)
        return (1.0 - self.exploration) * p + self.exploration / m

    def select(self, key, schedule, t, num_registered, n_samples, norms=None):
        """Multinomial(m_t, p) slot draws -> (distinct-participant mask,
        Horvitz-Thompson count weights)."""
        m = schedule.num_clients(t, num_registered)
        p = self.probabilities(norms)
        # Inverse-CDF multinomial slot draws.  Both the obvious routes are
        # quadratic in M — ``random.categorical(key, logits, shape=(M,))``
        # materializes an (M, M) Gumbel matrix and ``one_hot(draws, M)`` an
        # (M, M) indicator — 40 GB each at M = 10^5.  CDF inversion plus a
        # scatter-add is O(M log M) and draws from the identical
        # distribution (p has the exploration floor, so every bin is
        # non-empty).
        cdf = jnp.cumsum(p)
        u = jax.random.uniform(key, (num_registered,))
        draws = jnp.clip(jnp.searchsorted(cdf, u * cdf[-1], side="right"),
                         0, num_registered - 1)
        active = (jnp.arange(num_registered) < m).astype(jnp.float32)
        counts = jnp.zeros((num_registered,), jnp.float32).at[draws].add(active)
        part = (counts > 0).astype(jnp.float32)
        n_total = jnp.maximum(jnp.sum(n_samples), 1e-12)
        weights = counts * n_samples / (
            n_total * jnp.maximum(m.astype(jnp.float32), 1.0) * p)
        return part, weights


@dataclasses.dataclass(frozen=True)
class ThresholdSampler(ClientSampler):
    """Norm-threshold transmission (Ribero-Vikalo style), debiased.

    Each client transmits independently with probability
    ``p_i = min(1, norm_i / tau)`` where ``tau`` solves
    ``Σ min(1, norm_i / tau) = m_t`` (:func:`transmit_probabilities` — the
    optimal-sampling water-filling solution): clients whose tracked update
    norm clears the threshold always transmit, the rest transmit with
    probability proportional to how close they come.  Horvitz-Thompson
    weights ``n_i / (n * p_i)`` make the aggregate unbiased.

    Independent transmission has a *random* participant count (mean m_t),
    so the cohort buffer is sized to ``slack * m_t`` (next bucket) and both
    engines apply the SAME deterministic cap — selected clients ranked by
    their uniform draw, overflow beyond the bucket dropped — keeping cohort
    gathers bit-exact vs the oracle.  P(count > 2 m_t) is exponentially
    small, so the cap's bias is negligible (covered by the statistical
    tolerance of the unbiasedness test).
    """

    name = "threshold"
    adaptive = True
    normalize = False
    slack: float = 2.0
    ema: float = 0.5

    def __post_init__(self):
        """Validate the cohort-buffer slack factor."""
        if self.slack < 1.0:
            raise ValueError(f"slack must be >= 1, got {self.slack}")

    def cohort_bucket(self, schedule, m, num_registered):
        """Bucket for ``slack * m`` participants (random count, mean m)."""
        target = min(num_registered, int(np.ceil(self.slack * m)))
        return schedule.bucket_for(target, num_registered)

    def _cap(self, schedule, m, num_registered):
        """Traced participant cap == the host-side cohort bucket."""
        ladder = jnp.asarray(schedule.bucket_ladder(num_registered), jnp.int32)
        target = jnp.minimum(
            jnp.ceil(self.slack * m.astype(jnp.float32)),
            num_registered).astype(jnp.int32)
        return jnp.min(jnp.where(ladder >= target, ladder, num_registered))

    def select(self, key, schedule, t, num_registered, n_samples, norms=None):
        """Independent transmit draws at the water-filled probabilities,
        capped at the cohort bucket; Horvitz-Thompson ``1/p`` weights."""
        m = schedule.num_clients(t, num_registered)
        p = transmit_probabilities(norms, m)
        u = jax.random.uniform(key, (num_registered,))
        sel = u < p
        # Deterministic overflow cap, identical in oracle and cohort form:
        # selected clients ranked by their uniform draw (the "most firmly"
        # selected — smallest u — survive), capped at the bucket size.
        ranks = jnp.argsort(jnp.argsort(jnp.where(sel, u, 2.0)))
        cap = self._cap(schedule, m, num_registered)
        part = (sel & (ranks < cap)).astype(jnp.float32)
        n_total = jnp.maximum(jnp.sum(n_samples), 1e-12)
        weights = part * n_samples / (n_total * jnp.maximum(p, 1e-12))
        return part, weights


def transmit_probabilities(norms: jnp.ndarray, m) -> jnp.ndarray:
    """Water-filling transmit probabilities: ``p_i = min(1, norms_i / tau)``
    with ``tau`` chosen so ``Σ p_i = m`` (Chen & Horváth's optimal-sampling
    solution; also the debiased form of Ribero-Vikalo threshold
    transmission).

    Static-shape and fully traced: for every candidate count ``K`` of
    saturated clients (the K largest norms at p = 1), the implied threshold
    is ``tau_K = (Σ of the other norms) / (m - K)``; the solution is the
    first K whose tau clears the (K+1)-th largest norm.  ``m >= M`` returns
    all-ones.
    """
    a = jnp.maximum(jnp.asarray(norms, jnp.float32), 1e-12)
    num = a.shape[0]
    m_f = jnp.asarray(m, jnp.float32)
    desc = jnp.sort(a)[::-1]
    csum = jnp.cumsum(desc)
    total = csum[-1]
    ks = jnp.arange(num, dtype=jnp.float32)
    # tail(K) = sum of the M-K smallest norms = total - (K largest)
    tails = total - jnp.concatenate([jnp.zeros((1,)), csum[:-1]])
    denom = m_f - ks
    tau_k = jnp.where(denom > 0, tails / jnp.maximum(denom, 1e-12), jnp.inf)
    feasible = (denom > 0) & (tau_k >= desc)
    k_star = jnp.argmax(feasible)          # first feasible K
    tau = tau_k[k_star]
    p = jnp.minimum(1.0, a / tau)
    return jnp.where(m_f >= num, jnp.ones_like(p), p)


_SAMPLERS = {
    "uniform": UniformSampler,
    "importance": ImportanceSampler,
    "threshold": ThresholdSampler,
}


def get_sampler(name: str, **kwargs) -> ClientSampler:
    """Build a sampler by name: ``uniform`` | ``importance`` | ``threshold``
    (kwargs forward to the sampler's constructor)."""
    try:
        cls = _SAMPLERS[name]
    except KeyError:
        raise ValueError(
            f"unknown sampler {name!r}; known: {', '.join(sorted(_SAMPLERS))}"
        ) from None
    return cls(**kwargs)
