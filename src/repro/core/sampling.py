"""Client sampling schedules (paper §3.2 static, §4.1 dynamic).

The paper's dynamic sampling anneals the participation fraction
``c(t) = C * exp(-beta * t)`` (Eq. 3), floored so at least ``min_clients``
clients participate.  Static sampling is the ``beta = 0`` special case but is
kept as its own class because it is the paper's baseline (Alg. 1).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SamplingSchedule",
    "StaticSampling",
    "DynamicSampling",
    "sample_clients",
    "participation_mask",
    "transport_cost",
]


@dataclasses.dataclass(frozen=True)
class SamplingSchedule:
    """Base schedule: fraction of the M registered clients used at round t."""

    initial_rate: float = 1.0
    min_clients: int = 2

    def rate(self, t) -> jnp.ndarray:
        raise NotImplementedError

    def num_clients(self, t, num_registered: int) -> jnp.ndarray:
        """m_t = max(round(c_t * M), min_clients), capped at M (Alg. 3 line 9)."""
        m = jnp.round(self.rate(t) * num_registered).astype(jnp.int32)
        floor = min(self.min_clients, num_registered)
        return jnp.clip(m, floor, num_registered)

    # ---- cohort bucketing (DESIGN.md §3.5) ------------------------------
    # m_t is a pure function of t, so the cohort buffer size for every round
    # is host-computable before dispatch.  Buffer sizes are drawn from a
    # small static ladder so the number of distinct compiled round programs
    # stays O(log M) as c(t) anneals, instead of one per distinct m_t.

    def num_clients_host(self, t: int, num_registered: int) -> int:
        """Python-int m_t for host-side bucket selection (no tracing)."""
        rate = float(np.asarray(self.rate(np.float32(t))))
        m = int(round(rate * num_registered))
        floor = min(self.min_clients, num_registered)
        return max(min(m, num_registered), floor)

    def bucket_ladder(self, num_registered: int) -> tuple:
        """Static set of cohort buffer sizes: powers of two >= min_clients,
        capped at (and always including) M = num_registered."""
        floor = max(1, min(self.min_clients, num_registered))
        b = 1
        while b < floor:
            b *= 2
        ladder = []
        while b < num_registered:
            ladder.append(b)
            b *= 2
        ladder.append(num_registered)
        return tuple(ladder)

    def bucket_for(self, m: int, num_registered: int) -> int:
        """Smallest ladder bucket that fits an m-client cohort."""
        for b in self.bucket_ladder(num_registered):
            if b >= m:
                return b
        return num_registered

    def round_buckets(self, rounds: int, num_registered: int) -> list:
        """Per-round (m_t, bucket) for t = 1..rounds — the server's dispatch
        plan: consecutive equal buckets can share one compiled program and
        be folded into a single lax.scan segment."""
        out = []
        for t in range(1, rounds + 1):
            m = self.num_clients_host(t, num_registered)
            out.append((m, self.bucket_for(m, num_registered)))
        return out


@dataclasses.dataclass(frozen=True)
class StaticSampling(SamplingSchedule):
    """Alg. 1: constant sampling fraction C."""

    def rate(self, t) -> jnp.ndarray:
        return jnp.full_like(jnp.asarray(t, jnp.float32), self.initial_rate)


@dataclasses.dataclass(frozen=True)
class DynamicSampling(SamplingSchedule):
    """Alg. 3: c(t) = C * exp(-beta * t)  (Eq. 3)."""

    beta: float = 0.1

    def rate(self, t) -> jnp.ndarray:
        t = jnp.asarray(t, jnp.float32)
        return self.initial_rate * jnp.exp(-self.beta * t)


def sample_clients(key: jax.Array, schedule: SamplingSchedule, t: int,
                   num_registered: int) -> jax.Array:
    """Return the int32 ids of the clients participating in round ``t``.

    Uses a uniform random permutation — the paper accepts "the first m ACKs",
    which for simulation purposes is an unbiased random subset.
    Static-shape friendly only for fixed m; prefer :func:`participation_mask`
    inside jitted code.
    """
    m = int(schedule.num_clients(t, num_registered))
    perm = jax.random.permutation(key, num_registered)
    return perm[:m]


def participation_mask(key: jax.Array, schedule: SamplingSchedule, t,
                       num_registered: int) -> jax.Array:
    """0/1 float mask of shape (num_registered,) with exactly m_t ones.

    jit/scan-safe (static output shape): rank a random permutation and keep
    ranks < m_t.  This is the form used by the distributed (shard_map)
    federated round, where each client multiplies its contribution by its
    mask entry before the weighted psum.
    """
    m = schedule.num_clients(t, num_registered)
    scores = jax.random.uniform(key, (num_registered,))
    ranks = jnp.argsort(jnp.argsort(scores))  # rank of each client
    return (ranks < m).astype(jnp.float32)


def transport_cost(schedule: SamplingSchedule, gamma: float, rounds: int) -> float:
    """Paper Eq. 6: f(beta, gamma) = (gamma / R) * sum_t C*exp(-beta*t).

    Measured in units of one full-model single-client transfer, averaged per
    round.  For static sampling this reduces to gamma * C.
    """
    ts = np.arange(1, rounds + 1, dtype=np.float64)
    rates = np.asarray(jax.vmap(schedule.rate)(jnp.asarray(ts, jnp.float32)))
    return float(gamma * rates.sum() / rounds)


def cumulative_transport(schedule: SamplingSchedule, gamma: float,
                         rounds: int, num_registered: int) -> float:
    """Total client-model uploads over ``rounds``, in full-model units.

    Unlike Eq. 6 (a per-round average of the *rate*), this counts the actual
    integer number of clients per round times the kept fraction gamma —
    what a deployment would meter.
    """
    total = 0.0
    for t in range(1, rounds + 1):
        m = int(schedule.num_clients(t, num_registered))
        total += gamma * m
    return total


def rounds_for_budget(schedule: SamplingSchedule, gamma: float,
                      num_registered: int, budget: float) -> int:
    """How many rounds fit in ``budget`` full-model transfers (paper §5.2:
    'with a decay coefficient of 0.1 ... dynamic can update 31 epochs while
    static can only train 10')."""
    total, t = 0.0, 0
    while True:
        t += 1
        total += gamma * int(schedule.num_clients(t, num_registered))
        if total > budget:
            return t - 1
        if t > 1_000_000:  # pragma: no cover - safety
            return t
