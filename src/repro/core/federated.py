"""The federated round — simulation (vmap over clients) form.

``make_federated_round`` builds one jit-able function implementing paper
Alg. 1/3 server loop body + Alg. 2/4 client bodies:

  1. draw the participation mask from the sampling schedule (static/dynamic),
  2. every registered client runs its local update (vmap) — non-participants
     are masked out of the aggregation, which keeps shapes static,
  3. weighted FedAvg (Eq. 2): Θ_{t+1} = Θ_t + Σ_i w_i · upload_i with
     w_i = mask_i·n_i / Σ mask_j·n_j.

Note on Eq. 1/2: the paper writes an extra 1/m in front of Σ (n_i/n)Θ^i; since
the n_i/n weights already sum to 1 over the selected set, the extra 1/m would
shrink the model m-fold.  We take Σ (n_i/n)Θ^i, which matches FedAvg
(McMahan et al.) and the paper's cited behaviour.

The pod (shard_map) form of the same round lives in
``repro.launch.fedtrain`` — identical math, collectives instead of vmap.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core.client import ClientConfig, client_update
from repro.core.sampling import SamplingSchedule, participation_mask

PyTree = Any

__all__ = ["FederatedConfig", "make_federated_round", "fedavg_aggregate"]


@dataclasses.dataclass(frozen=True)
class FederatedConfig:
    num_clients: int
    client: ClientConfig
    error_feedback: bool = False  # beyond-paper (DGC-style residuals)


def fedavg_aggregate(global_params: PyTree, uploads: PyTree,
                     weights: jnp.ndarray, upload_semantics: str) -> PyTree:
    """Weighted FedAvg over stacked client uploads (leading client axis)."""
    wsum = jnp.maximum(jnp.sum(weights), 1e-12)
    norm_w = weights / wsum

    def combine(g, u):
        contrib = jnp.tensordot(norm_w, u, axes=(0, 0))
        if upload_semantics == "delta":
            return (g + contrib).astype(g.dtype)
        return contrib.astype(g.dtype)  # "zero": average of masked weights

    return jax.tree.map(combine, global_params, uploads)


def make_federated_round(loss_fn: Callable, schedule: SamplingSchedule,
                         cfg: FederatedConfig):
    """Returns ``round_fn(params, residuals, client_batches, n_samples, t, key)
    -> (params, residuals, metrics)``.

    ``client_batches``: pytree with leading (num_clients, num_batches, B, ...)
    axes.  ``n_samples``: (num_clients,) float per-client dataset sizes for
    Eq. 2 weighting.  ``residuals``: stacked error-feedback state (zeros when
    cfg.error_feedback is False).
    """

    def round_fn(params, residuals, client_batches, n_samples, t, key):
        sample_key, mask_key = jax.random.split(key)
        part = participation_mask(sample_key, schedule, t, cfg.num_clients)
        mask_keys = jax.random.split(mask_key, cfg.num_clients)

        def one_client(batches, k, res):
            res_arg = res if cfg.error_feedback else None
            up, new_res, loss = client_update(
                loss_fn, params, batches, k, cfg.client, res_arg)
            return up, new_res, loss

        uploads, new_residuals, losses = jax.vmap(one_client)(
            client_batches, mask_keys, residuals)

        weights = part * n_samples
        new_params = fedavg_aggregate(params, uploads, weights,
                                      cfg.client.upload)
        if cfg.error_feedback:
            # Non-participants did not really run this round: keep their old
            # residual; participants reset to the post-mask remainder.
            new_residuals = jax.tree.map(
                lambda old, new: jnp.where(
                    part.reshape((-1,) + (1,) * (new.ndim - 1)) > 0, new, old),
                residuals, new_residuals)
        else:
            new_residuals = residuals

        metrics = {
            "mean_loss": jnp.sum(losses * part) / jnp.maximum(jnp.sum(part), 1.0),
            "num_sampled": jnp.sum(part),
        }
        return new_params, new_residuals, metrics

    return round_fn
