"""The federated round — simulation (vmap over clients) form.

``make_federated_round`` builds one jit-able function implementing paper
Alg. 1/3 server loop body + Alg. 2/4 client bodies:

  1. draw the participation mask from the sampling schedule (static/dynamic),
  2. every registered client runs its local update (vmap) — non-participants
     are masked out of the aggregation, which keeps shapes static,
  3. weighted FedAvg (Eq. 2): Θ_{t+1} = Θ_t + Σ_i w_i · upload_i with
     w_i = mask_i·n_i / Σ mask_j·n_j.

Note on Eq. 1/2: the paper writes an extra 1/m in front of Σ (n_i/n)Θ^i; since
the n_i/n weights already sum to 1 over the selected set, the extra 1/m would
shrink the model m-fold.  We take Σ (n_i/n)Θ^i, which matches FedAvg
(McMahan et al.) and the paper's cited behaviour.

Two execution forms of the same round:

* **oracle** (``make_federated_round``): vmap over ALL registered clients,
  non-participants zero-weighted — simple, flat in c(t);
* **cohort engine** (``make_cohort_round`` / ``make_cohort_scan``): gather
  only the sampled m_t clients into a bucketed padded cohort buffer, run
  client_update over the cohort axis, scatter residuals back (DESIGN.md
  §3.5) — per-round work decays with c(t).

The pod (shard_map) form of the same round lives in
``repro.launch.fedtrain`` — identical math, collectives instead of vmap.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.client import ClientConfig, stacked_client_update
from repro.core.codecs import roundtrip_stacked
from repro.core.sampling import SamplingSchedule, participation_mask

PyTree = Any

__all__ = ["FederatedConfig", "make_federated_round", "make_cohort_round",
           "make_cohort_scan", "cohort_select", "fedavg_aggregate"]


def _resolve_policies(codec, aggregator):
    """Normalize the optional (codec, aggregator) pair every round builder
    takes: identity wire + plain fedavg when unset."""
    agg_fn = aggregator.fn if aggregator is not None else fedavg_aggregate

    def apply_wire(stacked):
        return roundtrip_stacked(codec, stacked)

    return apply_wire, agg_fn


@dataclasses.dataclass(frozen=True)
class FederatedConfig:
    num_clients: int
    client: ClientConfig
    error_feedback: bool = False  # beyond-paper (DGC-style residuals)


def fedavg_aggregate(global_params: PyTree, uploads: PyTree,
                     weights: jnp.ndarray, upload_semantics: str) -> PyTree:
    """Weighted FedAvg over stacked client uploads (leading client axis)."""
    wsum = jnp.maximum(jnp.sum(weights), 1e-12)
    norm_w = weights / wsum

    def combine(g, u):
        contrib = jnp.tensordot(norm_w, u, axes=(0, 0))
        if upload_semantics == "delta":
            return (g + contrib).astype(g.dtype)
        return contrib.astype(g.dtype)  # "zero": average of masked weights

    return jax.tree.map(combine, global_params, uploads)


def make_federated_round(loss_fn: Callable, schedule: SamplingSchedule,
                         cfg: FederatedConfig, *, codec=None, aggregator=None):
    """Returns ``round_fn(params, residuals, client_batches, n_samples, t, key)
    -> (params, residuals, metrics)``.

    ``client_batches``: pytree with leading (num_clients, num_batches, B, ...)
    axes.  ``n_samples``: (num_clients,) float per-client dataset sizes for
    Eq. 2 weighting.  ``residuals``: stacked error-feedback state (zeros when
    cfg.error_feedback is False).  ``codec`` (an
    ``repro.core.codecs.UploadCodec``) round-trips every client upload
    through its wire format before aggregation; ``aggregator`` (an
    ``repro.core.strategy.Aggregator``) replaces plain weighted FedAvg.
    """
    apply_wire, agg_fn = _resolve_policies(codec, aggregator)

    def round_fn(params, residuals, client_batches, n_samples, t, key):
        sample_key, mask_key = jax.random.split(key)
        part = participation_mask(sample_key, schedule, t, cfg.num_clients)
        mask_keys = jax.random.split(mask_key, cfg.num_clients)

        uploads, new_residuals, losses = stacked_client_update(
            loss_fn, params, client_batches, mask_keys, cfg.client,
            residuals, cfg.error_feedback)

        wired = apply_wire(uploads)
        weights = part * n_samples
        new_params = agg_fn(params, wired, weights, cfg.client.upload)
        if cfg.error_feedback:
            if wired is not uploads:
                # Wire loss (int8 quantisation, slot truncation) is real
                # masked-out mass: feed it back like any other residual so
                # error feedback compensates for the codec too.  Exact
                # no-op for bit-exact wires (u - w == 0).
                new_residuals = jax.tree.map(
                    lambda r, u, w: r + (u - w), new_residuals, uploads,
                    wired)
            # Non-participants did not really run this round: keep their old
            # residual; participants reset to the post-mask remainder.
            new_residuals = jax.tree.map(
                lambda old, new: jnp.where(
                    part.reshape((-1,) + (1,) * (new.ndim - 1)) > 0, new, old),
                residuals, new_residuals)
        else:
            new_residuals = residuals

        metrics = {
            "mean_loss": jnp.sum(losses * part) / jnp.maximum(jnp.sum(part), 1.0),
            "num_sampled": jnp.sum(part),
        }
        return new_params, new_residuals, metrics

    return round_fn


# ---------------------------------------------------------------------------
# Cohort execution engine (DESIGN.md §3.5)
# ---------------------------------------------------------------------------
# The oracle above runs EVERY registered client and multiplies
# non-participants by zero — per-round compute/memory is flat in c(t).  The
# cohort engine materializes only a padded cohort buffer of static size
# ``cohort_size`` (a SamplingSchedule.bucket_ladder entry >= m_t): gather the
# m_t participants' batch shards + error-feedback residuals into the buffer,
# vmap client_update over the cohort axis only, and scatter residuals back
# under the participation mask.  Padding slots (cohort rank >= m_t) execute
# but are masked out of the aggregation — exactly the oracle's zero-weight
# treatment, restricted to at most bucket-m_t clients instead of M-m_t.
#
# Equivalence with the oracle is by construction:
#   * the participant SET is identical — both rank the same uniform draw
#     from ``sample_key`` and keep ranks < m_t;
#   * per-client mask keys are row i of split(mask_key, M) in both paths;
#   * cohort ids are sorted ascending so the weighted reduction visits
#     participants in the same client-id order as the oracle (its extra
#     terms are exact zeros).


def cohort_select(sample_key: jax.Array, schedule: SamplingSchedule, t,
                  num_clients: int, cohort_size: int):
    """Pick the round's cohort: ``(cohort_ids, valid)`` with ids sorted
    ascending and ``valid[i] = 1`` iff cohort member i is a true participant
    (its global rank < m_t).  Identical participant set to
    :func:`repro.core.sampling.participation_mask` under the same key."""
    m = schedule.num_clients(t, num_clients)
    scores = jax.random.uniform(sample_key, (num_clients,))
    order = jnp.argsort(scores)                  # ids by ascending score
    ranks = jnp.argsort(order)                   # rank of each client id
    cohort_ids = jnp.sort(order[:cohort_size])   # participant superset
    valid = (jnp.take(ranks, cohort_ids) < m).astype(jnp.float32)
    return cohort_ids, valid


def make_cohort_round(loss_fn: Callable, schedule: SamplingSchedule,
                      cfg: FederatedConfig, cohort_size: int, *,
                      codec=None, aggregator=None):
    """Cohort-engine form of ``make_federated_round``: same signature and
    math, but client_update runs over ``cohort_size`` (static) clients
    instead of ``cfg.num_clients``.  Requires
    ``cohort_size >= m_t`` for every round it is dispatched to — the server
    guarantees this via ``SamplingSchedule.bucket_for``."""
    if not (0 < cohort_size <= cfg.num_clients):
        raise ValueError(
            f"cohort_size {cohort_size} not in (0, {cfg.num_clients}]")
    apply_wire, agg_fn = _resolve_policies(codec, aggregator)

    def round_fn(params, residuals, client_batches, n_samples, t, key):
        sample_key, mask_key = jax.random.split(key)
        cohort_ids, valid = cohort_select(
            sample_key, schedule, t, cfg.num_clients, cohort_size)

        def gather(x):
            return jnp.take(x, cohort_ids, axis=0)

        cohort_batches = jax.tree.map(gather, client_batches)
        cohort_res = jax.tree.map(gather, residuals)
        mask_keys = jnp.take(
            jax.random.split(mask_key, cfg.num_clients), cohort_ids, axis=0)

        uploads, new_res, losses = stacked_client_update(
            loss_fn, params, cohort_batches, mask_keys, cfg.client,
            cohort_res, cfg.error_feedback)

        wired = apply_wire(uploads)
        weights = valid * jnp.take(n_samples, cohort_ids)
        new_params = agg_fn(params, wired, weights, cfg.client.upload)
        if cfg.error_feedback:
            if wired is not uploads:
                # Same wire-loss feedback as the oracle round (bit-exact
                # equivalence holds: both engines adjust identically).
                new_res = jax.tree.map(
                    lambda r, u, w: r + (u - w), new_res, uploads, wired)

            def scatter(old, new, old_cohort):
                vm = valid.reshape((-1,) + (1,) * (new.ndim - 1))
                kept = jnp.where(vm > 0, new, old_cohort)
                return old.at[cohort_ids].set(kept)

            new_residuals = jax.tree.map(
                scatter, residuals, new_res, cohort_res)
        else:
            new_residuals = residuals

        metrics = {
            "mean_loss": jnp.sum(losses * valid)
            / jnp.maximum(jnp.sum(valid), 1.0),
            "num_sampled": jnp.sum(valid),
        }
        return new_params, new_residuals, metrics

    return round_fn


def make_cohort_scan(loss_fn: Callable, schedule: SamplingSchedule,
                     cfg: FederatedConfig, cohort_size: int, *,
                     codec=None, aggregator=None):
    """lax.scan-over-rounds fast path: one dispatch for a whole segment of
    rounds that share a cohort bucket.

    Returns ``scan_fn(params, residuals, client_batches, n_samples, ts,
    keys) -> (params, residuals, metrics)`` where ``ts``/``keys`` carry a
    leading segment-length axis and ``metrics`` leaves are stacked per
    round.  Bit-identical to calling the single-round function in a Python
    loop (same round body, scan just removes per-round dispatch)."""
    if not (0 < cohort_size <= cfg.num_clients):
        raise ValueError(
            f"cohort_size {cohort_size} not in (0, {cfg.num_clients}]")
    kw = dict(codec=codec, aggregator=aggregator)
    if cohort_size == cfg.num_clients:
        round_fn = make_federated_round(loss_fn, schedule, cfg, **kw)
    else:
        round_fn = make_cohort_round(loss_fn, schedule, cfg, cohort_size,
                                     **kw)

    def scan_fn(params, residuals, client_batches, n_samples, ts, keys):
        def body(carry, tk):
            p, r = carry
            t, k = tk
            p, r, metrics = round_fn(p, r, client_batches, n_samples, t, k)
            return (p, r), metrics

        (params, residuals), metrics = jax.lax.scan(
            body, (params, residuals), (ts, keys))
        return params, residuals, metrics

    return scan_fn
