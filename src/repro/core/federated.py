"""The federated round — simulation (vmap over clients) form.

``make_federated_round`` builds one jit-able function implementing paper
Alg. 1/3 server loop body + Alg. 2/4 client bodies:

  1. draw the participation mask from the sampling schedule (static/dynamic),
  2. every registered client runs its local update (vmap) — non-participants
     are masked out of the aggregation, which keeps shapes static,
  3. weighted FedAvg (Eq. 2): Θ_{t+1} = Θ_t + Σ_i w_i · upload_i with
     w_i = mask_i·n_i / Σ mask_j·n_j.

Note on Eq. 1/2: the paper writes an extra 1/m in front of Σ (n_i/n)Θ^i; since
the n_i/n weights already sum to 1 over the selected set, the extra 1/m would
shrink the model m-fold.  We take Σ (n_i/n)Θ^i, which matches FedAvg
(McMahan et al.) and the paper's cited behaviour.

Two execution forms of the same round:

* **oracle** (``make_federated_round``): vmap over ALL registered clients,
  non-participants zero-weighted — simple, flat in c(t);
* **cohort engine** (``make_cohort_round`` / ``make_cohort_scan``): gather
  only the sampled m_t clients into a bucketed padded cohort buffer, run
  client_update over the cohort axis, scatter residuals back (DESIGN.md
  §3.5) — per-round work decays with c(t).

Every builder takes two further scenario axes (DESIGN.md §5):

* ``sampler`` — a :class:`repro.core.sampling.ClientSampler` picking WHICH
  m_t clients and the aggregation weights that keep the weighted mean
  unbiased under non-uniform selection.  Adaptive samplers (importance /
  threshold) consume and emit a per-client norm-tracker vector, so the
  round signature gains a ``norms`` state argument/result.
* ``hetero`` — a :class:`repro.core.hetero.HeteroModel`; its per-client
  drop rates are drawn INSIDE the round (a dropped upload is zero-weighted
  and, under error feedback, leaves that client's residual untouched), so
  both engines agree bit-exactly on which uploads count.

The pod (shard_map) form of the same round lives in
``repro.launch.fedtrain`` — identical math, collectives instead of vmap.
"""

from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.attacks import attack_keys
from repro.core.client import ClientConfig, stacked_client_update
from repro.core.codecs import roundtrip_stacked
from repro.core.sampling import (SamplingSchedule, UniformSampler,
                                 participation_mask)

PyTree = Any

__all__ = ["FederatedConfig", "make_federated_round", "make_cohort_round",
           "make_cohort_scan", "make_cohort_compute", "cohort_select",
           "fedavg_aggregate", "make_store_selection", "make_store_compute",
           "make_store_round", "StoreRound"]


def _resolve_policies(codec, aggregator, normalize: bool = True):
    """Normalize the optional (codec, aggregator) pair every round builder
    takes: identity wire + plain fedavg when unset.

    ``normalize`` binds the sampler's weight semantics into the returned
    aggregation call.  Legacy aggregators registered against the PR-4
    4-argument ``fn(params, uploads, weights, semantics)`` contract keep
    working under self-normalizing samplers; pairing one with a
    Horvitz-Thompson sampler (``normalize=False``) raises at build time
    instead of silently re-normalizing the debiased weights.  Aggregators
    that declare ``ht_compatible=False`` (Krum-family: selection ignores
    weight magnitudes, so HT debiasing cannot reach the estimate) likewise
    raise at build time when paired with an HT sampler.
    """
    if not normalize and aggregator is not None and not getattr(
            aggregator, "ht_compatible", True):
        raise TypeError(
            f"aggregator {aggregator.name!r} is not Horvitz-Thompson "
            "compatible but the sampler emits HT weights (normalize="
            "False); use a weighted-rank aggregator (coordinate_median / "
            "trimmed_mean) or a self-normalizing sampler")
    fn = aggregator.fn if aggregator is not None else fedavg_aggregate
    params = inspect.signature(fn).parameters
    takes_normalize = "normalize" in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())
    if takes_normalize:
        def agg_fn(g, uploads, weights, semantics):
            return fn(g, uploads, weights, semantics, normalize=normalize)
    elif normalize:
        agg_fn = fn
    else:
        raise TypeError(
            f"aggregator {getattr(aggregator, 'name', fn)!r} does not accept "
            "normalize= but the sampler emits Horvitz-Thompson weights "
            "(normalize=False); extend its fn signature")

    def apply_wire(stacked):
        return roundtrip_stacked(codec, stacked)

    return apply_wire, agg_fn


def _is_plain(sampler, hetero, attack=None) -> bool:
    """True when the round reduces to the original schedule-only body —
    the path kept verbatim so default rounds stay bit-identical.  An
    active attack routes to the generalized body (adversary injection
    needs the full metering path)."""
    return (hetero is None and attack is None
            and (sampler is None or isinstance(sampler, UniformSampler)))


def _active_attack(attack):
    """Normalize the optional attack: a zero-fraction model is no attack."""
    return attack if attack is not None and attack.active else None


def _row_l2(stacked: PyTree) -> jnp.ndarray:
    """Per-client L2 norm over every leaf of a client-stacked pytree."""
    sq = sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)),
                     axis=tuple(range(1, leaf.ndim)))
             for leaf in jax.tree_util.tree_leaves(stacked))
    return jnp.sqrt(sq)


def _finite_rows(stacked: PyTree) -> jnp.ndarray:
    """1.0 for client rows whose every leaf entry is finite, else 0.0 —
    the decode-boundary quarantine gate, shared by both sync engines (the
    async engine applies the same check event-by-event, DESIGN.md §8)."""
    ok = None
    for leaf in jax.tree_util.tree_leaves(stacked):
        leaf_ok = jnp.all(jnp.isfinite(leaf.astype(jnp.float32)),
                          axis=tuple(range(1, leaf.ndim)))
        ok = leaf_ok if ok is None else ok & leaf_ok
    return ok.astype(jnp.float32)


def _zero_rows(stacked: PyTree, keep: jnp.ndarray) -> PyTree:
    """Zero whole client rows where ``keep == 0``.  Quarantined uploads
    must not reach any aggregator even zero-weighted (0 · NaN = NaN); for
    all-finite rows ``jnp.where`` is a bit-exact pass-through, so the
    always-on gate leaves attack-free rounds bit-identical."""
    return jax.tree.map(
        lambda u: jnp.where(
            keep.reshape((-1,) + (1,) * (u.ndim - 1)) > 0,
            u, jnp.zeros_like(u)),
        stacked)


def _attack_payload(attack, wired, adv, mask_key, num_clients,
                    cohort_ids=None):
    """What the server actually decodes: ``wired`` with adversary rows
    transformed.  ``adv`` is the full ``(M,)`` assignment; ``cohort_ids``
    gathers it (and the per-client attack keys) onto cohort rows so both
    engines perturb client i identically.  Returns ``wired`` itself when
    no attack is active — downstream ``is not`` checks stay exact."""
    if attack is None:
        return wired
    keys = None
    if attack.needs_keys:
        keys = attack_keys(mask_key, num_clients)
    if cohort_ids is not None:
        adv = jnp.take(adv, cohort_ids)
        if keys is not None:
            keys = jnp.take(keys, cohort_ids, axis=0)
    return attack.apply_stacked(wired, adv, keys)


@dataclasses.dataclass(frozen=True)
class FederatedConfig:
    """Population-level round configuration: how many clients are
    registered, their shared :class:`repro.core.client.ClientConfig`, and
    whether DGC-style error-feedback residuals accumulate (beyond-paper)."""

    num_clients: int
    client: ClientConfig
    error_feedback: bool = False  # beyond-paper (DGC-style residuals)


def fedavg_aggregate(global_params: PyTree, uploads: PyTree,
                     weights: jnp.ndarray, upload_semantics: str,
                     normalize: bool = True) -> PyTree:
    """Weighted FedAvg over stacked client uploads (leading client axis).

    ``normalize=True`` (default) re-normalizes ``weights`` to sum to 1 —
    Eq. 2's self-normalized mean.  ``normalize=False`` uses the weights as
    given: the Horvitz-Thompson path, where a non-uniform
    :class:`~repro.core.sampling.ClientSampler` has already folded the
    inverse selection probabilities in so the weighted sum is an unbiased
    estimate of the full-population mean.
    """
    if normalize:
        wsum = jnp.maximum(jnp.sum(weights), 1e-12)
        norm_w = weights / wsum
    else:
        norm_w = weights

    def combine(g, u):
        contrib = jnp.tensordot(norm_w, u, axes=(0, 0))
        if upload_semantics == "delta":
            return (g + contrib).astype(g.dtype)
        return contrib.astype(g.dtype)  # "zero": average of masked weights

    return jax.tree.map(combine, global_params, uploads)


def _round_extras(sampler, hetero, cfg):
    """Shared setup for the generalized (non-plain) round bodies: the
    resolved sampler and the static per-client drop-rate vector (or None)."""
    smp = sampler if sampler is not None else UniformSampler()
    drop = None
    if hetero is not None:
        drop = jnp.asarray(hetero.drop_rates(cfg.num_clients), jnp.float32)
    return smp, drop


def _split_round_key(key, with_drop: bool):
    """(sample, mask[, drop]) subkeys; the 2-way split is kept verbatim for
    hetero-free rounds so default rounds stay bit-identical."""
    if not with_drop:
        sample_key, mask_key = jax.random.split(key)
        return sample_key, mask_key, None
    return tuple(jax.random.split(key, 3))


def _apply_dropout(part, weights, drop, drop_key, normalize):
    """Draw upload losses and fold them into participation weights.

    Self-normalized weights just zero the lost rows (FedAvg re-normalizes
    over arrivals); Horvitz-Thompson weights additionally divide by the
    per-client survival probability so unbiasedness is preserved under
    dropout: ``E[arrived_i / (1 - q_i)] = part_i``.
    """
    if drop is None:
        return part, weights
    lost = (jax.random.uniform(drop_key, drop.shape) < drop)
    arrived = part * (1.0 - lost.astype(jnp.float32))
    if normalize:
        return arrived, weights * arrived
    return arrived, weights * arrived / jnp.maximum(1.0 - drop, 1e-6)


def _commit_rows(old: PyTree, new: PyTree, commit: jnp.ndarray) -> PyTree:
    """Per-row state commit: keep ``new[i]`` where ``commit[i] > 0``, else
    the round-entry ``old[i]`` — the same ``where`` every engine's EF
    scatter runs, shared so the drift tree commits identically."""
    return jax.tree.map(
        lambda o, n: jnp.where(
            commit.reshape((-1,) + (1,) * (n.ndim - 1)) > 0, n, o),
        old, new)


def _wire_feedback(new_res: PyTree, uploads: PyTree, wired: PyTree) -> PyTree:
    """EF wire-loss feedback ``r + (u − w)``, identical bits on EVERY
    execution form.

    ``wired`` is pinned through a float->int->float bitcast round-trip
    first: without it the backend may contract a lossy codec's
    dequantisation multiply into the subtraction (an FMA computing
    ``u − q·scale`` in one rounding) in one compiled program but not
    another, and the resulting ±1 ulp wobble breaks the cross-engine
    bit-exactness contract (caught by the store-form body in
    tests/test_equivalence.py).  A bitcast is used rather than
    ``jax.lax.optimization_barrier`` because XLA:CPU deletes barriers
    during optimization; contraction cannot cross an integer bitcast."""
    def pin(w):
        if not jnp.issubdtype(w.dtype, jnp.floating):
            return w
        bits = jnp.dtype(w.dtype).itemsize * 8
        return jax.lax.bitcast_convert_type(
            jax.lax.bitcast_convert_type(w, jnp.dtype(f"uint{bits}")),
            w.dtype)

    wired = jax.tree.map(pin, wired)
    return jax.tree.map(lambda r, u, w: r + (u - w), new_res, uploads, wired)


def _wrap_plain(round_impl, uses_drift: bool):
    """Adapt the plain round body ``(params, residuals, drift, batches,
    n_samples, t, key) -> (p, r, d, metrics)`` to its public signature:
    the drift slot appears (after ``residuals``) only when the objective
    carries drift state."""
    if uses_drift:
        return round_impl

    def round_fn(params, residuals, client_batches, n_samples, t, key):
        p, r, _, m = round_impl(params, residuals, None, client_batches,
                                n_samples, t, key)
        return p, r, m

    return round_fn


def _wrap_round(round_impl, uses_drift: bool, adaptive: bool):
    """Adapt the fully-general round body ``(params, residuals, drift,
    norms, batches, n_samples, t, key) -> (p, r, d, n, metrics)`` to the
    public signature for this (uses_drift, adaptive) combination.
    Optional state slots sit between ``residuals`` and the batch args,
    drift first — the convention every engine and the scan carry share."""
    if uses_drift and adaptive:
        return round_impl
    if uses_drift:
        def round_fn(params, residuals, drift, client_batches, n_samples,
                     t, key):
            p, r, d, _, m = round_impl(params, residuals, drift, None,
                                       client_batches, n_samples, t, key)
            return p, r, d, m
    elif adaptive:
        def round_fn(params, residuals, norms, client_batches, n_samples,
                     t, key):
            p, r, _, n, m = round_impl(params, residuals, None, norms,
                                       client_batches, n_samples, t, key)
            return p, r, n, m
    else:
        def round_fn(params, residuals, client_batches, n_samples, t, key):
            p, r, _, _, m = round_impl(params, residuals, None, None,
                                       client_batches, n_samples, t, key)
            return p, r, m
    return round_fn


def make_federated_round(loss_fn: Callable, schedule: SamplingSchedule,
                         cfg: FederatedConfig, *, codec=None, aggregator=None,
                         sampler=None, hetero=None, attack=None):
    """Build the full-population (oracle) round program.

    Returns ``round_fn(params, residuals, client_batches, n_samples, t, key)
    -> (params, residuals, metrics)`` — or, when ``sampler.adaptive``,
    ``round_fn(params, residuals, norms, client_batches, n_samples, t, key)
    -> (params, residuals, norms, metrics)`` with ``norms`` the (M,)
    per-client update-norm tracker the sampler feeds on.  When the
    strategy's :class:`~repro.core.objectives.LocalObjective` carries
    drift state (``cfg.client.objective.uses_drift``, i.e. FedDyn), a
    stacked ``drift`` argument/result is inserted between ``residuals``
    and ``norms`` — the full state convention is
    ``(params, residuals[, drift][, norms], …)``.

    ``client_batches``: pytree with leading (num_clients, num_batches, B, ...)
    axes.  ``n_samples``: (num_clients,) float per-client dataset sizes for
    Eq. 2 weighting.  ``residuals``: stacked error-feedback state (zeros when
    cfg.error_feedback is False).  ``codec`` (an
    ``repro.core.codecs.UploadCodec``) round-trips every client upload
    through its wire format before aggregation; ``aggregator`` (an
    ``repro.core.strategy.Aggregator``) replaces plain weighted FedAvg;
    ``sampler`` (a :class:`repro.core.sampling.ClientSampler`) picks the
    participants and their aggregation weights; ``hetero`` (a
    :class:`repro.core.hetero.HeteroModel`) adds in-round upload dropout
    plus ``part_mask``/``arrived_mask`` metrics for host-side clock
    simulation; ``attack`` (a :class:`repro.core.attacks.AttackModel`)
    perturbs the adversary rows of the decoded payload before aggregation.

    Both bodies gate the decoded payload through the non-finite quarantine
    (``metrics["quarantined"]``): a NaN/Inf upload is zero-weighted and
    zeroed out instead of poisoning Θ, matching the async engine's gate.
    """
    attack = _active_attack(attack)
    uses_drift = cfg.client.objective.uses_drift
    if _is_plain(sampler, hetero, attack):
        apply_wire, agg_fn = _resolve_policies(codec, aggregator)

        def plain_impl(params, residuals, drift, client_batches, n_samples,
                       t, key):
            sample_key, mask_key = jax.random.split(key)
            part = participation_mask(sample_key, schedule, t, cfg.num_clients)
            mask_keys = jax.random.split(mask_key, cfg.num_clients)

            uploads, new_residuals, new_drift, losses = stacked_client_update(
                loss_fn, params, client_batches, mask_keys, cfg.client,
                residuals, cfg.error_feedback, drift)

            wired = apply_wire(uploads)
            finite = _finite_rows(wired)
            weights = part * n_samples * finite
            new_params = agg_fn(params, _zero_rows(wired, finite), weights,
                                cfg.client.upload)
            if cfg.error_feedback:
                if wired is not uploads:
                    # Wire loss (int8 quantisation, slot truncation) is real
                    # masked-out mass: feed it back like any other residual so
                    # error feedback compensates for the codec too.  Exact
                    # no-op for bit-exact wires (u - w == 0).
                    new_residuals = _wire_feedback(new_residuals, uploads,
                                                   wired)
                # Non-participants did not really run this round: keep their
                # old residual; participants reset to the post-mask remainder.
                # Quarantined rows count as non-participants (their whole
                # update was discarded at the server).
                new_residuals = _commit_rows(residuals, new_residuals,
                                             part * finite)
            else:
                new_residuals = residuals

            if uses_drift:
                # Drift advances under the same gate as the residuals (the
                # upload applied), but independent of error_feedback: h_k
                # tracks the honest local trajectory, not the wire.
                new_drift = _commit_rows(drift, new_drift, part * finite)
            else:
                new_drift = drift

            metrics = {
                "mean_loss": jnp.sum(losses * part)
                / jnp.maximum(jnp.sum(part), 1.0),
                "num_sampled": jnp.sum(part),
                "quarantined": jnp.sum(part * (1.0 - finite)),
            }
            return new_params, new_residuals, new_drift, metrics

        return _wrap_plain(plain_impl, uses_drift)

    smp, drop = _round_extras(sampler, hetero, cfg)
    apply_wire, agg_fn = _resolve_policies(codec, aggregator, smp.normalize)
    adv = None
    if attack is not None:
        adv = jnp.asarray(attack.adversary_mask(cfg.num_clients),
                          jnp.float32)

    def round_impl(params, residuals, drift, norms, client_batches,
                   n_samples, t, key):
        M = cfg.num_clients
        sample_key, mask_key, drop_key = _split_round_key(
            key, drop is not None)
        part, weights = smp.select(sample_key, schedule, t, M, n_samples,
                                   norms)
        mask_keys = jax.random.split(mask_key, M)

        uploads, new_residuals, new_drift, losses = stacked_client_update(
            loss_fn, params, client_batches, mask_keys, cfg.client,
            residuals, cfg.error_feedback, drift)

        wired = apply_wire(uploads)
        # What the server decodes: adversary rows perturbed, then the
        # non-finite quarantine gate.  EF wire-loss feedback below stays on
        # the HONEST (uploads, wired) pair — a client's residual reflects
        # what IT failed to ship, not what an attacker forged in its name.
        payload = _attack_payload(attack, wired, adv, mask_key, M)
        finite = _finite_rows(payload)
        arrived, weights = _apply_dropout(part, weights, drop, drop_key,
                                          smp.normalize)
        weights = weights * finite
        new_params = agg_fn(params, _zero_rows(payload, finite), weights,
                            cfg.client.upload)
        if cfg.error_feedback:
            if wired is not uploads:
                new_residuals = _wire_feedback(new_residuals, uploads,
                                               wired)
            # Residuals advance only for clients whose upload ARRIVED (and
            # survived quarantine): a dropped upload discards the whole
            # local update, so its residual must stay consistent with the
            # global model the client re-downloads next round.
            new_residuals = _commit_rows(residuals, new_residuals,
                                         arrived * finite)
        else:
            new_residuals = residuals

        if uses_drift:
            # Same arrival gate as the residuals; independent of
            # error_feedback (drift tracks the honest local trajectory).
            new_drift = _commit_rows(drift, new_drift, arrived * finite)
        else:
            new_drift = drift

        new_norms = norms
        if smp.adaptive:
            # The tracker observes what the server saw — attacked rows feed
            # their forged norms in, exactly the signal a norm-adaptive
            # sampler would really receive under attack.
            obs = _row_l2(payload)
            new_norms = jnp.where(
                arrived * finite > 0,
                (1.0 - smp.ema) * norms + smp.ema * obs, norms)

        # An empty round (the threshold sampler's random count can be 0) is
        # a no-op for the params; report NaN, not a fabricated 0.0 loss.
        n_part = jnp.sum(part)
        metrics = {
            "mean_loss": jnp.where(
                n_part > 0,
                jnp.sum(losses * part) / jnp.maximum(n_part, 1.0),
                jnp.nan),
            "num_sampled": n_part,
            "quarantined": jnp.sum(arrived * (1.0 - finite)),
        }
        if attack is not None:
            metrics["num_adversarial"] = jnp.sum(part * adv)
        if drop is not None:
            metrics["part_mask"] = part
            metrics["arrived_mask"] = arrived
            metrics["num_arrived"] = jnp.sum(arrived)
        return new_params, new_residuals, new_drift, new_norms, metrics

    return _wrap_round(round_impl, uses_drift, smp.adaptive)


# ---------------------------------------------------------------------------
# Cohort execution engine (DESIGN.md §3.5)
# ---------------------------------------------------------------------------
# The oracle above runs EVERY registered client and multiplies
# non-participants by zero — per-round compute/memory is flat in c(t).  The
# cohort engine materializes only a padded cohort buffer of static size
# ``cohort_size`` (a SamplingSchedule.bucket_ladder entry >= m_t): gather the
# m_t participants' batch shards + error-feedback residuals into the buffer,
# vmap client_update over the cohort axis only, and scatter residuals back
# under the participation mask.  Padding slots (cohort rank >= m_t) execute
# but are masked out of the aggregation — exactly the oracle's zero-weight
# treatment, restricted to at most bucket-m_t clients instead of M-m_t.
#
# Equivalence with the oracle is by construction:
#   * the participant SET is identical — both rank the same uniform draw
#     from ``sample_key`` and keep ranks < m_t;
#   * per-client mask keys are row i of split(mask_key, M) in both paths;
#   * cohort ids are sorted ascending so the weighted reduction visits
#     participants in the same client-id order as the oracle (its extra
#     terms are exact zeros).


def cohort_select(sample_key: jax.Array, schedule: SamplingSchedule, t,
                  num_clients: int, cohort_size: int):
    """Pick the round's cohort: ``(cohort_ids, valid)`` with ids sorted
    ascending and ``valid[i] = 1`` iff cohort member i is a true participant
    (its global rank < m_t).  Identical participant set to
    :func:`repro.core.sampling.participation_mask` under the same key."""
    m = schedule.num_clients(t, num_clients)
    scores = jax.random.uniform(sample_key, (num_clients,))
    order = jnp.argsort(scores)                  # ids by ascending score
    ranks = jnp.argsort(order)                   # rank of each client id
    cohort_ids = jnp.sort(order[:cohort_size])   # participant superset
    valid = (jnp.take(ranks, cohort_ids) < m).astype(jnp.float32)
    return cohort_ids, valid


def make_cohort_compute(loss_fn: Callable, schedule: SamplingSchedule,
                        cfg: FederatedConfig, cohort_size: int, *,
                        codec=None, sampler=None, attack=None):
    """The round's *client-side sweep*, shared between execution engines:
    selection → cohort gather → local updates → wire round-trip — and
    nothing after it (no dropout draw, no aggregation, no state commit).

    The sync cohort engine (``make_cohort_round``) runs this then applies
    its barrier aggregation in the same jitted program; the async buffered
    engine (``repro.core.async_engine``) runs it as the round's *dispatch*
    phase and applies the uploads event-by-event as they arrive.  Both see
    the identical uploads because the whole sweep is a pure function of
    ``(params, residuals, norms, t, sample_key, mask_key)``.

    Returns ``compute(params, residuals, drift, norms, client_batches,
    n_samples, t, sample_key, mask_key) -> dict`` with keys ``part`` /
    ``weights`` (full ``(M,)`` selection mask and pre-dropout aggregation
    weights), ``cohort_ids`` (sorted ascending, padded with the lowest-id
    non-participants), ``cohort_res`` / ``cohort_drift`` (round-entry
    state rows, gathered), ``uploads`` / ``wired`` (pre-/post-wire stacked
    uploads), ``attacked`` (the payload the server decodes: ``wired`` with
    adversary rows perturbed — the same object when no attack is active),
    ``new_res`` / ``new_drift`` (post-round state candidates) and
    ``losses`` — everything a barrier or a buffer needs to finish the
    round.  Pass ``norms=None`` for non-adaptive samplers and
    ``drift=None`` unless ``cfg.client.objective.uses_drift``.
    """
    if not (0 < cohort_size <= cfg.num_clients):
        raise ValueError(
            f"cohort_size {cohort_size} not in (0, {cfg.num_clients}]")
    smp = sampler if sampler is not None else UniformSampler()
    attack = _active_attack(attack)
    adv = None
    if attack is not None:
        adv = jnp.asarray(attack.adversary_mask(cfg.num_clients),
                          jnp.float32)

    def compute(params, residuals, drift, norms, client_batches, n_samples,
                t, sample_key, mask_key):
        M = cfg.num_clients
        # Selection runs on the full (M,) arrays — identical ops to the
        # oracle — then the cohort buffer gathers the sampler's ids.
        part, weights = smp.select(sample_key, schedule, t, M, n_samples,
                                   norms)
        ids = jnp.arange(M, dtype=jnp.int32)
        order = jnp.argsort(jnp.where(part > 0, ids, ids + M))
        cohort_ids = jnp.sort(order[:cohort_size])

        def gather(x):
            return jnp.take(x, cohort_ids, axis=0)

        cohort_batches = jax.tree.map(gather, client_batches)
        cohort_res = jax.tree.map(gather, residuals)
        cohort_drift = jax.tree.map(gather, drift)  # None stays None
        mask_keys = jnp.take(
            jax.random.split(mask_key, M), cohort_ids, axis=0)

        uploads, new_res, new_drift, losses = stacked_client_update(
            loss_fn, params, cohort_batches, mask_keys, cfg.client,
            cohort_res, cfg.error_feedback, cohort_drift)

        wired = roundtrip_stacked(codec, uploads)
        attacked = _attack_payload(attack, wired, adv, mask_key, M,
                                   cohort_ids=cohort_ids)
        return {
            "part": part,
            "weights": weights,
            "cohort_ids": cohort_ids,
            "cohort_res": cohort_res,
            "cohort_drift": cohort_drift,
            "uploads": uploads,
            "new_res": new_res,
            "new_drift": new_drift,
            "losses": losses,
            "wired": wired,
            "attacked": attacked,
        }

    return compute


def make_cohort_round(loss_fn: Callable, schedule: SamplingSchedule,
                      cfg: FederatedConfig, cohort_size: int, *,
                      codec=None, aggregator=None, sampler=None, hetero=None,
                      attack=None):
    """Cohort-engine form of ``make_federated_round``: same signature(s) and
    math, but client_update runs over ``cohort_size`` (static) clients
    instead of ``cfg.num_clients``.

    Requires ``cohort_size`` to upper-bound the sampler's participant count
    for every round it is dispatched to — the server guarantees this via
    ``ClientSampler.cohort_bucket`` (``SamplingSchedule.bucket_for`` for
    the default uniform sampler).  Under a non-uniform sampler the cohort
    gather is keyed by the sampler's ids: the selection math runs on the
    full (M,)-shaped arrays exactly as in the oracle, and the cohort
    buffer gathers the ``part > 0`` ids (sorted ascending, padded with the
    lowest-id non-participants) so the weighted reductions see the same
    nonzero terms in the same order — bit-exact vs the oracle.
    """
    if not (0 < cohort_size <= cfg.num_clients):
        raise ValueError(
            f"cohort_size {cohort_size} not in (0, {cfg.num_clients}]")
    attack = _active_attack(attack)
    uses_drift = cfg.client.objective.uses_drift

    if _is_plain(sampler, hetero, attack):
        apply_wire, agg_fn = _resolve_policies(codec, aggregator)

        def plain_impl(params, residuals, drift, client_batches, n_samples,
                       t, key):
            sample_key, mask_key = jax.random.split(key)
            cohort_ids, valid = cohort_select(
                sample_key, schedule, t, cfg.num_clients, cohort_size)

            def gather(x):
                return jnp.take(x, cohort_ids, axis=0)

            cohort_batches = jax.tree.map(gather, client_batches)
            cohort_res = jax.tree.map(gather, residuals)
            cohort_drift = jax.tree.map(gather, drift)
            mask_keys = jnp.take(
                jax.random.split(mask_key, cfg.num_clients), cohort_ids,
                axis=0)

            uploads, new_res, new_drift, losses = stacked_client_update(
                loss_fn, params, cohort_batches, mask_keys, cfg.client,
                cohort_res, cfg.error_feedback, cohort_drift)

            wired = apply_wire(uploads)
            finite = _finite_rows(wired)
            weights = valid * jnp.take(n_samples, cohort_ids) * finite
            new_params = agg_fn(params, _zero_rows(wired, finite), weights,
                                cfg.client.upload)

            def scatter_back(full_old, rows, cohort_old, commit):
                def scatter(old, new, old_cohort):
                    vm = commit.reshape((-1,) + (1,) * (new.ndim - 1))
                    kept = jnp.where(vm > 0, new, old_cohort)
                    return old.at[cohort_ids].set(kept)

                return jax.tree.map(scatter, full_old, rows, cohort_old)

            if cfg.error_feedback:
                if wired is not uploads:
                    # Same wire-loss feedback as the oracle round (bit-exact
                    # equivalence holds: both engines adjust identically).
                    new_res = _wire_feedback(new_res, uploads, wired)
                new_residuals = scatter_back(residuals, new_res, cohort_res,
                                             valid * finite)
            else:
                new_residuals = residuals

            if uses_drift:
                new_drift = scatter_back(drift, new_drift, cohort_drift,
                                         valid * finite)
            else:
                new_drift = drift

            metrics = {
                "mean_loss": jnp.sum(losses * valid)
                / jnp.maximum(jnp.sum(valid), 1.0),
                "num_sampled": jnp.sum(valid),
                "quarantined": jnp.sum(valid * (1.0 - finite)),
            }
            return new_params, new_residuals, new_drift, metrics

        return _wrap_plain(plain_impl, uses_drift)

    smp, drop = _round_extras(sampler, hetero, cfg)
    _, agg_fn = _resolve_policies(codec, aggregator, smp.normalize)
    compute = make_cohort_compute(loss_fn, schedule, cfg, cohort_size,
                                  codec=codec, sampler=sampler, attack=attack)
    adv = None
    if attack is not None:
        adv = jnp.asarray(attack.adversary_mask(cfg.num_clients),
                          jnp.float32)

    def round_impl(params, residuals, drift, norms, client_batches,
                   n_samples, t, key):
        sample_key, mask_key, drop_key = _split_round_key(
            key, drop is not None)
        # The client-side sweep (selection → gather → updates → wire →
        # adversary injection) is the engine-shared compute; everything
        # below is this engine's barrier: dropout draw, quarantine gate,
        # one-shot aggregation, state commit.
        c = compute(params, residuals, drift, norms, client_batches,
                    n_samples, t, sample_key, mask_key)
        part, cohort_ids = c["part"], c["cohort_ids"]
        uploads, new_res, wired = c["uploads"], c["new_res"], c["wired"]
        losses, payload = c["losses"], c["attacked"]
        finite = _finite_rows(payload)
        arrived, weights = _apply_dropout(part, c["weights"], drop, drop_key,
                                          smp.normalize)

        def gather(x):
            return jnp.take(x, cohort_ids, axis=0)

        valid = gather(part)
        arr_c = gather(arrived)
        w_c = gather(weights) * finite
        new_params = agg_fn(params, _zero_rows(payload, finite), w_c,
                            cfg.client.upload)

        def scatter_back(full_old, rows, cohort_old, commit):
            def scatter(old, new, old_cohort):
                am = commit.reshape((-1,) + (1,) * (new.ndim - 1))
                kept = jnp.where(am > 0, new, old_cohort)
                return old.at[cohort_ids].set(kept)

            return jax.tree.map(scatter, full_old, rows, cohort_old)

        if cfg.error_feedback:
            # EF feedback stays on the HONEST (uploads, wired) pair — see
            # the oracle body.
            if wired is not uploads:
                new_res = _wire_feedback(new_res, uploads, wired)
            new_residuals = scatter_back(residuals, new_res, c["cohort_res"],
                                         arr_c * finite)
        else:
            new_residuals = residuals

        if uses_drift:
            new_drift = scatter_back(drift, c["new_drift"],
                                     c["cohort_drift"], arr_c * finite)
        else:
            new_drift = drift

        new_norms = norms
        if smp.adaptive:
            obs = _row_l2(payload)
            old_c = gather(norms)
            upd = jnp.where(arr_c * finite > 0,
                            (1.0 - smp.ema) * old_c + smp.ema * obs, old_c)
            new_norms = norms.at[cohort_ids].set(upd)

        # Same empty-round convention as the oracle body: NaN, not 0.0.
        n_part = jnp.sum(part)
        metrics = {
            "mean_loss": jnp.where(
                n_part > 0,
                jnp.sum(losses * valid) / jnp.maximum(jnp.sum(valid), 1.0),
                jnp.nan),
            "num_sampled": n_part,
            "quarantined": jnp.sum(arr_c * (1.0 - finite)),
        }
        if attack is not None:
            metrics["num_adversarial"] = jnp.sum(part * adv)
        if drop is not None:
            metrics["part_mask"] = part
            metrics["arrived_mask"] = arrived
            metrics["num_arrived"] = jnp.sum(arrived)
        return new_params, new_residuals, new_drift, new_norms, metrics

    return _wrap_round(round_impl, uses_drift, smp.adaptive)


def make_cohort_scan(loss_fn: Callable, schedule: SamplingSchedule,
                     cfg: FederatedConfig, cohort_size: int, *,
                     codec=None, aggregator=None, sampler=None, hetero=None,
                     attack=None):
    """lax.scan-over-rounds fast path: one dispatch for a whole segment of
    rounds that share a cohort bucket.

    Returns ``scan_fn(params, residuals, client_batches, n_samples, ts,
    keys) -> (params, residuals, metrics)`` where ``ts``/``keys`` carry a
    leading segment-length axis and ``metrics`` leaves are stacked per
    round.  Optional state (FedDyn ``drift``, then the adaptive samplers'
    ``norms``) extends the argument/result lists after ``residuals`` in
    the engine-wide ``(params, residuals[, drift][, norms], …)``
    convention, threaded through the scan carry.  Bit-identical to calling
    the single-round function in a Python loop (same round body, scan just
    removes per-round dispatch)."""
    if not (0 < cohort_size <= cfg.num_clients):
        raise ValueError(
            f"cohort_size {cohort_size} not in (0, {cfg.num_clients}]")
    kw = dict(codec=codec, aggregator=aggregator, sampler=sampler,
              hetero=hetero, attack=attack)
    if cohort_size == cfg.num_clients:
        round_fn = make_federated_round(loss_fn, schedule, cfg, **kw)
    else:
        round_fn = make_cohort_round(loss_fn, schedule, cfg, cohort_size,
                                     **kw)

    adaptive = sampler is not None and sampler.adaptive
    uses_drift = cfg.client.objective.uses_drift
    n_state = 2 + int(uses_drift) + int(adaptive)

    def scan_fn(*args):
        state = tuple(args[:n_state])
        client_batches, n_samples, ts, keys = args[n_state:]

        def body(carry, tk):
            t, k = tk
            out = round_fn(*carry, client_batches, n_samples, t, k)
            return tuple(out[:-1]), out[-1]

        state, metrics = jax.lax.scan(body, state, (ts, keys))
        return (*state, metrics)

    return scan_fn


# ---------------------------------------------------------------------------
# Store-form round (DESIGN.md §11)
# ---------------------------------------------------------------------------
# The two engines above close over the full (M, …) residual arrays: gather
# and scatter happen INSIDE the round program, so the dense stack must exist
# as a program input.  The store form splits the round at exactly that
# boundary so residual ownership can move into a
# ``repro.core.client_store.ClientStateStore`` (dense oracle or sharded slot
# pool) and the program only ever sees cohort-shaped rows:
#
#     select(norms, n_samples, t, sample_key)          [jit, (M,) arrays]
#         -> part, weights, cohort_ids
#     store.gather(cohort_ids)                          [host boundary]
#         -> cohort_res
#     body(params, cohort_res, cohort_batches, cohort_ids,
#          part, weights, norms, mask_key, drop_key)    [jit, cohort-shaped]
#         -> new_params, new_rows, commit, norm_upd, metrics
#     store.scatter(cohort_ids, new_rows, commit)       [host boundary]
#     store.update_norms(cohort_ids, norm_upd)
#
# Equivalence with the in-program engines is by the same construction
# argument as cohort-vs-oracle: the participant set, per-client mask keys
# and all per-row math are identical; cohort ids are sorted ascending so
# weighted reductions visit participants in client-id order (padding rows
# contribute exact zeros); and the store's commit-masked scatter is the very
# ``where(commit, new, old) → at[ids].set`` the in-program scatter ran.
# Padding rows never commit, so a sharded gather returning zeros for a
# client the window forgot can only differ from dense on rows whose output
# is masked out of every reduction and never written back.


def make_store_selection(schedule: SamplingSchedule, cfg: FederatedConfig,
                         cohort_size: int, *, sampler=None):
    """The round's *selection head*, jittable in isolation.

    Returns ``select(norms, n_samples, t, sample_key) -> (part, weights,
    cohort_ids)``: the participation draw on the full ``(M,)`` arrays
    (identical ops to the in-program selection of
    :func:`make_cohort_compute`) plus the sorted cohort-id buffer —
    everything the host needs to gather residual rows through a
    :class:`~repro.core.client_store.ClientStateStore` before dispatching
    the cohort-shaped body.  Pass ``norms=None`` for non-adaptive samplers.
    """
    if not (0 < cohort_size <= cfg.num_clients):
        raise ValueError(
            f"cohort_size {cohort_size} not in (0, {cfg.num_clients}]")
    smp = sampler if sampler is not None else UniformSampler()

    def select(norms, n_samples, t, sample_key):
        M = cfg.num_clients
        part, weights = smp.select(sample_key, schedule, t, M, n_samples,
                                   norms)
        ids = jnp.arange(M, dtype=jnp.int32)
        order = jnp.argsort(jnp.where(part > 0, ids, ids + M))
        cohort_ids = jnp.sort(order[:cohort_size])
        return part, weights, cohort_ids

    return select


def make_store_compute(loss_fn: Callable, cfg: FederatedConfig, *,
                       codec=None, attack=None):
    """Cohort-shaped client sweep over PRE-GATHERED residual rows.

    The store-form sibling of :func:`make_cohort_compute`: selection and
    the residual gather already happened outside the program, so this is
    the pure sweep — local updates → wire round-trip → adversary
    injection.  Returns ``compute(params, cohort_res, cohort_batches,
    cohort_ids, mask_key, cohort_drift=None) -> dict`` with keys
    ``uploads`` / ``wired`` / ``attacked`` / ``new_res`` / ``new_drift`` /
    ``losses`` (same meanings as :func:`make_cohort_compute`'s;
    ``cohort_drift`` carries the pre-gathered FedDyn drift rows when the
    objective uses them).  Per-client mask keys are row i of
    ``split(mask_key, M)`` exactly as in every other engine, so client i's
    masking draw does not depend on which execution form ran it.
    """
    attack = _active_attack(attack)
    adv = None
    if attack is not None:
        adv = jnp.asarray(attack.adversary_mask(cfg.num_clients),
                          jnp.float32)

    def compute(params, cohort_res, cohort_batches, cohort_ids, mask_key,
                cohort_drift=None):
        M = cfg.num_clients
        mask_keys = jnp.take(
            jax.random.split(mask_key, M), cohort_ids, axis=0)
        uploads, new_res, new_drift, losses = stacked_client_update(
            loss_fn, params, cohort_batches, mask_keys, cfg.client,
            cohort_res, cfg.error_feedback, cohort_drift)
        wired = roundtrip_stacked(codec, uploads)
        attacked = _attack_payload(attack, wired, adv, mask_key, M,
                                   cohort_ids=cohort_ids)
        return {
            "uploads": uploads,
            "new_res": new_res,
            "new_drift": new_drift,
            "losses": losses,
            "wired": wired,
            "attacked": attacked,
        }

    return compute


@dataclasses.dataclass(frozen=True)
class StoreRound:
    """The store-form round program, split at the store boundary.

    ``select`` and ``body`` are independently jittable; the driver
    (``FederatedServer._run_store``) moves residual rows between them
    through a :class:`~repro.core.client_store.ClientStateStore`.  The
    flags tell the driver which optional state the pieces consume."""

    select: Callable   # (norms, n_samples, t, sample_key) -> (part, w, ids)
    body: Callable     # cohort-shaped barrier; see make_store_round
    adaptive: bool     # body consumes/updates the (M,) norm EMA
    with_drop: bool    # round key splits 3 ways (hetero dropout draw)
    error_feedback: bool  # residual rows need scattering back
    uses_drift: bool = False  # body consumes/emits FedDyn drift rows


def make_store_round(loss_fn: Callable, schedule: SamplingSchedule,
                     cfg: FederatedConfig, cohort_size: int, *,
                     codec=None, aggregator=None, sampler=None, hetero=None,
                     attack=None) -> StoreRound:
    """Store-form sibling of :func:`make_cohort_round`.

    Same math as the generalized cohort body, but state gather/scatter
    are OUTSIDE the program: ``body(params, cohort_res, cohort_drift,
    cohort_batches, cohort_ids, part, weights, norms, mask_key, drop_key)
    -> (new_params, new_rows, drift_rows, commit, norm_upd, metrics)``
    where ``new_rows`` are the finalized post-round residual candidates
    (wire-loss feedback already folded in), ``drift_rows`` the post-round
    FedDyn drift candidates (None unless the objective uses drift),
    ``commit`` is the per-cohort-row "this upload applied" mask
    (``arrived × finite`` — ALWAYS computed; the driver gates the residual
    scatter on ``error_feedback`` and the drift scatter on ``uses_drift``),
    and ``norm_upd`` is the cohort's updated norm-EMA rows (None for
    non-adaptive samplers; rows with no arrival carry the old value, so
    setting them back is a no-op).

    Unlike the in-program engines there is no separate plain path: the
    generalized body IS bit-exact for plain rounds too — the uniform
    sampler's selection draw matches ``participation_mask``, and the only
    difference from ``cohort_select``'s buffer is WHICH non-participants
    pad the cohort, rows that contribute exact zeros to every reduction
    and never commit.
    """
    if not (0 < cohort_size <= cfg.num_clients):
        raise ValueError(
            f"cohort_size {cohort_size} not in (0, {cfg.num_clients}]")
    attack = _active_attack(attack)
    smp, drop = _round_extras(sampler, hetero, cfg)
    _, agg_fn = _resolve_policies(codec, aggregator, smp.normalize)
    compute = make_store_compute(loss_fn, cfg, codec=codec, attack=attack)
    select = make_store_selection(schedule, cfg, cohort_size, sampler=sampler)
    adv = None
    if attack is not None:
        adv = jnp.asarray(attack.adversary_mask(cfg.num_clients),
                          jnp.float32)

    def body(params, cohort_res, cohort_drift, cohort_batches, cohort_ids,
             part, weights, norms, mask_key, drop_key):
        c = compute(params, cohort_res, cohort_batches, cohort_ids, mask_key,
                    cohort_drift)
        uploads, new_res, wired = c["uploads"], c["new_res"], c["wired"]
        losses, payload = c["losses"], c["attacked"]
        drift_rows = c["new_drift"]
        finite = _finite_rows(payload)
        arrived, weights = _apply_dropout(part, weights, drop, drop_key,
                                          smp.normalize)

        def gather(x):
            return jnp.take(x, cohort_ids, axis=0)

        valid = gather(part)
        arr_c = gather(arrived)
        w_c = gather(weights) * finite
        new_params = agg_fn(params, _zero_rows(payload, finite), w_c,
                            cfg.client.upload)
        commit = arr_c * finite
        if cfg.error_feedback:
            # EF feedback on the HONEST (uploads, wired) pair, exactly as
            # in the in-program engines.
            if wired is not uploads:
                new_res = _wire_feedback(new_res, uploads, wired)

        norm_upd = None
        if smp.adaptive:
            obs = _row_l2(payload)
            old_c = gather(norms)
            norm_upd = jnp.where(arr_c * finite > 0,
                                 (1.0 - smp.ema) * old_c + smp.ema * obs,
                                 old_c)

        n_part = jnp.sum(part)
        metrics = {
            "mean_loss": jnp.where(
                n_part > 0,
                jnp.sum(losses * valid) / jnp.maximum(jnp.sum(valid), 1.0),
                jnp.nan),
            "num_sampled": n_part,
            "quarantined": jnp.sum(arr_c * (1.0 - finite)),
        }
        if attack is not None:
            metrics["num_adversarial"] = jnp.sum(part * adv)
        if drop is not None:
            metrics["part_mask"] = part
            metrics["arrived_mask"] = arrived
            metrics["num_arrived"] = jnp.sum(arrived)
        return new_params, new_res, drift_rows, commit, norm_upd, metrics

    return StoreRound(select=select, body=body, adaptive=smp.adaptive,
                      with_drop=drop is not None,
                      error_feedback=cfg.error_feedback,
                      uses_drift=cfg.client.objective.uses_drift)
