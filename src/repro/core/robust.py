"""Byzantine-robust aggregators (DESIGN.md §9).

Every factory here returns a :class:`repro.core.strategy.Aggregator` with
the standard five-argument contract ``fn(global_params, uploads, weights,
upload_semantics, normalize=True)`` — a drop-in for plain FedAvg on the
strategy's ``aggregator`` axis.  Three contract points matter more than
the statistics themselves:

* **Zero-weight rows are absent.**  The full-population oracle hands the
  aggregator all M client rows with zero weights on non-participants; the
  cohort/async engines hand it a padded cohort buffer.  Cohort-vs-oracle
  bit-exactness (DESIGN.md §3.5) therefore requires *weighted*-rank
  statistics in which a zero-weight row can never change the result: the
  weighted median/trim masses skip them, and Krum's pairwise distances
  and candidate set are restricted to ``weight > 0`` rows.
* **HT-weight compatibility is declared, not assumed.**  The weighted
  median and trimmed mean consume Horvitz-Thompson (``normalize=False``)
  weights as sampling masses — robust but no longer unbiased (rank
  statistics are nonlinear).  Krum ignores weight *magnitudes* entirely
  (selection is unweighted), so ``krum``/``multi_krum`` are built with
  ``ht_compatible=False`` and pairing them with an HT sampler
  (importance/threshold) raises at round-build time.
* **Construction-time validation.**  Out-of-range knobs
  (``trimmed_mean(beta=0.6)``, ``krum(f=-1)``, ``norm_filter(0.0)``)
  raise ``ValueError`` naming the knob instead of silently building a
  degenerate rule.

Sparse-upload caveat (§9.4): under selective masking, client supports
differ, so a coordinate owned by fewer than half the cohort's mass has
weighted median 0 — coordinate-wise robust rules act like an *extra*
masking stage on sparse uploads.  Krum compares whole vectors and is
immune to this, but needs ``n >= f + 3`` candidates — pair it with a
sampling floor that keeps an honest majority in every cohort.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.federated import _row_l2, fedavg_aggregate

__all__ = ["coordinate_median", "trimmed_mean", "krum", "multi_krum",
           "norm_filter"]

# Masked-out score/distance sentinel: a finite "infinity" (inf - inf = NaN
# would poison cumulative sums over padded rows).
_BIG = 1e30


def _make_aggregator(name, fn, ht_compatible=True):
    # Deferred import: strategy.py imports this module (registry entries),
    # so the Aggregator record class is looked up at call time.
    from repro.core.strategy import Aggregator
    return Aggregator(name, fn, ht_compatible=ht_compatible)


def _combine(global_params, contribution, upload_semantics):
    """Fold a per-leaf aggregated contribution into the global params under
    the strategy's upload semantics (same convention as fedavg)."""
    def one(g, c):
        if upload_semantics == "delta":
            return (g + c).astype(g.dtype)
        return c.astype(g.dtype)

    return jax.tree.map(one, global_params, contribution)


def _per_coordinate(uploads, reduce_2d):
    """Apply ``reduce_2d((rows, coords) leaf) -> (coords,)`` to every leaf
    of a client-stacked pytree, restoring leaf shapes."""
    def one(u):
        flat = u.reshape(u.shape[0], -1)
        return reduce_2d(flat).reshape(u.shape[1:])

    return jax.tree.map(one, uploads)


def coordinate_median() -> "Aggregator":
    """Coordinate-wise weighted median (breakdown point 1/2 of the weight
    mass per coordinate).

    Per coordinate: sort the row values, accumulate their weights, and
    take the first value whose cumulative mass reaches half the total
    (the lower weighted median).  Zero-weight rows carry no mass, so they
    can never be the crossing value — the oracle's extra rows are exact
    no-ops, and the single-row case degenerates to that row bit-exactly.
    HT-compatible in the *weighted-estimator* sense: the weights act as
    masses, but the median of an unbiased weighting is not itself
    unbiased (documented bias, DESIGN.md §9.3).
    """

    def agg(global_params, uploads, weights, upload_semantics,
            normalize=True):
        w = weights.astype(jnp.float32)
        total = jnp.sum(w)
        half = 0.5 * total

        def med(flat):
            order = jnp.argsort(flat, axis=0)
            vals = jnp.take_along_axis(flat, order, axis=0)
            ws = jnp.take_along_axis(
                jnp.broadcast_to(w[:, None], flat.shape), order, axis=0)
            crossed = jnp.cumsum(ws, axis=0) >= half
            idx = jnp.argmax(crossed, axis=0)
            picked = jnp.take_along_axis(vals, idx[None, :], axis=0)[0]
            # empty round (total mass 0): contribute nothing
            return jnp.where(total > 0, picked, jnp.zeros_like(picked))

        return _combine(global_params, _per_coordinate(uploads, med),
                        upload_semantics)

    return _make_aggregator("coordinate_median", agg)


def trimmed_mean(beta: float) -> "Aggregator":
    """Coordinate-wise ``beta``-trimmed weighted mean (breakdown point
    ``beta`` of the weight mass per coordinate).

    Per coordinate, the lowest and highest ``beta`` fractions of the
    *weight mass* are trimmed (interval-intersection trimming, so partial
    rows at the cut points keep their inside mass) and the remainder is
    averaged.  ``beta=0`` returns plain ``fedavg_aggregate`` itself —
    bit-exact honest-fleet degeneration.  Zero-weight rows have zero kept
    mass at every coordinate, so oracle padding rows are exact no-ops.
    Under HT weights (``normalize=False``) the kept mass is rescaled to
    the full mass so the estimator stays on the absolute scale the
    debiased weights encode.
    """
    if not 0.0 <= beta < 0.5:
        raise ValueError(
            f"trimmed_mean: beta must be in [0, 0.5), got {beta}")
    if beta == 0.0:
        return _make_aggregator(f"trimmed_mean({beta})", fedavg_aggregate)

    def agg(global_params, uploads, weights, upload_semantics,
            normalize=True):
        w = weights.astype(jnp.float32)
        total = jnp.sum(w)
        lo = beta * total
        hi = (1.0 - beta) * total

        def tmean(flat):
            order = jnp.argsort(flat, axis=0)
            vals = jnp.take_along_axis(flat, order, axis=0)
            ws = jnp.take_along_axis(
                jnp.broadcast_to(w[:, None], flat.shape), order, axis=0)
            cum = jnp.cumsum(ws, axis=0)
            # mass of sorted row i inside the kept interval [lo, hi]
            kept = jnp.clip(jnp.minimum(cum, hi)
                            - jnp.maximum(cum - ws, lo), 0.0, None)
            num = jnp.sum(kept * vals, axis=0)
            kept_mass = jnp.maximum(jnp.sum(kept, axis=0), 1e-12)
            if normalize:
                out = num / kept_mass
            else:
                out = num * (total / kept_mass)
            return jnp.where(total > 0, out, jnp.zeros_like(out))

        return _combine(global_params, _per_coordinate(uploads, tmean),
                        upload_semantics)

    return _make_aggregator(f"trimmed_mean({beta})", agg)


def _pairwise_sq_dists(uploads, present):
    """(rows, rows) sum of squared distances over all leaves, with pairs
    touching an absent (zero-weight) row or the diagonal pushed to _BIG."""
    rows = present.shape[0]
    d2 = jnp.zeros((rows, rows), jnp.float32)
    for leaf in jax.tree_util.tree_leaves(uploads):
        flat = leaf.reshape(rows, -1).astype(jnp.float32)
        diff = flat[:, None, :] - flat[None, :, :]
        d2 = d2 + jnp.sum(diff * diff, axis=-1)
    pair_ok = ((present[:, None] > 0) & (present[None, :] > 0)
               & ~jnp.eye(rows, dtype=bool))
    return jnp.where(pair_ok, d2, _BIG)


def _krum_scores(uploads, weights, f):
    """Krum scores over ``weight > 0`` candidate rows: sum of squared
    distances to each candidate's ``n - f - 2`` nearest present
    neighbours (clamped to at least one); absent rows score +inf (strictly
    worse than any present row — a lone candidate's score is the _BIG
    sentinel itself, and it must still win the argmin)."""
    present = (weights > 0).astype(jnp.float32)
    n = jnp.sum(present)
    dist = _pairwise_sq_dists(uploads, present)
    ranked = jnp.sort(dist, axis=1)
    cum = jnp.cumsum(ranked, axis=1)
    # n - f - 2 nearest neighbours; never more than the n - 1 present ones
    # (so the _BIG sentinels stay out of every present row's score).
    k = jnp.clip(n - f - 2, 1, jnp.maximum(n - 1.0, 1.0)).astype(jnp.int32)
    rows = present.shape[0]
    score = jnp.take_along_axis(
        cum, jnp.full((rows, 1), k - 1, jnp.int32), axis=1)[:, 0]
    return jnp.where(present > 0, score, jnp.inf), present, n


def krum(f: int) -> "Aggregator":
    """Krum (Blanchard et al., 2017): apply the single most central
    candidate upload, assuming at most ``f`` Byzantine rows.

    Selection is unweighted (weight magnitudes are ignored beyond
    presence), so this aggregator is NOT Horvitz-Thompson compatible —
    building a round with an HT sampler raises a ``TypeError``.  Needs
    ``n >= f + 3`` present rows for the neighbour count to be meaningful
    (smaller cohorts clamp to the single nearest neighbour).
    """
    if f < 0:
        raise ValueError(f"krum: f must be >= 0, got {f}")

    def agg(global_params, uploads, weights, upload_semantics,
            normalize=True):
        score, present, n = _krum_scores(uploads, weights, f)
        rows = present.shape[0]
        sel = (jnp.arange(rows) == jnp.argmin(score)).astype(jnp.float32)
        # empty round: no candidate, contribute nothing
        sel = sel * (n > 0)
        return fedavg_aggregate(global_params, uploads, sel,
                                upload_semantics, normalize=True)

    return _make_aggregator(f"krum({f})", agg, ht_compatible=False)


def multi_krum(f: int, m: int) -> "Aggregator":
    """Multi-Krum: weighted FedAvg over the ``m`` lowest-Krum-score
    candidates (breakdown: tolerates up to ``f`` of ``n >= 2f + 3``).

    The selected set is scored unweighted (hence ``ht_compatible=False``,
    like :func:`krum`), but the surviving rows are averaged with their
    sampler weights, so Eq. 2's n_i-proportional weighting still applies
    within the trusted set.
    """
    if f < 0:
        raise ValueError(f"multi_krum: f must be >= 0, got {f}")
    if m < 1:
        raise ValueError(f"multi_krum: m must be >= 1, got {m}")

    def agg(global_params, uploads, weights, upload_semantics,
            normalize=True):
        score, present, n = _krum_scores(uploads, weights, f)
        rank = jnp.argsort(jnp.argsort(score))
        sel = (rank < jnp.minimum(float(m), n)).astype(jnp.float32) * present
        return fedavg_aggregate(global_params, uploads, weights * sel,
                                upload_semantics, normalize=True)

    return _make_aggregator(f"multi_krum({f},{m})", agg, ht_compatible=False)


def norm_filter(max_norm: float,
                inner: Optional["Aggregator"] = None) -> "Aggregator":
    """Reject (zero-weight) uploads whose L2 norm exceeds ``max_norm``,
    then delegate to ``inner`` (plain FedAvg by default).

    The hard-reject complement of ``clipped_fedavg``'s soft clip — and
    composable with it: ``norm_filter(10.0, inner=clipped_fedavg(1.0))``
    drops obvious outliers and clips the rest.  Zero-weight rows are
    already absent for every aggregator in this registry, so filtering
    preserves the cohort-vs-oracle guarantee.  HT compatibility is
    inherited from ``inner`` (filtering censors the HT estimator — the
    same documented bias as any rejection rule).
    """
    if max_norm <= 0.0:
        raise ValueError(
            f"norm_filter: max_norm must be > 0, got {max_norm}")
    inner_fn = inner.fn if inner is not None else fedavg_aggregate
    inner_ht = inner.ht_compatible if inner is not None else True
    name = f"norm_filter({max_norm})"
    if inner is not None:
        name += f"+{inner.name}"

    def agg(global_params, uploads, weights, upload_semantics,
            normalize=True):
        keep = (_row_l2(uploads) <= max_norm).astype(weights.dtype)
        return inner_fn(global_params, uploads, weights * keep,
                        upload_semantics, normalize=normalize)

    return _make_aggregator(name, agg, ht_compatible=inner_ht)
