"""Sparse payload encoding + byte accounting for masked uploads.

The paper states masked models are "compressed when uploaded" but does not fix
an encoding.  We implement the two standard ones and meter both:

* **bitmap**   — 1 bit/parameter membership + gamma*P dense values.
* **coordinate** — gamma*P (index, value) pairs, 4-byte int32 indices.

Bitmap wins whenever gamma > value_bits/ (index_bits) ≈ 1/32 for fp32+int32,
so the cost model picks the cheaper automatically (``encoding="auto"``).
This byte accounting feeds the §Roofline collective term for the technique
(DESIGN.md §3.2) and the transport-cost numbers in EXPERIMENTS.md.

Both encodings are REAL wire transforms, not just byte models:
``encode_sparse``/``decode_sparse`` back ``codecs.SparseCodec`` and
``encode_bitmap``/``decode_bitmap`` back ``codecs.BitmapCodec`` (DESIGN.md
§10 derives the crossover).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

__all__ = [
    "payload_bytes",
    "pytree_num_params",
    "pytree_payload_bytes",
    "encode_sparse",
    "decode_sparse",
    "encode_bitmap",
    "decode_bitmap",
    "quantize_int8",
    "dequantize_int8",
    "quantize_pytree",
    "dequantize_pytree",
    "CompressionStats",
]


@dataclasses.dataclass(frozen=True)
class CompressionStats:
    """``encoding`` is the single encoding used, or "mixed" when leaves chose
    differently (auto mode routinely mixes bitmap big leaves with dense small
    ones); ``encoding_bytes`` carries the exact per-encoding byte totals so
    mixed uploads are metered correctly."""

    dense_bytes: int
    sparse_bytes: int
    encoding: str
    encoding_bytes: Mapping[str, int] = dataclasses.field(
        default_factory=dict)

    @property
    def ratio(self) -> float:
        return self.sparse_bytes / max(self.dense_bytes, 1)


def payload_bytes(num_params: int, gamma: float, value_bytes: int = 4,
                  encoding: str = "auto") -> Tuple[int, str]:
    """Bytes to ship ``gamma * num_params`` kept values of one tensor."""
    kept = int(round(gamma * num_params))
    dense = num_params * value_bytes
    if gamma >= 1.0:
        return dense, "dense"
    bitmap = kept * value_bytes + (num_params + 7) // 8
    coord = kept * (value_bytes + 4)
    if encoding == "bitmap":
        return bitmap, "bitmap"
    if encoding == "coordinate":
        return coord, "coordinate"
    if encoding == "auto":
        return (bitmap, "bitmap") if bitmap <= coord else (coord, "coordinate")
    raise ValueError(f"unknown encoding {encoding!r}")


def pytree_num_params(tree: PyTree) -> int:
    return int(sum(np.prod(leaf.shape)
                   for leaf in jax.tree_util.tree_leaves(tree)))


def pytree_payload_bytes(tree: PyTree, gamma: float, min_leaf_size: int = 256,
                         value_bytes: int = 4,
                         encoding: str = "auto") -> CompressionStats:
    """Account a full model upload under per-leaf masking (small leaves dense).

    Byte totals are accumulated PER ENCODING across leaves — an upload that
    mixes bitmap-encoded big leaves with dense small leaves (the common case)
    reports the split in ``encoding_bytes`` rather than whatever the last
    leaf happened to pick.
    """
    dense = 0
    sparse = 0
    per_enc: Dict[str, int] = {}
    for leaf in jax.tree_util.tree_leaves(tree):
        n = int(np.prod(leaf.shape))
        dense += n * value_bytes
        if n < min_leaf_size or gamma >= 1.0:
            b, enc = n * value_bytes, "dense"
        else:
            b, enc = payload_bytes(n, gamma, value_bytes, encoding)
        sparse += b
        per_enc[enc] = per_enc.get(enc, 0) + b
    if len(per_enc) == 1:
        label = next(iter(per_enc))
    else:
        label = "mixed" if per_enc else "dense"
    return CompressionStats(dense, sparse, label, per_enc)


def encode_sparse(masked: jax.Array, k: int) -> Dict[str, jax.Array]:
    """Coordinate-encode a masked tensor: the k nonzero (index, value) pairs.

    Static-shape (k fixed); zero-padded if fewer nonzeros survived the
    threshold.  This is the per-leaf primitive behind
    ``repro.core.codecs.SparseCodec`` — the real client->server wire format;
    the pod path aggregates masked dense tensors and only *meters* these
    bytes.

    Slot selection is MAGNITUDE-ranked (stable, index tie-break): with at
    most k nonzeros the round-trip is bit-exact, and a tensor that
    overflows its slot budget (e.g. a tie plateau on the kernel top-k
    path) degrades gracefully by shedding its *smallest* values — i.e. it
    behaves as a slightly tighter top-k mask, never dropping dominant
    coordinates.  With error feedback on, the shed mass re-enters the
    residual (see ``make_federated_round``).
    """
    if k < 1:
        raise ValueError(f"encode_sparse needs k >= 1, got {k}")
    flat = masked.reshape(-1)
    if k > flat.size:
        raise ValueError(
            f"encode_sparse k={k} exceeds tensor size {flat.size}")
    nz = flat != 0
    # Zeros sort last (+inf key); nonzeros by descending magnitude.
    key = jnp.where(nz, -jnp.abs(flat.astype(jnp.float32)), jnp.inf)
    order = jnp.argsort(key)          # jnp.argsort is stable: index tie-break
    idx = order[:k].astype(jnp.int32)
    vals = flat[idx] * nz[idx].astype(flat.dtype)
    return {"indices": idx, "values": vals,
            "shape": np.asarray(masked.shape, np.int32)}


def _is_concrete(x: Any) -> bool:
    """True when value-level validation is possible (not an abstract
    tracer)."""
    return not isinstance(x, jax.core.Tracer)


def _as_array(x: Any, name: str):
    """Normalize a payload entry to something with shape/dtype, so the
    validators below raise the documented ``ValueError`` (not
    ``AttributeError``) on non-array garbage.  Tracers and arrays pass
    through; lists/scalars coerce via numpy."""
    if hasattr(x, "dtype") and hasattr(x, "shape"):
        return x
    try:
        arr = np.asarray(x)
    except Exception as e:
        raise ValueError(
            f"{name} is not array-like: {type(x).__name__}") from e
    if arr.dtype == object:
        raise ValueError(f"{name} is not array-like: {type(x).__name__}")
    return arr


def decode_sparse(payload: Dict[str, jax.Array]) -> jax.Array:
    """Decode a COO payload back to a dense tensor.

    Malformed payloads fail loudly instead of silently scatter-adding
    garbage: missing keys, index/value length mismatch, non-integer
    indices, or (when the payload is concrete, i.e. not traced)
    out-of-range indices or non-finite values all raise ``ValueError``.
    Traced payloads cannot raise; the in-round quarantine gate
    (``repro.core.async_engine``) masks non-finite rows instead.
    """
    missing = {"indices", "values", "shape"} - set(payload)
    if missing:
        raise ValueError(f"sparse payload missing keys {sorted(missing)}")
    indices = _as_array(payload["indices"], "sparse indices")
    values = _as_array(payload["values"], "sparse values")
    if not jnp.issubdtype(indices.dtype, jnp.integer):
        raise ValueError(
            f"sparse indices must be integers, got {indices.dtype}")
    if indices.shape != values.shape or getattr(indices, "ndim", 1) != 1:
        raise ValueError(
            f"sparse indices/values must be matching 1-D arrays, got "
            f"{indices.shape} vs {values.shape}")
    shape = tuple(int(s) for s in payload["shape"])
    if any(s < 0 for s in shape):
        raise ValueError(f"sparse payload has negative shape {shape}")
    size = int(np.prod(shape, dtype=np.int64)) if shape else 1
    if indices.shape[0] > size:
        raise ValueError(
            f"sparse payload has {indices.shape[0]} slots for a tensor of "
            f"{size} elements")
    if _is_concrete(indices):
        idx = np.asarray(indices)
        if idx.size and (idx.min() < 0 or idx.max() >= size):
            raise ValueError(
                f"sparse indices out of range [0, {size}): "
                f"[{idx.min()}, {idx.max()}]")
    if _is_concrete(values):
        v = np.asarray(values)
        if (np.issubdtype(v.dtype, np.floating) and v.size
                and not np.isfinite(v).all()):
            raise ValueError(
                "sparse payload values contain non-finite entries")
    out = jnp.zeros((size,), values.dtype)
    out = out.at[indices].add(values)
    return out.reshape(shape)


def encode_bitmap(masked: jax.Array, k: int) -> Dict[str, jax.Array]:
    """Bitmap-encode a masked tensor: 1 membership bit/element + k values.

    The wire format behind ``repro.core.codecs.BitmapCodec`` (DESIGN.md
    §10): ``bitmap`` packs the kept-entry membership mask LSB-first
    (byte ``b`` bit ``j`` describes element ``8 b + j``, trailing padding
    bits zero) and ``values`` carries the kept entries in INDEX order,
    zero-padded to the static k slots.  Bytes: ``ceil(n / 8) + k * vb`` vs
    COO's ``k * (4 + vb)`` — bitmap wins whenever the kept density
    ``k / n > 1 / 32``, independent of the value width vb.

    Slot selection mirrors :func:`encode_sparse`: magnitude-ranked with a
    stable index tie-break, so a tensor overflowing its budget sheds its
    smallest values and the round-trip is bit-exact whenever at most k
    nonzeros survived the mask.
    """
    if k < 1:
        raise ValueError(f"encode_bitmap needs k >= 1, got {k}")
    flat = masked.reshape(-1)
    n = flat.size
    if k > n:
        raise ValueError(f"encode_bitmap k={k} exceeds tensor size {n}")
    nz = flat != 0
    key = jnp.where(nz, -jnp.abs(flat.astype(jnp.float32)), jnp.inf)
    order = jnp.argsort(key)
    sel = order[:k]
    keep = jnp.zeros((n,), bool).at[sel].set(nz[sel])
    slot = jnp.cumsum(keep) - 1
    dest = jnp.where(keep, slot, k)          # non-kept -> trash slot k
    vals = jnp.zeros((k + 1,), flat.dtype).at[dest].set(
        jnp.where(keep, flat, jnp.zeros_like(flat)))[:k]
    pad = (-n) % 8
    bits = jnp.pad(keep.astype(jnp.int32), (0, pad)).reshape(-1, 8)
    bm = jnp.sum(bits * (1 << jnp.arange(8)), axis=1).astype(jnp.uint8)
    return {"bitmap": bm, "values": vals,
            "shape": np.asarray(masked.shape, np.int32)}


def decode_bitmap(payload: Dict[str, jax.Array]) -> jax.Array:
    """Decode a bitmap payload back to a dense tensor.

    Mirrors :func:`decode_sparse`'s loud-failure contract: missing keys, a
    non-uint8 or wrongly-sized bitmap, non-1-D values, more value slots
    than elements, and — when the payload is concrete — stray bits in the
    trailing padding, a popcount exceeding the value slots, or non-finite
    values all raise ``ValueError``.  Traced payloads cannot raise; the
    in-round quarantine gate (``repro.core.async_engine``) masks
    non-finite rows instead, and an over-full traced bitmap clips to the
    first k set bits.
    """
    missing = {"bitmap", "values", "shape"} - set(payload)
    if missing:
        raise ValueError(f"bitmap payload missing keys {sorted(missing)}")
    bitmap = _as_array(payload["bitmap"], "bitmap payload bitmap")
    values = _as_array(payload["values"], "bitmap payload values")
    if bitmap.dtype != jnp.uint8:
        raise ValueError(
            f"bitmap payload bitmap must be uint8, got {bitmap.dtype}")
    if getattr(bitmap, "ndim", 1) != 1 or getattr(values, "ndim", 1) != 1:
        raise ValueError(
            f"bitmap payload bitmap/values must be 1-D, got shapes "
            f"{bitmap.shape} vs {values.shape}")
    shape = tuple(int(s) for s in payload["shape"])
    if any(s < 0 for s in shape):
        raise ValueError(f"bitmap payload has negative shape {shape}")
    size = int(np.prod(shape, dtype=np.int64)) if shape else 1
    nb = (size + 7) // 8
    if bitmap.shape[0] != nb:
        raise ValueError(
            f"bitmap payload has {bitmap.shape[0]} bytes for a tensor of "
            f"{size} elements (expected {nb})")
    k = int(values.shape[0])
    if k < 1 or k > size:
        raise ValueError(
            f"bitmap payload has {k} value slots for a tensor of "
            f"{size} elements")
    bits = ((bitmap.astype(jnp.int32)[:, None] >> jnp.arange(8)) & 1)
    bits = bits.reshape(-1)
    if _is_concrete(bits):
        b = np.asarray(bits)
        if b[size:].any():
            raise ValueError(
                "bitmap payload has membership bits set in the trailing "
                "padding")
        if int(b[:size].sum()) > k:
            raise ValueError(
                f"bitmap payload popcount {int(b[:size].sum())} exceeds its "
                f"{k} value slots")
    if _is_concrete(values):
        v = np.asarray(values)
        if (np.issubdtype(v.dtype, np.floating) and v.size
                and not np.isfinite(v).all()):
            raise ValueError(
                "bitmap payload values contain non-finite entries")
    bits = bits[:size].astype(bool)
    slot = jnp.clip(jnp.cumsum(bits) - 1, 0, k - 1)
    out = jnp.where(bits, values[slot], jnp.zeros((), values.dtype))
    return out.reshape(shape)


# ---------------------------------------------------------------------------
# int8 quantised uploads (beyond-paper; composes with selective masking)
# ---------------------------------------------------------------------------
def quantize_int8(x: jax.Array) -> Dict[str, jax.Array]:
    """Symmetric per-tensor int8 quantisation of a (masked) delta.

    Composes with the paper's masking: zeros stay exactly zero (the scale
    maps 0 -> 0), so sparsity encoding is unaffected; the value payload
    drops from 4 to 1 byte per kept entry (bitmap encoding then costs
    gamma*P + P/8 bytes).
    """
    x = _as_array(x, "quantize_int8 input")
    if not jnp.issubdtype(x.dtype, jnp.floating):
        raise ValueError(f"quantize_int8 expects a float tensor, got {x.dtype}")
    # Explicit multiply-by-reciprocal, NOT ``/ 127.0``: XLA strength-reduces
    # constant divisions to reciprocal multiplies in some program shapes but
    # not others, and the ±1 ulp wobble in ``scale`` breaks the cross-engine
    # bit-exactness contract (tests/test_equivalence.py).  Writing the
    # multiply ourselves makes every compiled form — and the Pallas wire
    # kernel (repro/kernels/ops.py), which mirrors this constant — compute
    # the same bits.
    scale = jnp.max(jnp.abs(x)) * jnp.float32(1.0 / 127.0)
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def dequantize_int8(payload: Dict[str, jax.Array]) -> jax.Array:
    """Dequantize an int8 payload; malformed payloads raise ``ValueError``
    (missing keys, non-int8 values, non-scalar or non-finite scale)."""
    missing = {"q", "scale"} - set(payload)
    if missing:
        raise ValueError(f"int8 payload missing keys {sorted(missing)}")
    q = _as_array(payload["q"], "int8 payload q")
    scale = _as_array(payload["scale"], "int8 payload scale")
    if q.dtype != jnp.int8:
        raise ValueError(f"int8 payload q must be int8, got {q.dtype}")
    if getattr(scale, "ndim", 0) != 0:
        raise ValueError(
            f"int8 payload scale must be a scalar, got shape {scale.shape}")
    if _is_concrete(scale) and not np.isfinite(np.asarray(scale)):
        raise ValueError(
            f"int8 payload scale is non-finite: {np.asarray(scale)}")
    return q.astype(jnp.float32) * scale


def quantize_pytree(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(quantize_int8, tree)


def dequantize_pytree(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        dequantize_int8, tree,
        is_leaf=lambda t: isinstance(t, dict) and "q" in t)
