"""On-device client update (paper Alg. 2 / Alg. 4 lines 4-8).

A client downloads the global parameters, runs ``E`` local epochs of
mini-batch SGD on its private shard, computes the parameter delta, masks it,
and uploads.  The update is pure/jit-able so the simulation can ``vmap`` it
over clients and the pod runtime can ``shard_map`` it over the data axis.

Upload semantics (see DESIGN.md §3 and EXPERIMENTS.md):

* ``"delta"`` (default): upload ``mask(W_{t+1} - W_t)``; the server applies it
  to the global model it already holds.  Information-equivalent to the
  paper's masked-weight upload (the server knows W_t and the mask indices)
  and numerically well behaved.
* ``"zero"``: the literal Alg. 4 line 14 — upload ``M ⊗ W_{t+1}`` and let the
  server average the zeroed weights.  Kept as an ablation of the paper's
  exact pseudocode.

Masking cost: with ``cfg.masking.use_kernel`` the whole delta pytree is
masked through the segmented Pallas subsystem (``ops.topk_mask_pytree``,
DESIGN.md §3.4) — ~4 HBM sweeps for the entire model instead of the
per-leaf O(L * iters) loop — which matters because this runs on every
client every round.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core.masking import MaskingConfig, mask_pytree
from repro.core.objectives import LocalObjective

PyTree = Any
LossFn = Callable[[PyTree, Any], jnp.ndarray]

__all__ = ["ClientConfig", "local_sgd", "client_update",
           "stacked_client_update", "local_update_flops"]


@dataclasses.dataclass(frozen=True)
class ClientConfig:
    """Per-client hyperparameters: local SGD (epochs, lr, momentum), the
    mask policy applied to the delta, the upload semantics
    ("delta" | "zero"; see module docstring), and the local objective
    (none / FedProx / FedDyn — ``repro.core.objectives``)."""

    local_epochs: int = 1
    learning_rate: float = 0.01
    momentum: float = 0.0
    masking: MaskingConfig = MaskingConfig()
    upload: str = "delta"  # delta | zero
    objective: LocalObjective = LocalObjective()


def local_sgd(loss_fn: LossFn, params: PyTree, batches: Any,
              cfg: ClientConfig) -> Tuple[PyTree, jnp.ndarray]:
    """Run E epochs of SGD over ``batches`` (a pytree whose leaves have a
    leading (num_batches, ...) axis).  Returns (new_params, mean_loss)."""

    grad_fn = jax.value_and_grad(loss_fn)

    def one_step(carry, batch):
        params, vel = carry
        loss, grads = grad_fn(params, batch)
        if cfg.momentum > 0.0:
            vel = jax.tree.map(lambda v, g: cfg.momentum * v + g, vel, grads)
            step = vel
        else:
            step = grads
        params = jax.tree.map(
            lambda p, g: p - cfg.learning_rate * g.astype(p.dtype), params, step)
        return (params, vel), loss

    def one_epoch(carry, _):
        carry, losses = jax.lax.scan(one_step, carry, batches)
        return carry, jnp.mean(losses)

    vel0 = jax.tree.map(jnp.zeros_like, params)
    (params, _), losses = jax.lax.scan(
        one_epoch, (params, vel0), None, length=cfg.local_epochs)
    return params, jnp.mean(losses)


def client_update(loss_fn: LossFn, global_params: PyTree, batches: Any,
                  mask_key: jax.Array, cfg: ClientConfig,
                  residual: PyTree | None = None,
                  drift: PyTree | None = None,
                  ) -> Tuple[PyTree, PyTree, PyTree | None, jnp.ndarray]:
    """One full client round: local SGD -> delta -> (error feedback) -> mask.

    Returns ``(upload, new_residual, new_drift, mean_loss)`` where
    ``upload`` is the masked delta ("delta" semantics) or the masked local
    weights ("zero").  ``residual`` enables beyond-paper error feedback:
    masked-out mass is accumulated locally and re-added next round (pass
    None to disable, which is the paper-faithful path).  ``drift`` is the
    client's FedDyn ``h_k`` state (required iff
    ``cfg.objective.uses_drift``); ``new_drift`` is the post-round
    ``h_k − alpha·delta`` update computed on the HONEST pre-mask delta, or
    None when the objective carries no drift.
    """
    obj = cfg.objective
    local_loss = obj.localize(loss_fn, global_params, drift)
    local_params, mean_loss = local_sgd(local_loss, global_params, batches,
                                        cfg)
    delta = jax.tree.map(lambda a, b: a - b, local_params, global_params)
    new_drift = obj.update_drift(drift, delta)

    if residual is not None:
        delta = jax.tree.map(lambda d, r: d + r, delta, residual)

    masked = mask_pytree(mask_key, delta, cfg.masking)

    if residual is not None:
        new_residual = jax.tree.map(lambda d, m: d - m, delta, masked)
    else:
        new_residual = jax.tree.map(jnp.zeros_like, delta)

    if cfg.upload == "delta":
        upload = masked
    elif cfg.upload == "zero":
        # Literal Alg. 4: masked *weights*; zeros where the mask dropped.
        # With masking disabled nothing is dropped (a delta entry that
        # happens to be exactly 0 is NOT a masked position).
        if cfg.masking.mode == "none" or cfg.masking.gamma >= 1.0:
            upload = jax.tree.map(lambda g, d: g + d, global_params, delta)
        else:
            keep = jax.tree.map(
                lambda m: (m != 0).astype(m.dtype) if m.ndim > 0 else m,
                masked)
            upload = jax.tree.map(
                lambda g, d, k: (g + d) * k if k.ndim > 0 else g + d,
                global_params, delta, keep)
    else:
        raise ValueError(f"unknown upload semantics {cfg.upload!r}")
    return upload, new_residual, new_drift, mean_loss


def stacked_client_update(loss_fn: LossFn, global_params: PyTree,
                          stacked_batches: Any, mask_keys: jax.Array,
                          cfg: ClientConfig, stacked_residuals: PyTree,
                          error_feedback: bool,
                          stacked_drift: PyTree | None = None,
                          ) -> Tuple[PyTree, PyTree, PyTree | None,
                                     jnp.ndarray]:
    """``client_update`` vmapped over a leading client axis.

    The axis may be the full registered population (oracle round) or a
    padded cohort buffer (cohort engine, DESIGN.md §3.5) — the per-client
    math is identical, which is what the cohort/oracle equivalence tests
    rely on.  ``stacked_drift`` carries the FedDyn per-client drift rows
    (None unless ``cfg.objective.uses_drift``).  Returns stacked
    ``(uploads, new_residuals, new_drift, losses)`` with ``new_drift``
    None when the objective carries no drift.
    """

    def one_client(batches, k, res, dr):
        res_arg = res if error_feedback else None
        return client_update(loss_fn, global_params, batches, k, cfg,
                             res_arg, dr)

    return jax.vmap(one_client)(stacked_batches, mask_keys,
                                stacked_residuals, stacked_drift)


def local_update_flops(stacked_batches: Any, num_params: int,
                       cfg: ClientConfig) -> int:
    """Per-client FLOP proxy for one round: 6 * params * examples seen
    (fwd 2 + bwd 4 per parameter per example), times local epochs.

    A proxy, not an HLO count (see launch/hlo.py for that): it is meant to
    make per-round *relative* cost visible in RoundRecord — full-population
    execution is flat in c(t); the cohort engine decays with it.
    """
    leaf = jax.tree_util.tree_leaves(stacked_batches)[0]
    # leading axes: (clients, num_batches, batch, ...)
    examples = int(leaf.shape[1]) * int(leaf.shape[2])
    return 6 * int(num_params) * examples * int(cfg.local_epochs)
