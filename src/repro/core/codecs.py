"""Wire codecs: real ``encode -> wire pytree -> decode`` upload transforms.

The paper says masked models are "compressed when uploaded" without fixing a
format.  Earlier revisions only *estimated* upload bytes
(``compression.pytree_payload_bytes``); this module is the real wire layer a
:class:`repro.core.strategy.FedStrategy` plugs in:

* ``IdentityCodec``     — dense pass-through (the baseline wire format).
* ``SparseCodec``       — per-leaf coordinate (COO) encoding of a masked
  delta: ``k = max(1, round(gamma * n))`` int32 index + value pairs per
  maskable leaf (leaves under ``min_leaf_size`` ship dense, mirroring
  ``MaskingConfig``).  Bit-exact round-trip whenever the tensor has at most
  k nonzeros — which the threshold masks guarantee (DESIGN.md §3.1).
* ``Int8Codec``         — symmetric per-tensor int8 quantisation of every
  float leaf (zeros stay exactly zero); 4 -> 1 value bytes.
* ``ChainCodec``        — composition, e.g. sparse COO then int8 on the
  surviving values (``Chain(Sparse, Int8)``); decode runs in reverse.
* ``BitmapCodec``       — per-leaf 1-bit/element membership bitmap + k
  values in index order: ``ceil(n/8) + k*vb`` bytes vs COO's ``k*(4+vb)``,
  cheaper whenever the kept density exceeds 1/32 (DESIGN.md §10).
* ``FusedSparseCodec``  — the kernel-backed wire path (DESIGN.md §10):
  ``encode`` runs ``repro.kernels.ops.topk_encode_pytree`` over the
  masked delta, so the COO/bitmap payload (optionally int8-quantised) is
  emitted by ONE fused Pallas sweep instead of the three re-reads the jnp
  codecs above cost.  Wire layout, bytes and decoded values are identical
  to the equivalent jnp codec (``SparseCodec`` / ``BitmapCodec`` /
  ``ChainCodec(..., Int8Codec())``) — the jnp codecs stay verbatim as the
  bit-exactness oracle, and ``decode`` simply delegates to them.

Every codec reports **exact** wire bytes: ``wire_bytes(tree)`` traces
``encode`` with ``jax.eval_shape`` (no FLOPs, no device buffers) and sums
the serialized nbytes of each wire leaf.  All wire shapes are static —
COO slot counts come from gamma and leaf shapes — so the byte count is
exact for every upload, not an estimate.

Encode/decode are jit/vmap-safe; ``roundtrip_stacked`` applies a codec to a
client-stacked upload pytree inside the federated round, so what the
aggregation consumes is exactly what survived the wire.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import (_is_concrete, decode_bitmap,
                                    decode_sparse, dequantize_int8,
                                    encode_bitmap, encode_sparse,
                                    quantize_int8)

PyTree = Any

__all__ = [
    "UploadCodec",
    "IdentityCodec",
    "SparseCodec",
    "Int8Codec",
    "ChainCodec",
    "BitmapCodec",
    "FusedSparseCodec",
    "tree_wire_nbytes",
    "roundtrip_stacked",
    "with_axis0_slices",
]


def _reject_nonfinite(leaf: Any, codec_name: str) -> Any:
    """Decode-boundary validation shared by every codec: a *concrete*
    (host-side, untraced) float payload carrying NaN/Inf raises
    ``ValueError`` before it can reach aggregation or error-feedback
    state.  Traced payloads pass through — inside a jitted round the
    async engine's quarantine gate masks non-finite rows instead
    (``repro.core.async_engine``)."""
    if _is_concrete(leaf):
        arr = np.asarray(leaf)
        if (np.issubdtype(arr.dtype, np.floating) and arr.size
                and not np.isfinite(arr).all()):
            raise ValueError(
                f"{codec_name} decode: payload contains non-finite values")
    return leaf


def _leaf_nbytes(leaf: Any) -> int:
    """Serialized size of one wire leaf — works on concrete arrays and on
    the ``ShapeDtypeStruct`` avals ``jax.eval_shape`` returns."""
    return int(np.prod(leaf.shape, dtype=np.int64)) * np.dtype(leaf.dtype).itemsize


def tree_wire_nbytes(wire: PyTree) -> int:
    """Exact serialized bytes of a wire pytree: sum of leaf nbytes."""
    return int(sum(_leaf_nbytes(leaf)
                   for leaf in jax.tree_util.tree_leaves(wire)))


@dataclasses.dataclass(frozen=True)
class UploadCodec:
    """Base wire codec.  Subclasses implement ``encode``/``decode`` as pure
    jit-able pytree transforms with static wire shapes."""

    name = "identity"

    def encode(self, tree: PyTree) -> PyTree:
        """Upload pytree -> wire pytree (static shapes)."""
        raise NotImplementedError

    def decode(self, wire: PyTree) -> PyTree:
        """Wire pytree -> upload pytree (inverse of :meth:`encode`)."""
        raise NotImplementedError

    def roundtrip(self, tree: PyTree) -> PyTree:
        """What the server sees after the upload crosses the wire."""
        return self.decode(self.encode(tree))

    def wire_bytes(self, tree: PyTree) -> int:
        """EXACT bytes of ``encode(tree)`` — shape-only (eval_shape), so it
        never materializes the wire."""
        template = jax.tree_util.tree_map(
            lambda leaf: jax.ShapeDtypeStruct(leaf.shape, leaf.dtype), tree)
        return tree_wire_nbytes(jax.eval_shape(self.encode, template))


@dataclasses.dataclass(frozen=True)
class IdentityCodec(UploadCodec):
    """Dense pass-through: the wire is the pytree itself."""

    name = "identity"

    def encode(self, tree: PyTree) -> PyTree:
        """The wire IS the upload pytree."""
        return tree

    def decode(self, wire: PyTree) -> PyTree:
        """The upload IS the wire pytree — after the non-finite gate."""
        return jax.tree_util.tree_map(
            lambda leaf: _reject_nonfinite(leaf, "identity"), wire)

    def roundtrip(self, tree: PyTree) -> PyTree:
        """Free: dense pass-through loses nothing."""
        return tree


def _is_coo(leaf: Any) -> bool:
    return isinstance(leaf, dict) and "indices" in leaf and "values" in leaf


def _is_q8(leaf: Any) -> bool:
    return isinstance(leaf, dict) and "q" in leaf and "scale" in leaf


@dataclasses.dataclass(frozen=True)
class SparseCodec(UploadCodec):
    """Per-leaf COO wire format for masked uploads.

    Mirrors the masking config it rides with: leaves smaller than
    ``min_leaf_size`` were never masked, so they ship dense; every other
    leaf ships ``k = max(1, round(gamma * n))`` (index, value) slots —
    the static capacity the threshold masks fill to at most k nonzeros
    (DESIGN.md §3.1), zero-padded below that.  Round-trip is bit-exact
    under that contract (property-tested in tests/test_codecs.py).
    """

    gamma: float = 0.1
    min_leaf_size: int = 256
    # Slot budgeting convention.  False (default): one top-k budget per
    # whole leaf — matches ``core.masking.mask_pytree``.  True: ndim >= 2
    # leaves get ``shape[0] * max(1, round(gamma * slice_size))`` slots —
    # matches the pod path's per-first-axis-slice masks
    # (``launch.fedtrain._threshold_mask`` / the kernel route), which can
    # keep more than round(gamma * n) entries per leaf in total.
    axis0_slices: bool = False

    @property
    def name(self) -> str:  # type: ignore[override]
        """Wire-format label surfaced in ``FederatedServer.summary()``."""
        suffix = ", per-slice" if self.axis0_slices else ""
        return f"sparse(gamma={self.gamma}{suffix})"

    def _slots(self, size: int) -> int:
        return max(1, int(round(self.gamma * size)))

    def _leaf_slots(self, leaf) -> int:
        if self.axis0_slices and leaf.ndim >= 2:
            return leaf.shape[0] * self._slots(leaf.size // leaf.shape[0])
        return self._slots(leaf.size)

    def encode(self, tree: PyTree) -> PyTree:
        """COO-encode every maskable leaf (small leaves ship dense)."""
        def enc(leaf):
            if leaf.size < self.min_leaf_size or self.gamma >= 1.0:
                return leaf
            return encode_sparse(leaf, min(self._leaf_slots(leaf), leaf.size))

        return jax.tree_util.tree_map(enc, tree)

    def decode(self, wire: PyTree) -> PyTree:
        """Scatter every COO leaf back to dense; pass dense leaves (after
        the non-finite gate — COO values are checked in decode_sparse)."""
        return jax.tree_util.tree_map(
            lambda leaf: (decode_sparse(leaf) if _is_coo(leaf)
                          else _reject_nonfinite(leaf, self.name)),
            wire, is_leaf=_is_coo)


@dataclasses.dataclass(frozen=True)
class Int8Codec(UploadCodec):
    """Symmetric per-tensor int8 quantisation of every float leaf.

    Composable after :class:`SparseCodec`: int32 indices and shape metadata
    pass through untouched; only float value payloads quantise.  Zeros map
    to exactly zero, so sparsity structure survives; the dequantised error
    per entry is bounded by ``scale/2 = max|x| / 254`` (rounding half a
    step), property-tested in tests/test_codecs.py.
    """

    name = "int8"

    def encode(self, tree: PyTree) -> PyTree:
        """Quantise every float leaf to (int8 q, fp32 scale) pairs."""
        def enc(leaf):
            if jnp.issubdtype(leaf.dtype, jnp.floating):
                return quantize_int8(leaf)
            return leaf

        return jax.tree_util.tree_map(enc, tree)

    def decode(self, wire: PyTree) -> PyTree:
        """Dequantise every (q, scale) leaf back to float32; float
        pass-through leaves hit the non-finite gate (q8 scales are checked
        in dequantize_int8)."""
        return jax.tree_util.tree_map(
            lambda leaf: (dequantize_int8(leaf) if _is_q8(leaf)
                          else _reject_nonfinite(leaf, "int8")),
            wire, is_leaf=_is_q8)


def _is_bitmap(leaf: Any) -> bool:
    return isinstance(leaf, dict) and "bitmap" in leaf and "values" in leaf


@dataclasses.dataclass(frozen=True)
class BitmapCodec(UploadCodec):
    """Per-leaf bitmap wire format for masked uploads (DESIGN.md §10).

    Same slot budgeting as :class:`SparseCodec` (leaves under
    ``min_leaf_size`` ship dense, others get ``k = max(1, round(gamma*n))``
    value slots), but membership ships as 1 bit/element instead of a 4-byte
    index per kept value: ``ceil(n/8) + k*vb`` wire bytes vs COO's
    ``k*(4+vb)``.  Bitmap is the cheaper wire whenever the kept density
    exceeds ``1/32`` (~3.1%) — independent of the value width, so the
    crossover survives int8 chaining.  Round-trip is bit-exact whenever at
    most k nonzeros survived the mask, like the COO codec.
    """

    gamma: float = 0.1
    min_leaf_size: int = 256

    @property
    def name(self) -> str:  # type: ignore[override]
        """Wire-format label surfaced in ``FederatedServer.summary()``."""
        return f"bitmap(gamma={self.gamma})"

    def _slots(self, size: int) -> int:
        return max(1, int(round(self.gamma * size)))

    def encode(self, tree: PyTree) -> PyTree:
        """Bitmap-encode every maskable leaf (small leaves ship dense)."""
        def enc(leaf):
            if leaf.size < self.min_leaf_size or self.gamma >= 1.0:
                return leaf
            return encode_bitmap(leaf, min(self._slots(leaf.size), leaf.size))

        return jax.tree_util.tree_map(enc, tree)

    def decode(self, wire: PyTree) -> PyTree:
        """Expand every bitmap leaf back to dense; pass dense leaves (after
        the non-finite gate — bitmap payloads are validated in
        decode_bitmap)."""
        return jax.tree_util.tree_map(
            lambda leaf: (decode_bitmap(leaf) if _is_bitmap(leaf)
                          else _reject_nonfinite(leaf, self.name)),
            wire, is_leaf=_is_bitmap)


@dataclasses.dataclass(frozen=True)
class FusedSparseCodec(UploadCodec):
    """Kernel-backed wire path: mask -> pack -> quantise in one HBM sweep.

    ``encode`` routes the (already-masked) upload pytree through
    ``repro.kernels.ops.topk_encode_pytree(assume_masked=True)`` — the
    fused segmented Pallas sweep emits the COO (``wire="coo"``) or bitmap
    (``wire="bitmap"``) payload, int8-quantised in the same pass when
    ``quantized`` — instead of re-reading the masked fp32 pytree three
    more times like the jnp codec chain (DESIGN.md §10,
    ``ops.wirepath_sweep_count``).

    The wire is structurally and byte-identical to the equivalent jnp
    codec — ``SparseCodec`` / ``BitmapCodec``, chained with
    :class:`Int8Codec` when ``quantized`` — and ``decode`` delegates to
    those oracles, inheriting their malformed-payload validation.  Decoded
    values are bit-exact vs the oracle whenever each leaf's nonzero count
    fits its slot budget (threshold masks guarantee this off tie
    plateaus); on an overflowing plateau the fused path sheds by highest
    index where the oracle sheds smallest magnitude.

    ``interpret=None`` auto-detects (CPU containers run the Pallas kernels
    in interpret mode; TPU compiles them).
    """

    gamma: float = 0.1
    min_leaf_size: int = 256
    quantized: bool = False
    wire: str = "coo"           # coo | bitmap
    interpret: bool | None = None

    def __post_init__(self):
        if self.wire not in ("coo", "bitmap"):
            raise ValueError(f"unknown wire format {self.wire!r}")

    @property
    def name(self) -> str:  # type: ignore[override]
        """Wire-format label surfaced in ``FederatedServer.summary()``."""
        kind = "bitmap" if self.wire == "bitmap" else "sparse"
        suffix = "+int8" if self.quantized else ""
        return f"fused-{kind}(gamma={self.gamma}){suffix}"

    def _oracle(self) -> UploadCodec:
        """The jnp codec whose wire this codec reproduces byte-for-byte."""
        base: UploadCodec = (
            BitmapCodec(gamma=self.gamma, min_leaf_size=self.min_leaf_size)
            if self.wire == "bitmap"
            else SparseCodec(gamma=self.gamma,
                             min_leaf_size=self.min_leaf_size))
        if self.quantized:
            return ChainCodec((base, Int8Codec()))
        return base

    def encode(self, tree: PyTree) -> PyTree:
        """One fused kernel sweep from masked delta to wire payload."""
        from repro.kernels import ops

        wire = ops.topk_encode_pytree(
            tree, self.gamma, min_leaf_size=self.min_leaf_size,
            quantize=self.quantized, wire=self.wire, assume_masked=True,
            interpret=self.interpret)
        if not self.quantized:
            return wire

        # The kernel only touches maskable leaves; quantise the small dense
        # float pass-through leaves here so the wire is byte-identical to
        # the ChainCodec oracle (whose Int8 stage quantises every float
        # leaf).
        def payload(leaf):
            return _is_coo(leaf) or _is_bitmap(leaf)

        def small(leaf):
            if not payload(leaf) and jnp.issubdtype(leaf.dtype, jnp.floating):
                return quantize_int8(leaf)
            return leaf

        return jax.tree_util.tree_map(small, wire, is_leaf=payload)

    def decode(self, wire: PyTree) -> PyTree:
        """Delegate to the jnp oracle codec (same wire, same validation)."""
        return self._oracle().decode(wire)


@dataclasses.dataclass(frozen=True)
class ChainCodec(UploadCodec):
    """Left-to-right composition: ``encode`` folds forward through
    ``stages``, ``decode`` unwinds in reverse — e.g.
    ``ChainCodec((SparseCodec(g), Int8Codec()))`` ships int8-quantised COO
    values."""

    stages: Tuple[UploadCodec, ...] = ()

    def __post_init__(self):
        if not self.stages:
            raise ValueError("ChainCodec needs at least one stage")

    @property
    def name(self) -> str:  # type: ignore[override]
        """Stage names joined with "+" (e.g. ``sparse(gamma=0.5)+int8``)."""
        return "+".join(s.name for s in self.stages)

    def encode(self, tree: PyTree) -> PyTree:
        """Fold every stage's encode left-to-right."""
        for stage in self.stages:
            tree = stage.encode(tree)
        return tree

    def decode(self, wire: PyTree) -> PyTree:
        """Unwind every stage's decode in reverse order."""
        for stage in reversed(self.stages):
            wire = stage.decode(wire)
        return wire


def with_axis0_slices(codec: UploadCodec) -> UploadCodec:
    """Re-budget every SparseCodec stage to the pod path's
    per-first-axis-slice masking granularity (see
    ``SparseCodec.axis0_slices``); other codecs — including the
    whole-leaf-budgeted :class:`BitmapCodec` / :class:`FusedSparseCodec`,
    which are simulation-engine wires — pass through unchanged."""
    if isinstance(codec, SparseCodec):
        return dataclasses.replace(codec, axis0_slices=True)
    if isinstance(codec, ChainCodec):
        return ChainCodec(tuple(with_axis0_slices(s) for s in codec.stages))
    return codec


def roundtrip_stacked(codec: UploadCodec | None, stacked: PyTree) -> PyTree:
    """Round-trip a client-stacked upload pytree (leading client axis per
    leaf) through ``codec``, restoring each leaf's original dtype (int8
    dequantisation comes back f32).  ``None`` / identity are free."""
    if codec is None or isinstance(codec, IdentityCodec):
        return stacked
    wired = jax.vmap(codec.roundtrip)(stacked)
    return jax.tree_util.tree_map(
        lambda w, ref: w.astype(ref.dtype), wired, stacked)
