"""Central-server training loop (paper Alg. 1 / Alg. 3 outer procedure).

``FederatedServer`` owns the global model, runs R communication rounds,
meters transport bytes per round (sampling × masking × encoding, see
``repro.core.compression``), and evaluates on a held-out set.

Two execution engines (DESIGN.md §3.5):

* ``engine="cohort"`` (default): per round, only the sampled cohort is
  materialized and executed — the cohort buffer size is bucketed to
  ``SamplingSchedule.bucket_ladder`` so recompiles stay O(log M) as c(t)
  anneals.  Consecutive rounds sharing a bucket are folded into one
  ``lax.scan`` dispatch.  Rounds whose bucket is the full population fall
  through to the oracle program, so full-participation runs are
  bit-identical to the legacy path.
* ``engine="full"``: the original full-population vmap (every registered
  client runs; non-participants are zero-weighted) — kept as the oracle
  the cohort engine is property-tested against.

Each distinct (bucket, segment-length) program is AOT-compiled once and
cached; compile time is recorded on the triggering round's
``RoundRecord.compile_s`` instead of polluting ``wall_s``, so bench JSON
reflects steady-state per-round cost.

This is the *simulation* driver used by the paper-reproduction benchmarks
(Figs. 3-9).  The pod-scale driver is ``repro.launch.train``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.client import local_update_flops
from repro.core.compression import pytree_payload_bytes, pytree_num_params
from repro.core.federated import (FederatedConfig, make_cohort_round,
                                  make_cohort_scan, make_federated_round)
from repro.core.sampling import SamplingSchedule

PyTree = Any

__all__ = ["RoundRecord", "FederatedServer"]


@dataclasses.dataclass
class RoundRecord:
    round: int
    num_sampled: int
    mean_loss: float
    transport_units: float      # full-model-upload units this round (Eq. 6 basis)
    transport_bytes: int        # metered bytes (values + index overhead)
    eval_metric: Optional[float] = None
    wall_s: float = 0.0         # steady-state execution time (compile excluded)
    compile_s: float = 0.0      # program build time; nonzero on bucket-change rounds
    cohort_size: int = 0        # padded cohort buffer actually executed
    flop_proxy: float = 0.0     # 6·params·examples·epochs·cohort_size (proxy)


class FederatedServer:
    """Owns Θ_t; runs rounds; meters communication."""

    def __init__(self, loss_fn: Callable, schedule: SamplingSchedule,
                 cfg: FederatedConfig, init_params: PyTree,
                 eval_fn: Optional[Callable] = None, seed: int = 0,
                 engine: str = "cohort", scan_rounds: bool = True):
        if engine not in ("cohort", "full"):
            raise ValueError(f"unknown engine {engine!r}")
        self.cfg = cfg
        self.schedule = schedule
        self.params = init_params
        self.eval_fn = eval_fn
        self.engine = engine
        self.scan_rounds = scan_rounds
        self._loss_fn = loss_fn
        self._key = jax.random.PRNGKey(seed)
        self._compiled: Dict[tuple, Any] = {}   # (bucket, seg_len) -> executable
        self._residuals = jax.tree.map(
            lambda p: jnp.zeros((cfg.num_clients,) + p.shape, p.dtype),
            init_params)
        self.history: List[RoundRecord] = []
        self._num_params = pytree_num_params(init_params)

    # ---- engine dispatch -------------------------------------------------
    def _round_program(self, bucket: int, seg_len: int):
        """Build the (bucket, seg_len) round program (uncompiled)."""
        if seg_len > 1:
            return make_cohort_scan(
                self._loss_fn, self.schedule, self.cfg, bucket)
        if bucket >= self.cfg.num_clients:
            return make_federated_round(self._loss_fn, self.schedule, self.cfg)
        return make_cohort_round(
            self._loss_fn, self.schedule, self.cfg, bucket)

    def _get_compiled(self, bucket: int, seg_len: int, args):
        """AOT-compile (once) the program for this bucket/segment shape.
        Returns ``(executable, compile_s)`` — compile_s is 0 on cache hit.
        The key includes the input avals so a later ``run()`` with
        differently-shaped data recompiles instead of hitting a stale
        executable (AOT calls don't retrace the way plain jit does)."""
        avals = tuple((tuple(leaf.shape), str(leaf.dtype))
                      for leaf in jax.tree_util.tree_leaves(args))
        cache_key = (bucket, seg_len, avals)
        hit = self._compiled.get(cache_key)
        if hit is not None:
            return hit, 0.0
        fn = self._round_program(bucket, seg_len)
        t0 = time.perf_counter()
        compiled = jax.jit(fn).lower(*args).compile()
        compile_s = time.perf_counter() - t0
        self._compiled[cache_key] = compiled
        return compiled, compile_s

    def _segments(self, rounds: int, eval_rounds) -> List[tuple]:
        """Split 1..rounds into (bucket, [t...]) segments: consecutive rounds
        sharing a cohort bucket, broken at eval rounds (the host needs Θ_t
        there).  engine="full" pins every bucket to the full population."""
        M = self.cfg.num_clients
        plan = self.schedule.round_buckets(rounds, M)
        segments: List[tuple] = []
        for t, (_m, bucket) in zip(range(1, rounds + 1), plan):
            b_eff = bucket if self.engine == "cohort" else M
            if (segments and self.scan_rounds
                    and segments[-1][0] == b_eff
                    and (t - 1) not in eval_rounds):
                segments[-1][1].append(t)
            else:
                segments.append((b_eff, [t]))
        return segments

    # ---- training loop ---------------------------------------------------
    def run(self, client_batches: PyTree, n_samples: np.ndarray,
            rounds: int, eval_every: int = 0,
            eval_data: Any = None) -> List[RoundRecord]:
        gamma = self.cfg.client.masking.gamma \
            if self.cfg.client.masking.mode != "none" else 1.0
        stats = pytree_payload_bytes(
            self.params, gamma, self.cfg.client.masking.min_leaf_size)
        self._compression = stats        # per-encoding byte split for summary()
        n_samples = jnp.asarray(n_samples, jnp.float32)
        flops_per_client = local_update_flops(
            client_batches, self._num_params, self.cfg.client)

        eval_rounds = set()
        if eval_every and self.eval_fn is not None:
            eval_rounds = {t for t in range(1, rounds + 1)
                           if t % eval_every == 0 or t == rounds}

        for bucket, ts in self._segments(rounds, eval_rounds):
            seg_len = len(ts)
            subs = []
            for _ in ts:
                self._key, sub = jax.random.split(self._key)
                subs.append(sub)
            if seg_len > 1:
                t_arg = jnp.asarray(ts, jnp.float32)
                key_arg = jnp.stack(subs)
            else:
                t_arg = jnp.asarray(ts[0], jnp.float32)
                key_arg = subs[0]
            args = (self.params, self._residuals, client_batches, n_samples,
                    t_arg, key_arg)
            compiled, compile_s = self._get_compiled(bucket, seg_len, args)
            t0 = time.perf_counter()
            self.params, self._residuals, metrics = compiled(*args)
            jax.block_until_ready(self.params)
            wall = time.perf_counter() - t0

            num_sampled = np.atleast_1d(np.asarray(metrics["num_sampled"]))
            mean_loss = np.atleast_1d(np.asarray(metrics["mean_loss"]))
            for i, t in enumerate(ts):
                m = float(num_sampled[i])
                rec = RoundRecord(
                    round=t,
                    num_sampled=int(m),
                    mean_loss=float(mean_loss[i]),
                    transport_units=m * gamma,
                    transport_bytes=int(m) * stats.sparse_bytes,
                    wall_s=wall / seg_len,
                    compile_s=compile_s if i == 0 else 0.0,
                    cohort_size=bucket,
                    flop_proxy=float(flops_per_client) * bucket,
                )
                if t in eval_rounds and t == ts[-1]:
                    rec.eval_metric = float(self.eval_fn(self.params, eval_data))
                self.history.append(rec)
        return self.history

    # ---- reporting ------------------------------------------------------
    def total_transport_units(self) -> float:
        return float(sum(r.transport_units for r in self.history))

    def total_transport_bytes(self) -> int:
        return int(sum(r.transport_bytes for r in self.history))

    def summary(self) -> Dict[str, Any]:
        evals = [r.eval_metric for r in self.history if r.eval_metric is not None]
        out = {
            "rounds": len(self.history),
            "final_loss": self.history[-1].mean_loss if self.history else float("nan"),
            "final_eval": evals[-1] if evals else float("nan"),
            "transport_units": self.total_transport_units(),
            "transport_GB": self.total_transport_bytes() / 1e9,
            "num_params": self._num_params,
            "engine": self.engine,
            "compile_s": float(sum(r.compile_s for r in self.history)),
            "steady_wall_s": float(sum(r.wall_s for r in self.history)),
        }
        stats = getattr(self, "_compression", None)
        if stats is not None:
            # Mixed bitmap/coordinate/dense uploads: report the exact split
            # (bytes per model upload per encoding), not just the last leaf's.
            out["upload_encoding"] = stats.encoding
            out["upload_encoding_bytes"] = dict(stats.encoding_bytes)
        return out
