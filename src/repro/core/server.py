"""Central-server training loop (paper Alg. 1 / Alg. 3 outer procedure).

``FederatedServer`` owns the global model, runs R communication rounds,
meters transport bytes per round, and evaluates on a held-out set.  The
scenario it runs — sampling schedule, mask policy, wire codec, aggregation
rule, client hyperparameters — is a single
:class:`repro.core.strategy.FedStrategy`; construct the server with
:meth:`FederatedServer.from_strategy` (the legacy ``(loss_fn, schedule,
cfg, ...)`` kwargs still work behind a ``DeprecationWarning`` shim that
synthesizes an equivalent strategy).

Transport is metered by the strategy's codec: every client upload is
round-tripped through the codec's wire format inside the round program, and
``RoundRecord.transport_bytes`` counts the EXACT serialized bytes of that
wire pytree (``UploadCodec.wire_bytes``, shape-only via ``eval_shape``) —
not the ``pytree_payload_bytes`` estimate earlier revisions reported.

Two further scenario axes ride on the strategy (DESIGN.md §5): adaptive
client samplers (importance/threshold) make the server carry a per-client
update-norm tracker as round-program state next to the error-feedback
residuals, and a ``HeteroModel`` fleet adds in-round upload dropout plus
host-side clock simulation — ``RoundRecord.sim_round_s`` (straggler
wall-clock on the simulated fleet), ``straggler_s`` and ``dropped``.

Three execution engines (DESIGN.md §3.5, §8):

* ``engine="cohort"`` (default): per round, only the sampled cohort is
  materialized and executed — the cohort buffer size is bucketed to
  ``SamplingSchedule.bucket_ladder`` so recompiles stay O(log M) as c(t)
  anneals.  Consecutive rounds sharing a bucket are folded into one
  ``lax.scan`` dispatch.  Rounds whose bucket is the full population fall
  through to the oracle program, so full-participation runs are
  bit-identical to the legacy path.
* ``engine="full"``: the original full-population vmap (every registered
  client runs; non-participants are zero-weighted) — kept as the oracle
  the cohort engine is property-tested against, under every registry
  preset (tests/test_strategy.py).
* ``engine="async"``: FedBuff-style asynchronous buffered aggregation
  (``repro.core.async_engine``) — uploads apply as they *arrive* on the
  strategy's simulated fleet, K at a time with staleness-discounted
  weights, under a failure model (deadlines, retry/backoff, upload
  quarantine) configured by ``strategy.async_cfg``.  Degenerates
  bit-exactly to the cohort engine on an instant fleet with no faults
  (property-tested in tests/test_async.py); per-round fault accounting
  lands in the new ``RoundRecord`` fields (arrivals, timeouts, retries,
  quarantined, flushes, mean_staleness).

The server also carries a persistent round counter: ``save_state`` /
``restore_state`` round-trip the full training state (params, EF
residuals, sampler norm EMAs, RNG key, round counter) through
``repro.checkpoint.checkpoint``, and ``run()`` continues from the restored
round — a resumed run is bit-identical to an uninterrupted one
(tests/test_async.py::test_crash_resume_bit_exact, both engines).

Each distinct (bucket, segment-length) program is AOT-compiled once and
cached; compile time is recorded on the triggering round's
``RoundRecord.compile_s`` instead of polluting ``wall_s``, so bench JSON
reflects steady-state per-round cost.

This is the *simulation* driver used by the paper-reproduction benchmarks
(Figs. 3-9).  The pod-scale driver is ``repro.launch.train``.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.client import local_update_flops
from repro.core.client_store import ClientStateStore, DenseStore
from repro.core.compression import pytree_num_params
from repro.core.federated import FederatedConfig, _split_round_key
from repro.core.hetero import simulate_round
from repro.core.sampling import SamplingSchedule

PyTree = Any

__all__ = ["RoundRecord", "FederatedServer"]


@dataclasses.dataclass
class RoundRecord:
    """Per-round ledger entry: who participated, what it cost (measured
    wall-clock, exact wire bytes) and — when the strategy carries a
    :class:`repro.core.hetero.HeteroModel` — what the round would have cost
    on the simulated fleet (``sim_round_s`` straggler wall-clock,
    ``straggler_s`` tail above the median arrival, ``dropped`` lost
    uploads)."""

    round: int
    num_sampled: int
    mean_loss: float
    transport_units: float      # full-model-upload units this round (Eq. 6 basis)
    transport_bytes: int        # EXACT wire bytes (codec-encoded uploads)
    eval_metric: Optional[float] = None
    wall_s: float = 0.0         # steady-state execution time (compile excluded)
    compile_s: float = 0.0      # program build time; nonzero on bucket-change rounds
    cohort_size: int = 0        # padded cohort buffer actually executed
    flop_proxy: float = 0.0     # 6·params·examples·epochs·cohort_size (proxy)
    sim_round_s: float = 0.0    # simulated fleet wall-clock (hetero only)
    straggler_s: float = 0.0    # sim straggler tail: max - median arrival
    dropped: int = 0            # uploads lost on the simulated fleet
    # --- async-engine accounting (engine="async" only; DESIGN.md §8) ---
    arrivals: int = 0           # uploads accepted into a buffer flush
    timeouts: int = 0           # uploads cut by the round deadline
    retries: int = 0            # retransmissions scheduled after drops
    quarantined: int = 0        # uploads rejected at the decode gate (ALL engines, §9)
    flushes: int = 0            # buffer flushes applied this round
    mean_staleness: float = 0.0  # mean flush-count staleness of applied rows
    # --- cross-round staleness (max_round_stale > 0 only; DESIGN.md §11.1) ---
    carried: int = 0            # deadline-cut uploads applied from earlier rounds
    pending: int = 0            # uploads still parked for a later round
    # --- Byzantine accounting (strategy.attack set; DESIGN.md §9) ---
    adversarial: int = 0        # adversary-controlled participants this round


class FederatedServer:
    """Owns Θ_t; runs rounds; meters communication."""

    def __init__(self, loss_fn: Callable = None, schedule: SamplingSchedule = None,
                 cfg: FederatedConfig = None, init_params: PyTree = None,
                 eval_fn: Optional[Callable] = None, seed: int = 0,
                 engine: str = "cohort", scan_rounds: bool = True, *,
                 strategy=None, num_clients: int = None,
                 store: Optional[ClientStateStore] = None):
        """Legacy kwargs constructor — DEPRECATED shim for one release.

        Prefer :meth:`from_strategy`.  The ``(schedule, cfg)`` pair is
        converted to an equivalent :class:`FedStrategy` (codec derived from
        the masking config), so both paths run the identical round
        program.
        """
        if strategy is None:
            if schedule is None or cfg is None:
                raise TypeError(
                    "FederatedServer needs either strategy=/num_clients= or "
                    "the legacy (schedule, cfg) pair")
            warnings.warn(
                "FederatedServer(loss_fn, schedule, cfg, ...) is deprecated; "
                "use FederatedServer.from_strategy(strategy, loss_fn, "
                "init_params, num_clients, ...) with a repro.core.strategy."
                "FedStrategy (see strategy.get presets)",
                DeprecationWarning, stacklevel=2)
            from repro.core.strategy import FedStrategy
            strategy = FedStrategy.from_components(
                "legacy", schedule, cfg.client.masking,
                local_epochs=cfg.client.local_epochs,
                learning_rate=cfg.client.learning_rate,
                momentum=cfg.client.momentum,
                upload=cfg.client.upload,
                error_feedback=cfg.error_feedback)
            num_clients = cfg.num_clients
        if engine not in ("cohort", "full", "async"):
            raise ValueError(f"unknown engine {engine!r}")
        if num_clients is None:
            raise TypeError("from_strategy/strategy= requires num_clients")
        self.strategy = strategy
        self.cfg = strategy.federated_config(num_clients)
        self.schedule = strategy.sampling
        self.params = init_params
        self.eval_fn = eval_fn
        self.engine = engine
        self.scan_rounds = scan_rounds
        self._loss_fn = loss_fn
        self._key = jax.random.PRNGKey(seed)
        self._compiled: Dict[tuple, Any] = {}   # (bucket, seg_len) -> executable
        self._store_programs: Dict[int, Any] = {}  # bucket -> StoreRound
        # All per-client server state — EF residuals, the adaptive
        # samplers' norm EMAs (ones = "everyone looks equally important"
        # until data arrives, so round 1 ~ uniform), model versions —
        # lives in a ClientStateStore (DESIGN.md §11).  The default dense
        # backend reproduces the historical (M, …) arrays bit for bit; a
        # sharded store holds residuals only inside its retention window
        # and routes sync rounds through _run_store.
        self._adaptive = strategy.sampler.adaptive
        # FedDyn's per-client drift vector is a SECOND O(M × model) state
        # tree riding the same store as the residuals (DESIGN.md §12).
        self._uses_drift = self.cfg.client.objective.uses_drift
        if store is None:
            extra = {"drift": init_params} if self._uses_drift else None
            store = DenseStore(num_clients, init_params,
                               track_norms=self._adaptive,
                               extra_trees=extra)
        if store.num_clients != num_clients:
            raise ValueError(
                f"store was built for {store.num_clients} clients but the "
                f"server registers {num_clients}")
        if self._adaptive and store.norms is None:
            raise ValueError(
                f"strategy {strategy.name!r} uses an adaptive sampler; "
                "build the store with track_norms=True")
        if self._uses_drift and "drift" not in store.trees:
            raise ValueError(
                f"strategy {strategy.name!r} carries FedDyn drift state; "
                "build the store with extra_trees={'drift': init_params}")
        if engine == "full" and store.kind != "dense":
            raise ValueError(
                "engine='full' materializes every client's state per round "
                f"— incompatible with a {store.kind!r} store; use "
                "engine='cohort' or 'async'")
        self.store = store
        # Simulated-fleet traits (static per-client draws) for the hetero
        # round clock; None on the paper's ideal homogeneous fleet.
        self._traits = (strategy.hetero.client_traits(num_clients)
                        if strategy.hetero is not None else None)
        # Absolute round counter: run() continues from here, so a server
        # restored via restore_state resumes mid-run bit-identically.
        self._round = 0
        self._async = None
        if engine == "async":
            from repro.core.async_engine import AsyncRoundRunner
            self._async = AsyncRoundRunner(strategy, loss_fn, num_clients,
                                           store=self.store)
        self.history: List[RoundRecord] = []
        self._num_params = pytree_num_params(init_params)
        # Exact per-client-upload wire bytes: the codec's encode traced
        # shape-only over a delta template (same avals as params).
        self.client_upload_bytes = strategy.codec.wire_bytes(init_params)

    @classmethod
    def from_strategy(cls, strategy, loss_fn: Callable, init_params: PyTree,
                      num_clients: int, eval_fn: Optional[Callable] = None,
                      seed: int = 0, engine: str = "cohort",
                      scan_rounds: bool = True,
                      store: Optional[ClientStateStore] = None
                      ) -> "FederatedServer":
        """Build a server from one :class:`FedStrategy` — sampling, masking,
        wire codec, aggregator and client hyperparameters all come from the
        strategy record (e.g. ``strategy.get("fig5")``).  ``store`` picks
        the client-state backend (``repro.core.client_store``); None means
        a dense oracle store, reproducing the historical behaviour."""
        return cls(loss_fn, init_params=init_params, eval_fn=eval_fn,
                   seed=seed, engine=engine, scan_rounds=scan_rounds,
                   strategy=strategy, num_clients=num_clients, store=store)

    # ---- per-client state (delegated to the store) -----------------------
    @property
    def _residuals(self) -> PyTree:
        """Dense ``(M, …)`` view of the store's EF residuals.  On the
        dense backend this is the backing array itself (the in-program
        engines consume and reassign it); a sharded store materializes it
        on demand — test/debug only."""
        return self.store.residuals_dense()

    @_residuals.setter
    def _residuals(self, value: PyTree) -> None:
        self.store.set_dense(value)

    @property
    def _drift(self) -> PyTree:
        """Dense ``(M, …)`` view of the FedDyn drift tree (same caveats
        as :attr:`_residuals`)."""
        return self.store.dense_view("drift")

    @_drift.setter
    def _drift(self, value: PyTree) -> None:
        self.store.set_dense(value, tree="drift")

    @property
    def _norms(self) -> Optional[jnp.ndarray]:
        return self.store.norms

    @_norms.setter
    def _norms(self, value) -> None:
        if value is None and self.store.norms is None:
            return
        self.store.set_norms(value)

    # ---- engine dispatch -------------------------------------------------
    def _round_program(self, bucket: int, seg_len: int):
        """Build the (bucket, seg_len) round program (uncompiled) from the
        strategy — ``strategy.build_round`` threads the codec and
        aggregator into every form."""
        from repro.core.strategy import build_round
        M = self.cfg.num_clients
        if seg_len > 1:
            return build_round(self.strategy, self._loss_fn, M,
                               form="scan", cohort_size=bucket)
        if bucket >= M:
            return build_round(self.strategy, self._loss_fn, M, form="full")
        return build_round(self.strategy, self._loss_fn, M,
                           form="cohort", cohort_size=bucket)

    def _get_compiled(self, bucket: int, seg_len: int, args):
        """AOT-compile (once) the program for this bucket/segment shape.
        Returns ``(executable, compile_s)`` — compile_s is 0 on cache hit.
        The key includes the input avals so a later ``run()`` with
        differently-shaped data recompiles instead of hitting a stale
        executable (AOT calls don't retrace the way plain jit does)."""
        avals = tuple((tuple(leaf.shape), str(leaf.dtype))
                      for leaf in jax.tree_util.tree_leaves(args))
        cache_key = (bucket, seg_len, avals)
        hit = self._compiled.get(cache_key)
        if hit is not None:
            return hit, 0.0
        fn = self._round_program(bucket, seg_len)
        t0 = time.perf_counter()
        compiled = jax.jit(fn).lower(*args).compile()
        compile_s = time.perf_counter() - t0
        self._compiled[cache_key] = compiled
        return compiled, compile_s

    def _segments(self, rounds: int, eval_rounds, start: int = 0) -> List[tuple]:
        """Split start+1..start+rounds into (bucket, [t...]) segments:
        consecutive rounds sharing a cohort bucket, broken at eval rounds
        (the host needs Θ_t there).  engine="full" pins every bucket to the
        full population.  Bucket sizing is sampler-aware:
        ``ClientSampler.cohort_bucket`` upper-bounds the participant count
        its selection can emit (e.g. the threshold sampler's random arrival
        count gets a slack bucket)."""
        M = self.cfg.num_clients
        sampler = self.strategy.sampler
        plan = self.schedule.round_buckets(rounds, M, start=start)
        segments: List[tuple] = []
        for t, (m, _bucket) in zip(range(start + 1, start + rounds + 1), plan):
            bucket = sampler.cohort_bucket(self.schedule, m, M)
            b_eff = bucket if self.engine == "cohort" else M
            if (segments and self.scan_rounds
                    and segments[-1][0] == b_eff
                    and (t - 1) not in eval_rounds):
                segments[-1][1].append(t)
            else:
                segments.append((b_eff, [t]))
        return segments

    # ---- training loop ---------------------------------------------------
    def run(self, client_batches: PyTree, n_samples: np.ndarray,
            rounds: int, eval_every: int = 0,
            eval_data: Any = None) -> List[RoundRecord]:
        """Run ``rounds`` communication rounds, appending to ``history``.

        ``client_batches``: pytree with leading (num_clients, num_batches,
        B, ...) axes; ``n_samples``: (num_clients,) per-client dataset
        sizes; ``eval_every``: evaluate ``eval_fn(params, eval_data)``
        every that many rounds (and on the last).  Returns the full
        history list.  Rounds are numbered from the server's persistent
        round counter, so a run on a ``restore_state``-d server continues
        where the checkpoint left off.

        On a sharded store (any engine), ``client_batches`` may instead be
        a *provider* callable ``provider(ids) -> cohort_batches`` (leading
        axes ``(len(ids), num_batches, B, ...)``) so the full ``(M, …)``
        batch stack never has to exist either — the scaling benchmark's
        path to M = 10^6.
        """
        gamma = self.cfg.client.masking.gamma \
            if self.cfg.client.masking.mode != "none" else 1.0
        wire_bytes = self.client_upload_bytes
        n_samples = jnp.asarray(n_samples, jnp.float32)
        if callable(client_batches):
            if self.store.kind == "dense":
                raise ValueError(
                    "a client_batches provider callable requires a sharded "
                    "store (the dense engines close over the full batch "
                    "stack)")
            probe = client_batches(np.zeros((1,), np.int64))
            flops_per_client = local_update_flops(
                probe, self._num_params, self.cfg.client)
        else:
            flops_per_client = local_update_flops(
                client_batches, self._num_params, self.cfg.client)
        start = self._round

        eval_rounds = set()
        if eval_every and self.eval_fn is not None:
            eval_rounds = {t for t in range(start + 1, start + rounds + 1)
                           if t % eval_every == 0 or t == start + rounds}

        if self.engine == "async":
            return self._run_async(client_batches, n_samples, rounds,
                                   eval_rounds, eval_data, gamma, wire_bytes,
                                   flops_per_client)
        if self.store.kind != "dense":
            return self._run_store(client_batches, n_samples, rounds,
                                   eval_rounds, eval_data, gamma, wire_bytes,
                                   flops_per_client)

        for bucket, ts in self._segments(rounds, eval_rounds, start):
            seg_len = len(ts)
            subs = []
            for _ in ts:
                self._key, sub = jax.random.split(self._key)
                subs.append(sub)
            if seg_len > 1:
                t_arg = jnp.asarray(ts, jnp.float32)
                key_arg = jnp.stack(subs)
            else:
                t_arg = jnp.asarray(ts[0], jnp.float32)
                key_arg = subs[0]
            # Engine-wide state convention: (params, residuals[, drift]
            # [, norms]) — optional slots appear only when the strategy
            # carries that state, so historical programs are unchanged.
            state = [self.params, self._residuals]
            if self._uses_drift:
                state.append(self._drift)
            if self._adaptive:
                state.append(self._norms)
            args = (*state, client_batches, n_samples, t_arg, key_arg)
            compiled, compile_s = self._get_compiled(bucket, seg_len, args)
            t0 = time.perf_counter()
            out = compiled(*args)
            self.params, self._residuals = out[0], out[1]
            i = 2
            if self._uses_drift:
                self._drift = out[i]
                i += 1
            if self._adaptive:
                self._norms = out[i]
                i += 1
            metrics = out[i]
            jax.block_until_ready(self.params)
            wall = time.perf_counter() - t0

            num_sampled = np.atleast_1d(np.asarray(metrics["num_sampled"]))
            mean_loss = np.atleast_1d(np.asarray(metrics["mean_loss"]))
            quarantined = np.atleast_1d(np.asarray(metrics["quarantined"]))
            adversarial = None
            if "num_adversarial" in metrics:
                adversarial = np.atleast_1d(
                    np.asarray(metrics["num_adversarial"]))
            if self._traits is not None:
                part_masks = np.atleast_2d(np.asarray(metrics["part_mask"]))
                arrived_masks = np.atleast_2d(
                    np.asarray(metrics["arrived_mask"]))
            for i, t in enumerate(ts):
                m = float(num_sampled[i])
                rec = RoundRecord(
                    round=t,
                    num_sampled=int(m),
                    mean_loss=float(mean_loss[i]),
                    transport_units=m * gamma,
                    transport_bytes=int(m) * wire_bytes,
                    wall_s=wall / seg_len,
                    compile_s=compile_s if i == 0 else 0.0,
                    cohort_size=bucket,
                    flop_proxy=float(flops_per_client) * bucket,
                    quarantined=int(quarantined[i]),
                    adversarial=(int(adversarial[i])
                                 if adversarial is not None else 0),
                )
                if self._traits is not None:
                    sim = simulate_round(self._traits, part_masks[i],
                                         arrived_masks[i],
                                         float(flops_per_client), wire_bytes)
                    rec.sim_round_s = sim["sim_round_s"]
                    rec.straggler_s = sim["straggler_s"]
                    rec.dropped = sim["dropped"]
                if t in eval_rounds and t == ts[-1]:
                    rec.eval_metric = float(self.eval_fn(self.params, eval_data))
                self.history.append(rec)
            self._round = ts[-1]
        return self.history

    def _run_async(self, client_batches, n_samples, rounds, eval_rounds,
                   eval_data, gamma, wire_bytes, flops_per_client):
        """engine="async" round loop: one buffered round at a time via
        :class:`repro.core.async_engine.AsyncRoundRunner`, with the SAME
        per-round key-split sequence as the sync engines (bit-exactness in
        the degenerate case depends on it).  Transport counts every
        transmission the fleet attempted — retries and deadline-cut sends
        included — because those bytes crossed the uplink either way."""
        M = self.cfg.num_clients
        sampler = self.strategy.sampler
        # On a sharded store the runner gathers/commits residual rows and
        # norm EMAs through the store itself — never materialize the dense
        # (M, …) view here.
        sharded = self.store.kind != "dense"
        for _ in range(rounds):
            t = self._round + 1
            self._key, sub = jax.random.split(self._key)
            m = self.schedule.num_clients_host(t, M)
            bucket = sampler.cohort_bucket(self.schedule, m, M)
            t0 = time.perf_counter()
            res_in = None if sharded else self._residuals
            (self.params, res_out, norms_out,
             stats) = self._async.run_round(
                self.params, res_in, self._norms, client_batches,
                n_samples, t, sub, cohort_size=bucket,
                flops=float(flops_per_client), wire_bytes=wire_bytes)
            if not sharded:
                self._residuals = res_out
                self._norms = norms_out
            jax.block_until_ready(self.params)
            wall = max(0.0, time.perf_counter() - t0 - stats["compile_s"])
            rec = RoundRecord(
                round=t,
                num_sampled=stats["num_sampled"],
                mean_loss=stats["mean_loss"],
                transport_units=stats["sends"] * gamma,
                transport_bytes=stats["sends"] * wire_bytes,
                wall_s=wall,
                compile_s=stats["compile_s"],
                cohort_size=bucket,
                flop_proxy=float(flops_per_client) * bucket,
                sim_round_s=stats["sim_round_s"],
                straggler_s=stats["straggler_s"],
                dropped=stats["dropped"],
                arrivals=stats["arrivals"],
                timeouts=stats["timeouts"],
                retries=stats["retries"],
                quarantined=stats["quarantined"],
                flushes=stats["flushes"],
                mean_staleness=stats["mean_staleness"],
                carried=stats.get("carried", 0),
                pending=stats.get("pending", 0),
                adversarial=stats["adversarial"],
            )
            if t in eval_rounds:
                rec.eval_metric = float(self.eval_fn(self.params, eval_data))
            self.history.append(rec)
            self._round = t
        return self.history

    # ---- store engine (sharded sync; DESIGN.md §11) ----------------------
    def _store_program(self, bucket: int):
        """The (cached) store-form round program for one cohort bucket."""
        prog = self._store_programs.get(bucket)
        if prog is None:
            from repro.core.strategy import build_round
            prog = build_round(self.strategy, self._loss_fn,
                               self.cfg.num_clients, form="store",
                               cohort_size=bucket)
            self._store_programs[bucket] = prog
        return prog

    def _aot(self, tag: str, bucket: int, fn, args):
        """AOT-compile ``fn`` once per (tag, bucket, input avals); returns
        ``(executable, compile_s)`` — same caching discipline as
        :meth:`_get_compiled`, keyed separately because the store-form
        round is two programs, not one."""
        avals = tuple((tuple(leaf.shape), str(leaf.dtype))
                      for leaf in jax.tree_util.tree_leaves(args))
        cache_key = (tag, bucket, avals)
        hit = self._compiled.get(cache_key)
        if hit is not None:
            return hit, 0.0
        t0 = time.perf_counter()
        compiled = jax.jit(fn).lower(*args).compile()
        compile_s = time.perf_counter() - t0
        self._compiled[cache_key] = compiled
        return compiled, compile_s

    def _run_store(self, client_batches, n_samples, rounds, eval_rounds,
                   eval_data, gamma, wire_bytes, flops_per_client):
        """Sync round loop through the client-state store: selection and
        the cohort-shaped barrier run as separate AOT programs, with the
        residual gather/scatter between them going through ``self.store``
        — the full ``(M, …)`` residual stack never exists.  Per-round key
        splits are identical to the in-program engines (bit-exactness of
        dense-vs-sharded runs depends on it)."""
        M = self.cfg.num_clients
        sampler = self.strategy.sampler
        store = self.store
        provider = client_batches if callable(client_batches) else None
        for _ in range(rounds):
            t = self._round + 1
            self._key, sub = jax.random.split(self._key)
            m = self.schedule.num_clients_host(t, M)
            bucket = sampler.cohort_bucket(self.schedule, m, M)
            prog = self._store_program(bucket)
            sample_key, mask_key, drop_key = _split_round_key(
                sub, prog.with_drop)
            t_arg = jnp.asarray(t, jnp.float32)
            norms = store.norms if prog.adaptive else None

            sel_args = (norms, n_samples, t_arg, sample_key)
            sel_fn, compile_s = self._aot("store-sel", bucket, prog.select,
                                          sel_args)
            t0 = time.perf_counter()
            part, weights, cohort_ids = sel_fn(*sel_args)
            ids_np = np.asarray(cohort_ids)
            cohort_res = store.gather(ids_np)
            cohort_drift = (store.gather(ids_np, tree="drift")
                            if prog.uses_drift else None)
            if provider is not None:
                cohort_batches = provider(ids_np)
            else:
                cohort_batches = jax.tree.map(
                    lambda x: jnp.take(x, cohort_ids, axis=0),
                    client_batches)
            gather_s = time.perf_counter() - t0

            body_args = (self.params, cohort_res, cohort_drift,
                         cohort_batches, cohort_ids, part, weights, norms,
                         mask_key, drop_key)
            body_fn, body_compile_s = self._aot("store-body", bucket,
                                                prog.body, body_args)
            compile_s += body_compile_s
            t0 = time.perf_counter()
            (self.params, new_rows, drift_rows, commit, norm_upd,
             metrics) = body_fn(*body_args)
            jax.block_until_ready(self.params)
            wall = gather_s + (time.perf_counter() - t0)

            part_np = np.asarray(part)
            # Θ_t went out to the true participants this round — the
            # version state cross-round staleness measures against.
            store.mark_dispatched(ids_np[part_np[ids_np] > 0], t)
            commit_np = np.asarray(commit)
            if prog.error_feedback:
                store.scatter(ids_np, new_rows, commit_np, t)
            if prog.uses_drift:
                store.scatter(ids_np, drift_rows, commit_np, t, tree="drift")
            if prog.adaptive:
                store.update_norms(ids_np, norm_upd)

            m_t = float(np.asarray(metrics["num_sampled"]))
            rec = RoundRecord(
                round=t,
                num_sampled=int(m_t),
                mean_loss=float(np.asarray(metrics["mean_loss"])),
                transport_units=m_t * gamma,
                transport_bytes=int(m_t) * wire_bytes,
                wall_s=wall,
                compile_s=compile_s,
                cohort_size=bucket,
                flop_proxy=float(flops_per_client) * bucket,
                quarantined=int(np.asarray(metrics["quarantined"])),
                adversarial=int(np.asarray(
                    metrics["num_adversarial"]))
                if "num_adversarial" in metrics else 0,
            )
            if self._traits is not None:
                sim = simulate_round(self._traits,
                                     np.asarray(metrics["part_mask"]),
                                     np.asarray(metrics["arrived_mask"]),
                                     float(flops_per_client), wire_bytes)
                rec.sim_round_s = sim["sim_round_s"]
                rec.straggler_s = sim["straggler_s"]
                rec.dropped = sim["dropped"]
            if t in eval_rounds:
                rec.eval_metric = float(self.eval_fn(self.params, eval_data))
            self.history.append(rec)
            self._round = t
        return self.history

    # ---- checkpoint / resume --------------------------------------------
    def state(self) -> Dict[str, Any]:
        """The complete resumable training state as one pytree: global
        params, the server RNG key, and the store's per-client state (EF
        residuals — dense stack or sharded slot pool —, sampler norm EMAs,
        model versions).  The round counter rides in the checkpoint's
        ``extra`` manifest."""
        return {
            "key": self._key,
            "params": self.params,
            **self.store.state(),
        }

    def save_state(self, ckpt_dir: str) -> str:
        """Checkpoint :meth:`state` (atomically) at the current round.
        The manifest's ``extra`` records the round counter plus the
        population size and store backend, so a mismatched restore fails
        loudly before any state is touched."""
        from repro.checkpoint.checkpoint import save_checkpoint
        return save_checkpoint(ckpt_dir, self._round, self.state(),
                               extra={"round": self._round,
                                      "num_clients": self.cfg.num_clients,
                                      "store": self.store.kind})

    def restore_state(self, ckpt_dir: str, step: Optional[int] = None) -> int:
        """Restore :meth:`state` from ``ckpt_dir`` (latest step unless
        pinned) and continue the round numbering where the checkpoint left
        off; the next ``run()`` resumes bit-identically to the run that
        wrote it.  Returns the restored step.

        Validates the checkpoint against this server BEFORE assigning
        anything: a checkpoint written for a different population size or
        store backend raises ``ValueError`` naming both values instead of
        silently loading mismatched per-client state."""
        from repro.checkpoint.checkpoint import (read_manifest,
                                                 restore_checkpoint)
        extra = read_manifest(ckpt_dir, step).get("extra", {})
        ckpt_m = extra.get("num_clients")
        if ckpt_m is not None and int(ckpt_m) != self.cfg.num_clients:
            raise ValueError(
                f"checkpoint was written for num_clients={int(ckpt_m)} but "
                f"this server registers num_clients={self.cfg.num_clients}")
        ckpt_store = extra.get("store")
        if ckpt_store is not None and ckpt_store != self.store.kind:
            raise ValueError(
                f"checkpoint holds a {ckpt_store!r} store but this server "
                f"owns a {self.store.kind!r} store")
        restored, step, extra = restore_checkpoint(ckpt_dir, self.state(),
                                                   step)
        self._key = jnp.asarray(restored.pop("key"))
        self.params = restored.pop("params")
        self.store.load_state(restored)
        self._round = int(extra.get("round", step))
        return step

    # ---- reporting ------------------------------------------------------
    def total_transport_units(self) -> float:
        """Cumulative client uploads in full-model units (Eq. 6 basis)."""
        return float(sum(r.transport_units for r in self.history))

    def total_transport_bytes(self) -> int:
        """Cumulative EXACT wire bytes across all recorded rounds."""
        return int(sum(r.transport_bytes for r in self.history))

    def summary(self) -> Dict[str, Any]:
        """Run-level roll-up of the history (loss, transport, timing; plus
        the simulated-fleet clock and drop counts when hetero is on)."""
        evals = [r.eval_metric for r in self.history if r.eval_metric is not None]
        out = {
            "rounds": len(self.history),
            "final_loss": self.history[-1].mean_loss if self.history else float("nan"),
            "final_eval": evals[-1] if evals else float("nan"),
            "transport_units": self.total_transport_units(),
            "transport_bytes": self.total_transport_bytes(),
            "transport_GB": self.total_transport_bytes() / 1e9,
            "num_params": self._num_params,
            "engine": self.engine,
            "strategy": self.strategy.name,
            "sampler": self.strategy.sampler.name,
            # wire accounting now comes from the codec, not an estimate
            "codec": self.strategy.codec.name,
            "client_upload_bytes": self.client_upload_bytes,
            "compile_s": float(sum(r.compile_s for r in self.history)),
            "steady_wall_s": float(sum(r.wall_s for r in self.history)),
            # decode-gate rejections, metered by every engine (§8/§9)
            "quarantined": int(sum(r.quarantined for r in self.history)),
        }
        attack = getattr(self.strategy, "attack", None)
        if attack is not None and attack.active:
            out["attack"] = f"{attack.kind}(f={attack.fraction})"
            out["adversarial_uploads"] = int(
                sum(r.adversarial for r in self.history))
        if self._traits is not None:
            out["hetero"] = self.strategy.hetero.profile
            out["sim_total_s"] = float(
                sum(r.sim_round_s for r in self.history))
            out["dropped_uploads"] = int(sum(r.dropped for r in self.history))
        if self.engine == "async":
            arrivals = int(sum(r.arrivals for r in self.history))
            out["sim_total_s"] = float(
                sum(r.sim_round_s for r in self.history))
            out["dropped_uploads"] = int(sum(r.dropped for r in self.history))
            out["arrivals"] = arrivals
            out["timeouts"] = int(sum(r.timeouts for r in self.history))
            out["retries"] = int(sum(r.retries for r in self.history))
            out["flushes"] = int(sum(r.flushes for r in self.history))
            # staleness averaged over APPLIED uploads, not over rounds
            out["mean_staleness"] = float(
                sum(r.mean_staleness * r.arrivals for r in self.history)
                / arrivals) if arrivals else 0.0
            out["carried"] = int(sum(r.carried for r in self.history))
        return out
