"""Central-server training loop (paper Alg. 1 / Alg. 3 outer procedure).

``FederatedServer`` owns the global model, runs R communication rounds via the
jitted round function, meters transport bytes per round (sampling × masking ×
encoding, see ``repro.core.compression``), and evaluates on a held-out set.

This is the *simulation* driver used by the paper-reproduction benchmarks
(Figs. 3-9).  The pod-scale driver is ``repro.launch.train``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import pytree_payload_bytes, pytree_num_params
from repro.core.federated import FederatedConfig, make_federated_round
from repro.core.sampling import SamplingSchedule

PyTree = Any

__all__ = ["RoundRecord", "FederatedServer"]


@dataclasses.dataclass
class RoundRecord:
    round: int
    num_sampled: int
    mean_loss: float
    transport_units: float      # full-model-upload units this round (Eq. 6 basis)
    transport_bytes: int        # metered bytes (values + index overhead)
    eval_metric: Optional[float] = None
    wall_s: float = 0.0


class FederatedServer:
    """Owns Θ_t; runs rounds; meters communication."""

    def __init__(self, loss_fn: Callable, schedule: SamplingSchedule,
                 cfg: FederatedConfig, init_params: PyTree,
                 eval_fn: Optional[Callable] = None, seed: int = 0):
        self.cfg = cfg
        self.schedule = schedule
        self.params = init_params
        self.eval_fn = eval_fn
        self._key = jax.random.PRNGKey(seed)
        self._round_fn = jax.jit(make_federated_round(loss_fn, schedule, cfg))
        self._residuals = jax.tree.map(
            lambda p: jnp.zeros((cfg.num_clients,) + p.shape, p.dtype),
            init_params)
        self.history: List[RoundRecord] = []
        self._num_params = pytree_num_params(init_params)

    def run(self, client_batches: PyTree, n_samples: np.ndarray,
            rounds: int, eval_every: int = 0,
            eval_data: Any = None) -> List[RoundRecord]:
        gamma = self.cfg.client.masking.gamma \
            if self.cfg.client.masking.mode != "none" else 1.0
        stats = pytree_payload_bytes(
            self.params, gamma, self.cfg.client.masking.min_leaf_size)
        self._compression = stats        # per-encoding byte split for summary()
        n_samples = jnp.asarray(n_samples, jnp.float32)

        for t in range(1, rounds + 1):
            t0 = time.perf_counter()
            self._key, sub = jax.random.split(self._key)
            self.params, self._residuals, metrics = self._round_fn(
                self.params, self._residuals, client_batches, n_samples,
                jnp.asarray(t, jnp.float32), sub)
            m = float(metrics["num_sampled"])
            rec = RoundRecord(
                round=t,
                num_sampled=int(m),
                mean_loss=float(metrics["mean_loss"]),
                transport_units=m * gamma,
                transport_bytes=int(m) * stats.sparse_bytes,
                wall_s=time.perf_counter() - t0,
            )
            if eval_every and self.eval_fn is not None and (
                    t % eval_every == 0 or t == rounds):
                rec.eval_metric = float(self.eval_fn(self.params, eval_data))
            self.history.append(rec)
        return self.history

    # ---- reporting ------------------------------------------------------
    def total_transport_units(self) -> float:
        return float(sum(r.transport_units for r in self.history))

    def total_transport_bytes(self) -> int:
        return int(sum(r.transport_bytes for r in self.history))

    def summary(self) -> Dict[str, Any]:
        evals = [r.eval_metric for r in self.history if r.eval_metric is not None]
        out = {
            "rounds": len(self.history),
            "final_loss": self.history[-1].mean_loss if self.history else float("nan"),
            "final_eval": evals[-1] if evals else float("nan"),
            "transport_units": self.total_transport_units(),
            "transport_GB": self.total_transport_bytes() / 1e9,
            "num_params": self._num_params,
        }
        stats = getattr(self, "_compression", None)
        if stats is not None:
            # Mixed bitmap/coordinate/dense uploads: report the exact split
            # (bytes per model upload per encoding), not just the last leaf's.
            out["upload_encoding"] = stats.encoding
            out["upload_encoding_bytes"] = dict(stats.encoding_bytes)
        return out
