"""Central-server training loop (paper Alg. 1 / Alg. 3 outer procedure).

``FederatedServer`` owns the global model, runs R communication rounds,
meters transport bytes per round, and evaluates on a held-out set.  The
scenario it runs — sampling schedule, mask policy, wire codec, aggregation
rule, client hyperparameters — is a single
:class:`repro.core.strategy.FedStrategy`; construct the server with
:meth:`FederatedServer.from_strategy` (the legacy ``(loss_fn, schedule,
cfg, ...)`` kwargs still work behind a ``DeprecationWarning`` shim that
synthesizes an equivalent strategy).

Transport is metered by the strategy's codec: every client upload is
round-tripped through the codec's wire format inside the round program, and
``RoundRecord.transport_bytes`` counts the EXACT serialized bytes of that
wire pytree (``UploadCodec.wire_bytes``, shape-only via ``eval_shape``) —
not the ``pytree_payload_bytes`` estimate earlier revisions reported.

Two further scenario axes ride on the strategy (DESIGN.md §5): adaptive
client samplers (importance/threshold) make the server carry a per-client
update-norm tracker as round-program state next to the error-feedback
residuals, and a ``HeteroModel`` fleet adds in-round upload dropout plus
host-side clock simulation — ``RoundRecord.sim_round_s`` (straggler
wall-clock on the simulated fleet), ``straggler_s`` and ``dropped``.

Two execution engines (DESIGN.md §3.5):

* ``engine="cohort"`` (default): per round, only the sampled cohort is
  materialized and executed — the cohort buffer size is bucketed to
  ``SamplingSchedule.bucket_ladder`` so recompiles stay O(log M) as c(t)
  anneals.  Consecutive rounds sharing a bucket are folded into one
  ``lax.scan`` dispatch.  Rounds whose bucket is the full population fall
  through to the oracle program, so full-participation runs are
  bit-identical to the legacy path.
* ``engine="full"``: the original full-population vmap (every registered
  client runs; non-participants are zero-weighted) — kept as the oracle
  the cohort engine is property-tested against, under every registry
  preset (tests/test_strategy.py).

Each distinct (bucket, segment-length) program is AOT-compiled once and
cached; compile time is recorded on the triggering round's
``RoundRecord.compile_s`` instead of polluting ``wall_s``, so bench JSON
reflects steady-state per-round cost.

This is the *simulation* driver used by the paper-reproduction benchmarks
(Figs. 3-9).  The pod-scale driver is ``repro.launch.train``.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.client import local_update_flops
from repro.core.compression import pytree_num_params
from repro.core.federated import FederatedConfig
from repro.core.hetero import simulate_round
from repro.core.sampling import SamplingSchedule

PyTree = Any

__all__ = ["RoundRecord", "FederatedServer"]


@dataclasses.dataclass
class RoundRecord:
    """Per-round ledger entry: who participated, what it cost (measured
    wall-clock, exact wire bytes) and — when the strategy carries a
    :class:`repro.core.hetero.HeteroModel` — what the round would have cost
    on the simulated fleet (``sim_round_s`` straggler wall-clock,
    ``straggler_s`` tail above the median arrival, ``dropped`` lost
    uploads)."""

    round: int
    num_sampled: int
    mean_loss: float
    transport_units: float      # full-model-upload units this round (Eq. 6 basis)
    transport_bytes: int        # EXACT wire bytes (codec-encoded uploads)
    eval_metric: Optional[float] = None
    wall_s: float = 0.0         # steady-state execution time (compile excluded)
    compile_s: float = 0.0      # program build time; nonzero on bucket-change rounds
    cohort_size: int = 0        # padded cohort buffer actually executed
    flop_proxy: float = 0.0     # 6·params·examples·epochs·cohort_size (proxy)
    sim_round_s: float = 0.0    # simulated fleet wall-clock (hetero only)
    straggler_s: float = 0.0    # sim straggler tail: max - median arrival
    dropped: int = 0            # uploads lost on the simulated fleet


class FederatedServer:
    """Owns Θ_t; runs rounds; meters communication."""

    def __init__(self, loss_fn: Callable = None, schedule: SamplingSchedule = None,
                 cfg: FederatedConfig = None, init_params: PyTree = None,
                 eval_fn: Optional[Callable] = None, seed: int = 0,
                 engine: str = "cohort", scan_rounds: bool = True, *,
                 strategy=None, num_clients: int = None):
        """Legacy kwargs constructor — DEPRECATED shim for one release.

        Prefer :meth:`from_strategy`.  The ``(schedule, cfg)`` pair is
        converted to an equivalent :class:`FedStrategy` (codec derived from
        the masking config), so both paths run the identical round
        program.
        """
        if strategy is None:
            if schedule is None or cfg is None:
                raise TypeError(
                    "FederatedServer needs either strategy=/num_clients= or "
                    "the legacy (schedule, cfg) pair")
            warnings.warn(
                "FederatedServer(loss_fn, schedule, cfg, ...) is deprecated; "
                "use FederatedServer.from_strategy(strategy, loss_fn, "
                "init_params, num_clients, ...) with a repro.core.strategy."
                "FedStrategy (see strategy.get presets)",
                DeprecationWarning, stacklevel=2)
            from repro.core.strategy import FedStrategy
            strategy = FedStrategy.from_components(
                "legacy", schedule, cfg.client.masking,
                local_epochs=cfg.client.local_epochs,
                learning_rate=cfg.client.learning_rate,
                momentum=cfg.client.momentum,
                upload=cfg.client.upload,
                error_feedback=cfg.error_feedback)
            num_clients = cfg.num_clients
        if engine not in ("cohort", "full"):
            raise ValueError(f"unknown engine {engine!r}")
        if num_clients is None:
            raise TypeError("from_strategy/strategy= requires num_clients")
        self.strategy = strategy
        self.cfg = strategy.federated_config(num_clients)
        self.schedule = strategy.sampling
        self.params = init_params
        self.eval_fn = eval_fn
        self.engine = engine
        self.scan_rounds = scan_rounds
        self._loss_fn = loss_fn
        self._key = jax.random.PRNGKey(seed)
        self._compiled: Dict[tuple, Any] = {}   # (bucket, seg_len) -> executable
        self._residuals = jax.tree.map(
            lambda p: jnp.zeros((num_clients,) + p.shape, p.dtype),
            init_params)
        # Adaptive samplers (importance/threshold) feed on a per-client
        # EMA of observed post-wire update norms; ones = "everyone looks
        # equally important" until data arrives, so round 1 ~ uniform.
        self._adaptive = strategy.sampler.adaptive
        self._norms = (jnp.ones((num_clients,), jnp.float32)
                       if self._adaptive else None)
        # Simulated-fleet traits (static per-client draws) for the hetero
        # round clock; None on the paper's ideal homogeneous fleet.
        self._traits = (strategy.hetero.client_traits(num_clients)
                        if strategy.hetero is not None else None)
        self.history: List[RoundRecord] = []
        self._num_params = pytree_num_params(init_params)
        # Exact per-client-upload wire bytes: the codec's encode traced
        # shape-only over a delta template (same avals as params).
        self.client_upload_bytes = strategy.codec.wire_bytes(init_params)

    @classmethod
    def from_strategy(cls, strategy, loss_fn: Callable, init_params: PyTree,
                      num_clients: int, eval_fn: Optional[Callable] = None,
                      seed: int = 0, engine: str = "cohort",
                      scan_rounds: bool = True) -> "FederatedServer":
        """Build a server from one :class:`FedStrategy` — sampling, masking,
        wire codec, aggregator and client hyperparameters all come from the
        strategy record (e.g. ``strategy.get("fig5")``)."""
        return cls(loss_fn, init_params=init_params, eval_fn=eval_fn,
                   seed=seed, engine=engine, scan_rounds=scan_rounds,
                   strategy=strategy, num_clients=num_clients)

    # ---- engine dispatch -------------------------------------------------
    def _round_program(self, bucket: int, seg_len: int):
        """Build the (bucket, seg_len) round program (uncompiled) from the
        strategy — ``strategy.build_round`` threads the codec and
        aggregator into every form."""
        from repro.core.strategy import build_round
        M = self.cfg.num_clients
        if seg_len > 1:
            return build_round(self.strategy, self._loss_fn, M,
                               form="scan", cohort_size=bucket)
        if bucket >= M:
            return build_round(self.strategy, self._loss_fn, M, form="full")
        return build_round(self.strategy, self._loss_fn, M,
                           form="cohort", cohort_size=bucket)

    def _get_compiled(self, bucket: int, seg_len: int, args):
        """AOT-compile (once) the program for this bucket/segment shape.
        Returns ``(executable, compile_s)`` — compile_s is 0 on cache hit.
        The key includes the input avals so a later ``run()`` with
        differently-shaped data recompiles instead of hitting a stale
        executable (AOT calls don't retrace the way plain jit does)."""
        avals = tuple((tuple(leaf.shape), str(leaf.dtype))
                      for leaf in jax.tree_util.tree_leaves(args))
        cache_key = (bucket, seg_len, avals)
        hit = self._compiled.get(cache_key)
        if hit is not None:
            return hit, 0.0
        fn = self._round_program(bucket, seg_len)
        t0 = time.perf_counter()
        compiled = jax.jit(fn).lower(*args).compile()
        compile_s = time.perf_counter() - t0
        self._compiled[cache_key] = compiled
        return compiled, compile_s

    def _segments(self, rounds: int, eval_rounds) -> List[tuple]:
        """Split 1..rounds into (bucket, [t...]) segments: consecutive rounds
        sharing a cohort bucket, broken at eval rounds (the host needs Θ_t
        there).  engine="full" pins every bucket to the full population.
        Bucket sizing is sampler-aware: ``ClientSampler.cohort_bucket``
        upper-bounds the participant count its selection can emit (e.g. the
        threshold sampler's random arrival count gets a slack bucket)."""
        M = self.cfg.num_clients
        sampler = self.strategy.sampler
        plan = self.schedule.round_buckets(rounds, M)
        segments: List[tuple] = []
        for t, (m, _bucket) in zip(range(1, rounds + 1), plan):
            bucket = sampler.cohort_bucket(self.schedule, m, M)
            b_eff = bucket if self.engine == "cohort" else M
            if (segments and self.scan_rounds
                    and segments[-1][0] == b_eff
                    and (t - 1) not in eval_rounds):
                segments[-1][1].append(t)
            else:
                segments.append((b_eff, [t]))
        return segments

    # ---- training loop ---------------------------------------------------
    def run(self, client_batches: PyTree, n_samples: np.ndarray,
            rounds: int, eval_every: int = 0,
            eval_data: Any = None) -> List[RoundRecord]:
        """Run ``rounds`` communication rounds, appending to ``history``.

        ``client_batches``: pytree with leading (num_clients, num_batches,
        B, ...) axes; ``n_samples``: (num_clients,) per-client dataset
        sizes; ``eval_every``: evaluate ``eval_fn(params, eval_data)``
        every that many rounds (and on the last).  Returns the full
        history list.
        """
        gamma = self.cfg.client.masking.gamma \
            if self.cfg.client.masking.mode != "none" else 1.0
        wire_bytes = self.client_upload_bytes
        n_samples = jnp.asarray(n_samples, jnp.float32)
        flops_per_client = local_update_flops(
            client_batches, self._num_params, self.cfg.client)

        eval_rounds = set()
        if eval_every and self.eval_fn is not None:
            eval_rounds = {t for t in range(1, rounds + 1)
                           if t % eval_every == 0 or t == rounds}

        for bucket, ts in self._segments(rounds, eval_rounds):
            seg_len = len(ts)
            subs = []
            for _ in ts:
                self._key, sub = jax.random.split(self._key)
                subs.append(sub)
            if seg_len > 1:
                t_arg = jnp.asarray(ts, jnp.float32)
                key_arg = jnp.stack(subs)
            else:
                t_arg = jnp.asarray(ts[0], jnp.float32)
                key_arg = subs[0]
            if self._adaptive:
                args = (self.params, self._residuals, self._norms,
                        client_batches, n_samples, t_arg, key_arg)
            else:
                args = (self.params, self._residuals, client_batches,
                        n_samples, t_arg, key_arg)
            compiled, compile_s = self._get_compiled(bucket, seg_len, args)
            t0 = time.perf_counter()
            if self._adaptive:
                (self.params, self._residuals, self._norms,
                 metrics) = compiled(*args)
            else:
                self.params, self._residuals, metrics = compiled(*args)
            jax.block_until_ready(self.params)
            wall = time.perf_counter() - t0

            num_sampled = np.atleast_1d(np.asarray(metrics["num_sampled"]))
            mean_loss = np.atleast_1d(np.asarray(metrics["mean_loss"]))
            if self._traits is not None:
                part_masks = np.atleast_2d(np.asarray(metrics["part_mask"]))
                arrived_masks = np.atleast_2d(
                    np.asarray(metrics["arrived_mask"]))
            for i, t in enumerate(ts):
                m = float(num_sampled[i])
                rec = RoundRecord(
                    round=t,
                    num_sampled=int(m),
                    mean_loss=float(mean_loss[i]),
                    transport_units=m * gamma,
                    transport_bytes=int(m) * wire_bytes,
                    wall_s=wall / seg_len,
                    compile_s=compile_s if i == 0 else 0.0,
                    cohort_size=bucket,
                    flop_proxy=float(flops_per_client) * bucket,
                )
                if self._traits is not None:
                    sim = simulate_round(self._traits, part_masks[i],
                                         arrived_masks[i],
                                         float(flops_per_client), wire_bytes)
                    rec.sim_round_s = sim["sim_round_s"]
                    rec.straggler_s = sim["straggler_s"]
                    rec.dropped = sim["dropped"]
                if t in eval_rounds and t == ts[-1]:
                    rec.eval_metric = float(self.eval_fn(self.params, eval_data))
                self.history.append(rec)
        return self.history

    # ---- reporting ------------------------------------------------------
    def total_transport_units(self) -> float:
        """Cumulative client uploads in full-model units (Eq. 6 basis)."""
        return float(sum(r.transport_units for r in self.history))

    def total_transport_bytes(self) -> int:
        """Cumulative EXACT wire bytes across all recorded rounds."""
        return int(sum(r.transport_bytes for r in self.history))

    def summary(self) -> Dict[str, Any]:
        """Run-level roll-up of the history (loss, transport, timing; plus
        the simulated-fleet clock and drop counts when hetero is on)."""
        evals = [r.eval_metric for r in self.history if r.eval_metric is not None]
        out = {
            "rounds": len(self.history),
            "final_loss": self.history[-1].mean_loss if self.history else float("nan"),
            "final_eval": evals[-1] if evals else float("nan"),
            "transport_units": self.total_transport_units(),
            "transport_bytes": self.total_transport_bytes(),
            "transport_GB": self.total_transport_bytes() / 1e9,
            "num_params": self._num_params,
            "engine": self.engine,
            "strategy": self.strategy.name,
            "sampler": self.strategy.sampler.name,
            # wire accounting now comes from the codec, not an estimate
            "codec": self.strategy.codec.name,
            "client_upload_bytes": self.client_upload_bytes,
            "compile_s": float(sum(r.compile_s for r in self.history)),
            "steady_wall_s": float(sum(r.wall_s for r in self.history)),
        }
        if self._traits is not None:
            out["hetero"] = self.strategy.hetero.profile
            out["sim_total_s"] = float(
                sum(r.sim_round_s for r in self.history))
            out["dropped_uploads"] = int(sum(r.dropped for r in self.history))
        return out
