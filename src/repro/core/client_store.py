"""Sharded client-state store: per-client server state at fleet scale.

Everything the server remembers *per client* — error-feedback residuals
absorbing wire loss, the adaptive samplers' update-norm EMAs, and the
model-version each client last pulled — used to live as dense ``(M, …) +
model`` stacked arrays owned by ``FederatedServer``.  That representation
is the memory wall on the road to M = 10^6 registered clients: residuals
alone cost ``M × model_bytes`` whether or not error feedback is even on,
and long before a million clients the host (let alone the device) runs out.

This module makes the state-ownership layer a pluggable subsystem
(DESIGN.md §11) with two interchangeable backends behind one
:class:`ClientStateStore` contract:

* :class:`DenseStore` — the original ``(M, …)`` stacked arrays, kept as
  the **bit-exact oracle**.  Gather/scatter are the same ``jnp.take`` /
  ``.at[ids].set`` ops the round programs used to run inline, so a server
  on a ``DenseStore`` reproduces the pre-store code paths to the bit.
* :class:`ShardedStore` — residuals held **sparsely**, only for clients
  whose upload committed within a configurable *retention window* of
  ``retention`` client slots.  The backing is a fixed-capacity slot pool
  (``(retention + 1, …)`` per leaf; the extra row is a permanent zero row
  that gather misses read), plus compact O(M) vectors: the norm EMA
  ``(M,)`` float32 and the per-client model-version ``(M,)`` int64 the
  async engine's cross-round staleness discount feeds on.  When the pool
  is full, the least-recently-committed client is **evicted to zero** —
  its residual is forgotten, exactly as if it had never shipped the lost
  mass (a safe degradation for error feedback: the residual is a
  correction, not required state).

Equivalence contract (property-tested in ``tests/test_client_store.py``):
as long as no eviction occurs (``retention`` covers every client that has
ever committed), a run on a ``ShardedStore`` is bit-identical to the same
run on a ``DenseStore`` — params, EF residuals and norm EMAs — under
every strategy preset in the registry.  Eviction is the documented
divergence point.

The O(M) vectors are the only state that must exist for all M clients;
:meth:`ClientStateStore.shard_over` places them (and the sharded slot
pool's client axis) over a mesh's data axes via ``jax.sharding`` so even
they distribute at pod scale (``launch/shardings.py`` conventions).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

__all__ = ["ClientStateStore", "DenseStore", "ShardedStore", "make_store"]


def _ids_array(ids) -> np.ndarray:
    """Normalize a gather/scatter id argument to a 1-D int64 numpy array."""
    out = np.asarray(ids)
    if out.ndim != 1:
        raise ValueError(f"ids must be 1-D, got shape {out.shape}")
    return out.astype(np.int64)


def _per_client_bytes(template: PyTree) -> int:
    """Residual bytes ONE client costs under ``template``'s shapes."""
    return int(sum(np.prod(leaf.shape) * np.dtype(leaf.dtype).itemsize
                   for leaf in jax.tree_util.tree_leaves(template)))


class ClientStateStore:
    """Backend-agnostic contract for per-client server state.

    Residual rows move through :meth:`gather` (cohort ids → stacked
    ``(B, …)`` rows; unknown clients read as zeros on sparse backends) and
    :meth:`scatter` (write back the rows whose ``commit`` mask is set —
    the round's "this upload actually applied" gate).  Norm EMAs and
    model versions are compact ``(M,)`` vectors with their own accessors.
    :meth:`state` / :meth:`load_state` expose a static-shaped pytree for
    the checkpoint layer, and :meth:`memory_bytes` is the accounting the
    scaling benchmark (``benchmarks/client_store.py``) meters.
    """

    kind: str = "abstract"

    def __init__(self, num_clients: int, template: PyTree,
                 track_norms: bool = False):
        if num_clients < 1:
            raise ValueError(f"num_clients must be >= 1, got {num_clients}")
        self.num_clients = int(num_clients)
        self.template = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), template)
        self._norms: Optional[jnp.ndarray] = (
            jnp.ones((num_clients,), jnp.float32) if track_norms else None)
        # Model-version vector: the round number of the Θ each client last
        # pulled (0 = never dispatched).  Host-side int64 — the async
        # engine's staleness math consumes it between device dispatches.
        self.versions = np.zeros((num_clients,), np.int64)

    # ---- residual rows ---------------------------------------------------
    def gather(self, ids) -> PyTree:
        """Stacked residual rows for ``ids`` (zeros where unknown)."""
        raise NotImplementedError

    def scatter(self, ids, rows: PyTree, commit, round: int) -> None:
        """Write back ``rows[i]`` for every i with ``commit[i] > 0``.

        Rows with ``commit[i] == 0`` are untouched (the client's upload
        was dropped / quarantined / timed out, so its residual must stay
        consistent with the model it will re-download)."""
        raise NotImplementedError

    def residuals_dense(self) -> PyTree:
        """The full ``(M, …)`` stacked residuals.  O(M × model) memory —
        the representation this subsystem exists to avoid; kept for the
        oracle engine, small-M tests and debugging."""
        raise NotImplementedError

    # ---- compact (M,) vectors --------------------------------------------
    @property
    def norms(self) -> Optional[jnp.ndarray]:
        """The adaptive samplers' per-client update-norm EMA (or None)."""
        return self._norms

    def set_norms(self, norms) -> None:
        """Replace the whole norm-EMA vector (dense engines hand back the
        full updated vector)."""
        if self._norms is None:
            raise ValueError(f"{self.kind} store was built without norm "
                             "tracking (track_norms=False)")
        self._norms = jnp.asarray(norms, jnp.float32)

    def update_norms(self, ids, values) -> None:
        """Set norm rows at ``ids`` to ``values`` (cohort-sized update)."""
        if self._norms is None:
            raise ValueError(f"{self.kind} store was built without norm "
                             "tracking (track_norms=False)")
        idx = jnp.asarray(_ids_array(ids))
        self._norms = self._norms.at[idx].set(
            jnp.asarray(values, jnp.float32))

    def mark_dispatched(self, ids, round: int) -> None:
        """Record that ``ids`` pulled Θ_{round} this round — the version
        state cross-round staleness (DESIGN.md §11.3) measures against."""
        self.versions[_ids_array(ids)] = int(round)

    def staleness(self, ids, round: int) -> np.ndarray:
        """Round-distance ``round - version[id]`` for each id (>= 0)."""
        return np.maximum(int(round) - self.versions[_ids_array(ids)], 0)

    # ---- checkpointing / accounting ---------------------------------------
    def state(self) -> Dict[str, Any]:
        """Static-shaped state pytree for ``checkpoint.save_checkpoint``."""
        raise NotImplementedError

    def load_state(self, tree: Dict[str, Any]) -> None:
        """Restore :meth:`state`'s pytree (inverse of :meth:`state`)."""
        raise NotImplementedError

    def memory_bytes(self) -> Dict[str, int]:
        """Exact client-state footprint: residual backing, O(M) vectors,
        and what a dense ``(M, …)`` store would cost for comparison."""
        client = _per_client_bytes(self.template)
        vectors = int(self.versions.nbytes)
        if self._norms is not None:
            vectors += int(np.dtype(np.float32).itemsize * self.num_clients)
        return {
            "backend": self.kind,
            "client_bytes": client,
            "vector_bytes": vectors,
            "residual_bytes": self._residual_backing_bytes(),
            "dense_equiv_bytes": client * self.num_clients,
        }

    def _residual_backing_bytes(self) -> int:
        raise NotImplementedError

    def shard_over(self, mesh) -> None:
        """Distribute the store's arrays over ``mesh``'s data axes
        (``launch.mesh.data_axes``): the O(M) norm vector and — for the
        sharded backend — the slot pool's client axis.  Dims that do not
        divide the data-axis product stay replicated, matching
        ``launch/shardings.py``'s fallback rule."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.launch.mesh import data_axes

        axes = data_axes(mesh)
        size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1

        def put_vec(v):
            if v is None or size <= 1 or v.shape[0] % size:
                return v
            return jax.device_put(v, NamedSharding(mesh, P(axes)))

        self._norms = put_vec(self._norms)
        self._shard_backing(put_vec)

    def _shard_backing(self, put_vec) -> None:
        """Backend hook for :meth:`shard_over` (vectors already placed)."""


class DenseStore(ClientStateStore):
    """The original dense ``(M, …)`` stacked residual arrays — the
    bit-exact oracle backend, and the default the server constructs.

    ``gather``/``scatter`` run the identical ``jnp.take`` /
    ``where(commit) → .at[ids].set`` ops the pre-store round programs ran
    inline, so dense-store runs reproduce the historical engines bit for
    bit (tier-1's cohort==oracle and async-degeneration suites all run on
    this backend).
    """

    kind = "dense"

    def __init__(self, num_clients: int, template: PyTree,
                 track_norms: bool = False):
        super().__init__(num_clients, template, track_norms)
        self.residuals = jax.tree.map(
            lambda p: jnp.zeros((num_clients,) + tuple(p.shape), p.dtype),
            template)

    def gather(self, ids) -> PyTree:
        """``jnp.take`` of the stacked rows (exact op the engines used)."""
        idx = jnp.asarray(_ids_array(ids))
        return jax.tree.map(lambda x: jnp.take(x, idx, axis=0),
                            self.residuals)

    def scatter(self, ids, rows: PyTree, commit, round: int) -> None:
        """Commit-masked row write-back, identical math to the in-program
        scatter of ``make_cohort_round`` (gather old rows, ``where`` on
        the commit mask, one ``.at[ids].set``)."""
        idx = jnp.asarray(_ids_array(ids))
        commit = jnp.asarray(commit, jnp.float32)

        def put(old, new):
            keep = commit.reshape((-1,) + (1,) * (new.ndim - 1))
            old_rows = jnp.take(old, idx, axis=0)
            return old.at[idx].set(jnp.where(keep > 0, new, old_rows))

        self.residuals = jax.tree.map(put, self.residuals, rows)

    def residuals_dense(self) -> PyTree:
        """The backing arrays themselves (no copy)."""
        return self.residuals

    def set_dense(self, residuals: PyTree) -> None:
        """Replace the whole stacked array — the dense engines' fast path
        (their round programs already did gather/scatter in-program)."""
        self.residuals = residuals

    def state(self) -> Dict[str, Any]:
        """Checkpoint tree: stacked residuals + versions (+ norms)."""
        tree: Dict[str, Any] = {
            "residuals": self.residuals,
            "versions": jnp.asarray(self.versions),
        }
        if self._norms is not None:
            tree["norms"] = self._norms
        return tree

    def load_state(self, tree: Dict[str, Any]) -> None:
        """Restore the checkpoint tree written by :meth:`state`."""
        self.residuals = tree["residuals"]
        self.versions = np.asarray(tree["versions"], np.int64).copy()
        if self._norms is not None:
            self._norms = jnp.asarray(tree["norms"], jnp.float32)

    def _residual_backing_bytes(self) -> int:
        return int(sum(leaf.nbytes for leaf in
                       jax.tree_util.tree_leaves(self.residuals)))

    def _shard_backing(self, put_vec) -> None:
        self.residuals = jax.tree.map(put_vec, self.residuals)


class ShardedStore(ClientStateStore):
    """Fixed-capacity sparse residual pool + compact O(M) vectors.

    ``retention`` is the window measured in **client slots**: residual
    rows exist only for the (at most) ``retention`` clients that committed
    most recently.  Layout per residual leaf: ``(retention + 1, …)`` — slot
    ``retention`` is a permanent zero row, so a gather of an unknown (or
    evicted) client is a plain ``jnp.take`` at the sentinel index, one
    gather per leaf with no branching.

    Eviction: when a committing client needs a slot and none is free, the
    slot whose owner committed least recently is reassigned (ties broken
    by slot index — deterministic).  The evicted client's residual is
    forgotten ("evicted to zero"); slots owned by clients committing in
    the SAME round are never victims.  A single round committing more than
    ``retention`` clients cannot be represented and raises ``ValueError``
    — size the window at or above the largest cohort.

    Peak residual memory is ``(retention + 1)/M`` of the dense footprint
    plus the O(M) vectors, the bound ``benchmarks/client_store.py``
    asserts (BENCH_store.json).
    """

    kind = "sharded"

    def __init__(self, num_clients: int, template: PyTree,
                 retention: int, track_norms: bool = False):
        super().__init__(num_clients, template, track_norms)
        if not 0 < retention <= num_clients:
            raise ValueError(
                f"retention must be in (0, num_clients={num_clients}], "
                f"got {retention}")
        self.retention = int(retention)
        self.slots = jax.tree.map(
            lambda p: jnp.zeros((self.retention + 1,) + tuple(p.shape),
                                p.dtype),
            template)
        # Host-side slot directory: owner id per slot (-1 = free), the
        # round its owner last committed (LRU key), and the id -> slot map.
        self._slot_ids = np.full((self.retention,), -1, np.int64)
        self._slot_round = np.zeros((self.retention,), np.int64)
        self._slot_of: Dict[int, int] = {}
        self.evictions = 0

    # ---- slot bookkeeping -------------------------------------------------
    def _slot_index(self, ids: np.ndarray) -> np.ndarray:
        """Slot per id; the zero-sentinel slot ``retention`` on a miss."""
        return np.asarray([self._slot_of.get(int(i), self.retention)
                           for i in ids], np.int64)

    def _assign_slots(self, cids: np.ndarray, round: int) -> np.ndarray:
        """Slots for this round's committing clients, evicting LRU owners
        as needed.  Raises if the commit set exceeds the window."""
        if len(cids) > self.retention:
            raise ValueError(
                f"round {round} commits {len(cids)} clients but the "
                f"sharded store retains only {self.retention} slots; "
                "raise retention above the largest cohort")
        pinned = set()
        assigned = np.empty((len(cids),), np.int64)
        misses = []
        for i, cid in enumerate(cids):
            slot = self._slot_of.get(int(cid))
            if slot is not None:
                assigned[i] = slot
                pinned.add(slot)
            else:
                misses.append(i)
        if misses:
            free = [s for s in range(self.retention)
                    if self._slot_ids[s] < 0]
            # LRU victims among non-free, non-pinned slots, oldest first.
            victims = sorted(
                (s for s in range(self.retention)
                 if self._slot_ids[s] >= 0 and s not in pinned),
                key=lambda s: (self._slot_round[s], s))
            for i in misses:
                if free:
                    slot = free.pop(0)
                else:
                    slot = victims.pop(0)
                    del self._slot_of[int(self._slot_ids[slot])]
                    self.evictions += 1
                assigned[i] = slot
                pinned.add(slot)
        for i, cid in enumerate(cids):
            slot = int(assigned[i])
            self._slot_of[int(cid)] = slot
            self._slot_ids[slot] = int(cid)
            self._slot_round[slot] = int(round)
        return assigned

    # ---- ClientStateStore API ---------------------------------------------
    def gather(self, ids) -> PyTree:
        """One ``jnp.take`` per leaf; misses read the zero sentinel row."""
        idx = jnp.asarray(self._slot_index(_ids_array(ids)))
        return jax.tree.map(lambda s: jnp.take(s, idx, axis=0), self.slots)

    def scatter(self, ids, rows: PyTree, commit, round: int) -> None:
        """Write committed rows into their (possibly newly-evicted) slots.

        Only the ``commit > 0`` subset touches the pool: uncommitted rows
        neither allocate slots nor refresh the LRU clock, so a client that
        was merely *sampled* (dropped, quarantined, padded) costs no
        retention."""
        ids = _ids_array(ids)
        commit = np.asarray(commit)
        pos = np.flatnonzero(commit > 0)
        if pos.size == 0:
            return
        slot_idx = self._assign_slots(ids[pos], round)
        pos_dev = jnp.asarray(pos)
        slot_dev = jnp.asarray(slot_idx)
        self.slots = jax.tree.map(
            lambda s, r: s.at[slot_dev].set(jnp.take(r, pos_dev, axis=0)),
            self.slots, rows)

    def residuals_dense(self) -> PyTree:
        """Materialize the full ``(M, …)`` view — zeros except occupied
        slots.  O(M × model): test/debug only, never on the hot path."""
        occupied = np.flatnonzero(self._slot_ids >= 0)
        owner = jnp.asarray(self._slot_ids[occupied])
        slot = jnp.asarray(occupied)

        def densify(s, spec):
            out = jnp.zeros((self.num_clients,) + tuple(spec.shape),
                            spec.dtype)
            if occupied.size == 0:
                return out
            return out.at[owner].set(jnp.take(s, slot, axis=0))

        return jax.tree.map(densify, self.slots, self.template)

    def state(self) -> Dict[str, Any]:
        """Checkpoint tree: slot pool + slot directory + versions (+
        norms) — all static shapes, so the checkpoint layer's structure
        validation works unchanged."""
        tree: Dict[str, Any] = {
            "slots": self.slots,
            "slot_ids": jnp.asarray(self._slot_ids),
            "slot_round": jnp.asarray(self._slot_round),
            "versions": jnp.asarray(self.versions),
        }
        if self._norms is not None:
            tree["norms"] = self._norms
        return tree

    def load_state(self, tree: Dict[str, Any]) -> None:
        """Restore :meth:`state` and rebuild the host slot directory."""
        self.slots = tree["slots"]
        self._slot_ids = np.asarray(tree["slot_ids"], np.int64).copy()
        self._slot_round = np.asarray(tree["slot_round"], np.int64).copy()
        self.versions = np.asarray(tree["versions"], np.int64).copy()
        self._slot_of = {int(cid): s for s, cid in enumerate(self._slot_ids)
                         if cid >= 0}
        if self._norms is not None:
            self._norms = jnp.asarray(tree["norms"], jnp.float32)

    def memory_bytes(self) -> Dict[str, int]:
        """Dense accounting plus the slot directory and window size."""
        out = super().memory_bytes()
        out["vector_bytes"] += int(self._slot_ids.nbytes
                                   + self._slot_round.nbytes)
        out["retention"] = self.retention
        out["evictions"] = self.evictions
        return out

    def _residual_backing_bytes(self) -> int:
        return int(sum(leaf.nbytes for leaf in
                       jax.tree_util.tree_leaves(self.slots)))

    def _shard_backing(self, put_vec) -> None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        # The slot axis is the sharded store's "client" axis; reuse the
        # same divisibility-or-replicate rule via a leading-dim put.
        def put_slots(s):
            probe = put_vec(jnp.zeros((s.shape[0],), jnp.float32))
            sharding = getattr(probe, "sharding", None)
            if sharding is None or not isinstance(sharding, NamedSharding):
                return s
            spec = sharding.spec
            return jax.device_put(
                s, NamedSharding(sharding.mesh,
                                 P(spec[0], *([None] * (s.ndim - 1)))))

        self.slots = jax.tree.map(put_slots, self.slots)


def make_store(kind: str, num_clients: int, template: PyTree, *,
               retention: int | None = None,
               track_norms: bool = False) -> ClientStateStore:
    """Build a store backend by name: ``"dense"`` (the oracle) or
    ``"sharded"`` (requires ``retention``, the client-slot window)."""
    if kind == "dense":
        return DenseStore(num_clients, template, track_norms=track_norms)
    if kind == "sharded":
        if retention is None:
            raise ValueError("sharded store requires retention= (the "
                             "client-slot window)")
        return ShardedStore(num_clients, template, retention,
                            track_norms=track_norms)
    raise ValueError(f"unknown store kind {kind!r}; use 'dense' | 'sharded'")
