"""Sharded client-state store: per-client server state at fleet scale.

Everything the server remembers *per client* — error-feedback residuals
absorbing wire loss, the adaptive samplers' update-norm EMAs, and the
model-version each client last pulled — used to live as dense ``(M, …) +
model`` stacked arrays owned by ``FederatedServer``.  That representation
is the memory wall on the road to M = 10^6 registered clients: residuals
alone cost ``M × model_bytes`` whether or not error feedback is even on,
and long before a million clients the host (let alone the device) runs out.

This module makes the state-ownership layer a pluggable subsystem
(DESIGN.md §11) with two interchangeable backends behind one
:class:`ClientStateStore` contract:

* :class:`DenseStore` — the original ``(M, …)`` stacked arrays, kept as
  the **bit-exact oracle**.  Gather/scatter are the same ``jnp.take`` /
  ``.at[ids].set`` ops the round programs used to run inline, so a server
  on a ``DenseStore`` reproduces the pre-store code paths to the bit.
* :class:`ShardedStore` — residuals held **sparsely**, only for clients
  whose upload committed within a configurable *retention window* of
  ``retention`` client slots.  The backing is a fixed-capacity slot pool
  (``(retention + 1, …)`` per leaf; the extra row is a permanent zero row
  that gather misses read), plus compact O(M) vectors: the norm EMA
  ``(M,)`` float32 and the per-client model-version ``(M,)`` int64 the
  async engine's cross-round staleness discount feeds on.  When the pool
  is full, the least-recently-committed client is **evicted to zero** —
  its residual is forgotten, exactly as if it had never shipped the lost
  mass (a safe degradation for error feedback: the residual is a
  correction, not required state).

Equivalence contract (property-tested in ``tests/test_client_store.py``
and the cross-engine matrix in ``tests/test_equivalence.py``): as long as
no eviction occurs (``retention`` covers every client that has ever
committed), a run on a ``ShardedStore`` is bit-identical to the same run
on a ``DenseStore`` — params, EF residuals, norm EMAs and FedDyn drift —
under every strategy preset in the registry.  Eviction is the documented
divergence point.

**Named state trees** (DESIGN.md §12): the store holds a *dict* of
per-client state trees sharing one layout — ``"residuals"`` always, plus
any ``extra_trees`` (the FedDyn drift vector ``"drift"`` is the first).
On the sharded backend every tree shares ONE slot directory: a client owns
one slot across all trees, commits to any tree refresh the same LRU clock,
and eviction forgets *all* of a client's trees at once (a newly assigned
slot is zeroed across every tree before the committing tree writes), so
evict-to-zero extends per-tree and dense-vs-sharded stays bit-exact
tree-by-tree.

The O(M) vectors are the only state that must exist for all M clients;
:meth:`ClientStateStore.shard_over` places them (and the sharded slot
pool's client axis) over a mesh's data axes via ``jax.sharding`` so even
they distribute at pod scale (``launch/shardings.py`` conventions).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

__all__ = ["ClientStateStore", "DenseStore", "ShardedStore", "make_store"]


def _ids_array(ids) -> np.ndarray:
    """Normalize a gather/scatter id argument to a 1-D int64 numpy array."""
    out = np.asarray(ids)
    if out.ndim != 1:
        raise ValueError(f"ids must be 1-D, got shape {out.shape}")
    return out.astype(np.int64)


def _per_client_bytes(template: PyTree) -> int:
    """Residual bytes ONE client costs under ``template``'s shapes."""
    return int(sum(np.prod(leaf.shape) * np.dtype(leaf.dtype).itemsize
                   for leaf in jax.tree_util.tree_leaves(template)))


class ClientStateStore:
    """Backend-agnostic contract for per-client server state.

    Residual rows move through :meth:`gather` (cohort ids → stacked
    ``(B, …)`` rows; unknown clients read as zeros on sparse backends) and
    :meth:`scatter` (write back the rows whose ``commit`` mask is set —
    the round's "this upload actually applied" gate).  Norm EMAs and
    model versions are compact ``(M,)`` vectors with their own accessors.
    :meth:`state` / :meth:`load_state` expose a static-shaped pytree for
    the checkpoint layer, and :meth:`memory_bytes` is the accounting the
    scaling benchmark (``benchmarks/client_store.py``) meters.
    """

    kind: str = "abstract"

    def __init__(self, num_clients: int, template: PyTree,
                 track_norms: bool = False,
                 extra_trees: Optional[Dict[str, PyTree]] = None):
        if num_clients < 1:
            raise ValueError(f"num_clients must be >= 1, got {num_clients}")
        self.num_clients = int(num_clients)

        def spec(tree):
            return jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), tree)

        # Named per-client state trees: "residuals" always exists; extras
        # (e.g. the FedDyn "drift" tree) share the same per-client layout
        # discipline.  ``self.template`` stays the residuals spec for
        # backward compatibility.
        self.templates: Dict[str, PyTree] = {"residuals": spec(template)}
        for name, tmpl in (extra_trees or {}).items():
            if name == "residuals":
                raise ValueError(
                    "extra_trees may not shadow the 'residuals' tree")
            self.templates[name] = spec(tmpl)
        self.template = self.templates["residuals"]
        self._norms: Optional[jnp.ndarray] = (
            jnp.ones((num_clients,), jnp.float32) if track_norms else None)
        # Model-version vector: the round number of the Θ each client last
        # pulled (0 = never dispatched).  Host-side int64 — the async
        # engine's staleness math consumes it between device dispatches.
        self.versions = np.zeros((num_clients,), np.int64)

    # ---- named state trees -----------------------------------------------
    @property
    def trees(self) -> Tuple[str, ...]:
        """Names of the per-client state trees this store holds."""
        return tuple(self.templates)

    def _check_tree(self, tree: str) -> str:
        if tree not in self.templates:
            raise KeyError(
                f"store holds no state tree {tree!r}; trees: "
                f"{', '.join(self.templates)}")
        return tree

    # ---- state rows --------------------------------------------------------
    def gather(self, ids, tree: str = "residuals") -> PyTree:
        """Stacked ``tree`` rows for ``ids`` (zeros where unknown)."""
        raise NotImplementedError

    def scatter(self, ids, rows: PyTree, commit, round: int,
                tree: str = "residuals") -> None:
        """Write back ``rows[i]`` for every i with ``commit[i] > 0``.

        Rows with ``commit[i] == 0`` are untouched (the client's upload
        was dropped / quarantined / timed out, so its state must stay
        consistent with the model it will re-download)."""
        raise NotImplementedError

    def dense_view(self, tree: str = "residuals") -> PyTree:
        """The full ``(M, …)`` stacked view of one state tree.
        O(M × model) memory — the representation this subsystem exists to
        avoid; kept for the oracle engine, small-M tests and debugging."""
        raise NotImplementedError

    def residuals_dense(self) -> PyTree:
        """``dense_view("residuals")`` (historical name)."""
        return self.dense_view("residuals")

    # ---- compact (M,) vectors --------------------------------------------
    @property
    def norms(self) -> Optional[jnp.ndarray]:
        """The adaptive samplers' per-client update-norm EMA (or None)."""
        return self._norms

    def set_norms(self, norms) -> None:
        """Replace the whole norm-EMA vector (dense engines hand back the
        full updated vector)."""
        if self._norms is None:
            raise ValueError(f"{self.kind} store was built without norm "
                             "tracking (track_norms=False)")
        self._norms = jnp.asarray(norms, jnp.float32)

    def update_norms(self, ids, values) -> None:
        """Set norm rows at ``ids`` to ``values`` (cohort-sized update)."""
        if self._norms is None:
            raise ValueError(f"{self.kind} store was built without norm "
                             "tracking (track_norms=False)")
        idx = jnp.asarray(_ids_array(ids))
        self._norms = self._norms.at[idx].set(
            jnp.asarray(values, jnp.float32))

    def mark_dispatched(self, ids, round: int) -> None:
        """Record that ``ids`` pulled Θ_{round} this round — the version
        state cross-round staleness (DESIGN.md §11.3) measures against."""
        self.versions[_ids_array(ids)] = int(round)

    def staleness(self, ids, round: int) -> np.ndarray:
        """Round-distance ``round - version[id]`` for each id (>= 0)."""
        return np.maximum(int(round) - self.versions[_ids_array(ids)], 0)

    # ---- checkpointing / accounting ---------------------------------------
    def state(self) -> Dict[str, Any]:
        """Static-shaped state pytree for ``checkpoint.save_checkpoint``."""
        raise NotImplementedError

    def load_state(self, tree: Dict[str, Any]) -> None:
        """Restore :meth:`state`'s pytree (inverse of :meth:`state`)."""
        raise NotImplementedError

    def memory_bytes(self) -> Dict[str, int]:
        """Exact client-state footprint: state-tree backing, O(M) vectors,
        and what a dense ``(M, …)`` store would cost for comparison.
        All named trees are summed (residuals + drift + …)."""
        client = sum(_per_client_bytes(t) for t in self.templates.values())
        vectors = int(self.versions.nbytes)
        if self._norms is not None:
            vectors += int(np.dtype(np.float32).itemsize * self.num_clients)
        return {
            "backend": self.kind,
            "client_bytes": client,
            "vector_bytes": vectors,
            "residual_bytes": self._residual_backing_bytes(),
            "dense_equiv_bytes": client * self.num_clients,
        }

    def _residual_backing_bytes(self) -> int:
        raise NotImplementedError

    def shard_over(self, mesh) -> None:
        """Distribute the store's arrays over ``mesh``'s data axes
        (``launch.mesh.data_axes``): the O(M) norm vector and — for the
        sharded backend — the slot pool's client axis.  Dims that do not
        divide the data-axis product stay replicated, matching
        ``launch/shardings.py``'s fallback rule."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.launch.mesh import data_axes

        axes = data_axes(mesh)
        size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1

        def put_vec(v):
            if v is None or size <= 1 or v.shape[0] % size:
                return v
            return jax.device_put(v, NamedSharding(mesh, P(axes)))

        self._norms = put_vec(self._norms)
        self._shard_backing(put_vec, mesh, axes, size)

    def _shard_backing(self, put_vec, mesh, axes, size) -> None:
        """Backend hook for :meth:`shard_over` (vectors already placed)."""


class DenseStore(ClientStateStore):
    """The original dense ``(M, …)`` stacked residual arrays — the
    bit-exact oracle backend, and the default the server constructs.

    ``gather``/``scatter`` run the identical ``jnp.take`` /
    ``where(commit) → .at[ids].set`` ops the pre-store round programs ran
    inline, so dense-store runs reproduce the historical engines bit for
    bit (tier-1's cohort==oracle and async-degeneration suites all run on
    this backend).
    """

    kind = "dense"

    def __init__(self, num_clients: int, template: PyTree,
                 track_norms: bool = False,
                 extra_trees: Optional[Dict[str, PyTree]] = None):
        super().__init__(num_clients, template, track_norms, extra_trees)
        self._data: Dict[str, PyTree] = {
            name: jax.tree.map(
                lambda p: jnp.zeros((num_clients,) + tuple(p.shape),
                                    p.dtype),
                spec)
            for name, spec in self.templates.items()}

    @property
    def residuals(self) -> PyTree:
        """The stacked residual backing (historical attribute name)."""
        return self._data["residuals"]

    @residuals.setter
    def residuals(self, value: PyTree) -> None:
        self._data["residuals"] = value

    def gather(self, ids, tree: str = "residuals") -> PyTree:
        """``jnp.take`` of the stacked rows (exact op the engines used)."""
        idx = jnp.asarray(_ids_array(ids))
        return jax.tree.map(lambda x: jnp.take(x, idx, axis=0),
                            self._data[self._check_tree(tree)])

    def scatter(self, ids, rows: PyTree, commit, round: int,
                tree: str = "residuals") -> None:
        """Commit-masked row write-back, identical math to the in-program
        scatter of ``make_cohort_round`` (gather old rows, ``where`` on
        the commit mask, one ``.at[ids].set``)."""
        tree = self._check_tree(tree)
        idx = jnp.asarray(_ids_array(ids))
        commit = jnp.asarray(commit, jnp.float32)

        def put(old, new):
            keep = commit.reshape((-1,) + (1,) * (new.ndim - 1))
            old_rows = jnp.take(old, idx, axis=0)
            return old.at[idx].set(jnp.where(keep > 0, new, old_rows))

        self._data[tree] = jax.tree.map(put, self._data[tree], rows)

    def dense_view(self, tree: str = "residuals") -> PyTree:
        """The backing arrays themselves (no copy)."""
        return self._data[self._check_tree(tree)]

    def set_dense(self, value: PyTree, tree: str = "residuals") -> None:
        """Replace a whole stacked tree — the dense engines' fast path
        (their round programs already did gather/scatter in-program)."""
        self._data[self._check_tree(tree)] = value

    def state(self) -> Dict[str, Any]:
        """Checkpoint tree: stacked residuals + versions (+ norms); extra
        state trees checkpoint under their own name (e.g. ``"drift"``)."""
        tree: Dict[str, Any] = {
            "residuals": self._data["residuals"],
            "versions": jnp.asarray(self.versions),
        }
        for name in self.templates:
            if name != "residuals":
                tree[name] = self._data[name]
        if self._norms is not None:
            tree["norms"] = self._norms
        return tree

    def load_state(self, tree: Dict[str, Any]) -> None:
        """Restore the checkpoint tree written by :meth:`state`."""
        self._data["residuals"] = tree["residuals"]
        for name in self.templates:
            if name != "residuals":
                self._data[name] = tree[name]
        self.versions = np.asarray(tree["versions"], np.int64).copy()
        if self._norms is not None:
            self._norms = jnp.asarray(tree["norms"], jnp.float32)

    def _residual_backing_bytes(self) -> int:
        return int(sum(leaf.nbytes
                       for data in self._data.values()
                       for leaf in jax.tree_util.tree_leaves(data)))

    def _shard_backing(self, put_vec, mesh, axes, size) -> None:
        self._data = {name: jax.tree.map(put_vec, data)
                      for name, data in self._data.items()}


class ShardedStore(ClientStateStore):
    """Fixed-capacity sparse residual pool + compact O(M) vectors.

    ``retention`` is the window measured in **client slots**: residual
    rows exist only for the (at most) ``retention`` clients that committed
    most recently.  Layout per residual leaf: ``(retention + 1, …)`` — slot
    ``retention`` is a permanent zero row, so a gather of an unknown (or
    evicted) client is a plain ``jnp.take`` at the sentinel index, one
    gather per leaf with no branching.

    Eviction: when a committing client needs a slot and none is free, the
    slot whose owner committed least recently is reassigned (ties broken
    by slot index — deterministic).  The evicted client's residual is
    forgotten ("evicted to zero"); slots owned by clients committing in
    the SAME round are never victims.  A single round committing more than
    ``retention`` clients cannot be represented and raises ``ValueError``
    — size the window at or above the largest cohort.

    Peak residual memory is ``(retention + 1)/M`` of the dense footprint
    plus the O(M) vectors, the bound ``benchmarks/client_store.py``
    asserts (BENCH_store.json).
    """

    kind = "sharded"

    def __init__(self, num_clients: int, template: PyTree,
                 retention: int, track_norms: bool = False,
                 extra_trees: Optional[Dict[str, PyTree]] = None):
        super().__init__(num_clients, template, track_norms, extra_trees)
        if not 0 < retention <= num_clients:
            raise ValueError(
                f"retention must be in (0, num_clients={num_clients}], "
                f"got {retention}")
        self.retention = int(retention)
        self._pools: Dict[str, PyTree] = {
            name: jax.tree.map(
                lambda p: jnp.zeros((self.retention + 1,) + tuple(p.shape),
                                    p.dtype),
                spec)
            for name, spec in self.templates.items()}
        # Host-side slot directory — SHARED across every state tree: a
        # client owns one slot for all its trees, so eviction forgets a
        # client's residuals and drift together.  Owner id per slot
        # (-1 = free), the round its owner last committed (LRU key), and
        # the id -> slot map.
        self._slot_ids = np.full((self.retention,), -1, np.int64)
        self._slot_round = np.zeros((self.retention,), np.int64)
        self._slot_of: Dict[int, int] = {}
        self.evictions = 0

    @property
    def slots(self) -> PyTree:
        """The residual slot pool (historical attribute name)."""
        return self._pools["residuals"]

    @slots.setter
    def slots(self, value: PyTree) -> None:
        self._pools["residuals"] = value

    # ---- slot bookkeeping -------------------------------------------------
    def _slot_index(self, ids: np.ndarray) -> np.ndarray:
        """Slot per id; the zero-sentinel slot ``retention`` on a miss."""
        return np.asarray([self._slot_of.get(int(i), self.retention)
                           for i in ids], np.int64)

    def _assign_slots(self, cids: np.ndarray,
                      round: int) -> Tuple[np.ndarray, np.ndarray]:
        """Slots for this round's committing clients, evicting LRU owners
        as needed.  Raises if the commit set exceeds the window.  Returns
        ``(assigned, fresh)``: the slot per client, and the subset of
        slots newly taken over this call (free or evicted) — those must be
        zeroed across EVERY state tree before any tree writes, or a
        reassigned slot's other-tree rows would leak the evicted client's
        state."""
        if len(cids) > self.retention:
            raise ValueError(
                f"round {round} commits {len(cids)} clients but the "
                f"sharded store retains only {self.retention} slots; "
                "raise retention above the largest cohort")
        pinned = set()
        assigned = np.empty((len(cids),), np.int64)
        misses = []
        for i, cid in enumerate(cids):
            slot = self._slot_of.get(int(cid))
            if slot is not None:
                assigned[i] = slot
                pinned.add(slot)
            else:
                misses.append(i)
        fresh = []
        if misses:
            free = [s for s in range(self.retention)
                    if self._slot_ids[s] < 0]
            # LRU victims among non-free, non-pinned slots, oldest first.
            victims = sorted(
                (s for s in range(self.retention)
                 if self._slot_ids[s] >= 0 and s not in pinned),
                key=lambda s: (self._slot_round[s], s))
            for i in misses:
                if free:
                    slot = free.pop(0)
                else:
                    slot = victims.pop(0)
                    del self._slot_of[int(self._slot_ids[slot])]
                    self.evictions += 1
                assigned[i] = slot
                pinned.add(slot)
                fresh.append(slot)
        for i, cid in enumerate(cids):
            slot = int(assigned[i])
            self._slot_of[int(cid)] = slot
            self._slot_ids[slot] = int(cid)
            self._slot_round[slot] = int(round)
        return assigned, np.asarray(fresh, np.int64)

    # ---- ClientStateStore API ---------------------------------------------
    def gather(self, ids, tree: str = "residuals") -> PyTree:
        """One ``jnp.take`` per leaf; misses read the zero sentinel row."""
        idx = jnp.asarray(self._slot_index(_ids_array(ids)))
        return jax.tree.map(lambda s: jnp.take(s, idx, axis=0),
                            self._pools[self._check_tree(tree)])

    def scatter(self, ids, rows: PyTree, commit, round: int,
                tree: str = "residuals") -> None:
        """Write committed rows into their (possibly newly-evicted) slots.

        Only the ``commit > 0`` subset touches the pool: uncommitted rows
        neither allocate slots nor refresh the LRU clock, so a client that
        was merely *sampled* (dropped, quarantined, padded) costs no
        retention.  A newly assigned slot (free or evicted) is first
        zeroed across EVERY state tree — evict-to-zero must forget all of
        the previous owner's trees, not just the one committing now."""
        tree = self._check_tree(tree)
        ids = _ids_array(ids)
        commit = np.asarray(commit)
        pos = np.flatnonzero(commit > 0)
        if pos.size == 0:
            return
        slot_idx, fresh = self._assign_slots(ids[pos], round)
        if fresh.size:
            fresh_dev = jnp.asarray(fresh)
            for name, pool in self._pools.items():
                self._pools[name] = jax.tree.map(
                    lambda s: s.at[fresh_dev].set(0), pool)
        pos_dev = jnp.asarray(pos)
        slot_dev = jnp.asarray(slot_idx)
        self._pools[tree] = jax.tree.map(
            lambda s, r: s.at[slot_dev].set(jnp.take(r, pos_dev, axis=0)),
            self._pools[tree], rows)

    def dense_view(self, tree: str = "residuals") -> PyTree:
        """Materialize the full ``(M, …)`` view — zeros except occupied
        slots.  O(M × model): test/debug only, never on the hot path."""
        tree = self._check_tree(tree)
        occupied = np.flatnonzero(self._slot_ids >= 0)
        owner = jnp.asarray(self._slot_ids[occupied])
        slot = jnp.asarray(occupied)

        def densify(s, spec):
            out = jnp.zeros((self.num_clients,) + tuple(spec.shape),
                            spec.dtype)
            if occupied.size == 0:
                return out
            return out.at[owner].set(jnp.take(s, slot, axis=0))

        return jax.tree.map(densify, self._pools[tree],
                            self.templates[tree])

    def state(self) -> Dict[str, Any]:
        """Checkpoint tree: slot pools + slot directory + versions (+
        norms) — all static shapes, so the checkpoint layer's structure
        validation works unchanged.  The residual pool keeps its
        historical ``"slots"`` key; extra trees checkpoint under
        ``"slots_<name>"`` (e.g. ``"slots_drift"``)."""
        tree: Dict[str, Any] = {
            "slots": self._pools["residuals"],
            "slot_ids": jnp.asarray(self._slot_ids),
            "slot_round": jnp.asarray(self._slot_round),
            "versions": jnp.asarray(self.versions),
        }
        for name in self.templates:
            if name != "residuals":
                tree[f"slots_{name}"] = self._pools[name]
        if self._norms is not None:
            tree["norms"] = self._norms
        return tree

    def load_state(self, tree: Dict[str, Any]) -> None:
        """Restore :meth:`state` and rebuild the host slot directory."""
        self._pools["residuals"] = tree["slots"]
        for name in self.templates:
            if name != "residuals":
                self._pools[name] = tree[f"slots_{name}"]
        self._slot_ids = np.asarray(tree["slot_ids"], np.int64).copy()
        self._slot_round = np.asarray(tree["slot_round"], np.int64).copy()
        self.versions = np.asarray(tree["versions"], np.int64).copy()
        self._slot_of = {int(cid): s for s, cid in enumerate(self._slot_ids)
                         if cid >= 0}
        if self._norms is not None:
            self._norms = jnp.asarray(tree["norms"], jnp.float32)

    def memory_bytes(self) -> Dict[str, int]:
        """Dense accounting plus the slot directory and window size."""
        out = super().memory_bytes()
        out["vector_bytes"] += int(self._slot_ids.nbytes
                                   + self._slot_round.nbytes)
        out["retention"] = self.retention
        out["evictions"] = self.evictions
        return out

    def _residual_backing_bytes(self) -> int:
        return int(sum(leaf.nbytes
                       for pool in self._pools.values()
                       for leaf in jax.tree_util.tree_leaves(pool)))

    def _shard_backing(self, put_vec, mesh, axes, size) -> None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        # The slot axis is the sharded store's "client" axis.  The pool has
        # ``retention + 1`` rows (the zero sentinel), which almost never
        # divides the data-axis product, so instead of the
        # divisibility-or-replicate fallback the pool is zero-padded up to
        # the next multiple of the axis size and the PADDED row axis is
        # sharded.  The pad rows sit beyond the sentinel index and are
        # never addressed by gather/scatter/dense_view; a checkpoint taken
        # after ``shard_over`` carries the padded pool shape.
        if size <= 1 or not axes:
            return
        rows = self.retention + 1
        padded = -(-rows // size) * size

        def put_slots(s):
            if padded != rows:
                pad = jnp.zeros((padded - rows,) + tuple(s.shape[1:]),
                                s.dtype)
                s = jnp.concatenate([s, pad], axis=0)
            return jax.device_put(
                s, NamedSharding(mesh, P(axes, *([None] * (s.ndim - 1)))))

        self._pools = {name: jax.tree.map(put_slots, pool)
                       for name, pool in self._pools.items()}


def make_store(kind: str, num_clients: int, template: PyTree, *,
               retention: int | None = None,
               track_norms: bool = False,
               extra_trees: Optional[Dict[str, PyTree]] = None,
               ) -> ClientStateStore:
    """Build a store backend by name: ``"dense"`` (the oracle) or
    ``"sharded"`` (requires ``retention``, the client-slot window).
    ``extra_trees`` adds named per-client state trees next to the
    residuals (e.g. ``{"drift": params_template}`` for FedDyn)."""
    if kind == "dense":
        return DenseStore(num_clients, template, track_norms=track_norms,
                          extra_trees=extra_trees)
    if kind == "sharded":
        if retention is None:
            raise ValueError("sharded store requires retention= (the "
                             "client-slot window)")
        return ShardedStore(num_clients, template, retention,
                            track_norms=track_norms,
                            extra_trees=extra_trees)
    raise ValueError(f"unknown store kind {kind!r}; use 'dense' | 'sharded'")
