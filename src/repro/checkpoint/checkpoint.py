"""Minimal but real checkpointing: flat-key npz payloads + json manifest.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json (treedef, dtypes, step).
Atomicity: written to a tmp dir then os.rename'd, so a crash never leaves a
half-written step visible to ``latest_step``.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np

PyTree = Any

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "read_manifest"]

_SEP = "||"


def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = [jax.tree_util.keystr(path) for path, _ in flat]
    vals = [np.asarray(v) for _, v in flat]
    return keys, vals, treedef


def _to_savable(v: np.ndarray) -> np.ndarray:
    """npz cannot hold ml_dtypes (bfloat16 etc.); store as a uint view and
    restore from the manifest dtype."""
    if v.dtype.kind == "V" or str(v.dtype) in ("bfloat16", "float8_e4m3fn",
                                               "float8_e5m2"):
        return v.view(np.uint16 if v.dtype.itemsize == 2 else np.uint8)
    return v


def _from_savable(v: np.ndarray, dtype_str: str) -> np.ndarray:
    if str(v.dtype) != dtype_str:
        import ml_dtypes
        return v.view(np.dtype(getattr(ml_dtypes, dtype_str)))
    return v


def save_checkpoint(ckpt_dir: str, step: int, tree: PyTree,
                    extra: Optional[dict] = None) -> str:
    keys, vals, _ = _flatten_with_paths(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"a{i}": _to_savable(v) for i, v in enumerate(vals)})
    manifest = {
        "step": step,
        "keys": keys,
        "dtypes": [str(v.dtype) for v in vals],
        "shapes": [list(v.shape) for v in vals],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def read_manifest(ckpt_dir: str, step: Optional[int] = None) -> dict:
    """The manifest dict of one step (latest unless pinned) WITHOUT loading
    the array payload — cheap pre-restore validation (e.g. the server
    checking the checkpoint's ``extra["num_clients"]`` against its own
    before touching any residual state)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def restore_checkpoint(ckpt_dir: str, like: PyTree,
                       step: Optional[int] = None) -> Tuple[PyTree, int, dict]:
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    vals = [_from_savable(data[f"a{i}"], manifest["dtypes"][i])
            for i in range(len(manifest["keys"]))]

    keys_like, vals_like, treedef = _flatten_with_paths(like)
    if keys_like != manifest["keys"]:
        raise ValueError("checkpoint structure mismatch: "
                         f"{set(keys_like) ^ set(manifest['keys'])}")
    for k, a, b in zip(keys_like, vals, vals_like):
        if tuple(a.shape) != tuple(b.shape):
            raise ValueError(f"shape mismatch at {k}: {a.shape} vs {b.shape}")
    restored = jax.tree_util.tree_unflatten(
        treedef, [v if v.dtype == b.dtype else v.astype(b.dtype)
                  for v, b in zip(vals, vals_like)])
    return restored, manifest["step"], manifest.get("extra", {})
