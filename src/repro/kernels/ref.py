"""Pure-jnp oracles for the masking kernels.

These define the semantics the Pallas kernels are tested against:

* ``topk_mask_ref``      — exact top-k-by-|x| mask (full sort), the paper's
  Alg. 4 as written.
* ``threshold_mask_ref`` — keep entries with |x| >= tau.
* ``exponent_histogram_ref`` — per-octave magnitude counts, the quantity the
  histogram kernel accumulates.
"""

from __future__ import annotations

import jax.numpy as jnp

NBINS = 128
EXPO_MIN = -96  # bin j counts magnitudes in [2^(j+EXPO_MIN), 2^(j+EXPO_MIN+1))


def topk_mask_ref(x: jnp.ndarray, gamma: float) -> jnp.ndarray:
    """Keep the k = max(1, round(gamma*size)) largest-|x| entries (exact)."""
    flat = x.reshape(-1)
    k = max(1, int(round(gamma * flat.size)))
    mag = jnp.abs(flat)
    thresh = jnp.sort(mag)[flat.size - k]
    keep = mag >= thresh
    surplus = jnp.cumsum(keep) > k
    keep = keep & ~surplus
    return (flat * keep.astype(flat.dtype)).reshape(x.shape)


def threshold_mask_ref(x: jnp.ndarray, tau) -> jnp.ndarray:
    return x * (jnp.abs(x) >= tau).astype(x.dtype)


def count_ge_ref(x: jnp.ndarray, tau) -> jnp.ndarray:
    return jnp.sum(jnp.abs(x) >= tau).astype(jnp.int32)


def exponent_histogram_ref(x: jnp.ndarray) -> jnp.ndarray:
    """(NBINS,) int32 counts of nonzero |x| per power-of-two bin."""
    mag = jnp.abs(x.reshape(-1)).astype(jnp.float32)
    valid = mag > 0
    e = jnp.floor(jnp.log2(jnp.where(valid, mag, 1.0)))
    b = jnp.clip(e.astype(jnp.int32) - EXPO_MIN, 0, NBINS - 1)
    onehot = (b[:, None] == jnp.arange(NBINS)[None, :]) & valid[:, None]
    return jnp.sum(onehot, axis=0).astype(jnp.int32)


def group_histogram_ref(x: jnp.ndarray,
                        octaves_per_bin: int = 4) -> jnp.ndarray:
    """Coarse magnitude histogram: octave bins grouped ``octaves_per_bin`` at
    a time — the quantity the segmented histogram kernel accumulates."""
    h = exponent_histogram_ref(x)
    return h.reshape(-1, octaves_per_bin).sum(axis=1).astype(jnp.int32)


def ssm_scan_ref(a: jnp.ndarray, bx: jnp.ndarray, c: jnp.ndarray,
                 h0: jnp.ndarray):
    """Oracle for the SSM-scan kernel.  a, bx: (B, T, N, D); c: (B, T, N);
    h0: (B, N, D).  Returns (y (B, T, D), hT (B, N, D))."""
    import jax

    def step(h, inp):
        a_t, bx_t, c_t = inp                      # (B,N,D),(B,N,D),(B,N)
        h = a_t * h + bx_t
        y = jnp.einsum("bnd,bn->bd", h, c_t)
        return h, y

    hT, ys = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (a.transpose(1, 0, 2, 3).astype(jnp.float32),
         bx.transpose(1, 0, 2, 3).astype(jnp.float32),
         c.transpose(1, 0, 2).astype(jnp.float32)))
    return ys.transpose(1, 0, 2), hT
