"""Segmented Pallas kernels: whole-pytree selective masking (DESIGN.md §3.4).

The per-leaf pipeline (``kernels/topk_mask.py``) costs O(L * (iters + 2)) HBM
sweeps for an L-leaf model.  These kernels operate on the packed buffer from
``kernels.packing`` — every SEG_LANE-wide row belongs to exactly one segment
(leaf) — and reduce whole-model masking to a leaf-count-independent number of
sweeps:

1. ``segmented_histogram``  — (num_segments, SEG_NBINS) magnitude histogram
   (SEG_NBINS = 32 bins of OCTAVES_PER_BIN = 4 octaves each, same
   [2^EXPO_MIN, 2^(EXPO_MIN+128)) coverage as the per-leaf kernel's 128
   per-octave bins) in ONE sweep, emitted in suffix form: bin counts are
   vectorised as one compare of every element against the iota-built
   bin-edge ladder + a lane reduction, instead of a fori_loop that rescans
   the block once per bin.  Bins are 4-octave groups so the compare is 32
   wide — the first refine sweep's geometric candidates win the resolution
   back.
2. ``segmented_count``      — counts |x| >= tau for C candidate taus per
   segment per sweep, collapsing the bisection refine loop from ``iters``
   sweeps to 1-2 multi-candidate sweeps (first sweep geometric across the
   4-octave bracket, later sweeps linear).
3. ``segmented_apply``      — fused threshold-apply + kept-count in one sweep
   using the final per-segment taus.
4. ``segmented_stats``      — the histogram sweep extended with a per-segment
   max|x| reduction, so the int8 wire scale (``max|x| / 127``) rides the
   sweep that was already bracketing thresholds (DESIGN.md §10).
5. ``segmented_encode``     — the *wire-path* sweep: threshold-apply,
   optional int8 quantisation against per-segment scales, a packed 1-bit/
   element keep-bitmap, and kept counts, all emitted from ONE read of the
   packed buffer.  ``ops.topk_encode_pytree`` compacts the outputs into
   COO / bitmap payloads without ever re-reading the fp32 data.

Grid/tiling: each grid step processes a ``(slab_rows, SEG_LANE)`` slab.  The
per-row segment ids ride along as an (R, 1) int32 input; inside the kernel
they become a (rows, S) one-hot matrix, and every per-segment gather
(taus -> rows) and scatter (row stats -> segments) is a matmul against that
one-hot — MXU work on TPU, no dynamic indexing anywhere.  The TPU grid is
sequential, so reduction outputs map every step to the same block and use
``@pl.when(first)`` init + accumulate, like the per-leaf kernels.
``slab_rows`` trades VMEM residency against grid steps: 512 rows = 2 MiB
fp32 per slab operand for the compiled TPU path; interpret mode (CPU) uses
much larger slabs since each interpreter grid step re-stages the full
operands.

Threshold selection/refinement math (pure jnp on the tiny (S, NBINS) /
(S, C) stats, no HBM sweeps over the data) lives here too:
``select_thresholds``, ``candidate_taus`` and ``shrink_brackets``.  Counts at
both bracket ends are threaded through — the refine and final tau choice
never issue an extra counting sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import NBINS, EXPO_MIN
from repro.kernels.packing import SEG_LANE

__all__ = [
    "SEG_NBINS",
    "OCTAVES_PER_BIN",
    "segmented_histogram",
    "segmented_count",
    "segmented_apply",
    "segmented_stats",
    "segmented_encode",
    "select_thresholds",
    "candidate_taus",
    "shrink_brackets",
    "pad_rows",
]

# Coarse histogram layout: SEG_NBINS bins of OCTAVES_PER_BIN octaves each,
# covering the same magnitude range as the per-leaf kernel's NBINS octaves.
OCTAVES_PER_BIN = 4
SEG_NBINS = NBINS // OCTAVES_PER_BIN

# Default slab height for the compiled TPU path: (512, 1024) fp32 = 2 MiB.
SLAB_ROWS = 512
# Rows per in-kernel chunk: bounds the one-hot transients —
# (32, SEG_LANE, SEG_NBINS) fp32 = 4 MiB — regardless of slab height.
CHUNK_ROWS = 32


def _seg_onehot(seg: jax.Array, num_segments: int) -> jax.Array:
    """(rows, 1) int32 segment ids -> (rows, S) fp32 one-hot."""
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, num_segments), 1)
    return (seg == iota).astype(jnp.float32)


def _bin_ladder() -> jax.Array:
    """(1, 1, SEG_NBINS) fp32 bin-edge magnitudes 2^(EXPO_MIN + 4j).

    Comparing |x| against the ladder yields the SUFFIX form of the 4-octave
    exponent histogram (count per bin = adjacent difference) with SEG_NBINS
    plain compares per element — no log2/floor/one-hot chain — and zeros
    (incl. padding) fall below every edge, so they never count.
    """
    j = jax.lax.broadcasted_iota(jnp.float32, (1, 1, SEG_NBINS), 2)
    return jnp.exp2(j * OCTAVES_PER_BIN + EXPO_MIN)


def _row_bin_hist(x: jax.Array) -> jax.Array:
    """(rows, SEG_LANE) values -> (rows, SEG_NBINS) fp32 suffix counts:
    out[r, j] = #{e : |x[r, e]| >= 2^(EXPO_MIN + 4j)}.  fp32 sums are exact
    (row counts <= SEG_LANE)."""
    ge = (jnp.abs(x)[:, :, None] >= _bin_ladder()).astype(jnp.float32)
    return jnp.sum(ge, axis=1)


# --------------------------------------------------------------------------
# Kernel 1: segmented exponent histogram — one sweep for the whole pytree.
# --------------------------------------------------------------------------
def _seg_hist_kernel(x_ref, seg_ref, hist_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    rows = x_ref.shape[0]
    S = hist_ref.shape[0]

    def chunk(c, acc):
        xc = jax.lax.dynamic_slice_in_dim(
            x_ref[...], c * CHUNK_ROWS, CHUNK_ROWS, 0).astype(jnp.float32)
        sc = jax.lax.dynamic_slice_in_dim(
            seg_ref[...], c * CHUNK_ROWS, CHUNK_ROWS, 0)
        row_hist = _row_bin_hist(xc)                      # (chunk, SEG_NBINS)
        seg_hot = _seg_onehot(sc, S)                      # (chunk, S)
        # scatter rows -> segments: one (S x chunk x SEG_NBINS) matmul
        return acc + jax.lax.dot_general(
            seg_hot, row_hist, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, rows // CHUNK_ROWS, chunk,
                            jnp.zeros(hist_ref.shape, jnp.float32))
    hist_ref[...] += acc.astype(jnp.int32)


def segmented_histogram(x2d: jax.Array, seg_ids: jax.Array,
                        num_segments: int, *, interpret: bool,
                        slab_rows: int | None = None) -> jax.Array:
    """x2d: (R, SEG_LANE) fp32; seg_ids: (R, 1) int32; R % slab_rows == 0.

    Returns (num_segments, SEG_NBINS) int32 per-segment 4-octave-bin
    histograms in SUFFIX form — out[s, j] = count(|x_s| >= 2^(EXPO_MIN+4j)),
    per-bin counts being adjacent differences — in one HBM sweep of the
    packed buffer.  The suffix form is exactly what ``select_thresholds``
    consumes (bracket counts come for free).
    """
    slab = _slab(x2d.shape[0], slab_rows, interpret)
    return pl.pallas_call(
        _seg_hist_kernel,
        grid=(x2d.shape[0] // slab,),
        in_specs=[
            pl.BlockSpec((slab, SEG_LANE), lambda i: (i, 0)),
            pl.BlockSpec((slab, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_segments, SEG_NBINS), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, SEG_NBINS), jnp.int32),
        interpret=interpret,
    )(x2d, seg_ids)


# --------------------------------------------------------------------------
# Kernel 2: multi-threshold segmented count — C candidates per sweep.
# --------------------------------------------------------------------------
def _seg_count_kernel(x_ref, seg_ref, taus_ref, cnt_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    rows = x_ref.shape[0]
    S, C = taus_ref.shape

    def chunk(c, acc):
        xc = jax.lax.dynamic_slice_in_dim(
            x_ref[...], c * CHUNK_ROWS, CHUNK_ROWS, 0).astype(jnp.float32)
        sc = jax.lax.dynamic_slice_in_dim(
            seg_ref[...], c * CHUNK_ROWS, CHUNK_ROWS, 0)
        seg_hot = _seg_onehot(sc, S)                      # (chunk, S)
        taus_row = seg_hot @ taus_ref[...]                # gather: (chunk, C)
        ge = (jnp.abs(xc)[:, :, None] >= taus_row[:, None, :]
              ).astype(jnp.float32)                       # (chunk, LANE, C)
        row_counts = jnp.sum(ge, axis=1)                  # (chunk, C)
        return acc + jax.lax.dot_general(
            seg_hot, row_counts, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, rows // CHUNK_ROWS, chunk,
                            jnp.zeros(cnt_ref.shape, jnp.float32))
    cnt_ref[...] += acc.astype(jnp.int32)


def segmented_count(x2d: jax.Array, seg_ids: jax.Array,
                    taus: jax.Array, *, interpret: bool,
                    slab_rows: int | None = None) -> jax.Array:
    """Counts of |x| >= tau per segment for ALL C candidate taus in one sweep.

    taus: (num_segments, C) fp32 (must be > 0 so padding zeros never count).
    Returns (num_segments, C) int32.
    """
    slab = _slab(x2d.shape[0], slab_rows, interpret)
    S, C = taus.shape
    return pl.pallas_call(
        _seg_count_kernel,
        grid=(x2d.shape[0] // slab,),
        in_specs=[
            pl.BlockSpec((slab, SEG_LANE), lambda i: (i, 0)),
            pl.BlockSpec((slab, 1), lambda i: (i, 0)),
            pl.BlockSpec((S, C), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((S, C), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((S, C), jnp.int32),
        interpret=interpret,
    )(x2d, seg_ids, taus.astype(jnp.float32))


# --------------------------------------------------------------------------
# Kernel 3: fused per-segment threshold apply + kept-count — one sweep.
# --------------------------------------------------------------------------
def _seg_apply_kernel(x_ref, seg_ref, tau_ref, out_ref, cnt_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    S = tau_ref.shape[0]
    x = x_ref[...]
    seg_hot = _seg_onehot(seg_ref[...], S)                # (rows, S)
    tau_row = seg_hot @ tau_ref[...]                      # gather: (rows, 1)
    keep = jnp.abs(x.astype(jnp.float32)) >= tau_row      # broadcast over lane
    out_ref[...] = x * keep.astype(x.dtype)
    row_kept = jnp.sum(keep.astype(jnp.float32), axis=1, keepdims=True)
    cnt_ref[...] += jax.lax.dot_general(
        seg_hot, row_kept, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(jnp.int32)


def segmented_apply(x2d: jax.Array, seg_ids: jax.Array, taus: jax.Array,
                    *, interpret: bool,
                    slab_rows: int | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Apply per-segment thresholds; returns (masked (R, LANE), kept (S, 1))."""
    slab = _slab(x2d.shape[0], slab_rows, interpret)
    S = taus.shape[0]
    return pl.pallas_call(
        _seg_apply_kernel,
        grid=(x2d.shape[0] // slab,),
        in_specs=[
            pl.BlockSpec((slab, SEG_LANE), lambda i: (i, 0)),
            pl.BlockSpec((slab, 1), lambda i: (i, 0)),
            pl.BlockSpec((S, 1), lambda i: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((slab, SEG_LANE), lambda i: (i, 0)),
            pl.BlockSpec((S, 1), lambda i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
            jax.ShapeDtypeStruct((S, 1), jnp.int32),
        ),
        interpret=interpret,
    )(x2d, seg_ids, taus.reshape(S, 1).astype(jnp.float32))


# --------------------------------------------------------------------------
# Kernel 4: histogram + per-segment absmax — the stats sweep of the fused
# wire path (DESIGN.md §10).  Identical HBM traffic to segmented_histogram;
# the absmax reduction rides along so the int8 wire scale needs no extra
# sweep.
# --------------------------------------------------------------------------
def _seg_stats_kernel(x_ref, seg_ref, hist_ref, amax_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)
        amax_ref[...] = jnp.zeros_like(amax_ref)

    rows = x_ref.shape[0]
    S = hist_ref.shape[0]

    def chunk(c, carry):
        acc, amax = carry
        xc = jax.lax.dynamic_slice_in_dim(
            x_ref[...], c * CHUNK_ROWS, CHUNK_ROWS, 0).astype(jnp.float32)
        sc = jax.lax.dynamic_slice_in_dim(
            seg_ref[...], c * CHUNK_ROWS, CHUNK_ROWS, 0)
        row_hist = _row_bin_hist(xc)                      # (chunk, SEG_NBINS)
        seg_hot = _seg_onehot(sc, S)                      # (chunk, S)
        acc = acc + jax.lax.dot_general(
            seg_hot, row_hist, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        # scatter-max rows -> segments: the one-hot zeroes other segments'
        # contributions, and |x| >= 0 makes max-with-zero harmless.
        row_amax = jnp.max(jnp.abs(xc), axis=1, keepdims=True)  # (chunk, 1)
        amax = jnp.maximum(amax, jnp.max(seg_hot * row_amax, axis=0))
        return acc, amax

    acc, amax = jax.lax.fori_loop(
        0, rows // CHUNK_ROWS, chunk,
        (jnp.zeros(hist_ref.shape, jnp.float32), jnp.zeros((S,), jnp.float32)))
    hist_ref[...] += acc.astype(jnp.int32)
    amax_ref[...] = jnp.maximum(amax_ref[...], amax[:, None])


def segmented_stats(x2d: jax.Array, seg_ids: jax.Array,
                    num_segments: int, *, interpret: bool,
                    slab_rows: int | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Histogram + per-segment max|x| in one sweep of the packed buffer.

    Same contract as :func:`segmented_histogram`, additionally returning the
    (num_segments, 1) fp32 per-segment absolute maximum.  Because top-k
    masking always keeps each segment's largest-magnitude entry, this absmax
    equals the masked segment's absmax — the exact quantity the int8 wire
    scale ``max|x| / 127`` needs (DESIGN.md §10), at zero extra sweeps.
    """
    slab = _slab(x2d.shape[0], slab_rows, interpret)
    return pl.pallas_call(
        _seg_stats_kernel,
        grid=(x2d.shape[0] // slab,),
        in_specs=[
            pl.BlockSpec((slab, SEG_LANE), lambda i: (i, 0)),
            pl.BlockSpec((slab, 1), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((num_segments, SEG_NBINS), lambda i: (0, 0)),
            pl.BlockSpec((num_segments, 1), lambda i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((num_segments, SEG_NBINS), jnp.int32),
            jax.ShapeDtypeStruct((num_segments, 1), jnp.float32),
        ),
        interpret=interpret,
    )(x2d, seg_ids)


# --------------------------------------------------------------------------
# Kernel 5: fused wire-path encode — threshold-apply + int8 quantise +
# packed keep-bitmap + kept counts, all from ONE read of the packed buffer.
# --------------------------------------------------------------------------
def _bit_group_weights() -> jax.Array:
    """(SEG_LANE, SEG_LANE // 8) block-diagonal bit-packing weights.

    ``weights[i, i // 8] = 2^(i % 8)`` (zero elsewhere), so a keep-mask row
    matmul'd against it yields one byte per 8 lanes with LSB-first bit
    order — the same layout ``np.packbits(bitorder="little")`` produces.
    Sums are <= 255, exact in fp32 (and in bf16 MXU accumulation: integers
    up to 256 are representable).
    """
    i = jax.lax.broadcasted_iota(jnp.int32, (SEG_LANE, SEG_LANE // 8), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (SEG_LANE, SEG_LANE // 8), 1)
    w = jnp.exp2((i % 8).astype(jnp.float32))
    return jnp.where(i // 8 == j, w, 0.0)


def _seg_encode_kernel(x_ref, seg_ref, tau_ref, scale_ref,
                       out_ref, bm_ref, cnt_ref, *, quantize):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    S = tau_ref.shape[0]
    x = x_ref[...].astype(jnp.float32)
    seg_hot = _seg_onehot(seg_ref[...], S)                # (rows, S)
    tau_row = seg_hot @ tau_ref[...]                      # gather: (rows, 1)
    keep = (jnp.abs(x) >= tau_row).astype(jnp.float32)
    masked = x * keep
    if quantize:
        # Same formula as compression.quantize_int8 (round then clip), with
        # the per-segment scale gathered through the one-hot — zeros stay
        # exactly zero, so the bitmap still describes the nonzero support.
        scale_row = seg_hot @ scale_ref[...]              # (rows, 1)
        out_ref[...] = jnp.clip(
            jnp.round(masked / scale_row), -127, 127).astype(jnp.int8)
    else:
        out_ref[...] = masked.astype(out_ref.dtype)
    bm = jax.lax.dot(keep, _bit_group_weights(),
                     preferred_element_type=jnp.float32)
    bm_ref[...] = bm.astype(jnp.uint8)
    row_kept = jnp.sum(keep, axis=1, keepdims=True)
    cnt_ref[...] += jax.lax.dot_general(
        seg_hot, row_kept, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(jnp.int32)


def segmented_encode(x2d: jax.Array, seg_ids: jax.Array, taus: jax.Array,
                     scales: jax.Array | None = None, *, interpret: bool,
                     slab_rows: int | None = None
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """The fused wire-path sweep (DESIGN.md §10): one read of the packed
    buffer emits everything the upload payload needs.

    Applies the per-segment thresholds ``taus`` ((S,) fp32, > 0) and returns

    * ``out``   — (R, SEG_LANE) masked values; int8-quantised against the
      per-segment ``scales`` ((S,) fp32, > 0) when given, else the masked
      fp32 values (``segmented_apply`` semantics);
    * ``bitmap`` — (R, SEG_LANE // 8) uint8 keep-mask, LSB-first within each
      byte (byte ``b`` bit ``j`` describes lane ``8 b + j``);
    * ``kept``  — (S, 1) int32 surviving-entry counts per segment.

    The downstream COO/bitmap compaction (``ops.topk_encode_pytree``) reads
    only these outputs — 1.125 bytes/param for the int8 wire instead of the
    4 bytes/param the jnp codec path re-reads three times over.
    """
    slab = _slab(x2d.shape[0], slab_rows, interpret)
    S = taus.shape[0]
    quantize = scales is not None
    if scales is None:
        scales = jnp.ones((S,), jnp.float32)
    out_dtype = jnp.int8 if quantize else x2d.dtype
    return pl.pallas_call(
        functools.partial(_seg_encode_kernel, quantize=quantize),
        grid=(x2d.shape[0] // slab,),
        in_specs=[
            pl.BlockSpec((slab, SEG_LANE), lambda i: (i, 0)),
            pl.BlockSpec((slab, 1), lambda i: (i, 0)),
            pl.BlockSpec((S, 1), lambda i: (0, 0)),
            pl.BlockSpec((S, 1), lambda i: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((slab, SEG_LANE), lambda i: (i, 0)),
            pl.BlockSpec((slab, SEG_LANE // 8), lambda i: (i, 0)),
            pl.BlockSpec((S, 1), lambda i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct(x2d.shape, out_dtype),
            jax.ShapeDtypeStruct((x2d.shape[0], SEG_LANE // 8), jnp.uint8),
            jax.ShapeDtypeStruct((S, 1), jnp.int32),
        ),
        interpret=interpret,
    )(x2d, seg_ids, taus.reshape(S, 1).astype(jnp.float32),
      scales.reshape(S, 1).astype(jnp.float32))


# --------------------------------------------------------------------------
# Slab sizing + row padding.
# --------------------------------------------------------------------------
# Interpret mode re-stages the FULL operands once per interpreter grid step,
# so its wall-clock is ~ grid_steps * buffer_bytes: use one huge slab.  The
# compiled TPU path is VMEM-bound: default (512, 1024) fp32 = 2 MiB slabs.
INTERPRET_SLAB_ROWS = 16384


def _slab(total_rows: int, slab_rows: int | None, interpret: bool) -> int:
    if slab_rows is None:
        slab_rows = INTERPRET_SLAB_ROWS if interpret else SLAB_ROWS
    # A slab never exceeds the (chunk-rounded) buffer and always divides into
    # whole CHUNK_ROWS chunks — a user value is rounded DOWN to the chunk
    # multiple (floor, never below one chunk), else the kernels' chunk loops
    # would silently skip the slab tail; pad_rows pads to a slab multiple.
    slab_rows = max(CHUNK_ROWS, slab_rows - slab_rows % CHUNK_ROWS)
    rounded = -(-total_rows // CHUNK_ROWS) * CHUNK_ROWS
    return min(slab_rows, rounded)


def pad_rows(x2d: jax.Array, seg_ids: jax.Array, *, interpret: bool,
             slab_rows: int | None = None):
    """Pad the packed buffer with zero rows to a whole number of slabs.

    Padding rows get segment id 0; all-zero rows contribute to no histogram
    bin, no count (taus > 0), and mask to zeros — they are invisible.
    """
    slab = _slab(max(x2d.shape[0], 1), slab_rows, interpret)
    pad = (-x2d.shape[0]) % slab
    if pad == 0:
        return x2d, seg_ids
    x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
    seg_ids = jnp.pad(seg_ids, ((0, pad), (0, 0)))
    return x2d, seg_ids


# --------------------------------------------------------------------------
# Threshold selection + multi-candidate bracket refinement (pure jnp; operates
# on (S, *) statistics only — no sweeps over the packed data).
# --------------------------------------------------------------------------
def select_thresholds(suffix: jax.Array, k: jax.Array):
    """Vectorised magnitude bracketing for every segment at once.

    suffix: (S, SEG_NBINS) int32 suffix-form histogram from
    ``segmented_histogram`` (suffix[s, j] = count at bin edge j); k: (S,)
    int32.  Returns ``(lo, hi, cnt_lo, cnt_hi)`` — per-segment 4-octave
    bounds [lo, hi) containing the k-th largest magnitude plus the EXACT
    counts at both ends, so refinement starts with known bracket counts and
    never needs an extra counting sweep.
    """
    S = suffix.shape[0]
    rows = jnp.arange(S)
    jstar = jnp.maximum(jnp.sum(suffix >= k[:, None], axis=1) - 1, 0)
    lo = jnp.exp2((jstar * OCTAVES_PER_BIN + EXPO_MIN).astype(jnp.float32))
    hi = float(2 ** OCTAVES_PER_BIN) * lo
    suffix_ext = jnp.concatenate(
        [suffix, jnp.zeros((S, 1), suffix.dtype)], axis=1)
    cnt_lo = suffix_ext[rows, jstar]
    cnt_hi = suffix_ext[rows, jstar + 1]
    # k exceeds the number of nonzeros: keep everything nonzero by dropping
    # the lower bound below the smallest representable bin.
    underfull = suffix[:, 0] < k
    lo = jnp.where(underfull, jnp.exp2(float(EXPO_MIN - 1)), lo)
    cnt_lo = jnp.where(underfull, suffix[:, 0], cnt_lo)
    return lo, hi, cnt_lo, cnt_hi


def candidate_taus(lo: jax.Array, hi: jax.Array, num: int,
                   geometric: bool = False) -> jax.Array:
    """(S, num) interior candidate thresholds of each [lo, hi] bracket.

    ``geometric`` spaces candidates by constant RATIO — right for the first
    refine over the histogram's 4-octave (16x) bracket, where linear spacing
    would waste most candidates on the top octave.  Later sweeps over narrow
    brackets use linear spacing.
    """
    frac = (jnp.arange(1, num + 1, dtype=jnp.float32) / (num + 1.0))
    if geometric:
        ratio = jnp.exp(frac[None, :] * jnp.log(hi / lo)[:, None])
        return lo[:, None] * ratio
    return lo[:, None] + frac[None, :] * (hi - lo)[:, None]


def shrink_brackets(lo, hi, cnt_lo, cnt_hi, cand, counts, k):
    """Tighten every segment's bracket around the k-th magnitude.

    ``cand``/``counts``: (S, C) ascending candidate taus and their counts
    from one ``segmented_count`` sweep.  Counts are non-increasing along the
    extended grid [lo, cand..., hi], so the number of entries with count > k
    locates the tightest bracket; counts at the new ends come for free.
    """
    ext_taus = jnp.concatenate([lo[:, None], cand, hi[:, None]], axis=1)
    ext_cnts = jnp.concatenate(
        [cnt_lo[:, None], counts, cnt_hi[:, None]], axis=1)
    C2 = ext_taus.shape[1]
    num_gt = jnp.sum(ext_cnts > k[:, None], axis=1)
    lo_idx = jnp.clip(num_gt - 1, 0, C2 - 1)
    hi_idx = jnp.clip(num_gt, 0, C2 - 1)
    rows = jnp.arange(ext_taus.shape[0])
    return (ext_taus[rows, lo_idx], ext_taus[rows, hi_idx],
            ext_cnts[rows, lo_idx], ext_cnts[rows, hi_idx])
