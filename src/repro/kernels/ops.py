"""Jit'd public wrappers around the Pallas masking kernels.

``topk_mask(x, gamma)`` keeps ~k = round(gamma * x.size) largest-|x| entries:
  1 histogram sweep + ``refine_iters`` count sweeps + 1 apply sweep,
vs the 24+ full bisection sweeps of the pure-jnp path (see EXPERIMENTS.md
§Perf for the sweep-count accounting).

On CPU (this container) the kernels run with ``interpret=True``; on TPU they
compile natively.  ``interpret=None`` auto-detects.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import topk_mask as tk

__all__ = ["topk_mask", "masked_count"]


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _pad_to_blocks(flat: jax.Array) -> jax.Array:
    n = flat.shape[0]
    block = tk.BLOCK_ROWS * tk.LANE
    padded = ((n + block - 1) // block) * block
    flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(padded // tk.LANE, tk.LANE)


@functools.partial(jax.jit, static_argnames=("gamma", "iters", "interpret"))
def topk_mask(x: jax.Array, gamma: float, iters: int = 8,
              interpret: bool | None = None) -> jax.Array:
    """Threshold-select the ~gamma fraction of largest-|x| entries of ``x``.

    Padding zeros never survive (the selected threshold is > 0), so arbitrary
    shapes are supported by flatten/pad/reshape.
    """
    interpret = _auto_interpret(interpret)
    n = x.size
    k = jnp.asarray(max(1, int(round(gamma * n))), jnp.int32)
    orig_dtype = x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    x2d = _pad_to_blocks(flat)

    hist = tk.exponent_histogram(x2d, interpret=interpret)
    tau_lo, tau_hi = tk.select_threshold(hist, k)

    def refine(_, bounds):
        lo, hi = bounds
        mid = 0.5 * (lo + hi)
        cnt = tk.count_ge(x2d, mid, interpret=interpret)
        lo = jnp.where(cnt > k, mid, lo)
        hi = jnp.where(cnt > k, hi, mid)
        return lo, hi

    tau_lo, tau_hi = jax.lax.fori_loop(0, iters, refine, (tau_lo, tau_hi))
    # hi is the conservative endpoint: count(mag >= hi) <= k... <= count(>= lo).
    # Use lo if hi would under-select badly (ties): pick whichever count is
    # closer to k without a fresh sweep by reusing the invariant counts.
    cnt_hi = tk.count_ge(x2d, tau_hi, interpret=interpret)
    tau = jnp.where(cnt_hi >= 1, tau_hi, tau_lo)

    out2d = tk.apply_threshold(x2d, tau, interpret=interpret)
    return out2d.reshape(-1)[:n].reshape(x.shape).astype(orig_dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_count(x: jax.Array, tau: jax.Array,
                 interpret: bool | None = None) -> jax.Array:
    """Number of entries with |x| >= tau (kernel-backed)."""
    interpret = _auto_interpret(interpret)
    x2d = _pad_to_blocks(x.reshape(-1).astype(jnp.float32))
    return tk.count_ge(x2d, jnp.asarray(tau, jnp.float32), interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssm_scan(a: jax.Array, bx: jax.Array, c: jax.Array, h0: jax.Array,
             interpret: bool | None = None):
    """Selective-SSM recurrence via the Pallas kernel (kernels/ssm_scan.py).

    a, bx: (B, T, d, N) decay / input terms (the layout models/ssm.py uses);
    c: (B, T, N); h0: (B, d, N).  Returns (y (B, T, d), hT (B, d, N)).
    Pads T to BLOCK_T (identity steps: a=1, bx=0) and d to the BLOCK_D lane
    tile; transposes so d rides the 128-wide lane axis.
    """
    from repro.kernels import ssm_scan as sk
    interpret = _auto_interpret(interpret)
    B, T, d, N = a.shape
    padT = (-T) % sk.BLOCK_T
    padD = (-d) % sk.BLOCK_D

    # (B, T, d, N) -> (B, T, N, d) with lane-axis d
    a_t = jnp.pad(a.transpose(0, 1, 3, 2).astype(jnp.float32),
                  ((0, 0), (0, padT), (0, 0), (0, padD)),
                  constant_values=1.0)           # identity decay on padding
    bx_t = jnp.pad(bx.transpose(0, 1, 3, 2).astype(jnp.float32),
                   ((0, 0), (0, padT), (0, 0), (0, padD)))
    c_t = jnp.pad(c.astype(jnp.float32), ((0, 0), (0, padT), (0, 0)))
    h0_t = jnp.pad(h0.transpose(0, 2, 1).astype(jnp.float32),
                   ((0, 0), (0, 0), (0, padD)))

    y, hT = sk.ssm_scan_tiled(a_t, bx_t, c_t, h0_t, interpret=interpret)
    return y[:, :T, :d], hT[:, :, :d].transpose(0, 2, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
         u: jax.Array, s0: jax.Array, interpret: bool | None = None):
    """RWKV6 wkv recurrence via the Pallas kernel (kernels/wkv6.py).

    r/k/v/logw: (B, T, H, D); u: (H, D); s0: (B, H, D, D).
    Pads T to the CHUNK tile with identity steps (logw=0, r=k=v=0).
    Returns (y (B, T, H, D), sT (B, H, D, D)).
    """
    from repro.kernels import wkv6 as wk
    interpret = _auto_interpret(interpret)
    B, T, H, D = r.shape
    padT = (-T) % wk.CHUNK

    def padt(x, val=0.0):
        return jnp.pad(x.astype(jnp.float32),
                       ((0, 0), (0, padT), (0, 0), (0, 0)),
                       constant_values=val)

    y, sT = wk.wkv6_tiled(padt(r), padt(k), padt(v), padt(logw),
                          u.astype(jnp.float32), s0.astype(jnp.float32),
                          interpret=interpret)
    return y[:, :T], sT
