"""Jit'd public wrappers around the Pallas masking kernels.

``topk_mask(x, gamma)`` keeps ~k = round(gamma * x.size) largest-|x| entries:
  1 histogram sweep + ``refine_iters`` count sweeps + 1 apply sweep
(= iters + 2 total; the histogram suffix-sums seed the bracket counts so the
final tau choice needs no extra sweep), vs the 24+ full bisection sweeps of
the pure-jnp path (see EXPERIMENTS.md §Perf for the sweep-count accounting).

``topk_mask_pytree(tree, gamma)`` masks EVERY maskable leaf of a delta pytree
in a leaf-count-independent number of sweeps (DESIGN.md §3.4):
  1 segmented histogram + ``refine_sweeps`` multi-candidate count sweeps
  + 1 fused count/apply sweep  (= 4 for the default config),
replacing the per-leaf Python loop of O(L * (iters + 2)) sweeps and its
per-shape ``pallas_call`` retraces.

On CPU (this container) the kernels run with ``interpret=True``; on TPU they
compile natively.  ``interpret=None`` auto-detects.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

import numpy as np

from repro.kernels import packing as pk
from repro.kernels import segmented as seg
from repro.kernels import topk_mask as tk
from repro.kernels.ref import EXPO_MIN

PyTree = Any

__all__ = ["topk_mask", "topk_mask_pytree", "topk_encode_pytree",
           "pytree_sweep_count", "wirepath_sweep_count",
           "wirepath_bytes_moved", "masked_count"]


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _pad_to_blocks(flat: jax.Array) -> jax.Array:
    n = flat.shape[0]
    block = tk.BLOCK_ROWS * tk.LANE
    padded = ((n + block - 1) // block) * block
    flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(padded // tk.LANE, tk.LANE)


@functools.partial(jax.jit, static_argnames=("gamma", "iters", "interpret"))
def topk_mask(x: jax.Array, gamma: float, iters: int = 8,
              interpret: bool | None = None) -> jax.Array:
    """Threshold-select the ~gamma fraction of largest-|x| entries of ``x``.

    Padding zeros never survive (the selected threshold is > 0), so arbitrary
    shapes are supported by flatten/pad/reshape.
    """
    interpret = _auto_interpret(interpret)
    n = x.size
    k = jnp.asarray(max(1, int(round(gamma * n))), jnp.int32)
    orig_dtype = x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    x2d = _pad_to_blocks(flat)

    hist = tk.exponent_histogram(x2d, interpret=interpret)
    tau_lo, tau_hi, _, cnt_hi = tk.select_threshold_counts(hist, k)

    def refine(_, carry):
        lo, hi, cnt_hi = carry
        mid = 0.5 * (lo + hi)
        cnt = tk.count_ge(x2d, mid, interpret=interpret)
        raise_lo = cnt > k
        lo = jnp.where(raise_lo, mid, lo)
        hi = jnp.where(raise_lo, hi, mid)
        cnt_hi = jnp.where(raise_lo, cnt_hi, cnt)  # hi moved -> its count is cnt
        return lo, hi, cnt_hi

    tau_lo, tau_hi, cnt_hi = jax.lax.fori_loop(
        0, iters, refine, (tau_lo, tau_hi, cnt_hi))
    # hi is the conservative endpoint: count(mag >= hi) <= k <= count(>= lo).
    # Use lo if hi would under-select badly (ties); cnt_hi was threaded
    # through the refine loop (seeded from the histogram suffix sums), so no
    # fresh counting sweep is needed here.
    tau = jnp.where(cnt_hi >= 1, tau_hi, tau_lo)

    out2d = tk.apply_threshold(x2d, tau, interpret=interpret)
    return out2d.reshape(-1)[:n].reshape(x.shape).astype(orig_dtype)


DEFAULT_REFINE_SWEEPS = 2
DEFAULT_CANDIDATES = 16


def pytree_sweep_count(num_leaves: int, *, segmented: bool = True,
                       iters: int = 8,
                       refine_sweeps: int = DEFAULT_REFINE_SWEEPS) -> int:
    """HBM sweeps to selectively mask an L-leaf pytree (analytic accounting).

    Per-leaf pipeline: every leaf pays 1 histogram + ``iters`` counts + 1
    apply.  Segmented: 1 histogram + ``refine_sweeps`` multi-candidate counts
    + 1 fused count/apply, independent of L.
    """
    if segmented:
        return 1 + refine_sweeps + 1
    return num_leaves * (iters + 2)


@functools.partial(jax.jit, static_argnames=(
    "gamma", "min_leaf_size", "refine_sweeps", "candidates", "interpret",
    "slab_rows"))
def topk_mask_pytree(tree: PyTree, gamma: float, *,
                     min_leaf_size: int = 256,
                     refine_sweeps: int = DEFAULT_REFINE_SWEEPS,
                     candidates: int = DEFAULT_CANDIDATES,
                     interpret: bool | None = None,
                     slab_rows: int | None = None) -> PyTree:
    """Whole-model selective masking in ~``refine_sweeps + 2`` HBM sweeps.

    Packs every leaf with >= ``min_leaf_size`` elements into one padded
    (R, LANE) buffer (kernels/packing.py) and runs the segmented kernels
    (kernels/segmented.py): one histogram sweep brackets every leaf's k-th
    magnitude to an octave, each refine sweep evaluates ``candidates``
    thresholds per leaf (shrinking the bracket (candidates+1)-fold), and one
    fused sweep applies the final per-leaf taus.  Leaves below
    ``min_leaf_size`` pass through dense, mirroring ``mask_pytree``.

    All packing metadata is static (shapes/dtypes only) — the function is
    jit/scan/pjit-safe and traces ONE pallas_call per kernel regardless of
    how many distinct leaf shapes the model has.

    Accuracy: per leaf, the kept count is <= k and misses at most the
    entries whose magnitude falls inside the final bracket around the k-th
    magnitude: the histogram brackets it to a 16x range, the geometric first
    sweep narrows that to ratio 16^(1/(candidates+1)), and each further
    linear sweep divides the width by candidates+1 — ~1% of tau for the
    defaults (C=16, 2 sweeps).  Property-tested against the sort oracle in
    tests/test_masking.py; magnitudes separated by more than that relative
    tolerance mask exactly.

    Tie caveat (shared with the per-leaf ``topk_mask`` pipeline): threshold
    selection cannot split entries of EQUAL magnitude, so when the bracket
    converges onto a tie plateau at the k-th magnitude, all tied entries are
    kept (the sort oracle instead drops surplus ties by index).  The <= k
    bound therefore holds only when the k-th and (k+1)-th magnitudes differ
    by more than the bracket resolution; degenerate inputs (e.g. a constant
    leaf) keep every tied entry.
    """
    interpret = _auto_interpret(interpret)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    mask_idx = [i for i, leaf in enumerate(leaves)
                if leaf.size >= min_leaf_size]
    if gamma >= 1.0 or not mask_idx:
        return tree

    sel = [leaves[i] for i in mask_idx]
    x2d, spec = pk.pack_leaves(sel)
    x2d, seg_ids = seg.pad_rows(x2d, jnp.asarray(spec.seg_ids()),
                                interpret=interpret, slab_rows=slab_rows)
    k = jnp.asarray([max(1, int(round(gamma * ls.size)))
                     for ls in spec.leaves], jnp.int32)

    hist = seg.segmented_histogram(x2d, seg_ids, spec.num_segments,
                                   interpret=interpret, slab_rows=slab_rows)
    lo, hi, cnt_lo, cnt_hi = seg.select_thresholds(hist, k)
    for sweep in range(refine_sweeps):
        # Sweep 0 subdivides the histogram's 16x bracket geometrically;
        # later sweeps refine the now-narrow bracket linearly.
        cand = seg.candidate_taus(lo, hi, candidates, geometric=(sweep == 0))
        counts = seg.segmented_count(x2d, seg_ids, cand, interpret=interpret,
                                     slab_rows=slab_rows)
        lo, hi, cnt_lo, cnt_hi = seg.shrink_brackets(
            lo, hi, cnt_lo, cnt_hi, cand, counts, k)

    # Conservative endpoint per segment; fall back to lo when hi would keep
    # nothing (counts were threaded through the refine — no extra sweep).
    tau = jnp.where(cnt_hi >= 1, hi, lo)
    out2d, _kept = seg.segmented_apply(x2d, seg_ids, tau, interpret=interpret,
                                       slab_rows=slab_rows)

    masked = pk.unpack_leaves(out2d[:spec.rows], spec)
    for i, m in zip(mask_idx, masked):
        leaves[i] = m
    return jax.tree_util.tree_unflatten(treedef, leaves)


# --------------------------------------------------------------------------
# Fused wire path: delta pytree -> COO / bitmap wire payload (DESIGN.md §10).
# --------------------------------------------------------------------------
# "Keep everything nonzero" threshold for the assume-masked path: one bin
# below the histogram ladder's smallest edge, matching the underfull branch
# of seg.select_thresholds — magnitudes below 2^(EXPO_MIN-1) (~6e-30 for the
# default EXPO_MIN = -96) are treated as zero on the wire, the same floor
# the masking subsystem already applies.
_WIRE_FLOOR_TAU = float(2.0 ** (EXPO_MIN - 1))


def _leaf_wire(flat_vals, flat_bm, ls, seg_index, gamma, wire, scales):
    """Compact ONE packed leaf's fused-sweep outputs into its wire payload.

    Reads the (already int8/bitmap-width) ``segmented_encode`` outputs only:
    expands the leaf's keep-bits, assigns each surviving entry its
    index-order slot via a cumulative sum (overflow beyond the k-slot budget
    is shed by highest index — the jnp oracle sheds smallest magnitude
    instead, an observable difference only on tie plateaus that overflow
    the budget), and scatters values/indices into the static k-slot wire
    arrays.  No sort, no re-read of fp32 data.
    """
    size = ls.size
    k = min(max(1, int(round(gamma * size))), size)
    v = jax.lax.slice_in_dim(flat_vals, ls.offset, ls.offset + size)
    byte0 = ls.offset // 8                       # offset is a SEG_LANE multiple
    nb = (size + 7) // 8
    bb = jax.lax.slice_in_dim(flat_bm, byte0, byte0 + nb)
    bits = ((bb.astype(jnp.int32)[:, None] >> jnp.arange(8)) & 1)
    bits = bits.reshape(-1)[:size].astype(bool)  # LSB-first, trailing pad = 0

    slot = jnp.cumsum(bits) - 1                  # index-order slot per entry
    live = bits & (slot < k)
    dest = jnp.where(live, slot, k)              # overflow -> trash slot k
    val_buf = jnp.zeros((k + 1,), v.dtype).at[dest].set(
        jnp.where(live, v, jnp.zeros_like(v)))
    if scales is not None:
        values = {"q": val_buf[:k],
                  "scale": scales[seg_index].astype(jnp.float32)}
    else:
        values = val_buf[:k].astype(ls.dtype)
    shape = np.asarray(ls.shape, np.int32)

    if wire == "coo":
        idx_buf = jnp.zeros((k + 1,), jnp.int32).at[dest].set(
            jnp.where(live, jnp.arange(size, dtype=jnp.int32), 0))
        return {"indices": idx_buf[:k], "values": values, "shape": shape}
    # bitmap wire: repack the budget-capped bits so the popcount can never
    # exceed the value slots (byte-identical to compression.encode_bitmap).
    pad = (-size) % 8
    capped = jnp.pad(live.astype(jnp.int32), (0, pad)).reshape(-1, 8)
    bm = jnp.sum(capped * (1 << jnp.arange(8)), axis=1).astype(jnp.uint8)
    return {"bitmap": bm, "values": values, "shape": shape}


def topk_encode_pytree(tree: PyTree, gamma: float, *,
                       min_leaf_size: int = 256,
                       refine_sweeps: int = DEFAULT_REFINE_SWEEPS,
                       candidates: int = DEFAULT_CANDIDATES,
                       quantize: bool = False,
                       wire: str = "coo",
                       assume_masked: bool = False,
                       interpret: bool | None = None,
                       slab_rows: int | None = None) -> PyTree:
    """Delta pytree -> upload wire payload in one fused kernel pipeline.

    The wire-path successor of :func:`topk_mask_pytree` (DESIGN.md §10):
    instead of materialising a masked dense pytree for ``core.codecs`` to
    re-read three more times, the final segmented sweep
    (``seg.segmented_encode``) emits int8-quantised values, a 1-bit/element
    keep-bitmap and kept counts directly, and the per-leaf compaction into
    static ``k = max(1, round(gamma * size))``-slot payloads reads only
    those narrow outputs.  HBM cost: ``wirepath_sweep_count`` /
    ``wirepath_bytes_moved``.

    Per maskable leaf (``size >= min_leaf_size``) the returned pytree holds

    * ``wire="coo"``    — ``{"indices", "values", "shape"}``, decoded by
      ``core.compression.decode_sparse``;
    * ``wire="bitmap"`` — ``{"bitmap", "values", "shape"}`` (LSB-first
      membership bits), decoded by ``core.compression.decode_bitmap``;

    with ``values = {"q": int8, "scale": f32}`` when ``quantize`` (the scale
    is ``max|leaf| / 127``, computed by the stats sweep — identical to
    ``compression.quantize_int8`` because top-k keeps the max-magnitude
    entry).  Smaller leaves pass through dense and UNQUANTISED — the codec
    layer (``core.codecs.FusedSparseCodec``) owns small-leaf quantisation so
    wire bytes match the jnp ``ChainCodec`` oracle exactly.

    ``assume_masked=True`` skips threshold selection (the input is already a
    masked delta, e.g. inside the codec layer): every entry with magnitude
    above the masking subsystem's floor (2^(EXPO_MIN-1)) is shipped, so the
    pipeline costs 1 sweep (2 with ``quantize``, for the scale) instead of
    ``refine_sweeps + 2``.  Decoded payloads are then bit-exact vs the jnp
    ``SparseCodec``/``Int8Codec`` oracle whenever each leaf's nonzero count
    fits its slot budget — which threshold masks guarantee off tie plateaus
    (property-tested in tests/test_wirepath.py).

    Note the packed buffer is fp32 (``kernels.packing``): non-float leaves
    are shipped through the same f32 cast the masking path applies, and
    ``quantize`` treats every maskable leaf as float.

    Deliberately NOT ``@jax.jit``-wrapped: each payload's ``"shape"`` entry
    is a static numpy constant (like ``PackSpec``), which a whole-function
    jit would turn into a traced array and break the decoders' static
    shape handling.  Jit the enclosing computation instead — the round
    engines do (``codecs.roundtrip_stacked`` under the round's jit/vmap).
    """
    if wire not in ("coo", "bitmap"):
        raise ValueError(f"unknown wire format {wire!r}")
    interpret = _auto_interpret(interpret)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    mask_idx = [i for i, leaf in enumerate(leaves)
                if leaf.size >= min_leaf_size]
    if gamma >= 1.0 or not mask_idx:
        return tree

    sel = [leaves[i] for i in mask_idx]
    x2d, spec = pk.pack_leaves(sel)
    x2d, seg_ids = seg.pad_rows(x2d, jnp.asarray(spec.seg_ids()),
                                interpret=interpret, slab_rows=slab_rows)
    S = spec.num_segments

    scales = None
    if assume_masked:
        tau = jnp.full((S,), _WIRE_FLOOR_TAU, jnp.float32)
        if quantize:
            _, amax = seg.segmented_stats(x2d, seg_ids, S,
                                          interpret=interpret,
                                          slab_rows=slab_rows)
            scales = jnp.maximum(amax[:, 0] * jnp.float32(1.0 / 127.0),
                                 1e-12)
    else:
        k = jnp.asarray([max(1, int(round(gamma * ls.size)))
                         for ls in spec.leaves], jnp.int32)
        hist, amax = seg.segmented_stats(x2d, seg_ids, S, interpret=interpret,
                                         slab_rows=slab_rows)
        lo, hi, cnt_lo, cnt_hi = seg.select_thresholds(hist, k)
        for sweep in range(refine_sweeps):
            cand = seg.candidate_taus(lo, hi, candidates,
                                      geometric=(sweep == 0))
            counts = seg.segmented_count(x2d, seg_ids, cand,
                                         interpret=interpret,
                                         slab_rows=slab_rows)
            lo, hi, cnt_lo, cnt_hi = seg.shrink_brackets(
                lo, hi, cnt_lo, cnt_hi, cand, counts, k)
        tau = jnp.where(cnt_hi >= 1, hi, lo)
        if quantize:
            scales = jnp.maximum(amax[:, 0] * jnp.float32(1.0 / 127.0),
                                 1e-12)

    out2d, bm2d, _kept = seg.segmented_encode(
        x2d, seg_ids, tau, scales, interpret=interpret, slab_rows=slab_rows)
    flat_vals = out2d[:spec.rows].reshape(-1)
    flat_bm = bm2d[:spec.rows].reshape(-1)
    for s, (i, ls) in enumerate(zip(mask_idx, spec.leaves)):
        leaves[i] = _leaf_wire(flat_vals, flat_bm, ls, s, gamma, wire, scales)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def wirepath_sweep_count(*, fused: bool,
                         refine_sweeps: int = DEFAULT_REFINE_SWEEPS,
                         assume_masked: bool = False,
                         quantize: bool = True) -> int:
    """Full-width HBM passes over an n-param delta to build ONE upload's
    wire payload (DESIGN.md §10).

    A "sweep" is a read or write of the full fp32 packed buffer; the fused
    path's narrow int8/bitmap writes and its k-slot compaction reads
    (1.125 bytes/param vs 4) are sub-width and accounted in
    :func:`wirepath_bytes_moved`, not here.

    * fused      — 1 stats (histogram + absmax) + ``refine_sweeps`` counts
      + 1 fused encode; with ``assume_masked`` the selection sweeps vanish
      (1 encode, + 1 absmax sweep when ``quantize``).
    * jnp oracle — the same masking front half PLUS a dense fp32 write
      (apply), then the codec re-reads the masked tree three more times
      (sort-key build, argsort, gather) to build the COO payload.
    """
    if fused:
        if assume_masked:
            return 2 if quantize else 1
        return 1 + refine_sweeps + 1
    select = 0 if assume_masked else 1 + refine_sweeps
    return select + 2 + 3


def wirepath_bytes_moved(n_params: int, gamma: float, *, fused: bool,
                         quantize: bool = True, wire: str = "coo",
                         refine_sweeps: int = DEFAULT_REFINE_SWEEPS,
                         assume_masked: bool = False) -> dict:
    """Analytic HBM bytes (reads + writes) to wire-encode one n-param delta.

    The roofline companion of :func:`wirepath_sweep_count` — every term is a
    byte count over the packed fp32 buffer (4 bytes/param) or the fused
    sweep's narrow outputs (1 byte/param int8, 1 bit/param bitmap), so
    ``total / hbm_bandwidth`` is the wire path's HBM-bound time floor
    (benchmarks/roofline.py).  Returns a dict with ``reads``, ``writes``,
    ``total``, ``payload_bytes`` and the per-stage ``breakdown``.
    """
    n = int(n_params)
    dense = 4 * n
    k = min(max(1, int(round(gamma * n))), n)
    vb = 1 if quantize else 4
    payload = (k * (4 + vb)) if wire == "coo" else (k * vb + (n + 7) // 8)
    if quantize:
        payload += 4                                   # fp32 scale
    breakdown = {}
    if not assume_masked:
        breakdown["select_reads"] = (1 + refine_sweeps) * dense
    elif fused and quantize:
        breakdown["select_reads"] = dense              # absmax-only sweep
    if fused:
        narrow = (n if quantize else dense) + (n + 7) // 8
        breakdown["encode_read"] = dense
        breakdown["encode_writes"] = narrow            # int8/fp32 + bitmap
        breakdown["compact_reads"] = narrow            # never fp32 again
        breakdown["payload_writes"] = payload
    else:
        breakdown["apply_read"] = dense
        breakdown["apply_write"] = dense               # masked fp32 pytree
        breakdown["codec_rereads"] = 3 * dense         # key, argsort, gather
        breakdown["payload_writes"] = payload
    reads = (breakdown.get("select_reads", 0)
             + breakdown.get("encode_read", 0)
             + breakdown.get("compact_reads", 0)
             + breakdown.get("apply_read", 0)
             + breakdown.get("codec_rereads", 0))
    writes = (breakdown.get("encode_writes", 0)
              + breakdown.get("apply_write", 0)
              + breakdown.get("payload_writes", 0))
    return {"reads": reads, "writes": writes, "total": reads + writes,
            "payload_bytes": payload, "breakdown": breakdown}


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_count(x: jax.Array, tau: jax.Array,
                 interpret: bool | None = None) -> jax.Array:
    """Number of entries with |x| >= tau (kernel-backed)."""
    interpret = _auto_interpret(interpret)
    x2d = _pad_to_blocks(x.reshape(-1).astype(jnp.float32))
    return tk.count_ge(x2d, jnp.asarray(tau, jnp.float32), interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssm_scan(a: jax.Array, bx: jax.Array, c: jax.Array, h0: jax.Array,
             interpret: bool | None = None):
    """Selective-SSM recurrence via the Pallas kernel (kernels/ssm_scan.py).

    a, bx: (B, T, d, N) decay / input terms (the layout models/ssm.py uses);
    c: (B, T, N); h0: (B, d, N).  Returns (y (B, T, d), hT (B, d, N)).
    Pads T to BLOCK_T (identity steps: a=1, bx=0) and d to the BLOCK_D lane
    tile; transposes so d rides the 128-wide lane axis.
    """
    from repro.kernels import ssm_scan as sk
    interpret = _auto_interpret(interpret)
    B, T, d, N = a.shape
    padT = (-T) % sk.BLOCK_T
    padD = (-d) % sk.BLOCK_D

    # (B, T, d, N) -> (B, T, N, d) with lane-axis d
    a_t = jnp.pad(a.transpose(0, 1, 3, 2).astype(jnp.float32),
                  ((0, 0), (0, padT), (0, 0), (0, padD)),
                  constant_values=1.0)           # identity decay on padding
    bx_t = jnp.pad(bx.transpose(0, 1, 3, 2).astype(jnp.float32),
                   ((0, 0), (0, padT), (0, 0), (0, padD)))
    c_t = jnp.pad(c.astype(jnp.float32), ((0, 0), (0, padT), (0, 0)))
    h0_t = jnp.pad(h0.transpose(0, 2, 1).astype(jnp.float32),
                   ((0, 0), (0, 0), (0, padD)))

    y, hT = sk.ssm_scan_tiled(a_t, bx_t, c_t, h0_t, interpret=interpret)
    return y[:, :T, :d], hT[:, :, :d].transpose(0, 2, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
         u: jax.Array, s0: jax.Array, interpret: bool | None = None):
    """RWKV6 wkv recurrence via the Pallas kernel (kernels/wkv6.py).

    r/k/v/logw: (B, T, H, D); u: (H, D); s0: (B, H, D, D).
    Pads T to the CHUNK tile with identity steps (logw=0, r=k=v=0).
    Returns (y (B, T, H, D), sT (B, H, D, D)).
    """
    from repro.kernels import wkv6 as wk
    interpret = _auto_interpret(interpret)
    B, T, H, D = r.shape
    padT = (-T) % wk.CHUNK

    def padt(x, val=0.0):
        return jnp.pad(x.astype(jnp.float32),
                       ((0, 0), (0, padT), (0, 0), (0, 0)),
                       constant_values=val)

    y, sT = wk.wkv6_tiled(padt(r), padt(k), padt(v), padt(logw),
                          u.astype(jnp.float32), s0.astype(jnp.float32),
                          interpret=interpret)
    return y[:, :T], sT
