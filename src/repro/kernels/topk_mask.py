"""Pallas TPU kernels for selective (top-k) masking.

TPU adaptation of the paper's per-layer top-k (DESIGN.md §3.1): instead of a
global sort we (1) build a per-octave magnitude histogram in one HBM sweep,
(2) locate the octave containing the k-th largest magnitude, (3) refine the
threshold with a few count sweeps, (4) apply ``x * (|x| >= tau)``.

All kernels tile the (flattened, padded) input as (BLOCK_ROWS, LANE) fp32
blocks in VMEM — BLOCK_ROWS=256, LANE=1024 → 1 MiB per block, well under the
~16 MiB v5e VMEM budget, with the lane dimension a multiple of 128 for the
VPU.  Reduction outputs map every grid step to the same output block; the TPU
grid is sequential so ``@pl.when(first)`` init + accumulate is safe (and
interpret mode preserves the semantics on CPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import NBINS, EXPO_MIN

BLOCK_ROWS = 256
LANE = 1024


def _grid_blocks(n_rows: int) -> int:
    return n_rows // BLOCK_ROWS


# --------------------------------------------------------------------------
# Kernel 1: per-octave magnitude histogram (one sweep of HBM).
# --------------------------------------------------------------------------
def _hist_kernel(x_ref, hist_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    x = x_ref[...].astype(jnp.float32)
    mag = jnp.abs(x)
    valid = mag > 0.0
    e = jnp.floor(jnp.log2(jnp.where(valid, mag, 1.0)))
    b = jnp.clip(e.astype(jnp.int32) - EXPO_MIN, 0, NBINS - 1)

    bins = jax.lax.broadcasted_iota(jnp.int32, (1, NBINS), 1)

    def body(j, _):
        cnt = jnp.sum((b == j) & valid).astype(jnp.int32)
        onehot = (bins == j).astype(jnp.int32)
        hist_ref[...] += cnt * onehot
        return 0

    jax.lax.fori_loop(0, NBINS, body, 0)


def exponent_histogram(x2d: jax.Array, *, interpret: bool) -> jax.Array:
    """x2d: (R, LANE) fp32, R multiple of BLOCK_ROWS. Returns (NBINS,) int32."""
    rows = x2d.shape[0]
    hist = pl.pallas_call(
        _hist_kernel,
        grid=(_grid_blocks(rows),),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, NBINS), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, NBINS), jnp.int32),
        interpret=interpret,
    )(x2d)
    return hist[0]


# --------------------------------------------------------------------------
# Kernel 2: count of |x| >= tau (one sweep; used by the refine loop).
# --------------------------------------------------------------------------
def _count_kernel(x_ref, tau_ref, cnt_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    x = x_ref[...].astype(jnp.float32)
    tau = tau_ref[0, 0]
    cnt_ref[0, 0] += jnp.sum(jnp.abs(x) >= tau).astype(jnp.int32)


def count_ge(x2d: jax.Array, tau: jax.Array, *, interpret: bool) -> jax.Array:
    rows = x2d.shape[0]
    cnt = pl.pallas_call(
        _count_kernel,
        grid=(_grid_blocks(rows),),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        interpret=interpret,
    )(x2d, tau.reshape(1, 1).astype(jnp.float32))
    return cnt[0, 0]


# --------------------------------------------------------------------------
# Kernel 3: apply the threshold mask (one sweep, elementwise).
# --------------------------------------------------------------------------
def _apply_kernel(x_ref, tau_ref, out_ref):
    x = x_ref[...]
    tau = tau_ref[0, 0]
    keep = (jnp.abs(x.astype(jnp.float32)) >= tau).astype(x.dtype)
    out_ref[...] = x * keep


def apply_threshold(x2d: jax.Array, tau: jax.Array, *, interpret: bool) -> jax.Array:
    rows = x2d.shape[0]
    return pl.pallas_call(
        _apply_kernel,
        grid=(_grid_blocks(rows),),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        interpret=interpret,
    )(x2d, tau.reshape(1, 1).astype(jnp.float32))


# --------------------------------------------------------------------------
# Threshold selection from the histogram + refinement.
# --------------------------------------------------------------------------
def select_threshold(hist: jax.Array, k: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Octave bounds [tau_lo, tau_hi) containing the k-th largest magnitude.

    ``count_ge(2^(j+EXPO_MIN))`` = suffix-sum of hist from bin j; the k-th
    largest lies in the highest bin j* whose suffix count is still >= k.
    """
    suffix = jnp.cumsum(hist[::-1])[::-1]  # suffix[j] = count(mag >= 2^(j+EXPO_MIN))
    jstar = jnp.maximum(jnp.sum(suffix >= k) - 1, 0)
    tau_lo = jnp.exp2((jstar + EXPO_MIN).astype(jnp.float32))
    tau_hi = 2.0 * tau_lo
    # If even the lowest bin has < k entries (k > #nonzero), keep everything
    # nonzero: threshold below the smallest representable bin.
    tau_lo = jnp.where(suffix[0] < k, jnp.exp2(float(EXPO_MIN - 1)), tau_lo)
    return tau_lo, tau_hi
