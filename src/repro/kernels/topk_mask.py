"""Pallas TPU kernels for selective (top-k) masking.

TPU adaptation of the paper's per-layer top-k (DESIGN.md §3.1): instead of a
global sort we (1) build a per-octave magnitude histogram in one HBM sweep,
(2) locate the octave containing the k-th largest magnitude, (3) refine the
threshold with a few count sweeps, (4) apply ``x * (|x| >= tau)``.

All kernels tile the (flattened, padded) input as (BLOCK_ROWS, LANE) fp32
blocks in VMEM — BLOCK_ROWS=256, LANE=1024 → 1 MiB per block, well under the
~16 MiB v5e VMEM budget, with the lane dimension a multiple of 128 for the
VPU.  Reduction outputs map every grid step to the same output block; the TPU
grid is sequential so ``@pl.when(first)`` init + accumulate is safe (and
interpret mode preserves the semantics on CPU).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import NBINS, EXPO_MIN

BLOCK_ROWS = 256
LANE = 1024


def _grid_blocks(n_rows: int) -> int:
    return n_rows // BLOCK_ROWS


# --------------------------------------------------------------------------
# Kernel 1: per-octave magnitude histogram (one sweep of HBM).
# --------------------------------------------------------------------------
# Rows per factored-one-hot chunk: the (HIST_CHUNK_ROWS * LANE, NBINS/8)
# fp32 one-hot is 64 * 1024 * 16 * 4 B = 4 MiB of VMEM transient.
HIST_CHUNK_ROWS = 64


def _factored_bin_counts(b: jax.Array) -> jax.Array:
    """(rows, LANE) bin ids (-1 = none) -> (1, NBINS) fp32 counts.

    Factored one-hot: NBINS = QBINS * RBINS, bin = 8q + r.  Two narrow
    one-hots (16 + 8 compares per element instead of 128) contract into the
    (QBINS, RBINS) count matrix with one matmul — MXU work on TPU.  fp32
    accumulation is exact (chunk counts << 2^24).
    """
    QBINS, RBINS = NBINS // 8, 8
    flat = b.reshape(-1, 1)
    q_iota = jax.lax.broadcasted_iota(jnp.int32, (1, QBINS), 1)
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (1, RBINS), 1)
    # b = -1 yields q = -1: matches no q bin, so zeros never count.
    q_hot = (jnp.where(flat >= 0, flat // RBINS, -1) == q_iota
             ).astype(jnp.float32)
    r_hot = ((flat % RBINS) == r_iota).astype(jnp.float32)
    counts = jax.lax.dot_general(
        q_hot, r_hot, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # (QBINS, RBINS)
    return counts.reshape(1, NBINS)


def _hist_kernel(x_ref, hist_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    x = x_ref[...].astype(jnp.float32)
    mag = jnp.abs(x)
    valid = mag > 0.0
    e = jnp.floor(jnp.log2(jnp.where(valid, mag, 1.0)))
    b = jnp.clip(e.astype(jnp.int32) - EXPO_MIN, 0, NBINS - 1)
    b = jnp.where(valid, b, -1)                   # zeros match no bin

    # Factored one-hot/iota bin counting, chunked over rows so the one-hot
    # transients stay in VMEM — instead of rescanning the block once per bin.
    def chunk(c, acc):
        bc = jax.lax.dynamic_slice_in_dim(b, c * HIST_CHUNK_ROWS,
                                          HIST_CHUNK_ROWS, 0)
        return acc + _factored_bin_counts(bc)

    hist_ref[...] += jax.lax.fori_loop(
        0, BLOCK_ROWS // HIST_CHUNK_ROWS, chunk,
        jnp.zeros((1, NBINS), jnp.float32)).astype(jnp.int32)


def exponent_histogram(x2d: jax.Array, *, interpret: bool) -> jax.Array:
    """x2d: (R, LANE) fp32, R multiple of BLOCK_ROWS. Returns (NBINS,) int32."""
    rows = x2d.shape[0]
    hist = pl.pallas_call(
        _hist_kernel,
        grid=(_grid_blocks(rows),),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, NBINS), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, NBINS), jnp.int32),
        interpret=interpret,
    )(x2d)
    return hist[0]


# --------------------------------------------------------------------------
# Kernel 2: count of |x| >= tau (one sweep; used by the refine loop).
# --------------------------------------------------------------------------
def _count_kernel(x_ref, tau_ref, cnt_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    x = x_ref[...].astype(jnp.float32)
    tau = tau_ref[0, 0]
    cnt_ref[0, 0] += jnp.sum(jnp.abs(x) >= tau).astype(jnp.int32)


def count_ge(x2d: jax.Array, tau: jax.Array, *, interpret: bool) -> jax.Array:
    rows = x2d.shape[0]
    cnt = pl.pallas_call(
        _count_kernel,
        grid=(_grid_blocks(rows),),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        interpret=interpret,
    )(x2d, tau.reshape(1, 1).astype(jnp.float32))
    return cnt[0, 0]


# --------------------------------------------------------------------------
# Kernel 3: apply the threshold mask (one sweep, elementwise).
# --------------------------------------------------------------------------
def _apply_kernel(x_ref, tau_ref, out_ref):
    x = x_ref[...]
    tau = tau_ref[0, 0]
    keep = (jnp.abs(x.astype(jnp.float32)) >= tau).astype(x.dtype)
    out_ref[...] = x * keep


def apply_threshold(x2d: jax.Array, tau: jax.Array, *, interpret: bool) -> jax.Array:
    rows = x2d.shape[0]
    return pl.pallas_call(
        _apply_kernel,
        grid=(_grid_blocks(rows),),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        interpret=interpret,
    )(x2d, tau.reshape(1, 1).astype(jnp.float32))


# --------------------------------------------------------------------------
# Threshold selection from the histogram + refinement.
# --------------------------------------------------------------------------
def select_threshold_counts(hist: jax.Array, k: jax.Array):
    """Octave bounds [tau_lo, tau_hi) containing the k-th largest magnitude,
    plus the exact counts at both bounds.

    ``count_ge(2^(j+EXPO_MIN))`` = suffix-sum of hist from bin j; the k-th
    largest lies in the highest bin j* whose suffix count is still >= k.
    The suffix sums ARE the counts at the octave bounds, so downstream
    refinement starts with known bracket counts — no extra counting sweep.
    """
    suffix = jnp.cumsum(hist[::-1])[::-1]  # suffix[j] = count(mag >= 2^(j+EXPO_MIN))
    jstar = jnp.maximum(jnp.sum(suffix >= k) - 1, 0)
    tau_lo = jnp.exp2((jstar + EXPO_MIN).astype(jnp.float32))
    tau_hi = 2.0 * tau_lo
    suffix_ext = jnp.concatenate([suffix, jnp.zeros((1,), suffix.dtype)])
    cnt_lo = suffix_ext[jstar]
    cnt_hi = suffix_ext[jstar + 1]
    # If even the lowest bin has < k entries (k > #nonzero), keep everything
    # nonzero: threshold below the smallest representable bin.
    underfull = suffix[0] < k
    tau_lo = jnp.where(underfull, jnp.exp2(float(EXPO_MIN - 1)), tau_lo)
    cnt_lo = jnp.where(underfull, suffix[0], cnt_lo)
    return tau_lo, tau_hi, cnt_lo, cnt_hi


def select_threshold(hist: jax.Array, k: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Octave bounds only (see ``select_threshold_counts``)."""
    tau_lo, tau_hi, _, _ = select_threshold_counts(hist, k)
    return tau_lo, tau_hi
