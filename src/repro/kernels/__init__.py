"""Pallas TPU kernels (interpret=True on CPU) + jnp oracles:

* topk_mask.py — selective-masking hot-spot (histogram / count / apply)
* ssm_scan.py  — selective-SSM recurrence, state resident in VMEM
* wkv6.py      — RWKV6 chunked recurrence, (D,D) state in VMEM
"""
