"""Pallas TPU kernels (interpret=True on CPU) + jnp oracles:

* topk_mask.py — per-leaf selective-masking pipeline (histogram / count /
  apply), the fallback/oracle path
* packing.py   — whole-pytree leaf packing: one (R, 1024) buffer + static
  per-row segment-id map (DESIGN.md §3.4)
* segmented.py — segmented kernels over the packed buffer: whole-model
  masking in ~4 HBM sweeps, leaf-count independent
* ssm_scan.py  — selective-SSM recurrence, state resident in VMEM
* wkv6.py      — RWKV6 chunked recurrence, (D,D) state in VMEM
"""
