"""Pallas TPU kernel for the selective-SSM recurrence (§Perf hillclimb 2).

The XLA lowering of ``jax.lax.associative_scan`` materialises O(log T)
staged (B, chunk, d, N) tensors in HBM — measured 32.3 s of HBM time on
hymba-1.5b/prefill_32k vs 0.2 s of compute.  This kernel keeps the (N, d)
recurrence state resident in VMEM and streams a/bx/C through once:

    h_t = a_t * h_{t-1} + bx_t          (elementwise over (N, d))
    y_t = sum_N C_t[n] * h_t[n, :]

HBM traffic = read(a) + read(bx) + read(C) + write(y)  — one pass, the
analytic floor (13 GB/layer => ~1.2 s total on the same shape).

Layout: inputs are (B, T, N, D_BLK)-tiled with **d on the lane axis**
(d % 128 == 0 after padding) and N on sublanes; the sequential TPU grid
walks (B, d-blocks, T-blocks) with T innermost, carrying the state in a
VMEM scratch accumulator across T-blocks.

Validated in interpret mode against the pure-jnp oracle
(``ref.ssm_scan_ref``) and against ``models/ssm.ssm_forward`` in
tests/test_kernels.py.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_T = 256
BLOCK_D = 256     # lane-axis tile (multiple of 128)


def _ssm_kernel(a_ref, bx_ref, c_ref, h0_ref, y_ref, hT_ref, h_scr):
    """Blocks: a/bx (1, BLOCK_T, N, BLOCK_D); c (1, BLOCK_T, N);
    h0/hT (1, N, BLOCK_D); y (1, BLOCK_T, BLOCK_D); scratch h (N, BLOCK_D)."""
    jt = pl.program_id(2)
    n_t = pl.num_programs(2)

    @pl.when(jt == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    def step(t, h):
        a_t = a_ref[0, t]                    # (N, BLOCK_D)
        bx_t = bx_ref[0, t]
        c_t = c_ref[0, t]                    # (N,)
        h = a_t * h + bx_t
        y_ref[0, t] = jnp.sum(c_t[:, None] * h, axis=0)
        return h

    h = jax.lax.fori_loop(0, a_ref.shape[1], step, h_scr[...])
    h_scr[...] = h

    @pl.when(jt == n_t - 1)
    def _emit():
        hT_ref[0] = h


def ssm_scan_tiled(a: jax.Array, bx: jax.Array, c: jax.Array,
                   h0: jax.Array, *, interpret: bool):
    """a, bx: (B, T, N, D) fp32 with T % BLOCK_T == 0, D % BLOCK_D == 0;
    c: (B, T, N); h0: (B, N, D).  Returns (y (B, T, D), hT (B, N, D))."""
    B, T, N, D = a.shape
    grid = (B, D // BLOCK_D, T // BLOCK_T)
    y, hT = pl.pallas_call(
        _ssm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_T, N, BLOCK_D),
                         lambda b, jd, jt: (b, jt, 0, jd)),
            pl.BlockSpec((1, BLOCK_T, N, BLOCK_D),
                         lambda b, jd, jt: (b, jt, 0, jd)),
            pl.BlockSpec((1, BLOCK_T, N), lambda b, jd, jt: (b, jt, 0)),
            pl.BlockSpec((1, N, BLOCK_D), lambda b, jd, jt: (b, 0, jd)),
        ],
        out_specs=[
            pl.BlockSpec((1, BLOCK_T, BLOCK_D),
                         lambda b, jd, jt: (b, jt, jd)),
            pl.BlockSpec((1, N, BLOCK_D), lambda b, jd, jt: (b, 0, jd)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, D), jnp.float32),
            jax.ShapeDtypeStruct((B, N, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, BLOCK_D), jnp.float32)],
        interpret=interpret,
    )(a, bx, c, h0)
    return y, hT
