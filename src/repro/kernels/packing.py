"""Leaf packing for the segmented whole-pytree masking kernels (DESIGN.md §3.4).

The per-leaf kernel pipeline in ``ops.topk_mask`` pays O(L * (iters + 2)) HBM
sweeps and kernel launches for an L-leaf model, plus one ``pallas_call`` trace
per distinct leaf shape.  The segmented path instead packs every maskable leaf
into ONE padded ``(R, SEG_LANE)`` fp32 buffer with a static per-ROW segment-id
map, so the whole model is swept in a leaf-count-independent number of passes
(see ``repro.kernels.segmented``).

Layout
------
Each leaf is flattened, cast to fp32, zero-padded up to a whole number of
SEG_LANE-wide rows and concatenated.  A row therefore belongs to exactly ONE
leaf, and the (R, 1) int32 ``seg_ids`` array — a *static* numpy constant
derived purely from leaf shapes — tells the kernels which histogram / count /
tau row each data row contributes to.  Row granularity keeps worst-case
padding at SEG_LANE - 1 elements per leaf (vs. a whole kernel tile if the
map were per grid block), and the kernels turn the per-row ids into one-hot
matrices contracted with matmuls — no dynamic indexing anywhere.  Padding
zeros never survive masking because every selected threshold is > 0.

All metadata (offsets, shapes, dtypes, row counts) is static Python data, so
``pack_leaves`` / ``unpack_leaves`` are jit/scan/pjit-safe: under ``jax.jit``
the pack is a fused pad+concat+reshape and the unpack a set of static slices.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SEG_LANE",
    "LeafSpec",
    "PackSpec",
    "build_pack_spec",
    "pack_leaves",
    "unpack_leaves",
]

# Lane width of the packed buffer; also the per-leaf padding granularity.
# A multiple of 128 for the VPU lane axis.
SEG_LANE = 1024


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Static placement of one leaf inside the packed buffer."""

    shape: Tuple[int, ...]
    dtype: Any
    size: int
    offset: int      # element offset of the leaf's first entry
    num_rows: int    # SEG_LANE-wide rows this leaf occupies (size padded up)


@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Static description of a packed multi-leaf buffer.

    ``seg_ids`` maps row index -> segment (leaf) index; it is a numpy
    constant so it closes over traces without becoming a traced value.
    """

    leaves: Tuple[LeafSpec, ...]
    total_rows: int

    @property
    def num_segments(self) -> int:
        """Number of packed leaves (segments)."""
        return len(self.leaves)

    @property
    def rows(self) -> int:
        """Total SEG_LANE-wide rows in the packed buffer."""
        return self.total_rows

    def seg_ids(self) -> np.ndarray:
        """(rows, 1) int32 row -> segment map, as a numpy constant."""
        out = np.empty((self.total_rows, 1), np.int32)
        for s, leaf in enumerate(self.leaves):
            start = leaf.offset // SEG_LANE
            out[start:start + leaf.num_rows] = s
        return out

    def sizes(self) -> np.ndarray:
        """(num_segments,) int32 element counts per leaf."""
        return np.asarray([leaf.size for leaf in self.leaves], np.int32)


def build_pack_spec(leaves: Sequence[jax.Array]) -> PackSpec:
    """Derive the static packing layout from leaf shapes/dtypes only."""
    specs: List[LeafSpec] = []
    offset = 0
    for leaf in leaves:
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        num_rows = max(1, -(-size // SEG_LANE))
        specs.append(LeafSpec(tuple(leaf.shape), leaf.dtype, size,
                              offset, num_rows))
        offset += num_rows * SEG_LANE
    return PackSpec(tuple(specs), offset // SEG_LANE)


def pack_leaves(leaves: Sequence[jax.Array],
                spec: PackSpec | None = None) -> Tuple[jax.Array, PackSpec]:
    """Pack ``leaves`` into one (rows, SEG_LANE) fp32 buffer.

    Returns ``(x2d, spec)``; pass a pre-built ``spec`` to skip re-derivation
    (it must match the leaves' shapes).
    """
    if spec is None:
        spec = build_pack_spec(leaves)
    # Write each leaf into a zeroed buffer at its static offset: one
    # allocation + one copy per leaf.  (A concatenate of per-leaf padded
    # flats costs ~9x more wall-clock on CPU and lowers worse on TPU.)
    buf = jnp.zeros((spec.rows * SEG_LANE,), jnp.float32)
    for leaf, ls in zip(leaves, spec.leaves):
        buf = jax.lax.dynamic_update_slice(
            buf, leaf.reshape(-1).astype(jnp.float32), (ls.offset,))
    return buf.reshape(spec.rows, SEG_LANE), spec


def unpack_leaves(x2d: jax.Array, spec: PackSpec) -> List[jax.Array]:
    """Invert ``pack_leaves``: static slices back to original shapes/dtypes."""
    flat = x2d.reshape(-1)
    out = []
    for ls in spec.leaves:
        leaf = jax.lax.slice_in_dim(flat, ls.offset, ls.offset + ls.size)
        out.append(leaf.reshape(ls.shape).astype(ls.dtype))
    return out
