"""Pallas TPU kernel for the RWKV6 (Finch) wkv recurrence.

Chunked-parallel wkv6 with the (D, D) per-head state resident in VMEM —
the same design as kernels/ssm_scan.py but with MXU work: each T-chunk does
three (C x D)(D x D)/(C x C) matmuls against log-domain cumulative decays
(the models/rwkv.py math, one chunk per grid step):

    cum_t   = sum_{s<=t} logw_s
    q'_t    = r_t * exp(cum_{t-1})
    y       = q' S + tril_strict(q' (k e^{-cum})^T) v + (r.u.k) v
    S'      = diag(e^{cum_C}) S + (k e^{cum_C - cum})^T v

Grid: (B, H, T/CHUNK), sequential in T; state carried in a VMEM scratch.
Validated in interpret mode against the step-by-step oracle and against
``models/rwkv.wkv6_chunked`` in tests/test_kernels.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 64


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
                 y_ref, sT_ref, s_scr):
    """Blocks: r/k/v/lw (1, CHUNK, 1, D); u (1, D); s0/sT (1, 1, D, D);
    y (1, CHUNK, 1, D); scratch S (D, D) fp32."""
    jt = pl.program_id(2)
    n_t = pl.num_programs(2)

    @pl.when(jt == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0]

    r = r_ref[0, :, 0, :].astype(jnp.float32)          # (C, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    lw = lw_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)                   # (D,)
    S = s_scr[...]

    C = r.shape[0]
    cum = jnp.cumsum(lw, axis=0)                       # (C, D)
    cum_prev = cum - lw
    q_state = r * jnp.exp(cum_prev)
    y = jnp.dot(q_state, S, preferred_element_type=jnp.float32)
    k_adj = k * jnp.exp(-cum)
    A = jnp.dot(q_state, k_adj.T, preferred_element_type=jnp.float32)
    ti = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    A = jnp.where(si < ti, A, 0.0)                     # strict lower
    y = y + jnp.dot(A, v, preferred_element_type=jnp.float32)
    diag = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True)
    y = y + diag * v
    y_ref[0, :, 0, :] = y

    wtot = cum[-1]                                     # (D,)
    k_carry = k * jnp.exp(wtot[None, :] - cum)
    S = jnp.exp(wtot)[:, None] * S + jnp.dot(
        k_carry.T, v, preferred_element_type=jnp.float32)
    s_scr[...] = S

    @pl.when(jt == n_t - 1)
    def _emit():
        sT_ref[0, 0] = S


def wkv6_tiled(r, k, v, lw, u, s0, *, interpret: bool):
    """r/k/v/lw: (B, T, H, D) fp32 with T % CHUNK == 0; u: (H, D);
    s0: (B, H, D, D).  Returns (y (B, T, H, D), sT (B, H, D, D))."""
    B, T, H, D = r.shape
    grid = (B, H, T // CHUNK)
    io_spec = pl.BlockSpec((1, CHUNK, 1, D), lambda b, h, jt: (b, jt, h, 0))
    y, sT = pl.pallas_call(
        _wkv6_kernel,
        grid=grid,
        in_specs=[
            io_spec, io_spec, io_spec, io_spec,
            pl.BlockSpec((1, D), lambda b, h, jt: (h, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, jt: (b, h, 0, 0)),
        ],
        out_specs=[
            io_spec,
            pl.BlockSpec((1, 1, D, D), lambda b, h, jt: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u, s0)
    return y, sT
