"""Batching helpers for the pod-scale (non-federated) training driver."""

from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

__all__ = ["tokens_for_training", "batched_stream"]


def tokens_for_training(tokens: np.ndarray, batch: int, seq_len: int,
                        seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """All (batch, seq_len) windows as one epoch: (steps, B, T) inputs/targets."""
    rng = np.random.default_rng(seed)
    num_win = (tokens.shape[0] - 1) // seq_len
    wins = np.stack([tokens[i * seq_len:(i + 1) * seq_len + 1]
                     for i in range(num_win)])
    wins = wins[rng.permutation(num_win)]
    steps = num_win // batch
    wins = wins[: steps * batch].reshape(steps, batch, seq_len + 1)
    return wins[..., :-1].astype(np.int32), wins[..., 1:].astype(np.int32)


def batched_stream(x: np.ndarray, y: np.ndarray, batch: int,
                   seed: int = 0) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    while True:
        order = rng.permutation(x.shape[0])
        for i in range(x.shape[0] // batch):
            sl = order[i * batch:(i + 1) * batch]
            yield x[sl], y[sl]
