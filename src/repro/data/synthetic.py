"""Synthetic stand-ins for MNIST / CIFAR-10 / WikiText-2.

The container is offline, so the paper's datasets are replaced with
statistically-matched synthetic generators (DESIGN.md §7):

* ``class_gaussian_images`` — K-class dataset where each class is an
  anisotropic Gaussian blob around a class-specific low-frequency template
  image (learnable by a convnet, non-trivially separable: the noise scale is
  chosen so a linear model underfits).
* ``markov_text`` — order-2 Markov-chain token stream over a Zipf-weighted
  vocabulary, giving an LM task with a meaningful (non-uniform) optimal
  perplexity so perplexity comparisons between methods are informative.

All generators are deterministic in ``seed``.
"""

from __future__ import annotations

import dataclasses
import numpy as np

__all__ = ["ImageDataset", "TextDataset", "class_gaussian_images", "markov_text"]


@dataclasses.dataclass
class ImageDataset:
    train_x: np.ndarray  # (N, H, W, C) float32 in [-1, 1]-ish
    train_y: np.ndarray  # (N,) int32
    test_x: np.ndarray
    test_y: np.ndarray
    num_classes: int


@dataclasses.dataclass
class TextDataset:
    train_tokens: np.ndarray  # (N,) int32
    test_tokens: np.ndarray
    vocab_size: int


def _class_templates(rng: np.random.Generator, num_classes: int, h: int, w: int,
                     c: int) -> np.ndarray:
    """Low-frequency class templates: random 2D Fourier modes."""
    yy, xx = np.meshgrid(np.linspace(0, 1, h), np.linspace(0, 1, w), indexing="ij")
    out = np.zeros((num_classes, h, w, c), np.float32)
    for k in range(num_classes):
        img = np.zeros((h, w), np.float32)
        for _ in range(4):
            fy, fx = rng.integers(1, 4, size=2)
            phase = rng.uniform(0, 2 * np.pi, size=2)
            amp = rng.uniform(0.5, 1.0)
            img += amp * np.sin(2 * np.pi * fy * yy + phase[0]) * \
                np.sin(2 * np.pi * fx * xx + phase[1])
        img /= max(np.abs(img).max(), 1e-6)
        out[k] = img[..., None].repeat(c, axis=-1)
        if c > 1:
            # decorrelate channels a little
            out[k] *= rng.uniform(0.6, 1.0, size=(1, 1, c)).astype(np.float32)
    return out


def class_gaussian_images(num_train: int = 4000, num_test: int = 1000,
                          num_classes: int = 10, image_size: int = 14,
                          channels: int = 1, noise: float = 0.7,
                          seed: int = 0) -> ImageDataset:
    rng = np.random.default_rng(seed)
    h = w = image_size
    templates = _class_templates(rng, num_classes, h, w, channels)

    def gen(n):
        y = rng.integers(0, num_classes, size=n).astype(np.int32)
        x = templates[y] + noise * rng.standard_normal(
            (n, h, w, channels)).astype(np.float32)
        return x.astype(np.float32), y

    tx, ty = gen(num_train)
    ex, ey = gen(num_test)
    return ImageDataset(tx, ty, ex, ey, num_classes)


def markov_text(num_train: int = 200_000, num_test: int = 20_000,
                vocab_size: int = 512, branching: int = 8,
                seed: int = 0) -> TextDataset:
    """Order-2 Markov chain: each (prev2, prev1) context admits ``branching``
    possible next tokens with Zipf-ish weights."""
    rng = np.random.default_rng(seed)
    # context hashing keeps the transition table small & dense
    num_ctx = 4096
    # quadratic bias toward low token ids -> Zipf-like marginal
    nexts = (vocab_size * rng.random((num_ctx, branching)) ** 2.5)\
        .astype(np.int32).clip(0, vocab_size - 1)
    probs = 1.0 / np.arange(1, branching + 1)
    probs /= probs.sum()

    def gen(n):
        toks = np.empty(n, np.int32)
        toks[0], toks[1] = rng.integers(0, vocab_size, size=2)
        _ = rng.integers(0, num_ctx)  # RNG warm start (stream stability)
        choices = rng.choice(branching, size=n, p=probs)
        for i in range(2, n):
            ctx = (toks[i - 2] * 31 + toks[i - 1] * 7) % num_ctx
            toks[i] = nexts[ctx, choices[i]]
        return toks

    return TextDataset(gen(num_train), gen(num_test), vocab_size)
