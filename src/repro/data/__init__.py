"""Data pipeline: synthetic corpora + federated partitioning."""

from repro.data.synthetic import (
    ImageDataset, TextDataset, class_gaussian_images, markov_text,
)
from repro.data.partition import (
    iid_partition_images, noniid_partition_images,
    dirichlet_partition_images, partition_text,
)
from repro.data.loader import tokens_for_training, batched_stream
