"""Federated data partitioning (paper §5.1.2).

IID partitioning follows McMahan et al.: shuffle the training set and deal
equal-size shards to the M clients.  A non-IID (label-sharded) partitioner is
included as a beyond-paper extension; the paper itself evaluates IID only.

Client shards are returned STACKED — leaves with leading
(num_clients, num_batches, batch, ...) axes — so the simulation can vmap the
client update (repro.core.federated) and the pod runtime can shard the client
axis over the mesh.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = ["iid_partition_images", "noniid_partition_images", "partition_text"]


def _batch_clients(x: np.ndarray, y: np.ndarray, num_clients: int,
                   batch_size: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    per_client = (x.shape[0] // num_clients // batch_size) * batch_size
    if per_client == 0:
        raise ValueError("not enough samples per client for one batch")
    nb = per_client // batch_size
    xs = x[: per_client * num_clients].reshape(
        (num_clients, nb, batch_size) + x.shape[1:])
    ys = y[: per_client * num_clients].reshape((num_clients, nb, batch_size))
    n_samples = np.full((num_clients,), per_client, np.float32)
    return xs, ys, n_samples


def iid_partition_images(x: np.ndarray, y: np.ndarray, num_clients: int,
                         batch_size: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    order = rng.permutation(x.shape[0])
    return _batch_clients(x[order], y[order], num_clients, batch_size)


def noniid_partition_images(x: np.ndarray, y: np.ndarray, num_clients: int,
                            batch_size: int, shards_per_client: int = 2,
                            seed: int = 0):
    """McMahan-style pathological non-IID: sort by label, deal label-shards."""
    rng = np.random.default_rng(seed)
    order = np.argsort(y, kind="stable")
    x, y = x[order], y[order]
    num_shards = num_clients * shards_per_client
    shard_size = x.shape[0] // num_shards
    shard_ids = rng.permutation(num_shards)
    xs, ys = [], []
    for c in range(num_clients):
        ids = shard_ids[c * shards_per_client:(c + 1) * shards_per_client]
        cx = np.concatenate([x[i * shard_size:(i + 1) * shard_size] for i in ids])
        cy = np.concatenate([y[i * shard_size:(i + 1) * shard_size] for i in ids])
        perm = rng.permutation(cx.shape[0])
        xs.append(cx[perm])
        ys.append(cy[perm])
    x = np.stack(xs).reshape((-1,) + x.shape[1:])
    y = np.stack(ys).reshape(-1)
    return _batch_clients(x, y, num_clients, batch_size)


def partition_text(tokens: np.ndarray, num_clients: int, batch_size: int,
                   seq_len: int, seed: int = 0):
    """Chop the corpus into (seq_len+1)-token windows, deal IID to clients.

    Returns (inputs, targets, n_samples) with inputs/targets of shape
    (num_clients, num_batches, batch, seq_len).
    """
    rng = np.random.default_rng(seed)
    num_win = (tokens.shape[0] - 1) // seq_len
    wins = np.stack([tokens[i * seq_len:(i + 1) * seq_len + 1]
                     for i in range(num_win)])
    wins = wins[rng.permutation(num_win)]
    per_client = (num_win // num_clients // batch_size) * batch_size
    if per_client == 0:
        raise ValueError("not enough windows per client")
    nb = per_client // batch_size
    wins = wins[: per_client * num_clients].reshape(
        num_clients, nb, batch_size, seq_len + 1)
    inputs, targets = wins[..., :-1], wins[..., 1:]
    n_samples = np.full((num_clients,), per_client, np.float32)
    return inputs.astype(np.int32), targets.astype(np.int32), n_samples
