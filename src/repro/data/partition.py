"""Federated data partitioning (paper §5.1.2).

IID partitioning follows McMahan et al.: shuffle the training set and deal
equal-size shards to the M clients.  A non-IID (label-sharded) partitioner is
included as a beyond-paper extension; the paper itself evaluates IID only.

Client shards are returned STACKED — leaves with leading
(num_clients, num_batches, batch, ...) axes — so the simulation can vmap the
client update (repro.core.federated) and the pod runtime can shard the client
axis over the mesh.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = ["iid_partition_images", "noniid_partition_images",
           "dirichlet_partition_images", "partition_text"]


def _batch_clients(x: np.ndarray, y: np.ndarray, num_clients: int,
                   batch_size: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    per_client = (x.shape[0] // num_clients // batch_size) * batch_size
    if per_client == 0:
        raise ValueError("not enough samples per client for one batch")
    nb = per_client // batch_size
    xs = x[: per_client * num_clients].reshape(
        (num_clients, nb, batch_size) + x.shape[1:])
    ys = y[: per_client * num_clients].reshape((num_clients, nb, batch_size))
    n_samples = np.full((num_clients,), per_client, np.float32)
    return xs, ys, n_samples


def iid_partition_images(x: np.ndarray, y: np.ndarray, num_clients: int,
                         batch_size: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    order = rng.permutation(x.shape[0])
    return _batch_clients(x[order], y[order], num_clients, batch_size)


def noniid_partition_images(x: np.ndarray, y: np.ndarray, num_clients: int,
                            batch_size: int, shards_per_client: int = 2,
                            seed: int = 0):
    """McMahan-style pathological non-IID: sort by label, deal label-shards."""
    rng = np.random.default_rng(seed)
    order = np.argsort(y, kind="stable")
    x, y = x[order], y[order]
    num_shards = num_clients * shards_per_client
    shard_size = x.shape[0] // num_shards
    shard_ids = rng.permutation(num_shards)
    xs, ys = [], []
    for c in range(num_clients):
        ids = shard_ids[c * shards_per_client:(c + 1) * shards_per_client]
        cx = np.concatenate([x[i * shard_size:(i + 1) * shard_size] for i in ids])
        cy = np.concatenate([y[i * shard_size:(i + 1) * shard_size] for i in ids])
        perm = rng.permutation(cx.shape[0])
        xs.append(cx[perm])
        ys.append(cy[perm])
    x = np.stack(xs).reshape((-1,) + x.shape[1:])
    y = np.stack(ys).reshape(-1)
    return _batch_clients(x, y, num_clients, batch_size)


def dirichlet_partition_images(x: np.ndarray, y: np.ndarray, num_clients: int,
                               batch_size: int, alpha: float = 0.5,
                               seed: int = 0):
    """Dirichlet label-skew non-IID (Hsu et al. 2019): each client draws a
    label distribution p_c ~ Dir(alpha) and fills its shard by sampling
    class counts ~ Multinomial(per_client, p_c) from class-sorted pools.

    ``alpha`` tunes the skew continuously — alpha -> inf recovers IID,
    alpha -> 0 approaches one-class-per-client — which is what the
    non-IID benchmark grid (benchmarks/noniid.py) sweeps.  Pools cycle on
    exhaustion so every client still gets exactly ``per_client`` samples
    (the stacked-leaf layout needs equal shard sizes).
    """
    if alpha <= 0.0:
        raise ValueError(f"alpha must be > 0, got {alpha}")
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    pools = {c: rng.permutation(np.flatnonzero(y == c)) for c in classes}
    cursor = {c: 0 for c in classes}
    per_client = (x.shape[0] // num_clients // batch_size) * batch_size
    if per_client == 0:
        raise ValueError("not enough samples per client for one batch")

    def take(c, n):
        pool = pools[c]
        out = np.empty((n,), np.int64)
        filled = 0
        while filled < n:
            start = cursor[c]
            grab = min(n - filled, pool.shape[0] - start)
            out[filled:filled + grab] = pool[start:start + grab]
            cursor[c] = (start + grab) % pool.shape[0]
            filled += grab
        return out

    xs, ys = [], []
    for _ in range(num_clients):
        p = rng.dirichlet(np.full(classes.shape[0], alpha))
        counts = rng.multinomial(per_client, p)
        idx = np.concatenate([take(c, n)
                              for c, n in zip(classes, counts) if n > 0])
        idx = idx[rng.permutation(idx.shape[0])]
        xs.append(x[idx])
        ys.append(y[idx])
    x = np.stack(xs).reshape((-1,) + x.shape[1:])
    y = np.stack(ys).reshape(-1)
    return _batch_clients(x, y, num_clients, batch_size)


def partition_text(tokens: np.ndarray, num_clients: int, batch_size: int,
                   seq_len: int, seed: int = 0):
    """Chop the corpus into (seq_len+1)-token windows, deal IID to clients.

    Returns (inputs, targets, n_samples) with inputs/targets of shape
    (num_clients, num_batches, batch, seq_len).
    """
    rng = np.random.default_rng(seed)
    num_win = (tokens.shape[0] - 1) // seq_len
    wins = np.stack([tokens[i * seq_len:(i + 1) * seq_len + 1]
                     for i in range(num_win)])
    wins = wins[rng.permutation(num_win)]
    per_client = (num_win // num_clients // batch_size) * batch_size
    if per_client == 0:
        raise ValueError("not enough windows per client")
    nb = per_client // batch_size
    wins = wins[: per_client * num_clients].reshape(
        num_clients, nb, batch_size, seq_len + 1)
    inputs, targets = wins[..., :-1], wins[..., 1:]
    n_samples = np.full((num_clients,), per_client, np.float32)
    return inputs.astype(np.int32), targets.astype(np.int32), n_samples
