"""SGD / Adam / AdamW implemented directly in JAX.

The federated clients use plain SGD (paper Alg. 2/4 line 8); the pod-scale
training driver defaults to AdamW.  Interface mirrors optax:
``opt.init(params) -> state``, ``opt.update(grads, state, params) ->
(updates, state)``; apply with :func:`apply_updates`.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple]


def _lr_at(lr: Schedule, count: jnp.ndarray) -> jnp.ndarray:
    return lr(count) if callable(lr) else jnp.asarray(lr, jnp.float32)


def sgd(learning_rate: Schedule, momentum: float = 0.0,
        nesterov: bool = False) -> Optimizer:
    def init(params):
        vel = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return {"count": jnp.zeros((), jnp.int32), "velocity": vel}

    def update(grads, state, params=None):
        count = state["count"] + 1
        lr = _lr_at(learning_rate, count)
        if momentum:
            vel = jax.tree.map(lambda v, g: momentum * v + g,
                               state["velocity"], grads)
            if nesterov:
                step = jax.tree.map(lambda v, g: momentum * v + g, vel, grads)
            else:
                step = vel
        else:
            vel, step = None, grads
        updates = jax.tree.map(lambda s: -lr * s, step)
        return updates, {"count": count, "velocity": vel}

    return Optimizer(init, update)


def adam(learning_rate: Schedule, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {
            "count": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(jnp.zeros_like, params),
            "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params=None):
        count = state["count"] + 1
        lr = _lr_at(learning_rate, count)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
        nu = jax.tree.map(
            lambda n, g: b2 * n + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads)
        c = count.astype(jnp.float32)
        mu_hat_scale = 1.0 / (1 - b1 ** c)
        nu_hat_scale = 1.0 / (1 - b2 ** c)

        def step(m, n, p):
            upd = (m * mu_hat_scale) / (jnp.sqrt(n * nu_hat_scale) + eps)
            if weight_decay and p is not None:
                upd = upd + weight_decay * p
            return -lr * upd

        if weight_decay:
            updates = jax.tree.map(step, mu, nu, params)
        else:
            updates = jax.tree.map(lambda m, n: step(m, n, None), mu, nu)
        return updates, {"count": count, "mu": mu, "nu": nu}

    return Optimizer(init, update)


def adamw(learning_rate: Schedule, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1) -> Optimizer:
    return adam(learning_rate, b1, b2, eps, weight_decay)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jnp.ndarray]:
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                         for leaf in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def adafactor(learning_rate: Schedule, decay: float = 0.8,
              eps: float = 1e-30, clip_threshold: float = 1.0) -> Optimizer:
    """Adafactor (Shazeer & Stern, 2018) with factored second moments and no
    first moment: O(n+m) optimizer state for an (n, m) matrix instead of
    Adam's 2nm.  This is what lets the 400B llama4 config train on a single
    256-chip pod (16 GB HBM/chip); see EXPERIMENTS.md §Dry-run."""

    def init(params):
        def leaf(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"count": jnp.zeros((), jnp.int32),
                "v": jax.tree.map(leaf, params,
                                  is_leaf=lambda x: hasattr(x, "ndim"))}

    def update(grads, state, params=None):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        beta = 1.0 - c ** -decay
        lr = _lr_at(learning_rate, count)

        def leaf(g, v):
            g2 = jnp.square(g.astype(jnp.float32)) + eps
            if g.ndim >= 2:
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(jnp.mean(vr, axis=-1,
                                                keepdims=True)[..., None],
                                       eps))
                upd = g.astype(jnp.float32) * jax.lax.rsqrt(denom + eps)
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": beta * v["v"] + (1 - beta) * g2}
                upd = g.astype(jnp.float32) * jax.lax.rsqrt(nv["v"] + eps)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + eps)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            return -lr * upd, nv

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_v = treedef.flatten_up_to(state["v"])
        outs = [leaf(g, v) for g, v in zip(flat_g, flat_v)]
        updates = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
        new_v = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        return updates, {"count": count, "v": new_v}

    return Optimizer(init, update)
