"""Learning-rate schedules."""

from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda count: jnp.asarray(value, jnp.float32)


def cosine_decay(init_value: float, decay_steps: int, alpha: float = 0.0):
    def fn(count):
        frac = jnp.clip(count.astype(jnp.float32) / decay_steps, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return init_value * ((1 - alpha) * cos + alpha)
    return fn


def warmup_cosine(peak: float, warmup_steps: int, decay_steps: int,
                  floor: float = 0.0):
    def fn(count):
        c = count.astype(jnp.float32)
        warm = peak * c / jnp.maximum(warmup_steps, 1)
        frac = jnp.clip((c - warmup_steps) / jnp.maximum(decay_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = floor + (peak - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(c < warmup_steps, warm, cos)
    return fn
