"""Optimizers (pure JAX, optax-style (init, update) pairs)."""

from repro.optim.optimizers import (
    Optimizer, sgd, adam, adamw, adafactor, apply_updates,
    clip_by_global_norm,
)
from repro.optim.schedules import constant, cosine_decay, warmup_cosine
