"""Config registry: ``get_arch(id)`` + paper-model configs."""

from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ArchConfig, InputShape, LayerSpec, INPUT_SHAPES

_ARCH_MODULES = {
    "internvl2-26b": "internvl2_26b",
    "hymba-1.5b": "hymba_1_5b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "gemma2-2b": "gemma2_2b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen2-72b": "qwen2_72b",
    "qwen2-1.5b": "qwen2_1_5b",
    "musicgen-medium": "musicgen_medium",
    "qwen2.5-14b": "qwen2_5_14b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_arch(arch_id: str) -> ArchConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def all_archs() -> Dict[str, ArchConfig]:
    return {a: get_arch(a) for a in ARCH_IDS}


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


def supports_shape(cfg: ArchConfig, shape: InputShape) -> bool:
    """long_500k only for sub-quadratic archs (DESIGN.md §Shape-applicability)."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True
