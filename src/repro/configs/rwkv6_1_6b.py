"""RWKV6 'Finch' 1.6B [arXiv:2404.05892] — attention-free, data-dependent
per-channel decay (wkv6), token-shift, squared-relu channel-mix."""

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    source="arXiv:2404.05892",
    num_layers=24,
    d_model=2048,
    num_heads=32,           # wkv heads = d_model / rwkv_head_dim
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65_536,
    layer_pattern=(LayerSpec(kind="rwkv", attn="none", mlp="none"),),
    rwkv_head_dim=64,
    norm="layernorm",
    sub_quadratic=True,     # O(1) recurrent state
)
