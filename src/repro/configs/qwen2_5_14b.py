"""Qwen2.5-14B [hf:Qwen/Qwen2.5-0.5B family] — dense, GQA (40H/8KV), QKV bias."""

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen2.5-14b",
    family="dense",
    source="hf:Qwen/Qwen2.5-0.5B",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=13_824,
    vocab_size=152_064,
    layer_pattern=(LayerSpec(kind="attn", attn="full"),),
    qkv_bias=True,
    rope_theta=1_000_000.0,
)
