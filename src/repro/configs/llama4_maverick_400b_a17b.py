"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family] —
MoE 128 routed experts top-1 + 1 shared expert on ALTERNATING layers
(interleave_moe_layer_step=2, dense d_ff=16384 on the others), iRoPE-style
interleaved chunked(8k)/full attention (3:1), early-fusion multimodal
(text backbone here).  ~400B total / ~17B active."""

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16_384,            # dense-layer ffn
    vocab_size=202_048,
    layer_pattern=(
        LayerSpec(kind="attn", attn="chunked", window=8192, mlp="moe"),
        LayerSpec(kind="attn", attn="chunked", window=8192, mlp="dense"),
        LayerSpec(kind="attn", attn="chunked", window=8192, mlp="moe"),
        LayerSpec(kind="attn", attn="full", mlp="dense"),
    ),
    moe_experts=128,
    moe_topk=1,
    moe_shared_experts=1,
    moe_d_ff=8192,          # per-expert hidden (spec d_ff=8192)
    moe_shared_d_ff=8192,
    sub_quadratic=True,     # chunked-attention layers; full layers seq-sharded
    param_dtype_train="bfloat16",   # 400B: bf16 params + Adafactor on 256 chips
)
