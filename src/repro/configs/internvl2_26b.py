"""InternVL2-26B [arXiv:2404.16821] — InternLM2-20B language backbone
(48L, GQA 48H/8KV); InternViT-6B vision encoder is STUBBED: input_specs()
feeds 256 projected patch embeddings per image alongside text tokens."""

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    source="arXiv:2404.16821",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16_384,
    vocab_size=92_553,
    layer_pattern=(LayerSpec(kind="attn", attn="full"),),
    rope_theta=1_000_000.0,
    modality="vision_stub",
    num_prefix_embeddings=256,   # ViT patch embeddings, precomputed
)
