"""Architecture + input-shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; the generic decoder in
``repro.models.transformer`` is assembled purely from this description, so a
new architecture is a new config file, not new model code.

Layer heterogeneity (gemma2 local/global alternation, llama4 chunked/full
interleave) is expressed as a ``layer_pattern`` of ``LayerSpec``s; the model
scans over ``num_layers / len(pattern)`` repeats of the pattern so HLO size is
depth-independent (MaxText-style stacked-scan).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

__all__ = ["LayerSpec", "ArchConfig", "InputShape", "INPUT_SHAPES"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One position in the repeating layer pattern."""
    kind: str = "attn"        # attn | rwkv | hymba (parallel attn+ssm)
    attn: str = "full"        # full | sliding | chunked | none
    window: int = 0           # sliding window size / chunk size
    mlp: str = "dense"        # dense | moe | none (rwkv has its own channel-mix)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | vlm | audio
    source: str               # citation
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    layer_pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    head_dim: int = 0                      # 0 -> d_model // num_heads
    # attention
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    attn_softcap: float = 0.0              # gemma2 attention-logit softcap
    logit_softcap: float = 0.0             # gemma2 final-logit softcap
    # mlp
    gated_mlp: bool = True                 # SwiGLU/GeGLU vs plain MLP
    act: str = "silu"                      # silu | gelu
    norm: str = "rmsnorm"                  # rmsnorm | layernorm
    tie_embeddings: bool = False
    # moe
    moe_experts: int = 0
    moe_topk: int = 0
    moe_shared_experts: int = 0            # number of always-on shared experts
    moe_d_ff: int = 0                      # per-routed-expert hidden dim
    moe_shared_d_ff: int = 0               # shared-expert hidden dim (total)
    moe_pad_experts: bool = False          # pad E to a multiple of 16 so the
                                           # expert axis shards over "model"
                                           # (§Perf: qwen2-moe 60 -> 64)
    router_aux_coef: float = 0.01
    # ssm / rwkv / hybrid
    ssm_state: int = 0                     # mamba N
    ssm_heads: int = 0                     # 0 -> num_heads
    rwkv_head_dim: int = 64
    # modality (vlm/audio backbones consume precomputed embeddings)
    modality: str = "text"                 # text | vision_stub | audio_stub
    num_codebooks: int = 1                 # musicgen parallel EnCodec streams
    num_prefix_embeddings: int = 0         # vlm: patch embeds prepended
    # capability flags
    sub_quadratic: bool = False            # may run long_500k
    # memory: layer-groups per remat checkpoint (forward saves the residual
    # stream every remat_span groups; bigger span = smaller checkpoint
    # buffer, same recompute cost)
    remat_span: int = 1
    # numerics
    param_dtype_train: str = "float32"
    param_dtype_serve: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.num_layers % len(self.layer_pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers {self.num_layers} not a multiple of "
                f"pattern length {len(self.layer_pattern)}")
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError(f"{self.name}: heads not divisible by kv heads")

    # ---- derived ---------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def num_groups(self) -> int:
        return self.num_layers // len(self.layer_pattern)

    @property
    def padded_experts(self) -> int:
        if not self.moe_experts:
            return 0
        if self.moe_pad_experts:
            return -(-self.moe_experts // 16) * 16
        return self.moe_experts

    @property
    def uses_attention(self) -> bool:
        return any(s.kind in ("attn", "hymba") for s in self.layer_pattern)

    def reduced(self, num_layers: int = 0, d_model: int = 256,
                vocab: int = 512) -> "ArchConfig":
        """Smoke-test variant: same family/pattern, tiny dims (spec: <=2
        pattern repeats, d_model<=512, <=4 experts)."""
        hd = 32
        n_heads = max(2, min(4, self.num_heads))
        n_kv = max(1, min(n_heads, self.num_kv_heads))
        while n_heads % n_kv:
            n_kv -= 1
        nl = num_layers or len(self.layer_pattern)
        if nl % len(self.layer_pattern):
            nl = len(self.layer_pattern)
        pattern = tuple(
            dataclasses.replace(s, window=min(s.window, 32) if s.window else 0)
            for s in self.layer_pattern)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=nl,
            d_model=min(d_model, 512),
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=hd,
            d_ff=min(2 * d_model, 1024),
            vocab_size=min(self.vocab_size, vocab),
            layer_pattern=pattern,
            moe_experts=min(self.moe_experts, 4) if self.moe_experts else 0,
            moe_pad_experts=False,
            moe_topk=min(self.moe_topk, 2) if self.moe_topk else 0,
            moe_shared_experts=min(self.moe_shared_experts, 1),
            moe_d_ff=min(self.moe_d_ff, 128) if self.moe_d_ff else 0,
            moe_shared_d_ff=min(self.moe_shared_d_ff, 128) if self.moe_shared_d_ff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_heads=0,
            rwkv_head_dim=hd,
            num_prefix_embeddings=min(self.num_prefix_embeddings, 8),
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                 # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
