"""Gemma2-2B [arXiv:2408.00118] — alternating local(4k SWA)/global attention,
attention + final logit softcaps, GeGLU, tied embeddings."""

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    source="arXiv:2408.00118",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    layer_pattern=(
        LayerSpec(kind="attn", attn="sliding", window=4096),
        LayerSpec(kind="attn", attn="full"),
    ),
    attn_softcap=50.0,
    logit_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
    sub_quadratic=True,   # SWA local layers; global layers seq-sharded at 500k
)
