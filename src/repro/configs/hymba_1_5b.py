"""Hymba-1.5B [arXiv:2411.13676] — hybrid-head: parallel attention + Mamba
(SSM state=16) heads in every layer; SWA in most layers, 3 full-attention.
Pattern: [full, sliding x15] approximated as 1 full : 15 sliding (32L = 2x16)."""

from repro.configs.base import ArchConfig, LayerSpec

_PATTERN = (LayerSpec(kind="hymba", attn="full"),) + tuple(
    LayerSpec(kind="hymba", attn="sliding", window=1024) for _ in range(15))

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    source="arXiv:2411.13676",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32_001,
    layer_pattern=_PATTERN,
    ssm_state=16,
    sub_quadratic=True,     # SSM branch carries long context; 2 full layers seq-sharded
)
