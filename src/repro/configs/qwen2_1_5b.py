"""Qwen2-1.5B [arXiv:2407.10671] — dense, GQA (12H/2KV), QKV bias, tied emb."""

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen2-1.5b",
    family="dense",
    source="arXiv:2407.10671",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151_936,
    layer_pattern=(LayerSpec(kind="attn", attn="full"),),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
