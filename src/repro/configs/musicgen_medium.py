"""MusicGen-medium [arXiv:2306.05284] — decoder-only LM over EnCodec tokens,
4 parallel codebooks (delay pattern handled by the data layer); the EnCodec
conv codec is STUBBED: input_specs() feeds 4-codebook token grids.
MHA (24H/24KV), LayerNorm, plain GELU MLP (Audiocraft transformer)."""

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    source="arXiv:2306.05284",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    layer_pattern=(LayerSpec(kind="attn", attn="full"),),
    gated_mlp=False,
    act="gelu",
    norm="layernorm",
    modality="audio_stub",
    num_codebooks=4,
)
