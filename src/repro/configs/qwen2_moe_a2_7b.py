"""Qwen1.5/2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B] — 60 routed experts top-4
+ 4 shared experts (fused as one 5632-wide shared FFN), GQA kv=16 (MHA)."""

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,              # per-expert hidden (spec: d_ff=1408)
    vocab_size=151_936,
    layer_pattern=(LayerSpec(kind="attn", attn="full", mlp="moe"),),
    qkv_bias=True,
    moe_experts=60,
    moe_topk=4,
    moe_shared_experts=4,
    moe_d_ff=1408,
    moe_shared_d_ff=5632,   # 4 shared experts fused: 4 * 1408
    moe_pad_experts=True,   # 60 -> 64: expert axis shards over model (§Perf)
)
