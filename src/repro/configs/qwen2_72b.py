"""Qwen2-72B [arXiv:2407.10671] — dense, GQA (64H/8KV), QKV bias."""

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen2-72b",
    family="dense",
    source="arXiv:2407.10671",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29_568,
    vocab_size=152_064,
    layer_pattern=(LayerSpec(kind="attn", attn="full"),),
    qkv_bias=True,
    rope_theta=1_000_000.0,
)
